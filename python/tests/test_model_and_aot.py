"""L2 model composition + the AOT bridge itself.

The AOT test round-trips each artifact through the same
xla_client-compiled path the Rust side uses (compile the HLO text with
the *local* CPU client and compare numerics against the jit'd model) —
so a Rust-side mismatch would implicate the bridge, not the lowering.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def test_edm_model_returns_tuple():
    xa = _rand((2, 4, 3))
    (out,) = model.edm_model(xa, xa)
    assert out.shape == (2, 4, 4)


def test_edm_threshold_counts_neighbours():
    xa = jnp.zeros((1, 4, 2), jnp.float32)
    xb = jnp.asarray(
        [[[0.0, 0.0], [0.1, 0.0], [5.0, 0.0], [0.0, 0.2]]], jnp.float32
    )
    (count,) = model.edm_threshold_model(xa, xb, jnp.float32(0.05))
    # Each of the 4 identical a-points is near b0, b1, b3 → 12 pairs.
    assert int(count[0]) == 12


def test_nbody_model_shape():
    pa = _rand((3, 8, 4))
    (out,) = model.nbody_model(pa, pa)
    assert out.shape == (3, 8, 3)


def test_triple_model_shape():
    p = _rand((2, 4, 3))
    (out,) = model.triple_model(p, p, p)
    assert out.shape == (2,)


def test_ktuple_model_shape():
    p = _rand((2, 2, 3))
    (out,) = model.ktuple_model(p, p, p, p)
    assert out.shape == (2,)


def test_gasket_model_shape():
    patch = _rand((3, 10, 10))
    (out,) = model.gasket_model(patch)
    assert out.shape == (3, 8, 8)


def test_aot_configs_cover_all_models():
    names = set(aot.configs().keys())
    assert names == {
        "edm_tile",
        "edm_threshold",
        "nbody_tile",
        "collision_tile",
        "triple_tile",
        "ktuple_tile",
        "gasket_tile",
    }


def test_hlo_text_is_valid_hlo():
    fn, specs = aot.configs()["edm_tile"]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[64,16,8]" in text  # parameters carry the fixed shapes


def test_manifest_written_and_consistent():
    with tempfile.TemporaryDirectory() as d:
        entry = aot.lower_one("edm_tile", *aot.configs()["edm_tile"], d)
        assert os.path.exists(os.path.join(d, entry["file"]))
        assert entry["input_shapes"] == [[64, 16, 8], [64, 16, 8]]
        assert entry["output_shapes"] == [[64, 16, 16]]


@pytest.mark.parametrize("name", list(aot.configs().keys()))
def test_hlo_text_parses_back(name):
    """The emitted text must round-trip through XLA's HLO text parser —
    the same parser the Rust side (`HloModuleProto::from_text_file`)
    uses. Numeric equivalence across the bridge is asserted by the
    Rust integration test rust/tests/runtime_e2e.rs against the golden
    vectors aot.py emits (artifacts/goldens.json)."""
    fn, specs = aot.configs()[name]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    hlo_mod = xc._xla.hlo_module_from_text(text)
    # The parsed module preserves the program shape.
    assert hlo_mod.computations()[0] is not None
    assert "f32" in text


@pytest.mark.parametrize("name", list(aot.configs().keys()))
def test_goldens_are_deterministic(name):
    """Golden vectors must be reproducible run-to-run (fixed seed)."""
    g1 = aot.golden_for(name)
    g2 = aot.golden_for(name)
    for a, b in zip(g1["inputs"], g2["inputs"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(g1["output"]), np.asarray(g2["output"]))


def test_golden_output_matches_ref_oracle():
    """The golden outputs come from the jit'd model; cross-check one
    against the independent jnp oracle."""
    g = aot.golden_for("edm_tile")
    xa, xb = [jnp.asarray(a) for a in g["inputs"]]
    np.testing.assert_allclose(
        np.asarray(g["output"]),
        np.asarray(ref.edm_tile_ref(xa, xb)),
        rtol=1e-4,
        atol=1e-4,
    )
