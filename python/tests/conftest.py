"""Test bootstrap: make `compile` importable when pytest runs from the
repository root (`python -m pytest python/tests -q`), and skip modules
whose optional dependencies are absent in the offline image."""

import os
import sys

# python/ holds the `compile` package; running from the repo root (or
# anywhere else) must resolve it without an install step.
_PYTHON_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _PYTHON_DIR not in sys.path:
    sys.path.insert(0, _PYTHON_DIR)

collect_ignore = []

try:
    import hypothesis  # noqa: F401
except ImportError:
    # test_kernels.py sweeps shapes with hypothesis; without it the
    # module cannot even import, so exclude it from collection.
    collect_ignore.append("test_kernels.py")
