"""L1 correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes and dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import collision, edm, gasket, ktuple, nbody, ref, triple

SEED = st.integers(min_value=0, max_value=2**31 - 1)
BATCH = st.integers(min_value=1, max_value=5)
RHO = st.sampled_from([1, 2, 4, 8, 16])
DIM = st.sampled_from([1, 2, 3, 8])


def _rng(seed):
    return np.random.default_rng(seed)


@settings(max_examples=25, deadline=None)
@given(seed=SEED, b=BATCH, r=RHO, d=DIM)
def test_edm_matches_ref(seed, b, r, d):
    rng = _rng(seed)
    xa = jnp.asarray(rng.normal(size=(b, r, d)).astype(np.float32))
    xb = jnp.asarray(rng.normal(size=(b, r, d)).astype(np.float32))
    np.testing.assert_allclose(
        edm.edm_tile(xa, xb), ref.edm_tile_ref(xa, xb), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(seed=SEED, b=BATCH, r=RHO)
def test_nbody_matches_ref(seed, b, r):
    rng = _rng(seed)
    pa = jnp.asarray(rng.normal(size=(b, r, 4)).astype(np.float32))
    pb = jnp.asarray(rng.normal(size=(b, r, 4)).astype(np.float32))
    np.testing.assert_allclose(
        nbody.nbody_tile(pa, pb),
        ref.nbody_tile_ref(pa, pb),
        rtol=5e-4,
        atol=5e-4,
    )


def _boxes(rng, b, r):
    lo = rng.normal(size=(b, r, 3)).astype(np.float32)
    ext = rng.uniform(0.05, 1.5, size=(b, r, 3)).astype(np.float32)
    return jnp.asarray(np.concatenate([lo, lo + ext], axis=-1))


@settings(max_examples=20, deadline=None)
@given(seed=SEED, b=BATCH, r=RHO)
def test_collision_matches_ref(seed, b, r):
    rng = _rng(seed)
    ba = _boxes(rng, b, r)
    bb = _boxes(rng, b, r)
    got = collision.collision_tile(ba, bb)
    want = ref.collision_tile_ref(ba, bb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(seed=SEED, b=st.integers(min_value=1, max_value=3), r=st.sampled_from([1, 2, 4, 8]))
def test_triple_matches_ref(seed, b, r):
    rng = _rng(seed)
    pts = [
        jnp.asarray(rng.normal(size=(b, r, 3)).astype(np.float32))
        for _ in range(3)
    ]
    got = triple.triple_tile(*pts)
    want = ref.triple_tile_ref(*pts)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(seed=SEED, b=st.integers(min_value=1, max_value=3), r=st.sampled_from([1, 2, 4]))
def test_ktuple_matches_ref(seed, b, r):
    rng = _rng(seed)
    pts = [
        jnp.asarray(rng.normal(size=(b, r, 3)).astype(np.float32))
        for _ in range(4)
    ]
    got = ktuple.ktuple_tile(*pts)
    want = ref.ktuple_tile_ref(*pts)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(seed=SEED, b=BATCH, r=st.sampled_from([1, 2, 4, 8]))
def test_gasket_matches_ref(seed, b, r):
    # Integer-valued patches (the automaton's real domain): the kernel
    # must be bit-exact against the oracle.
    rng = _rng(seed)
    patch = jnp.asarray(
        rng.integers(0, 5, size=(b, r + 2, r + 2)).astype(np.float32)
    )
    got = gasket.gasket_tile(patch)
    want = ref.gasket_tile_ref(patch)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- Deterministic edge cases -------------------------------------------

def test_edm_zero_distance_on_identical_points():
    x = jnp.ones((2, 4, 3), jnp.float32)
    out = np.asarray(edm.edm_tile(x, x))
    np.testing.assert_allclose(out, 0.0, atol=1e-5)


def test_edm_known_values():
    xa = jnp.asarray([[[0.0, 0.0]]], jnp.float32)  # (1,1,2)
    xb = jnp.asarray([[[3.0, 4.0]]], jnp.float32)
    out = np.asarray(edm.edm_tile(xa, xb))
    np.testing.assert_allclose(out, [[[25.0]]], rtol=1e-6)


def test_edm_symmetry():
    rng = _rng(7)
    x = jnp.asarray(rng.normal(size=(1, 8, 3)).astype(np.float32))
    out = np.asarray(edm.edm_tile(x, x))[0]
    np.testing.assert_allclose(out, out.T, atol=1e-5)


def test_nbody_equal_masses_opposite_forces():
    # Two mirrored particles: accelerations must be opposite.
    pa = jnp.asarray([[[1.0, 0.0, 0.0, 1.0], [-1.0, 0.0, 0.0, 1.0]]], jnp.float32)
    out = np.asarray(nbody.nbody_tile(pa, pa))[0]
    np.testing.assert_allclose(out[0], -out[1], atol=1e-6)
    assert out[0][0] < 0.0  # particle at +x pulled toward -x


def test_nbody_zero_mass_exerts_no_force():
    pa = jnp.asarray([[[0.0, 0.0, 0.0, 1.0]]], jnp.float32)
    pb = jnp.asarray([[[1.0, 1.0, 1.0, 0.0]]], jnp.float32)
    out = np.asarray(nbody.nbody_tile(pa, pb))
    np.testing.assert_allclose(out, 0.0, atol=1e-7)


def test_collision_disjoint_and_contained():
    a = jnp.asarray([[[0, 0, 0, 1, 1, 1], [10, 10, 10, 11, 11, 11]]], jnp.float32)
    b = jnp.asarray([[[0.5, 0.5, 0.5, 2, 2, 2], [-5, -5, -5, -4, -4, -4]]], jnp.float32)
    out = np.asarray(collision.collision_tile(a, b))[0]
    assert out[0, 0] == 1.0  # overlapping
    assert out[0, 1] == 0.0  # disjoint
    assert out[1, 0] == 0.0
    assert out[1, 1] == 0.0


def test_triple_energy_is_permutation_invariant_on_identical_chunks():
    rng = _rng(11)
    p = jnp.asarray(rng.normal(size=(1, 4, 3)).astype(np.float32))
    e1 = np.asarray(triple.triple_tile(p, p, p))
    e2 = np.asarray(ref.triple_tile_ref(p, p, p))
    np.testing.assert_allclose(e1, e2, rtol=1e-3)


def test_ktuple_coincident_points_hit_the_softening_floor():
    # All points coincident: S = 0, so each of the R^4 tuples
    # contributes exactly EPS^(-3/2).
    p = jnp.zeros((1, 2, 3), jnp.float32)
    out = np.asarray(ktuple.ktuple_tile(p, p, p, p))
    np.testing.assert_allclose(out, [16 * ktuple.EPS**-1.5], rtol=1e-4)


def test_gasket_zero_patch_stays_zero_and_mod_wraps():
    patch = jnp.zeros((1, 5, 5), jnp.float32)
    np.testing.assert_array_equal(np.asarray(gasket.gasket_tile(patch)), 0.0)
    # A uniform patch of 4s: every 3x3 window sums to 36 ≡ 1 (mod 5).
    patch = jnp.full((1, 5, 5), 4.0, jnp.float32)
    np.testing.assert_array_equal(np.asarray(gasket.gasket_tile(patch)), 1.0)


def test_kernels_are_jittable_and_stable_across_calls():
    rng = _rng(3)
    xa = jnp.asarray(rng.normal(size=(2, 8, 8)).astype(np.float32))
    first = np.asarray(edm.edm_tile(xa, xa))
    second = np.asarray(edm.edm_tile(xa, xa))
    np.testing.assert_array_equal(first, second)
