"""Ablation for the §Perf slab parameter: the kernels must compute
identical results for every slab size that divides the batch (the slab
only changes the HBM<->VMEM schedule, never the math)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import collision, edm, nbody, ref, triple


@pytest.mark.parametrize("slab", [1, 2, 8, 16])
def test_edm_slab_invariant(slab):
    rng = np.random.default_rng(0)
    xa = jnp.asarray(rng.normal(size=(16, 8, 4)).astype(np.float32))
    xb = jnp.asarray(rng.normal(size=(16, 8, 4)).astype(np.float32))
    full = edm.edm_tile(xa, xb)  # slab = B
    sliced = edm.edm_tile(xa, xb, slab=slab)
    np.testing.assert_allclose(np.asarray(full), np.asarray(sliced), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(sliced), np.asarray(ref.edm_tile_ref(xa, xb)), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("slab", [1, 4, 8])
def test_nbody_slab_invariant(slab):
    rng = np.random.default_rng(1)
    pa = jnp.asarray(rng.normal(size=(8, 4, 4)).astype(np.float32))
    pb = jnp.asarray(rng.normal(size=(8, 4, 4)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(nbody.nbody_tile(pa, pb)),
        np.asarray(nbody.nbody_tile(pa, pb, slab=slab)),
        rtol=1e-6,
    )


@pytest.mark.parametrize("slab", [1, 4])
def test_collision_and_triple_slab_invariant(slab):
    rng = np.random.default_rng(2)
    lo = rng.normal(size=(4, 4, 3)).astype(np.float32)
    boxes = jnp.asarray(
        np.concatenate([lo, lo + rng.uniform(0.1, 1, lo.shape).astype(np.float32)], -1)
    )
    np.testing.assert_array_equal(
        np.asarray(collision.collision_tile(boxes, boxes)),
        np.asarray(collision.collision_tile(boxes, boxes, slab=slab)),
    )
    pts = jnp.asarray(rng.normal(size=(4, 4, 3)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(triple.triple_tile(pts, pts, pts)),
        np.asarray(triple.triple_tile(pts, pts, pts, slab=slab)),
        rtol=1e-5,
    )


def test_slab_must_divide_batch():
    x = jnp.zeros((6, 4, 2), jnp.float32)
    with pytest.raises(AssertionError):
        edm.edm_tile(x, x, slab=4)  # 4 does not divide 6
