"""L1 Pallas kernel: AABB collision-culling tile.

One program instance tests one R x R block of the pairwise overlap
matrix — the collision-detection workload [1] that motivates the
2-simplex maps. Output is f32 {0, 1} so one artifact dtype serves all
kernels through the PJRT bridge.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _collision_kernel(ba_ref, bb_ref, out_ref):
    ba = ba_ref[...]  # (S, R, 6): min xyz, max xyz
    bb = bb_ref[...]
    amin = ba[:, :, None, :3]  # (S, R, 1, 3)
    amax = ba[:, :, None, 3:]
    bmin = bb[:, None, :, :3]  # (S, 1, R, 3)
    bmax = bb[:, None, :, 3:]
    overlap = jnp.logical_and(amin <= bmax, bmin <= amax)  # (S, R, R, 3)
    out_ref[...] = jnp.all(overlap, axis=-1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "slab"))
def collision_tile(boxa, boxb, interpret=True, slab=None):
    """Batched overlap tiles: (B, R, 6), (B, R, 6) -> (B, R, R).

    slab=B (default) collapses the grid to one program instance — the
    interpret-mode fast configuration (§Perf)."""
    b, r, c = boxa.shape
    assert c == 6 and boxb.shape == (b, r, 6)
    slab = b if slab is None else slab
    assert b % slab == 0
    return pl.pallas_call(
        _collision_kernel,
        grid=(b // slab,),
        in_specs=[
            pl.BlockSpec((slab, r, 6), lambda i: (i, 0, 0)),
            pl.BlockSpec((slab, r, 6), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((slab, r, r), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, r), jnp.float32),
        interpret=interpret,
    )(boxa, boxb)
