"""L1 Pallas kernel: n-body force tile.

One program instance accumulates, for a slab of tiles, the
gravitational acceleration that one R-particle chunk (b) exerts on
another (a) — the unit of work a lambda2-mapped block owns in the
pairwise O(n^2) sweep (the coordinator applies the tile both ways for
off-diagonal blocks; that symmetry is why the triangular domain halves
the work).

VMEM per slab: 2 * S * R * 4 in, S * R * 3 out; the (S, R, R, 3)
displacement field lives only inside the slab. slab=B (single
instance) is the interpret-mode fast configuration (§Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-3  # Plummer softening, matches ref.py


def _nbody_kernel(pa_ref, pb_ref, out_ref):
    pa = pa_ref[...]  # (S, R, 4): x y z m
    pb = pb_ref[...]
    ra = pa[..., :3]
    rb = pb[..., :3]
    mb = pb[..., 3]  # (S, R)
    d = rb[:, None, :, :] - ra[:, :, None, :]  # (S, R, R, 3)
    r2 = jnp.sum(d * d, axis=-1) + EPS  # (S, R, R)
    w = mb[:, None, :] * r2 ** (-1.5)  # (S, R, R)
    out_ref[...] = jnp.einsum("bijk,bij->bik", d, w)


@functools.partial(jax.jit, static_argnames=("interpret", "slab"))
def nbody_tile(pa, pb, interpret=True, slab=None):
    """Batched force tiles: (B, R, 4), (B, R, 4) -> (B, R, 3)."""
    b, r, c = pa.shape
    assert c == 4 and pb.shape == (b, r, 4)
    slab = b if slab is None else slab
    assert b % slab == 0
    return pl.pallas_call(
        _nbody_kernel,
        grid=(b // slab,),
        in_specs=[
            pl.BlockSpec((slab, r, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((slab, r, 4), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((slab, r, 3), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, 3), pa.dtype),
        interpret=interpret,
    )(pa, pb)
