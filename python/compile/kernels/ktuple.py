"""L1 Pallas kernel: unique 4-tuple interaction tile.

One program instance reduces the softened inverse-power energy of all
R^4 tuples drawn from four R-point chunks — the unit of work a
lambda_m-mapped block owns in the O(n^4) 4-simplex sweep (the general-m
workload of §III.D). With S = sum of the tuple's 6 pairwise squared
distances, each tuple contributes (S + EPS)^(-3/2); the (R, R, R, R)
intermediate never leaves the tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-3  # matches rust/src/workloads/ktuple.rs EPS


def _ktuple_kernel(p1_ref, p2_ref, p3_ref, p4_ref, out_ref):
    p1 = p1_ref[...]  # (S, R, 3)
    p2 = p2_ref[...]
    p3 = p3_ref[...]
    p4 = p4_ref[...]

    def d2(pa, pb):
        d = pa[:, :, None, :] - pb[:, None, :, :]  # (S, R, R, 3)
        return jnp.sum(d * d, axis=-1)  # (S, R, R)

    # Pair sums broadcast into the (S, R1, R2, R3, R4) tuple lattice.
    s = (
        d2(p1, p2)[:, :, :, None, None]
        + d2(p1, p3)[:, :, None, :, None]
        + d2(p1, p4)[:, :, None, None, :]
        + d2(p2, p3)[:, None, :, :, None]
        + d2(p2, p4)[:, None, :, None, :]
        + d2(p3, p4)[:, None, None, :, :]
    )
    e = (s + EPS) ** -1.5
    out_ref[...] = jnp.sum(e, axis=(1, 2, 3, 4))


@functools.partial(jax.jit, static_argnames=("interpret", "slab"))
def ktuple_tile(p1, p2, p3, p4, interpret=True, slab=None):
    """Batched 4-tuple energy tiles: 4 x (B, R, 3) -> (B,).

    slab=B (default) collapses the grid to one program instance — the
    interpret-mode fast configuration (§Perf)."""
    b, r, c = p1.shape
    assert c == 3
    for p in (p2, p3, p4):
        assert p.shape == (b, r, 3)
    slab = b if slab is None else slab
    assert b % slab == 0
    return pl.pallas_call(
        _ktuple_kernel,
        grid=(b // slab,),
        in_specs=[
            pl.BlockSpec((slab, r, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((slab, r, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((slab, r, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((slab, r, 3), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((slab,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), p1.dtype),
        interpret=interpret,
    )(p1, p2, p3, p4)
