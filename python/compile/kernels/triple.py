"""L1 Pallas kernel: triple-interaction (Axilrod–Teller) tile.

One program instance reduces the AT triple-dipole energy of all R^3
triples drawn from three R-point chunks — the unit of work a
lambda3-mapped block owns in the O(n^3) 3-simplex sweep ([11], [6]).
The (R, R, R) intermediate lives only inside one tile: this is the
VMEM-tiling answer to the paper's 3-simplex motivation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-3  # matches ref.py


def _triple_kernel(pi_ref, pj_ref, pk_ref, out_ref):
    pi = pi_ref[...]  # (S, R, 3)
    pj = pj_ref[...]
    pk = pk_ref[...]
    dij = pi[:, :, None, :] - pj[:, None, :, :]  # (S, R, R, 3)
    dik = pi[:, :, None, :] - pk[:, None, :, :]
    djk = pj[:, :, None, :] - pk[:, None, :, :]
    r2ij = jnp.sum(dij * dij, axis=-1) + EPS  # (S, Ri, Rj)
    r2ik = jnp.sum(dik * dik, axis=-1) + EPS  # (S, Ri, Rk)
    r2jk = jnp.sum(djk * djk, axis=-1) + EPS  # (S, Rj, Rk)
    dot_i = jnp.einsum("bijd,bikd->bijk", dij, dik)
    dot_j = jnp.einsum("bijd,bjkd->bijk", -dij, djk)
    dot_k = jnp.einsum("bikd,bjkd->bijk", dik, djk)
    r2prod = r2ij[:, :, :, None] * r2ik[:, :, None, :] * r2jk[:, None, :, :]
    denom = r2prod**1.5
    e = (1.0 + 3.0 * dot_i * dot_j * dot_k / r2prod) / denom
    out_ref[...] = jnp.sum(e, axis=(1, 2, 3))


@functools.partial(jax.jit, static_argnames=("interpret", "slab"))
def triple_tile(pi, pj, pk, interpret=True, slab=None):
    """Batched AT energy tiles: 3 x (B, R, 3) -> (B,).

    slab=B (default) collapses the grid to one program instance — the
    interpret-mode fast configuration (§Perf)."""
    b, r, c = pi.shape
    assert c == 3 and pj.shape == (b, r, 3) and pk.shape == (b, r, 3)
    slab = b if slab is None else slab
    assert b % slab == 0
    return pl.pallas_call(
        _triple_kernel,
        grid=(b // slab,),
        in_specs=[
            pl.BlockSpec((slab, r, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((slab, r, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((slab, r, 3), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((slab,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), pi.dtype),
        interpret=interpret,
    )(pi, pj, pk)
