"""L1 Pallas kernel: Euclidean-distance-matrix tile.

One Pallas program instance computes a *slab* of the tile batch: the
Rust coordinator gathers the two R-point chunks each lambda-mapped
block addresses, batches B of them, and executes this kernel
AOT-compiled over the whole batch.

TPU thinking (DESIGN.md §Hardware-Adaptation): a slab of tiles is held
in VMEM (slab*R*D floats per operand, slab*R*R out — the default
slab=B=64 uses ~132 KiB, far under VMEM) and the cross term is a
batched (R, D) x (D, R) matmul — MXU work — via the expanded-norm
identity ||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b. The `slab` parameter
is the HBM<->VMEM schedule: grid=(B/slab,) streams slabs when B*tile
exceeds VMEM.

PERF (§Perf, EXPERIMENTS.md): slab=B collapses the grid to one program
instance; under interpret=True (required: CPU PJRT cannot run Mosaic
custom-calls) this is 9.4x faster than grid=(B,) because interpret
mode pays per-instance overhead, and it is within 1.3x of the pure-jnp
XLA roofline.

interpret=True lowers to plain HLO, which is what the AOT bridge ships
to Rust.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _edm_kernel(xa_ref, xb_ref, out_ref):
    """Slab body: xa (S, R, D), xb (S, R, D) -> out (S, R, R)."""
    xa = xa_ref[...]
    xb = xb_ref[...]
    na = jnp.sum(xa * xa, axis=-1)[:, :, None]  # (S, R, 1)
    nb = jnp.sum(xb * xb, axis=-1)[:, None, :]  # (S, 1, R)
    # MXU-shaped batched cross term: (S, R, D) @ (S, D, R).
    cross = jnp.einsum("bid,bjd->bij", xa, xb)
    out_ref[...] = na + nb - 2.0 * cross


@functools.partial(jax.jit, static_argnames=("interpret", "slab"))
def edm_tile(xa, xb, interpret=True, slab=None):
    """Batched EDM tiles: (B, R, D), (B, R, D) -> (B, R, R).

    `slab` = tiles per program instance (default: the whole batch —
    single instance, maximum vectorization).
    """
    b, r, d = xa.shape
    assert xb.shape == (b, r, d)
    slab = b if slab is None else slab
    assert b % slab == 0, "slab must divide the batch"
    return pl.pallas_call(
        _edm_kernel,
        grid=(b // slab,),
        in_specs=[
            pl.BlockSpec((slab, r, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((slab, r, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((slab, r, r), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, r), xa.dtype),
        interpret=interpret,
    )(xa, xb)
