"""Pure-jnp oracles for every Pallas kernel (L1 correctness signal).

Each function mirrors a kernel in this package with straightforward
jax.numpy so pytest can assert_allclose kernel-vs-ref across shapes and
dtypes (hypothesis sweeps live in python/tests).
"""

import jax.numpy as jnp


def edm_tile_ref(xa, xb):
    """Squared Euclidean distances between two point chunks.

    xa: (B, R, D), xb: (B, R, D) -> (B, R, R) with
    out[b, i, j] = ||xa[b,i] - xb[b,j]||^2.

    Expanded-norm formulation (the MXU-friendly form the kernel also
    uses): ||a||^2 + ||b||^2 - 2 a.b.
    """
    na = jnp.sum(xa * xa, axis=-1)[:, :, None]  # (B, R, 1)
    nb = jnp.sum(xb * xb, axis=-1)[:, None, :]  # (B, 1, R)
    cross = jnp.einsum("bid,bjd->bij", xa, xb)  # (B, R, R)
    return na + nb - 2.0 * cross


def nbody_tile_ref(pa, pb, eps=1e-3):
    """Gravitational accelerations on chunk-a particles from chunk-b.

    pa, pb: (B, R, 4) = (x, y, z, mass) -> (B, R, 3)
    a_i = sum_j m_j * (r_j - r_i) / (|r_j - r_i|^2 + eps)^(3/2)
    (Plummer softening; G folded into masses.)
    """
    ra = pa[..., :3]
    rb = pb[..., :3]
    mb = pb[..., 3]  # (B, R)
    d = rb[:, None, :, :] - ra[:, :, None, :]  # (B, R, R, 3)
    r2 = jnp.sum(d * d, axis=-1) + eps  # (B, R, R)
    inv_r3 = r2 ** (-1.5)
    return jnp.einsum("bijk,bij,bj->bik", d, inv_r3, mb)


def collision_tile_ref(boxa, boxb):
    """AABB overlap tests between two box chunks.

    boxa, boxb: (B, R, 6) = (xmin, ymin, zmin, xmax, ymax, zmax)
    -> (B, R, R) f32 in {0, 1}: 1 where the boxes overlap on all axes.
    """
    amin = boxa[..., :3][:, :, None, :]  # (B, R, 1, 3)
    amax = boxa[..., 3:][:, :, None, :]
    bmin = boxb[..., :3][:, None, :, :]  # (B, 1, R, 3)
    bmax = boxb[..., 3:][:, None, :, :]
    overlap = jnp.logical_and(amin <= bmax, bmin <= amax)  # (B, R, R, 3)
    return jnp.all(overlap, axis=-1).astype(jnp.float32)


def ktuple_tile_ref(p1, p2, p3, p4, eps=1e-3):
    """Softened inverse-power energy over a tile of 4-tuples.

    p1..p4: (B, R, 3) -> (B,): with S the sum of the 6 pairwise squared
    distances inside each tuple, every tuple contributes
    (S + eps)^(-3/2); summed over all R^4 tuples.
    """

    def d2(pa, pb):
        d = pa[:, :, None, :] - pb[:, None, :, :]
        return jnp.sum(d * d, axis=-1)

    s = (
        d2(p1, p2)[:, :, :, None, None]
        + d2(p1, p3)[:, :, None, :, None]
        + d2(p1, p4)[:, :, None, None, :]
        + d2(p2, p3)[:, None, :, :, None]
        + d2(p2, p4)[:, None, :, None, :]
        + d2(p3, p4)[:, None, None, :, :]
    )
    return jnp.sum((s + eps) ** -1.5, axis=(1, 2, 3, 4))


def gasket_tile_ref(patch, mod=5.0):
    """One mod-sum CA step over dense halo patches.

    patch: (B, R+2, R+2) -> (B, R, R) with
    out[b, i, j] = (sum of the 3x3 window at patch[b, i:i+3, j:j+3]) mod 5.
    """
    r = patch.shape[1] - 2
    total = jnp.zeros_like(patch[:, :r, :r])
    for di in range(3):
        for dj in range(3):
            total = total + patch[:, di : di + r, dj : dj + r]
    return jnp.mod(total, mod)


def triple_tile_ref(pi, pj, pk, eps=1e-3):
    """Axilrod–Teller triple-dipole energy over a tile of triples.

    pi, pj, pk: (B, R, 3) -> (B,): summed AT energy over all R^3
    triples (i from pi, j from pj, k from pk):

        E = (1 + 3 cos t_i cos t_j cos t_k) / (r_ij r_ik r_jk)^3

    with nu = 1 and Plummer-softened squared distances.
    """
    dij = pi[:, :, None, :] - pj[:, None, :, :]  # (B, R, R, 3)
    dik = pi[:, :, None, :] - pk[:, None, :, :]
    djk = pj[:, :, None, :] - pk[:, None, :, :]
    r2ij = jnp.sum(dij * dij, axis=-1) + eps  # (B, Ri, Rj)
    r2ik = jnp.sum(dik * dik, axis=-1) + eps  # (B, Ri, Rk)
    r2jk = jnp.sum(djk * djk, axis=-1) + eps  # (B, Rj, Rk)
    # cos t_i = (dij . dik) / (r_ij r_ik), etc.
    dot_i = jnp.einsum("bijd,bikd->bijk", dij, dik)
    dot_j = jnp.einsum("bijd,bjkd->bijk", -dij, djk)
    dot_k = jnp.einsum("bikd,bjkd->bijk", dik, djk)
    r2prod = r2ij[:, :, :, None] * r2ik[:, :, None, :] * r2jk[:, None, :, :]
    denom = r2prod**1.5
    e = (1.0 + 3.0 * dot_i * dot_j * dot_k / r2prod) / denom
    return jnp.sum(e, axis=(1, 2, 3))
