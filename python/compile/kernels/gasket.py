"""L1 Pallas kernel: Sierpinski-gasket CA halo-patch tile.

One program instance advances a dense (R+2)x(R+2) halo patch of the
mod-sum neighbour automaton one step, emitting the RxR interior's next
values: out[i, j] = (sum of the 3x3 window centred on patch[i+1, j+1])
mod 5. The host zeroes every off-gasket / off-grid patch cell, so the
dense window sum equals the automaton's gasket-masked neighbour sum at
every live cell (off-gasket outputs are junk the host never scatters).
All values are small non-negative integers, so f32 arithmetic — and the
mod — is exact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MOD = 5.0  # matches rust/src/workloads/gasket_ca.rs MOD


def _gasket_kernel(patch_ref, out_ref):
    p = patch_ref[...]  # (S, R+2, R+2)
    r = p.shape[1] - 2
    total = jnp.zeros_like(p[:, :r, :r])
    for di in range(3):
        for dj in range(3):
            total = total + p[:, di : di + r, dj : dj + r]
    out_ref[...] = jnp.mod(total, MOD)


@functools.partial(jax.jit, static_argnames=("interpret", "slab"))
def gasket_tile(patch, interpret=True, slab=None):
    """Batched CA steps: (B, R+2, R+2) halo patches -> (B, R, R).

    slab=B (default) collapses the grid to one program instance — the
    interpret-mode fast configuration (§Perf)."""
    b, h, w = patch.shape
    assert h == w and h >= 3
    r = h - 2
    slab = b if slab is None else slab
    assert b % slab == 0
    return pl.pallas_call(
        _gasket_kernel,
        grid=(b // slab,),
        in_specs=[pl.BlockSpec((slab, h, h), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((slab, r, r), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, r), patch.dtype),
        interpret=interpret,
    )(patch)
