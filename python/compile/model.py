"""L2 — the JAX compute graphs that get AOT-lowered for the Rust side.

Each model composes an L1 Pallas kernel with the pre/post-processing
that belongs on-device (so the Rust hot path ships raw chunk tensors
and receives finished tile results). The shapes are fixed at lowering
time (one artifact per (B, R, ...) configuration, chosen in aot.py);
Rust pads the final partial batch.

Python runs only at `make artifacts`; nothing here is imported at
serving time.
"""

import jax.numpy as jnp

from .kernels.collision import collision_tile
from .kernels.edm import edm_tile
from .kernels.gasket import gasket_tile
from .kernels.ktuple import ktuple_tile
from .kernels.nbody import nbody_tile
from .kernels.triple import triple_tile


def edm_model(xa, xb):
    """Batched EDM tiles, returning *squared* distances.

    (B, R, D) x (B, R, D) -> (B, R, R). Squared distances are what the
    downstream consumers (k-NN screening, DNA distance matrices [22])
    threshold on; taking the sqrt on-device would only lose precision
    for the comparison use-case.
    """
    return (edm_tile(xa, xb),)


def edm_threshold_model(xa, xb, r2):
    """EDM tile + on-device epsilon-neighbour counting.

    (B, R, D) x (B, R, D) x scalar -> (B,): per-tile count of pairs
    with squared distance <= r2. Demonstrates kernel + reduction
    fusion in one artifact (the XLA fusion shows up in the HLO).
    """
    d2 = edm_tile(xa, xb)
    return (jnp.sum(jnp.where(d2 <= r2, 1.0, 0.0), axis=(1, 2)),)


def nbody_model(pa, pb):
    """Batched force tiles: (B, R, 4) x (B, R, 4) -> (B, R, 3)."""
    return (nbody_tile(pa, pb),)


def collision_model(boxa, boxb):
    """Batched AABB overlap tiles: (B, R, 6) x2 -> (B, R, R) in {0,1}."""
    return (collision_tile(boxa, boxb),)


def triple_model(pi, pj, pk):
    """Batched Axilrod–Teller tile energies: 3 x (B, R, 3) -> (B,)."""
    return (triple_tile(pi, pj, pk),)


def ktuple_model(p1, p2, p3, p4):
    """Batched 4-tuple tile energies: 4 x (B, R, 3) -> (B,)."""
    return (ktuple_tile(p1, p2, p3, p4),)


def gasket_model(patch):
    """Batched gasket-CA steps: (B, R+2, R+2) halo patches -> (B, R, R)."""
    return (gasket_tile(patch),)
