"""AOT bridge: lower the L2 models to HLO *text* + manifest.json.

HLO text (not `HloModuleProto.serialize()`): jax >= 0.5 emits protos
with 64-bit instruction ids which the Rust side's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run as `python -m compile.aot --out-dir ../artifacts` (what `make
artifacts` does). One artifact per model x shape configuration; the
manifest records input/output shapes so the Rust executor can validate
calls without parsing HLO.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# One entry per artifact: name -> (fn, input ShapeDtypeStructs).
# B = tiles per batch, R = threads per block side (rho), D = point dim.
B = 64
R = 16
R3 = 8  # triple tiles are R^3 work: keep blocks smaller in m=3
RM = 2  # ktuple tiles are R^4 work: matches the Rust rho_m policy
RG = 8  # gasket CA blocks (rho_gasket); halo patches are (RG+2)^2


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def configs():
    return {
        "edm_tile": (model.edm_model, [_f32(B, R, 8), _f32(B, R, 8)]),
        "edm_threshold": (
            model.edm_threshold_model,
            [_f32(B, R, 8), _f32(B, R, 8), _f32()],
        ),
        "nbody_tile": (model.nbody_model, [_f32(B, R, 4), _f32(B, R, 4)]),
        "collision_tile": (
            model.collision_model,
            [_f32(B, R, 6), _f32(B, R, 6)],
        ),
        "triple_tile": (
            model.triple_model,
            [_f32(B, R3, 3), _f32(B, R3, 3), _f32(B, R3, 3)],
        ),
        "ktuple_tile": (
            model.ktuple_model,
            [_f32(B, RM, 3)] * 4,
        ),
        "gasket_tile": (
            model.gasket_model,
            [_f32(B, RG + 2, RG + 2)],
        ),
    }


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name, fn, specs, out_dir):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_shapes = [
        list(o.shape) for o in jax.eval_shape(fn, *specs)
    ]
    entry = {
        "name": name,
        "file": fname,
        "input_shapes": [list(s.shape) for s in specs],
        "output_shapes": out_shapes,
    }
    print(f"  {name}: {len(text)} chars, in={entry['input_shapes']} out={out_shapes}")
    return entry


def golden_for(name):
    """Deterministic golden input/output vectors for one artifact —
    the cross-language numeric contract rust/tests/runtime_e2e.rs
    checks after executing the HLO through PJRT."""
    import numpy as np

    fn, specs = configs()[name]
    rng = np.random.default_rng(0xC0FFEE)
    inputs = []
    for s in specs:
        if s.shape == ():
            inputs.append(np.float32(0.5))
        else:
            inputs.append((rng.normal(size=s.shape) * 0.5).astype(np.float32))
    (out,) = jax.jit(fn)(*[jnp.asarray(a) for a in inputs])
    return {"inputs": inputs, "output": out}


def write_goldens(out_dir, names):
    import numpy as np

    doc = {}
    for name in names:
        g = golden_for(name)
        doc[name] = {
            "inputs": [np.asarray(a).ravel().tolist() for a in g["inputs"]],
            "output": np.asarray(g["output"]).ravel().tolist(),
        }
    with open(os.path.join(out_dir, "goldens.json"), "w") as f:
        json.dump(doc, f)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    for name, (fn, specs) in configs().items():
        if args.only and name not in args.only:
            continue
        entries.append(lower_one(name, fn, specs, args.out_dir))
    manifest = {
        "schema": 1,
        "batch": B,
        "rho2": R,
        "rho3": R3,
        "rho_m": RM,
        "rho_gasket": RG,
        "artifacts": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    write_goldens(args.out_dir, [e["name"] for e in entries])
    print(f"wrote {len(entries)} artifacts + manifest + goldens to {args.out_dir}")


if __name__ == "__main__":
    main()
