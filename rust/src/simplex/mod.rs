//! Discrete orthogonal simplices: exact volumes (eq. 2-4), point
//! membership/enumeration (eq. 1), orthotope parallel spaces, and the
//! recursive orthotope sets `S_n^m` of eq. 25-29.

pub mod block_m;
pub mod gasket;
pub mod orthotope;
pub mod point;
pub mod recursive_set;
pub mod volume;

pub use block_m::{BlockM, OrthotopeM, M_MAX};
pub use gasket::DomainKind;
pub use orthotope::Orthotope;
pub use point::{PointM, Simplex};
pub use volume::{simplex_volume, simplex_volume_bruteforce};
