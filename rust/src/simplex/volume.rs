//! Exact volumes of discrete orthogonal simplices and orthotopes.
//!
//! Implements the paper's eq. (2): `V(Δ_n^m) = C(n+m-1, m)` — the
//! simplicial polytopic numbers — plus the stacked-sum identity eq. (3)
//! and the bounding-box waste ratio eq. (4). All in u128 (checked) so
//! the general-m analysis (§III.D) can run exactly up to very large n.

/// Binomial coefficient C(n, k) in u128, checked against overflow.
///
/// Uses the multiplicative form with interleaved division (each prefix
/// product of the multiplicative formula is itself a binomial, hence
/// divisible), so intermediate values stay minimal.
pub fn binomial(n: u128, k: u128) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc
            .checked_mul(n - i)
            .expect("binomial overflow: use smaller n/m");
        acc /= i + 1;
    }
    acc
}

/// `V(Δ_n^m)` — number of discrete elements of the orthogonal m-simplex
/// of linear size n (paper eq. 2): `C(n+m-1, m) = n(n+1)…(n+m-1)/m!`.
///
/// Conventions: `Δ_n^m = { x ∈ Z_+^m : Σ x_i ≤ n-1 }`; `V(Δ_0^m) = 0`,
/// `V(Δ_n^0) = 1`.
pub fn simplex_volume(n: u64, m: u32) -> u128 {
    if m == 0 {
        return 1;
    }
    if n == 0 {
        return 0;
    }
    binomial(n as u128 + m as u128 - 1, m as u128)
}

/// f64 evaluation of `V(Δ_n^m)` for sizes where u128 would overflow
/// (the §III.D n₀ scans go to n ~ 2^40 at m up to 10).
pub fn simplex_volume_f64(n: u64, m: u32) -> f64 {
    if m == 0 {
        return 1.0;
    }
    if n == 0 {
        return 0.0;
    }
    let mut acc = 1.0f64;
    // lint: allow(cast, u32 to u64 widens)
    for i in 0..m as u64 {
        acc *= (n + i) as f64 / (i + 1) as f64;
    }
    acc
}

/// `V(Π_n^m) = n^m` — bounding-box volume.
pub fn orthotope_volume(n: u64, m: u32) -> u128 {
    (n as u128)
        .checked_pow(m)
        .expect("orthotope volume overflow")
}

/// factorial in u128 (m ≤ 33 fits).
pub fn factorial(m: u32) -> u128 {
    (1..=m as u128).product()
}

/// Finite bounding-box waste ratio `α(Π,Δ)_n^m = V(Π)/V(Δ) - 1`
/// (paper eq. 4 gives its limit `m! - 1`).
pub fn bb_alpha(n: u64, m: u32) -> f64 {
    let v_bb = orthotope_volume(n, m) as f64;
    let v_s = simplex_volume(n, m) as f64;
    v_bb / v_s - 1.0
}

/// The limit of eq. (4): `m! - 1`.
pub fn bb_alpha_limit(m: u32) -> f64 {
    factorial(m) as f64 - 1.0
}

/// Brute-force volume by enumeration — the oracle the closed forms are
/// tested against. Counts `{ x ∈ Z_+^m : Σ x_i ≤ n-1 }`.
pub fn simplex_volume_bruteforce(n: u64, m: u32) -> u128 {
    fn rec(budget: i64, dims: u32) -> u128 {
        if dims == 0 {
            return 1;
        }
        let mut total = 0u128;
        for x in 0..=budget {
            total += rec(budget - x, dims - 1);
        }
        total
    }
    if m == 0 {
        return 1;
    }
    if n == 0 {
        return 0;
    }
    rec(n as i64 - 1, m)
}

/// Stacked-sum identity, paper eq. (3):
/// `V(Δ_n^{m+1}) = Σ_{i=1..n} V(Δ_i^m)`.
pub fn simplex_volume_stacked(n: u64, m_plus_1: u32) -> u128 {
    assert!(m_plus_1 >= 1);
    (1..=n).map(|i| simplex_volume(i, m_plus_1 - 1)).sum()
}

/// Triangular number T(n) = n(n+1)/2 (eq. 5).
pub fn triangular(n: u64) -> u128 {
    simplex_volume(n, 2)
}

/// Tetrahedral number n(n+1)(n+2)/6 (eq. 16).
pub fn tetrahedral(n: u64) -> u128 {
    simplex_volume(n, 3)
}

/// Integer floor of log2; panics on 0.
/// This is the paper's eq. (14): `⌊log2 y⌋ = (bits-1) - clz(y)`,
/// compiled to a single `lzcnt`/`bsr` on x86-64.
#[inline]
pub fn ilog2(x: u64) -> u32 {
    debug_assert!(x > 0);
    63 - x.leading_zeros()
}

/// `true` iff x is a power of two (and nonzero).
#[inline]
pub fn is_pow2(x: u64) -> bool {
    x != 0 && x & (x - 1) == 0
}

/// Next power of two ≥ x (x ≥ 1).
#[inline]
pub fn next_pow2(x: u64) -> u64 {
    assert!(x >= 1);
    x.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomial_basics() {
        assert_eq!(binomial(5, 0), 1);
        assert_eq!(binomial(5, 5), 1);
        assert_eq!(binomial(5, 2), 10);
        assert_eq!(binomial(10, 3), 120);
        assert_eq!(binomial(3, 7), 0);
        // Symmetric.
        assert_eq!(binomial(40, 11), binomial(40, 29));
    }

    #[test]
    fn binomial_pascal_identity() {
        for n in 1..40u128 {
            for k in 1..n {
                assert_eq!(binomial(n, k), binomial(n - 1, k - 1) + binomial(n - 1, k));
            }
        }
    }

    #[test]
    fn triangular_matches_eq5() {
        // V(Δ_n^2) = n(n+1)/2
        for n in 0..200u64 {
            assert_eq!(triangular(n), (n as u128 * (n as u128 + 1)) / 2);
        }
    }

    #[test]
    fn tetrahedral_matches_eq16() {
        // V(Δ_n^3) = n(n+1)(n+2)/6
        for n in 0..100u64 {
            let n_ = n as u128;
            assert_eq!(tetrahedral(n), n_ * (n_ + 1) * (n_ + 2) / 6);
        }
    }

    #[test]
    fn closed_form_matches_bruteforce() {
        for m in 0..5u32 {
            for n in 0..12u64 {
                assert_eq!(
                    simplex_volume(n, m),
                    simplex_volume_bruteforce(n, m),
                    "n={n} m={m}"
                );
            }
        }
    }

    #[test]
    fn stacked_sum_identity_eq3() {
        for m1 in 1..6u32 {
            for n in 0..30u64 {
                assert_eq!(
                    simplex_volume(n, m1),
                    simplex_volume_stacked(n, m1),
                    "n={n} m+1={m1}"
                );
            }
        }
    }

    #[test]
    fn bb_alpha_limit_is_m_factorial_minus_1() {
        // eq. (4): lim α = m! - 1. Check convergence numerically.
        for m in 1..7u32 {
            let a = bb_alpha(4096, m);
            let lim = bb_alpha_limit(m);
            assert!(
                (a - lim).abs() / lim.max(1.0) < 0.01,
                "m={m}: α(4096)={a} vs limit {lim}"
            );
        }
    }

    #[test]
    fn bb_alpha_m2_approaches_1() {
        // Fig. 2: for m=2 the BB parallel space approaches 2× the volume.
        let a = bb_alpha(1 << 20, 2);
        assert!((a - 1.0).abs() < 1e-4, "α={a}");
    }

    #[test]
    fn bb_alpha_m3_approaches_5() {
        // Fig. 3 discussion: BB ≈ 600% of tetrahedron for large n.
        let a = bb_alpha(1 << 20, 3);
        assert!((a - 5.0).abs() < 1e-3, "α={a}");
    }

    #[test]
    fn factorials() {
        assert_eq!(factorial(0), 1);
        assert_eq!(factorial(5), 120);
        assert_eq!(factorial(7), 5040);
    }

    #[test]
    fn pow2_helpers() {
        assert!(is_pow2(1));
        assert!(is_pow2(64));
        assert!(!is_pow2(0));
        assert!(!is_pow2(48));
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(5), 8);
        assert_eq!(next_pow2(64), 64);
    }

    #[test]
    fn volume_edge_cases() {
        assert_eq!(simplex_volume(0, 3), 0);
        assert_eq!(simplex_volume(1, 3), 1);
        assert_eq!(simplex_volume(5, 0), 1);
        assert_eq!(orthotope_volume(10, 3), 1000);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn binomial_overflow_is_checked() {
        binomial(1000, 500);
    }
}
