//! Dynamic-dimension block coordinates and orthotopes for the
//! general-m subsystem (§III.D made executable).
//!
//! The fixed `[u64; 3]` types in [`crate::simplex::Orthotope`] and the
//! [`crate::maps::ThreadMap`] trait cap the system at m = 3. [`BlockM`]
//! is a SmallVec-style fixed-capacity coordinate (inline `[u64; M_MAX]`
//! plus a length — `Copy`, no allocation, cheap to pass through the
//! launcher hot path), and [`OrthotopeM`] is its axis-aligned orthotope
//! with the same volume/linearization/iteration API as the fixed-m
//! `Orthotope`. Together they carry the m-dimensional parallel spaces
//! of `λ_m` and the m-simplex block domains of the k-tuple workloads.

/// Hard cap on the executable dimension. The paper's general-m analysis
/// runs to m = 10 and beyond, but executable grids above m = 8 overflow
/// u64 linear indices at any interesting size, so the subsystem stops
/// there.
pub const M_MAX: usize = 8;

/// An m-dimensional block coordinate, 1 ≤ m ≤ [`M_MAX`].
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct BlockM {
    len: u8,
    xs: [u64; M_MAX],
}

impl BlockM {
    /// The zero coordinate of dimension m.
    pub fn zeros(m: u32) -> BlockM {
        // lint: allow(cast, u32 to usize widens)
        assert!(m >= 1 && m as usize <= M_MAX, "m={m} out of 1..={M_MAX}");
        BlockM {
            len: m as u8,
            xs: [0; M_MAX],
        }
    }

    /// Build from a slice (length = dimension).
    pub fn from_slice(xs: &[u64]) -> BlockM {
        let mut b = BlockM::zeros(xs.len() as u32);
        b.xs[..xs.len()].copy_from_slice(xs);
        b
    }

    /// Dimensionality m.
    #[inline]
    pub fn m(&self) -> u32 {
        self.len as u32
    }

    #[inline]
    pub fn as_slice(&self) -> &[u64] {
        // lint: allow(cast, u32 to usize widens)
        &self.xs[..self.len as usize]
    }

    /// Coordinate sum `Σ x_i` (the simplex membership quantity).
    #[inline]
    pub fn sum(&self) -> u64 {
        self.as_slice().iter().sum()
    }

    /// Widen a fixed `[u64; 3]` coordinate (m ≤ 3 legacy maps).
    #[inline]
    pub fn from_fixed3(p: [u64; 3], m: u32) -> BlockM {
        debug_assert!((1..=3).contains(&m));
        let mut b = BlockM::zeros(m);
        // lint: allow(cast, u32 to usize widens)
        b.xs[..m as usize].copy_from_slice(&p[..m as usize]);
        b
    }

    /// Narrow to `[u64; 3]`, zero-padded (requires m ≤ 3).
    #[inline]
    pub fn to_fixed3(&self) -> [u64; 3] {
        debug_assert!(self.len <= 3);
        let mut p = [0u64; 3];
        // lint: allow(cast, u32 to usize widens)
        p[..self.len as usize].copy_from_slice(self.as_slice());
        p
    }
}

impl std::ops::Index<usize> for BlockM {
    type Output = u64;
    #[inline]
    fn index(&self, i: usize) -> &u64 {
        &self.as_slice()[i]
    }
}

impl std::ops::IndexMut<usize> for BlockM {
    #[inline]
    fn index_mut(&mut self, i: usize) -> &mut u64 {
        // lint: allow(cast, u32 to usize widens)
        &mut self.xs[..self.len as usize][i]
    }
}

/// An axis-aligned discrete orthotope `[0, d_0) × … × [0, d_{m-1})` of
/// dynamic dimension — the shape of one `λ_m` launch pass.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct OrthotopeM {
    pub dims: BlockM,
}

impl OrthotopeM {
    pub fn new(dims: &[u64]) -> OrthotopeM {
        OrthotopeM {
            dims: BlockM::from_slice(dims),
        }
    }

    #[inline]
    pub fn m(&self) -> u32 {
        self.dims.m()
    }

    /// Total number of cells (blocks, when used as a grid).
    pub fn volume(&self) -> u128 {
        self.dims.as_slice().iter().map(|&d| d as u128).product()
    }

    #[inline]
    pub fn contains(&self, p: &BlockM) -> bool {
        p.m() == self.m()
            && p.as_slice()
                .iter()
                .zip(self.dims.as_slice())
                .all(|(&x, &d)| x < d)
    }

    /// Linearize a cell coordinate (axis 0 fastest). The volume must
    /// fit u64 — map constructors guard this via `supports`.
    #[inline]
    pub fn linear_of(&self, p: &BlockM) -> u64 {
        debug_assert!(self.contains(p));
        let dims = self.dims.as_slice();
        let mut idx = 0u64;
        for i in (0..dims.len()).rev() {
            idx = idx * dims[i] + p[i];
        }
        idx
    }

    /// Inverse of [`OrthotopeM::linear_of`].
    #[inline]
    pub fn of_linear(&self, mut idx: u64) -> BlockM {
        let m = self.m();
        let mut p = BlockM::zeros(m);
        // lint: allow(cast, u32 to usize widens)
        for i in 0..m as usize {
            let d = self.dims[i];
            p[i] = idx % d;
            idx /= d;
        }
        p
    }

    /// Iterate all cells (axis 0 fastest), matching `linear_of` order.
    pub fn iter(&self) -> OrthotopeMIter {
        OrthotopeMIter {
            shape: *self,
            next: Some(BlockM::zeros(self.m())),
        }
    }
}

/// Odometer iterator over an [`OrthotopeM`].
pub struct OrthotopeMIter {
    shape: OrthotopeM,
    next: Option<BlockM>,
}

impl Iterator for OrthotopeMIter {
    type Item = BlockM;

    fn next(&mut self) -> Option<BlockM> {
        if self.shape.volume() == 0 {
            return None;
        }
        let cur = self.next?;
        let mut succ = cur;
        let mut i = 0usize;
        loop {
            // lint: allow(cast, u32 to usize widens)
            if i == succ.m() as usize {
                self.next = None;
                break;
            }
            succ[i] += 1;
            if succ[i] < self.shape.dims[i] {
                self.next = Some(succ);
                break;
            }
            succ[i] = 0;
            i += 1;
        }
        Some(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blockm_roundtrips_and_sums() {
        let b = BlockM::from_slice(&[3, 1, 4, 1, 5]);
        assert_eq!(b.m(), 5);
        assert_eq!(b.sum(), 14);
        assert_eq!(b[2], 4);
        assert_eq!(b.as_slice(), &[3, 1, 4, 1, 5]);
        let mut c = b;
        c[0] = 9;
        assert_eq!(c.as_slice(), &[9, 1, 4, 1, 5]);
        assert_eq!(b[0], 3, "BlockM is a value type");
    }

    #[test]
    fn fixed3_conversions() {
        let b = BlockM::from_fixed3([7, 2, 0], 2);
        assert_eq!(b.m(), 2);
        assert_eq!(b.as_slice(), &[7, 2]);
        assert_eq!(b.to_fixed3(), [7, 2, 0]);
        let t = BlockM::from_fixed3([1, 2, 3], 3);
        assert_eq!(t.to_fixed3(), [1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "out of 1..=")]
    fn dimension_above_cap_rejected() {
        BlockM::zeros(M_MAX as u32 + 1);
    }

    #[test]
    fn orthotope_m_volume_and_contains() {
        let o = OrthotopeM::new(&[4, 3, 2, 2]);
        assert_eq!(o.m(), 4);
        assert_eq!(o.volume(), 48);
        assert!(o.contains(&BlockM::from_slice(&[3, 2, 1, 1])));
        assert!(!o.contains(&BlockM::from_slice(&[4, 0, 0, 0])));
        assert!(!o.contains(&BlockM::from_slice(&[0, 0, 0])), "wrong m");
    }

    #[test]
    fn linearization_roundtrip_matches_iteration_order() {
        let o = OrthotopeM::new(&[3, 2, 4, 2]);
        let mut count = 0u64;
        for (i, p) in o.iter().enumerate() {
            assert_eq!(o.linear_of(&p), i as u64);
            assert_eq!(o.of_linear(i as u64), p);
            count += 1;
        }
        assert_eq!(count as u128, o.volume());
    }

    #[test]
    fn iteration_agrees_with_fixed_orthotope() {
        // Same cell order as Orthotope::iter (x fastest) for m = 3.
        let fixed = crate::simplex::Orthotope::d3(3, 4, 2);
        let dynamic = OrthotopeM::new(&[3, 4, 2]);
        let a: Vec<[u64; 3]> = fixed.iter().collect();
        let b: Vec<[u64; 3]> = dynamic.iter().map(|p| p.to_fixed3()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn empty_orthotope_iterates_nothing() {
        let o = OrthotopeM::new(&[3, 0, 2]);
        assert_eq!(o.iter().count(), 0);
        assert_eq!(o.volume(), 0);
    }
}
