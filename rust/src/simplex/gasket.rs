//! The embedded Sierpiński gasket — the first non-simplex block-level
//! domain (Navarro & Bustos's follow-up "Block-space GPU Mapping for
//! Embedded Sierpiński Gasket Fractals", arXiv:1706.04552, applies the
//! paper's recursive block-space idea to fractal domains).
//!
//! ## Embedding
//!
//! The gasket of order k lives on an n×n grid with `n = 2^k`, as the
//! odd entries of Pascal's triangle mod 2:
//!
//! ```text
//! G(k) = { (col, row) : row < 2^k, col & !row == 0 }
//! ```
//!
//! `col & !row == 0` (col's set bits are a subset of row's) implies
//! `col ≤ row`, so `G(k)` embeds inside the inclusive lower-triangle
//! convention every m = 2 map in this repo already uses — which is why
//! the simplex maps *cover* the gasket (with waste) while the dedicated
//! gasket maps hit it exactly.
//!
//! ## Recursion
//!
//! Splitting the top bit of (row, col) decomposes `G(k)` into three
//! disjoint copies of `G(k-1)` (top, bottom-left, bottom-right), so
//! `|G(k)| = 3^k` — against a tight bounding box of `4^k` cells, the
//! compact parallel space is a `(4/3)^k` improvement. The same split at
//! block granularity makes the domain exactly self-similar: with
//! `ρ = 2^s` threads per block side, block `(bc, br)` intersects the
//! thread-level gasket of order `k+s` iff `(bc, br) ∈ G(k)`, and then
//! contains exactly `3^s` gasket cells.
//!
//! ## Rank
//!
//! Reading the three copies as base-3 digits (0 = top, 1 = bottom-left,
//! 2 = bottom-right, most significant first) gives the canonical
//! bijection `[0, 3^k) ↔ G(k)` — [`gasket_rank`]/[`gasket_cell`]. It
//! composes across granularity:
//! `rank_{k+s}(cell) = rank_k(block)·3^s + rank_s(local)`, which is how
//! the CA workload stores per-cell state densely in `3^{k+s}` bytes.

/// Which block-level data domain a map covers / a workload consumes.
///
/// Simplex maps cover `Gasket` workloads too (the gasket embeds in the
/// inclusive triangle); gasket maps cover *only* the gasket — the
/// scheduler rejects that mismatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DomainKind {
    /// The paper's discrete orthogonal m-simplex (default).
    Simplex,
    /// The embedded Sierpiński gasket (m = 2 only).
    Gasket,
}

/// Gasket order k for a grid of `nb` cells per side, i.e. `log2(nb)`
/// when `nb` is a power of two (and `3^k` fits u64), else `None`.
pub fn gasket_order(nb: u64) -> Option<u32> {
    if nb == 0 || !nb.is_power_of_two() {
        return None;
    }
    let k = nb.trailing_zeros();
    // 3^k must fit a u64 linear rank (3^40 < 2^64 < 3^41).
    if k <= 40 {
        Some(k)
    } else {
        None
    }
}

/// `|G(k)| = 3^k`.
pub fn gasket_volume(k: u32) -> u128 {
    3u128.pow(k)
}

/// Whether `(col, row)` is a gasket cell on the `nb × nb` grid.
#[inline]
pub fn in_gasket(nb: u64, col: u64, row: u64) -> bool {
    row < nb && col & !row == 0
}

/// The cell of rank `t ∈ [0, 3^k)`: walk t's base-3 digits from most
/// significant, descending one sub-triangle per level (0 = top,
/// 1 = bottom-left, 2 = bottom-right). O(k) = O(log n), mirroring the
/// recursive λ maps of the source papers.
#[inline]
pub fn gasket_cell(k: u32, t: u64) -> (u64, u64) {
    debug_assert!((t as u128) < gasket_volume(k));
    let (mut col, mut row) = (0u64, 0u64);
    let mut rem = t;
    for i in (0..k).rev() {
        let p = 3u64.pow(i);
        let d = rem / p;
        rem %= p;
        let s = 1u64 << i;
        if d >= 1 {
            row += s;
        }
        if d == 2 {
            col += s;
        }
    }
    (col, row)
}

/// Inverse of [`gasket_cell`]: the base-3 rank of a gasket cell, read
/// off the bit pairs of (row, col) from the top: (0,0) → 0, (1,0) → 1,
/// (1,1) → 2. (The pair (row bit 0, col bit 1) cannot occur on a
/// gasket cell.)
#[inline]
pub fn gasket_rank(k: u32, col: u64, row: u64) -> u64 {
    debug_assert!(in_gasket(1 << k, col, row), "({col},{row}) ∉ G({k})");
    let mut t = 0u64;
    for i in (0..k).rev() {
        let rb = (row >> i) & 1;
        let cb = (col >> i) & 1;
        t = t * 3 + rb + cb;
    }
    t
}

/// Brute-force enumeration of `G(k)` by grid scan — the reference the
/// conformance tests cross-check the rank bijection and the maps
/// against (deliberately *not* built from [`gasket_cell`]).
pub fn enumerate_gasket(nb: u64) -> Vec<(u64, u64)> {
    let mut cells = Vec::new();
    for row in 0..nb {
        for col in 0..nb {
            if in_gasket(nb, col, row) {
                cells.push((col, row));
            }
        }
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volume_is_3_pow_k_by_scan() {
        for k in 0..=7u32 {
            let nb = 1u64 << k;
            assert_eq!(enumerate_gasket(nb).len() as u128, gasket_volume(k), "k={k}");
        }
    }

    #[test]
    fn order_accepts_powers_of_two_only() {
        assert_eq!(gasket_order(1), Some(0));
        assert_eq!(gasket_order(64), Some(6));
        assert_eq!(gasket_order(0), None);
        assert_eq!(gasket_order(12), None);
        assert_eq!(gasket_order(1 << 41), None, "3^41 overflows u64 ranks");
        assert_eq!(gasket_order(1 << 40), Some(40));
    }

    #[test]
    fn membership_implies_lower_triangle() {
        // col & !row == 0 ⇒ col ≤ row: the gasket embeds in the m=2
        // inclusive block-pair domain.
        for &(col, row) in &enumerate_gasket(32) {
            assert!(col <= row, "({col},{row})");
        }
        assert!(in_gasket(8, 5, 7));
        assert!(!in_gasket(8, 1, 2), "bit 0 of col not in row");
        assert!(!in_gasket(8, 0, 8), "row out of grid");
    }

    #[test]
    fn rank_is_a_bijection_onto_the_scan() {
        for k in 0..=6u32 {
            let nb = 1u64 << k;
            let mut by_rank: Vec<(u64, u64)> =
                (0..3u64.pow(k)).map(|t| gasket_cell(k, t)).collect();
            for (t, &(col, row)) in by_rank.iter().enumerate() {
                assert_eq!(gasket_rank(k, col, row), t as u64, "k={k}");
            }
            let mut scan = enumerate_gasket(nb);
            by_rank.sort_unstable();
            scan.sort_unstable();
            assert_eq!(by_rank, scan, "k={k}");
        }
    }

    #[test]
    fn rank_composes_across_granularity() {
        // rank_{k+s}(global) = rank_k(block)·3^s + rank_s(local): the
        // identity the CA workload's dense storage rests on.
        let (k, s) = (3u32, 2u32);
        let (nb, rho) = (1u64 << k, 1u64 << s);
        for bt in 0..3u64.pow(k) {
            let (bc, br) = gasket_cell(k, bt);
            for u in 0..3u64.pow(s) {
                let (lc, lr) = gasket_cell(s, u);
                let (col, row) = (bc * rho + lc, br * rho + lr);
                assert!(in_gasket(nb * rho, col, row));
                assert_eq!(gasket_rank(k + s, col, row), bt * 3u64.pow(s) + u);
            }
        }
    }

    #[test]
    fn blocks_are_self_similar() {
        // A ρ×ρ block holds 3^s gasket cells iff the block coordinate
        // is itself a gasket cell, and zero otherwise.
        let (k, s) = (2u32, 2u32);
        let (nb, rho) = (1u64 << k, 1u64 << s);
        let n = nb * rho;
        for br in 0..nb {
            for bc in 0..nb {
                let cells = (0..rho)
                    .flat_map(|lr| (0..rho).map(move |lc| (bc * rho + lc, br * rho + lr)))
                    .filter(|&(c, r)| in_gasket(n, c, r))
                    .count() as u128;
                let expect = if in_gasket(nb, bc, br) {
                    gasket_volume(s)
                } else {
                    0
                };
                assert_eq!(cells, expect, "block ({bc},{br})");
            }
        }
    }
}
