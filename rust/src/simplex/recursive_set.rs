//! Recursive orthotope sets `S_n^m` — the paper's central construction.
//!
//! `V(S_n^m) = (rn)^m + β · V(S_{rn}^m)` (eq. 25) with reduction factor
//! `r` and arity `β`. This module evaluates the recurrence exactly (for
//! r = 1/2, in u128) and in f64 (for general real r), plus the closed
//! form of eq. 27 and the waste ratios of eqs. 19, 24, 29.

use crate::simplex::volume::{factorial, ilog2, is_pow2, simplex_volume};

/// Exact volume of the recursive set for r = 1/2 and integer arity β,
/// by direct evaluation of the recurrence (eq. 25). `n` must be a power
/// of two; boundary `V(S_1) = 0` (a side-1 sub-orthotope at the deepest
/// level is the paper's boundary `V(S_2^2) = 1 = (2/2)^m + β·0`).
pub fn recursive_volume_half(n: u64, m: u32, beta: u32) -> u128 {
    assert!(is_pow2(n), "recursive set requires n = 2^k, got {n}");
    let mut total = 0u128;
    let mut count = 1u128; // sub-orthotopes at this level
    let mut size = n / 2; // side of each sub-orthotope
    while size >= 1 {
        let cell = (size as u128).checked_pow(m).expect("volume overflow");
        total += count
            .checked_mul(cell)
            .expect("volume overflow (count*cell)");
        count = count.checked_mul(beta as u128).expect("count overflow");
        size /= 2;
    }
    total
}

/// Closed form of eq. 27 for r = 1/2:
/// `V(S_n^m) = (n^m - β^{log2 n}) / (2^m - β)` (requires `2^m ≠ β`).
pub fn recursive_volume_half_closed(n: u64, m: u32, beta: u32) -> u128 {
    assert!(is_pow2(n));
    let k = ilog2(n);
    let n_m = (n as u128).pow(m);
    let beta_k = (beta as u128).pow(k);
    let denom_pos = 1u128 << m; // 2^m
    assert!(
        denom_pos != beta as u128,
        "closed form undefined at β = 2^m"
    );
    if denom_pos > beta as u128 {
        (n_m - beta_k) / (denom_pos - beta as u128)
    } else {
        (beta_k - n_m) / (beta as u128 - denom_pos)
    }
}

/// General real-valued evaluation of eq. 25 for arbitrary `r ∈ (0,1)`,
/// `β ≥ 1`: levels `i = 0 .. ⌈log_{1/r} n⌉ - 1`, sub-orthotope side
/// `r^{i+1} n`. Matches the exact evaluation when r = 1/2.
pub fn recursive_volume_general(n: f64, m: u32, r: f64, beta: f64) -> f64 {
    assert!(n >= 1.0 && r > 0.0 && r < 1.0 && beta >= 1.0);
    let levels = (n.ln() / (1.0 / r).ln()).ceil() as i64;
    let mut total = 0.0;
    let mut count = 1.0;
    let mut size = r * n;
    for _ in 0..levels {
        total += count * size.powi(m as i32);
        count *= beta;
        size *= r;
    }
    total
}

/// Closed form eq. 27 in f64 for general (r, β):
/// `V = (n^m - β^{log_{1/r} n}) / (1/r^m - β)`.
pub fn recursive_volume_closed_general(n: f64, m: u32, r: f64, beta: f64) -> f64 {
    let log_levels = n.ln() / (1.0 / r).ln();
    let n_m = n.powi(m as i32);
    let beta_l = beta.powf(log_levels);
    let denom = (1.0 / r).powi(m as i32) - beta;
    (n_m - beta_l) / denom
}

/// Asymptotic extra-volume ratio of eq. 29 for r = 1/2, β = 2:
/// `lim α(S,Δ)_n^m = m!/(2^m - 2) - 1`.
pub fn alpha_limit_half_beta2(m: u32) -> f64 {
    assert!(m >= 2);
    factorial(m) as f64 / ((1u128 << m) as f64 - 2.0) - 1.0
}

/// Finite extra-volume ratio `V(S_n^m)/V(Δ_{n-1}^m) - 1` for r=1/2.
pub fn alpha_half(n: u64, m: u32, beta: u32) -> f64 {
    let v_s = recursive_volume_half(n, m, beta) as f64;
    let v_d = simplex_volume(n - 1, m) as f64;
    v_s / v_d - 1.0
}

/// §III.D search point. The paper's prescription: fix
/// `r = (m!)^{-1/m}` (so `1/r^m = m!`), leaving β free; the effective
/// denominator of eq. 27 is then `1/r^m - β = m! - β`, which
/// *approaches m! from below* as required for coverage — `V(S_n^m) ≈
/// n^m/(m!-β)` eventually exceeds `V(Δ_{n-1}^m) = n^m/m! + Θ(n^{m-1})`.
/// (Hitting m! exactly, as the text first suggests, can never cover:
/// the simplex's positive n^{m-1} term always wins — this is the
/// open-question tension §III.D describes, quantified in gensearch.)
#[derive(Clone, Copy, Debug)]
pub struct GeneralSetParams {
    pub m: u32,
    pub beta: f64,
    pub r: f64,
}

impl GeneralSetParams {
    pub fn for_paper(m: u32, beta: f64) -> GeneralSetParams {
        assert!(
            beta >= 2.0 && beta < factorial(m) as f64,
            "need 2 ≤ β < m! for a positive denominator"
        );
        let r = (factorial(m) as f64).powf(-1.0 / m as f64);
        GeneralSetParams { m, beta, r }
    }

    /// Asymptotic waste ratio `m!/(m!-β) - 1 = β/(m!-β)` — the price of
    /// bringing n₀ closer to the origin by raising β.
    pub fn waste_limit(&self) -> f64 {
        let f = factorial(self.m) as f64;
        self.beta / (f - self.beta)
    }

    /// `1/r^m - β` — equals `m! - β` for the paper parametrization.
    pub fn denom(&self) -> f64 {
        (1.0 / self.r).powi(self.m as i32) - self.beta
    }

    /// Volume of the set at size n (recurrence evaluation).
    pub fn volume(&self, n: f64) -> f64 {
        recursive_volume_general(n, self.m, self.r, self.beta)
    }

    /// Coverage condition of §III.D: `V(S_n^m) ≥ V(Δ_{n-1}^m)`.
    /// (f64 volumes: the scans reach n ~ 2^40 where u128 overflows.)
    pub fn covers(&self, n: u64) -> bool {
        self.volume(n as f64) >= crate::simplex::volume::simplex_volume_f64(n - 1, self.m)
    }

    /// Integer discretization of the level geometry (the executable
    /// side of §III.D, used by `maps::lambda_m`): level `i` holds
    /// `β^i` orthotopes of side `round(r^{i+1} n)`, zero sides dropped
    /// (sides decrease, so the first zero ends the recursion). Requires
    /// integer β; returns `None` when a level count overflows u128.
    pub fn level_plan(&self, n: u64) -> Option<LevelPlan> {
        assert!(n >= 2, "level plan needs n ≥ 2, got {n}");
        assert!(
            self.beta.fract() == 0.0 && self.beta >= 1.0,
            "executable plans need integer β, got {}",
            self.beta
        );
        let beta = self.beta as u128;
        let levels = ((n as f64).ln() / (1.0 / self.r).ln()).ceil() as u32;
        let mut sides = Vec::new();
        let mut counts = Vec::new();
        let mut size = self.r * n as f64;
        let mut count = 1u128;
        for _ in 0..levels {
            // lint: allow(cast, size stays in 0..=n; float-to-int saturates)
            let s = (size + 0.5).floor() as u64; // round half up
            if s == 0 {
                break;
            }
            sides.push(s);
            counts.push(count);
            size *= self.r;
            count = count.checked_mul(beta)?;
        }
        Some(LevelPlan {
            m: self.m,
            sides,
            counts,
        })
    }

    /// Total integer volume of the discretized set, or None on overflow.
    pub fn discrete_volume(&self, n: u64) -> Option<u128> {
        self.level_plan(n).and_then(|p| p.volume())
    }

    /// Whether the *discretized* set covers the inclusive block domain
    /// `Δ_n^m` (the executable coverage condition; the real-valued
    /// `covers` compares against `Δ_{n-1}` per the paper's text).
    pub fn discrete_covers(&self, n: u64) -> bool {
        match self.discrete_volume(n) {
            Some(v) => v >= simplex_volume(n, self.m),
            None => false,
        }
    }

    /// Smallest discretely-covered size in `[lo, hi]`. Keep `hi` ≤ 4096
    /// so u128 simplex volumes cannot overflow at m ≤ 8.
    pub fn first_covered(&self, lo: u64, hi: u64) -> Option<u64> {
        (lo.max(2)..=hi).find(|&n| self.discrete_covers(n))
    }

    /// `n_0 = min { n : covers for all n' ∈ [n, horizon] }`, scanning a
    /// doubling grid up to `horizon`. Returns None if never covered.
    pub fn n0(&self, horizon: u64) -> Option<u64> {
        let mut n0 = None;
        let mut n = 2u64;
        while n <= horizon {
            if self.covers(n) {
                if n0.is_none() {
                    n0 = Some(n);
                }
            } else {
                n0 = None; // must hold from n0 onwards
            }
            n = n.saturating_mul(2);
        }
        n0
    }
}

/// The integer-side geometry of one discretized recursive set: level
/// `i` launches `counts[i]` orthotopes of side `sides[i]` (in blocks).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LevelPlan {
    pub m: u32,
    pub sides: Vec<u64>,
    pub counts: Vec<u128>,
}

impl LevelPlan {
    pub fn levels(&self) -> usize {
        self.sides.len()
    }

    /// Volume of level `i`: `counts[i] · sides[i]^m`, None on overflow.
    pub fn level_volume(&self, i: usize) -> Option<u128> {
        (self.sides[i] as u128)
            .checked_pow(self.m)
            .and_then(|c| c.checked_mul(self.counts[i]))
    }

    /// Total volume over all levels, None on overflow.
    pub fn volume(&self) -> Option<u128> {
        let mut total = 0u128;
        for i in 0..self.levels() {
            total = total.checked_add(self.level_volume(i)?)?;
        }
        Some(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simplex::volume::triangular;

    #[test]
    fn m2_recurrence_matches_eq11() {
        // V(S_n^2) = n(n-1)/2 for r=1/2, β=2 (eq. 11).
        for k in 1..16u32 {
            let n = 1u64 << k;
            let v = recursive_volume_half(n, 2, 2);
            assert_eq!(v, (n as u128) * (n as u128 - 1) / 2, "n={n}");
        }
    }

    #[test]
    fn m2_eq12_relation() {
        // V(S_n^2) + n = V(S_{n+1}^2) = V(Δ_n^2) (eq. 12) — interpreted
        // on the triangular numbers: V(S_n) = T(n-1).
        for k in 1..16u32 {
            let n = 1u64 << k;
            assert_eq!(recursive_volume_half(n, 2, 2), triangular(n - 1));
        }
    }

    #[test]
    fn m3_beta2_matches_eq22() {
        // V(S_n^3) = (n³ - n)/6 = V(Δ_{n-1}^3) (eq. 22).
        for k in 1..12u32 {
            let n = 1u64 << k;
            let v = recursive_volume_half(n, 3, 2);
            let n_ = n as u128;
            assert_eq!(v, (n_ * n_ * n_ - n_) / 6, "n={n}");
            assert_eq!(v, simplex_volume(n - 1, 3));
        }
    }

    #[test]
    fn m3_beta3_matches_eq18() {
        // V(S_n^3) = (n³ - 3^{log2 n})/5 (eq. 18, with the /5 the paper
        // dropped typographically).
        for k in 1..12u32 {
            let n = 1u64 << k;
            let v = recursive_volume_half(n, 3, 3);
            let expect = ((n as u128).pow(3) - 3u128.pow(k)) / 5;
            assert_eq!(v, expect, "n={n}");
        }
    }

    #[test]
    fn m4_beta2_matches_eq28() {
        // V(S_n^4) = (n⁴ - n)/14 (eq. 28).
        for k in 1..10u32 {
            let n = 1u64 << k;
            let v = recursive_volume_half(n, 4, 2);
            assert_eq!(v, ((n as u128).pow(4) - n as u128) / 14, "n={n}");
        }
    }

    #[test]
    fn m4_beta2_exceeds_simplex() {
        // eq. 28's inequality: (n⁴-n)/14 > (n-1)n(n+1)(n+2)/24 for
        // n ≥ 2 (equality at exactly n = 2, strict from n = 4 on).
        for k in 1..10u32 {
            let n = 1u64 << k;
            let lhs = recursive_volume_half(n, 4, 2);
            let n_ = n as u128;
            let rhs = (n_ - 1) * n_ * (n_ + 1) * (n_ + 2) / 24;
            if n == 2 {
                assert_eq!(lhs, rhs, "n=2 is the equality point");
            } else {
                assert!(lhs > rhs, "n={n}: {lhs} vs {rhs}");
            }
        }
    }

    #[test]
    fn closed_form_matches_recurrence() {
        for m in 2..6u32 {
            for beta in 2..5u32 {
                if (1u128 << m) == beta as u128 {
                    continue;
                }
                for k in 1..10u32 {
                    let n = 1u64 << k;
                    assert_eq!(
                        recursive_volume_half(n, m, beta),
                        recursive_volume_half_closed(n, m, beta),
                        "n={n} m={m} β={beta}"
                    );
                }
            }
        }
    }

    #[test]
    fn general_evaluation_matches_exact_at_half() {
        for m in 2..5u32 {
            for k in 2..12u32 {
                let n = 1u64 << k;
                let exact = recursive_volume_half(n, m, 2) as f64;
                let general = recursive_volume_general(n as f64, m, 0.5, 2.0);
                assert!(
                    (exact - general).abs() / exact.max(1.0) < 1e-9,
                    "n={n} m={m}: {exact} vs {general}"
                );
            }
        }
    }

    #[test]
    fn alpha_limits_match_eq29() {
        // m=5 → 3×, m=7 → 39× (paper text below eq. 29).
        assert!((alpha_limit_half_beta2(5) - 3.0).abs() < 1e-12);
        assert!((alpha_limit_half_beta2(7) - 39.0).abs() < 1e-12);
        // m=2, m=3 → 0 (the exact-fit cases).
        assert!(alpha_limit_half_beta2(2).abs() < 1e-12);
        assert!(alpha_limit_half_beta2(3).abs() < 1e-12);
    }

    #[test]
    fn alpha_converges_to_limit() {
        for m in 2..7u32 {
            let lim = alpha_limit_half_beta2(m);
            let a = alpha_half(1 << 14, m, 2);
            assert!(
                (a - lim).abs() < 0.01 * (1.0 + lim.abs()),
                "m={m}: α={a} lim={lim}"
            );
        }
    }

    #[test]
    fn arity3_alpha_approaches_one_fifth() {
        // eq. 19: the Sierpinski-like arity-3 set has 1/5 extra volume
        // relative to the tetrahedron.
        let n = 1u64 << 14;
        let v_s = recursive_volume_half(n, 3, 3) as f64;
        let v_d = simplex_volume(n, 3) as f64;
        let alpha = v_s / v_d - 1.0;
        assert!((alpha - 0.2).abs() < 1e-3, "α={alpha}");
    }

    #[test]
    fn paper_params_hit_denominator_below_mfact() {
        for m in 4..9u32 {
            for beta in [2.0, 4.0, 8.0] {
                let p = GeneralSetParams::for_paper(m, beta);
                let expect = factorial(m) as f64 - beta;
                assert!(
                    (p.denom() - expect).abs() < 1e-6 * factorial(m) as f64,
                    "m={m} β={beta}: denom={} want {expect}",
                    p.denom()
                );
                assert!(p.denom() < factorial(m) as f64, "below m!");
            }
        }
    }

    #[test]
    fn n0_exists_and_decreases_with_beta() {
        // §III.D: raising β brings n_0 closer to the origin.
        let horizon = 1 << 40;
        let m = 5;
        let n0_b2 = GeneralSetParams::for_paper(m, 2.0)
            .n0(horizon)
            .expect("n0 exists for β=2");
        let n0_b32 = GeneralSetParams::for_paper(m, 32.0)
            .n0(horizon)
            .expect("n0 exists for β=32");
        assert!(n0_b32 < n0_b2, "n0(β=32)={n0_b32} vs n0(β=2)={n0_b2}");
        // Measured against the python cross-check: n0(m=5, β=2) = 512.
        assert_eq!(n0_b2, 512);
        assert_eq!(n0_b32, 16);
    }

    #[test]
    fn waste_limit_grows_with_beta() {
        let m = 5;
        let w2 = GeneralSetParams::for_paper(m, 2.0).waste_limit();
        let w32 = GeneralSetParams::for_paper(m, 32.0).waste_limit();
        assert!(w2 < w32);
        // β/(m!-β): 2/118 and 32/88.
        assert!((w2 - 2.0 / 118.0).abs() < 1e-12);
        assert!((w32 - 32.0 / 88.0).abs() < 1e-12);
    }

    #[test]
    fn exact_mfact_denominator_never_covers_high_m() {
        // The quantified §III.D tension: with 1/r^m - β = m! exactly,
        // the simplex's Θ(n^{m-1}) term always wins for m ≥ 4.
        let m = 5u32;
        let beta = 2.0f64;
        let r = (factorial(m) as f64 + beta).powf(-1.0 / m as f64);
        let p = GeneralSetParams { m, beta, r };
        assert!(p.n0(1 << 40).is_none());
    }

    #[test]
    #[should_panic(expected = "n = 2^k")]
    fn non_pow2_rejected() {
        recursive_volume_half(12, 2, 2);
    }

    #[test]
    fn level_plan_m4_beta2_matches_cross_check() {
        // Python cross-check: m=4 β=2 n=28 → sides [13,6,3,1,1],
        // counts 2^i, total volume 31501 (vs V(Δ_28^4) = 31465).
        let p = GeneralSetParams::for_paper(4, 2.0);
        let plan = p.level_plan(28).unwrap();
        assert_eq!(plan.sides, vec![13, 6, 3, 1, 1]);
        assert_eq!(plan.counts, vec![1, 2, 4, 8, 16]);
        assert_eq!(plan.volume(), Some(31501));
        assert_eq!(p.discrete_volume(28), Some(31501));
        assert_eq!(simplex_volume(28, 4), 31465);
    }

    #[test]
    fn level_plan_m5_beta32_matches_cross_check() {
        // m=5 β=32 n=4 → sides [2,1], counts [1,32], volume 64 ≥ 56.
        let p = GeneralSetParams::for_paper(5, 32.0);
        let plan = p.level_plan(4).unwrap();
        assert_eq!(plan.sides, vec![2, 1]);
        assert_eq!(plan.counts, vec![1, 32]);
        assert_eq!(plan.volume(), Some(64));
        assert_eq!(simplex_volume(4, 5), 56);
    }

    #[test]
    fn discrete_coverage_matches_cross_checked_sizes() {
        // Covered sizes (python): m=4 β=2 → 28, 30, 37, 39, …;
        // m=5 β=32 → 4, 9, 10, 11, 12, 17, ….
        let p4 = GeneralSetParams::for_paper(4, 2.0);
        for n in [28u64, 30, 37, 39, 41] {
            assert!(p4.discrete_covers(n), "m=4 β=2 n={n}");
        }
        for n in [27u64, 29, 31, 36] {
            assert!(!p4.discrete_covers(n), "m=4 β=2 n={n}");
        }
        assert_eq!(p4.first_covered(2, 300), Some(28));

        let p5 = GeneralSetParams::for_paper(5, 32.0);
        for n in [4u64, 9, 10, 11, 12, 17] {
            assert!(p5.discrete_covers(n), "m=5 β=32 n={n}");
        }
        for n in [2u64, 3, 5, 8, 13] {
            assert!(!p5.discrete_covers(n), "m=5 β=32 n={n}");
        }
        assert_eq!(p5.first_covered(2, 300), Some(4));
    }

    #[test]
    fn discrete_volume_tracks_closed_form_at_scale() {
        // Rounding noise vanishes as n grows: the integer plan volume
        // is within 2% of eq. 27's real-valued closed form by n = 1024.
        for (m, beta) in [(4u32, 2.0f64), (4, 4.0), (5, 16.0), (5, 32.0)] {
            let p = GeneralSetParams::for_paper(m, beta);
            for n in [1024u64, 4096] {
                let discrete = p.discrete_volume(n).unwrap() as f64;
                let closed = recursive_volume_closed_general(n as f64, m, p.r, beta);
                let ratio = discrete / closed;
                assert!(
                    (ratio - 1.0).abs() < 0.02,
                    "m={m} β={beta} n={n}: discrete/closed = {ratio}"
                );
            }
        }
    }
}
