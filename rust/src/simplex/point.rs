//! Discrete points, simplex membership and canonical enumeration.
//!
//! The data space is `Δ_n^m = { x ∈ Z_+^m : Σ x_i ≤ n-1 }` (paper
//! eq. 1 with the volume convention of eq. 2). This module provides
//! membership tests, iteration in lexicographic order, and the
//! triangular/tetrahedral matrix views used by the workloads.

use crate::simplex::volume::simplex_volume;

/// Maximum dimensionality supported by the fixed-size point type.
pub const MAX_M: usize = 8;

/// A point in data space, up to MAX_M dimensions (stack-allocated: the
/// hot path must not allocate).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct PointM {
    pub coords: [u64; MAX_M],
    pub m: u32,
}

impl PointM {
    pub fn new(coords: &[u64]) -> PointM {
        assert!(coords.len() <= MAX_M, "m ≤ {MAX_M}");
        let mut c = [0u64; MAX_M];
        c[..coords.len()].copy_from_slice(coords);
        PointM {
            coords: c,
            m: coords.len() as u32,
        }
    }

    pub fn as_slice(&self) -> &[u64] {
        // lint: allow(cast, u32 to usize widens)
        &self.coords[..self.m as usize]
    }

    pub fn sum(&self) -> u64 {
        self.as_slice().iter().sum()
    }
}

/// The discrete orthogonal m-simplex `Δ_n^m`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Simplex {
    pub n: u64,
    pub m: u32,
}

impl Simplex {
    pub fn new(n: u64, m: u32) -> Simplex {
        // lint: allow(cast, u32 to usize widens)
        assert!(m as usize <= MAX_M && m >= 1, "1 ≤ m ≤ {MAX_M}");
        Simplex { n, m }
    }

    /// Membership per eq. (1): all coordinates ≥ 0 and Σ x_i ≤ n-1.
    #[inline]
    pub fn contains(&self, p: &PointM) -> bool {
        p.m == self.m && self.n > 0 && p.sum() <= self.n - 1
    }

    #[inline]
    pub fn contains_coords(&self, coords: &[u64]) -> bool {
        // lint: allow(cast, u32 to usize widens)
        coords.len() == self.m as usize && self.n > 0 && coords.iter().sum::<u64>() <= self.n - 1
    }

    /// Exact element count (eq. 2).
    pub fn volume(&self) -> u128 {
        simplex_volume(self.n, self.m)
    }

    /// Iterate all elements in lexicographic order.
    pub fn iter(&self) -> SimplexIter {
        SimplexIter {
            simplex: *self,
            next: if self.n == 0 {
                None
            } else {
                // lint: allow(cast, u32 to usize widens)
                Some(PointM::new(&vec![0; self.m as usize]))
            },
        }
    }
}

/// Lexicographic iterator over simplex elements.
pub struct SimplexIter {
    simplex: Simplex,
    next: Option<PointM>,
}

impl Iterator for SimplexIter {
    type Item = PointM;

    fn next(&mut self) -> Option<PointM> {
        let current = self.next?;
        // Advance: increment the last coordinate; on budget overflow,
        // carry into earlier coordinates.
        // lint: allow(cast, u32 to usize widens)
        let m = self.simplex.m as usize;
        let budget = self.simplex.n - 1;
        let mut c = current;
        let mut advanced = false;
        for i in (0..m).rev() {
            c.coords[i] += 1;
            if c.sum() <= budget {
                advanced = true;
                break;
            }
            c.coords[i] = 0;
        }
        self.next = if advanced { Some(c) } else { None };
        Some(current)
    }
}

/// 2-simplex as a triangular matrix index pair: strictly-lower pairs
/// `(row, col)` with `col < row < n` — the canonical domain of the EDM /
/// collision / n-body workloads. Bijective with `Δ_{n-1}^2` via
/// `(row, col) → (col, n-1-row)`.
#[inline]
pub fn lower_tri_contains(n: u64, row: u64, col: u64) -> bool {
    col < row && row < n
}

/// Map a strictly-lower-triangular pair into simplex coordinates.
#[inline]
pub fn tri_pair_to_simplex(n: u64, row: u64, col: u64) -> (u64, u64) {
    debug_assert!(lower_tri_contains(n, row, col));
    (col, n - 1 - row)
}

/// Inverse of [`tri_pair_to_simplex`].
#[inline]
pub fn simplex_to_tri_pair(n: u64, x: u64, y: u64) -> (u64, u64) {
    (n - 1 - y, x)
}

/// 3-simplex as unique triples `(i, j, k)` with `k < j < i < n` — the
/// domain of triple-interaction workloads. Bijective with `Δ_{n-2}^3`.
#[inline]
pub fn lower_tet_contains(n: u64, i: u64, j: u64, k: u64) -> bool {
    k < j && j < i && i < n
}

/// Map a strictly-decreasing triple into simplex coordinates
/// `(x, y, z) ∈ Δ_{n-2}^3` (sum ≤ n-3).
#[inline]
pub fn tet_triple_to_simplex(n: u64, i: u64, j: u64, k: u64) -> (u64, u64, u64) {
    debug_assert!(lower_tet_contains(n, i, j, k));
    (k, j - k - 1, n - 1 - i)
}

/// Inverse of [`tet_triple_to_simplex`].
#[inline]
pub fn simplex_to_tet_triple(n: u64, x: u64, y: u64, z: u64) -> (u64, u64, u64) {
    (n - 1 - z, x + y + 1, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iterator_count_matches_volume() {
        for m in 1..5u32 {
            for n in 0..10u64 {
                let s = Simplex::new(n, m);
                assert_eq!(s.iter().count() as u128, s.volume(), "n={n} m={m}");
            }
        }
    }

    #[test]
    fn iterator_yields_members_only_and_unique() {
        let s = Simplex::new(7, 3);
        let pts: Vec<_> = s.iter().collect();
        for p in &pts {
            assert!(s.contains(p), "{p:?}");
        }
        let set: std::collections::HashSet<_> = pts.iter().cloned().collect();
        assert_eq!(set.len(), pts.len());
    }

    #[test]
    fn membership_boundary() {
        let s = Simplex::new(4, 2);
        assert!(s.contains_coords(&[0, 0]));
        assert!(s.contains_coords(&[3, 0]));
        assert!(s.contains_coords(&[1, 2]));
        assert!(!s.contains_coords(&[2, 2]));
        assert!(!s.contains_coords(&[4, 0]));
        assert!(!s.contains_coords(&[0])); // wrong arity
    }

    #[test]
    fn empty_simplex_has_no_elements() {
        let s = Simplex::new(0, 2);
        assert_eq!(s.iter().count(), 0);
        assert!(!s.contains_coords(&[0, 0]));
    }

    #[test]
    fn tri_pair_bijection_with_simplex() {
        let n = 16u64;
        let mut seen = std::collections::HashSet::new();
        for row in 0..n {
            for col in 0..n {
                if lower_tri_contains(n, row, col) {
                    let (x, y) = tri_pair_to_simplex(n, row, col);
                    // Lands inside Δ_{n-1}^2 (sum ≤ n-2).
                    assert!(x + y <= n - 2, "({row},{col})→({x},{y})");
                    assert!(seen.insert((x, y)), "duplicate image");
                    // Round-trips.
                    assert_eq!(simplex_to_tri_pair(n, x, y), (row, col));
                }
            }
        }
        assert_eq!(seen.len() as u128, simplex_volume(n - 1, 2));
    }

    #[test]
    fn tet_triple_bijection_with_simplex() {
        let n = 12u64;
        let mut seen = std::collections::HashSet::new();
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    if lower_tet_contains(n, i, j, k) {
                        let (x, y, z) = tet_triple_to_simplex(n, i, j, k);
                        assert!(x + y + z <= n - 3, "triple ({i},{j},{k})");
                        assert!(seen.insert((x, y, z)), "duplicate image");
                        assert_eq!(simplex_to_tet_triple(n, x, y, z), (i, j, k));
                    }
                }
            }
        }
        assert_eq!(seen.len() as u128, simplex_volume(n - 2, 3));
    }

    #[test]
    fn point_sum_and_slices() {
        let p = PointM::new(&[1, 2, 3]);
        assert_eq!(p.sum(), 6);
        assert_eq!(p.as_slice(), &[1, 2, 3]);
        assert_eq!(p.m, 3);
    }
}
