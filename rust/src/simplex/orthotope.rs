//! Orthotopes (the only geometry a GPU parallel space can take) and the
//! parallel-space containers used by each map.

/// An axis-aligned discrete orthotope `[0, d_0) × … × [0, d_{m-1})` —
/// the shape of a CUDA grid (§I: parallel spaces are orthotopes in
/// m = 1, 2, 3; higher m linearizes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Orthotope {
    pub dims: [u64; 3],
    pub m: u32,
}

impl Orthotope {
    pub fn d1(x: u64) -> Orthotope {
        Orthotope { dims: [x, 1, 1], m: 1 }
    }
    pub fn d2(x: u64, y: u64) -> Orthotope {
        Orthotope { dims: [x, y, 1], m: 2 }
    }
    pub fn d3(x: u64, y: u64, z: u64) -> Orthotope {
        Orthotope { dims: [x, y, z], m: 3 }
    }

    /// Total number of cells (blocks, when used as a grid).
    pub fn volume(&self) -> u128 {
        self.dims.iter().map(|&d| d as u128).product()
    }

    #[inline]
    pub fn contains(&self, p: [u64; 3]) -> bool {
        p[0] < self.dims[0] && p[1] < self.dims[1] && p[2] < self.dims[2]
    }

    /// Linearize a cell coordinate (x fastest).
    #[inline]
    pub fn linear_of(&self, p: [u64; 3]) -> u64 {
        debug_assert!(self.contains(p));
        p[0] + self.dims[0] * (p[1] + self.dims[1] * p[2])
    }

    /// Inverse of [`Orthotope::linear_of`].
    #[inline]
    pub fn of_linear(&self, idx: u64) -> [u64; 3] {
        let x = idx % self.dims[0];
        let rest = idx / self.dims[0];
        let y = rest % self.dims[1];
        let z = rest / self.dims[1];
        [x, y, z]
    }

    /// Iterate all cells (z-major, x-minor).
    pub fn iter(&self) -> impl Iterator<Item = [u64; 3]> + '_ {
        let [dx, dy, dz] = self.dims;
        (0..dz).flat_map(move |z| (0..dy).flat_map(move |y| (0..dx).map(move |x| [x, y, z])))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volume_and_contains() {
        let o = Orthotope::d3(4, 3, 2);
        assert_eq!(o.volume(), 24);
        assert!(o.contains([3, 2, 1]));
        assert!(!o.contains([4, 0, 0]));
        assert_eq!(Orthotope::d2(5, 7).volume(), 35);
        assert_eq!(Orthotope::d1(9).volume(), 9);
    }

    #[test]
    fn linearization_roundtrip() {
        let o = Orthotope::d3(5, 4, 3);
        for (i, p) in o.iter().enumerate() {
            assert_eq!(o.linear_of(p), i as u64);
            assert_eq!(o.of_linear(i as u64), p);
        }
    }

    #[test]
    fn iter_visits_volume_cells() {
        let o = Orthotope::d3(3, 3, 3);
        assert_eq!(o.iter().count() as u128, o.volume());
        let set: std::collections::HashSet<_> = o.iter().collect();
        assert_eq!(set.len() as u128, o.volume());
    }
}
