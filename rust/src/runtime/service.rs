//! Executor service: the PJRT client confined to one dedicated thread.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (neither `Send` nor
//! `Sync`), but the coordinator is multi-threaded. Standard remedy: an
//! actor. [`ExecutorService::spawn`] starts one thread that owns the
//! [`Executor`]; callers hold a cloneable [`ExecHandle`] (channels are
//! Send+Sync) and submit execution requests that are answered over a
//! per-request reply channel. Requests serialize naturally — which
//! matches the single-device CPU client and makes batching (not
//! concurrency) the throughput lever, as in the real system.
//!
//! Memory-ordering policy: the only atomic is the round-robin device
//! cursor, which needs nothing beyond atomicity — Relaxed.
// lint: atomics(Relaxed)

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;

use super::{ArtifactSpec, Executor, Result, RuntimeError, TensorF32};
use crate::log_info;

enum Request {
    Run {
        artifact: String,
        inputs: Vec<TensorF32>,
        reply: mpsc::Sender<Result<TensorF32>>,
    },
    Shutdown,
}

/// Cloneable, thread-safe handle to the executor pool. Requests are
/// distributed round-robin over the pool's threads (each owns its own
/// PJRT client + compiled executables), so up to `pool_size` batches
/// execute concurrently — the §Perf lever that recovers concurrency
/// without sharing the non-Sync client.
#[derive(Clone)]
pub struct ExecHandle {
    txs: Arc<Vec<mpsc::Sender<Request>>>,
    next: Arc<std::sync::atomic::AtomicUsize>,
    specs: Arc<BTreeMap<String, ArtifactSpec>>,
    platform: Arc<String>,
}

impl ExecHandle {
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.specs
            .get(name)
            .ok_or_else(|| RuntimeError::ArtifactMissing(name.to_string()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.specs.keys().map(|s| s.as_str()).collect()
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Number of independent executor threads.
    pub fn pool_size(&self) -> usize {
        self.txs.len()
    }

    /// Submit an execution without waiting; the result arrives on the
    /// returned channel. Requests round-robin over the pool.
    pub fn run_f32_async(
        &self,
        artifact: &str,
        inputs: Vec<TensorF32>,
    ) -> Result<mpsc::Receiver<Result<TensorF32>>> {
        let (reply, rx) = mpsc::channel();
        let i = self
            .next
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed)
            % self.txs.len();
        self.txs[i]
            .send(Request::Run {
                artifact: artifact.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| RuntimeError::Xla("executor service stopped".into()))?;
        Ok(rx)
    }

    /// Execute an artifact and wait for the result.
    pub fn run_f32(&self, artifact: &str, inputs: Vec<TensorF32>) -> Result<TensorF32> {
        self.run_f32_async(artifact, inputs)?
            .recv()
            .map_err(|_| RuntimeError::Xla("executor service dropped reply".into()))?
    }
}

/// Owns the pool threads; dropping shuts them down.
pub struct ExecutorService {
    handle: ExecHandle,
    joins: Vec<std::thread::JoinHandle<()>>,
    txs: Arc<Vec<mpsc::Sender<Request>>>,
}

fn spawn_worker(
    dir: std::path::PathBuf,
    idx: usize,
) -> Result<(
    mpsc::Sender<Request>,
    std::thread::JoinHandle<()>,
    BTreeMap<String, ArtifactSpec>,
    String,
)> {
    let (tx, rx) = mpsc::channel::<Request>();
    let (init_tx, init_rx) =
        mpsc::channel::<Result<(BTreeMap<String, ArtifactSpec>, String)>>();
    let join = std::thread::Builder::new()
        .name(format!("pjrt-executor-{idx}"))
        .spawn(move || {
            let exe = match Executor::load_all(&dir) {
                Ok(exe) => {
                    let specs: BTreeMap<String, ArtifactSpec> = exe
                        .names()
                        .iter()
                        .map(|n| (n.to_string(), exe.spec(n).unwrap().clone()))
                        .collect();
                    let _ = init_tx.send(Ok((specs, exe.platform())));
                    exe
                }
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Shutdown => break,
                    Request::Run {
                        artifact,
                        inputs,
                        reply,
                    } => {
                        let _ = reply.send(exe.run_f32(&artifact, &inputs));
                    }
                }
            }
            log_info!("runtime", "executor worker {idx} stopped");
        })
        .expect("spawn executor thread");
    let (specs, platform) = init_rx
        .recv()
        .map_err(|_| RuntimeError::Xla("executor thread died during init".into()))??;
    Ok((tx, join, specs, platform))
}

impl ExecutorService {
    /// Load all artifacts on one executor thread.
    pub fn spawn(dir: &Path) -> Result<ExecutorService> {
        Self::spawn_pool(dir, 1)
    }

    /// Load all artifacts on `n` executor threads (each its own PJRT
    /// client); batches round-robin over them.
    pub fn spawn_pool(dir: &Path, n: usize) -> Result<ExecutorService> {
        let n = n.max(1);
        let mut txs = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        let mut meta = None;
        for idx in 0..n {
            let (tx, join, specs, platform) = spawn_worker(dir.to_path_buf(), idx)?;
            txs.push(tx);
            joins.push(join);
            meta = Some((specs, platform));
        }
        let (specs, platform) = meta.unwrap();
        let txs = Arc::new(txs);
        let handle = ExecHandle {
            txs: Arc::clone(&txs),
            next: Arc::new(std::sync::atomic::AtomicUsize::new(0)),
            specs: Arc::new(specs),
            platform: Arc::new(platform),
        };
        Ok(ExecutorService {
            handle,
            joins,
            txs,
        })
    }

    pub fn handle(&self) -> ExecHandle {
        self.handle.clone()
    }
}

impl Drop for ExecutorService {
    fn drop(&mut self) {
        for tx in self.txs.iter() {
            let _ = tx.send(Request::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    // Service tests requiring artifacts live in
    // rust/tests/coordinator_e2e.rs; here we only check the error path.
    use super::*;

    #[test]
    fn spawn_on_missing_dir_fails_cleanly() {
        let err = ExecutorService::spawn(Path::new("/nonexistent-dir-xyz"));
        assert!(err.is_err());
    }
}
