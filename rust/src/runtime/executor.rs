//! The PJRT executor: one CPU client, one compiled executable per
//! artifact, and a typed f32 tensor interface.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥
//! 0.5 emits serialized protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). Executables are compiled once at load
//! and reused; `run_f32` serializes calls per executable with a mutex
//! (the PJRT CPU client is not documented thread-safe for concurrent
//! executions of one executable — the coordinator batches instead).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use crate::log_info;

use super::xla_stub as xla;
use super::{ArtifactRegistry, ArtifactSpec, Result, RuntimeError};

/// A row-major f32 tensor with shape.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> TensorF32 {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorF32 { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> TensorF32 {
        let len = shape.iter().product();
        TensorF32 {
            shape,
            data: vec![0.0; len],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

struct Compiled {
    spec: ArtifactSpec,
    exe: Mutex<xla::PjRtLoadedExecutable>,
}

/// The process-wide executor.
pub struct Executor {
    client: xla::PjRtClient,
    compiled: BTreeMap<String, Compiled>,
}

impl Executor {
    /// Create a CPU PJRT client and compile every artifact in `dir`.
    pub fn load_all(dir: &Path) -> Result<Executor> {
        let registry = ArtifactRegistry::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        log_info!(
            "runtime",
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        let mut compiled = BTreeMap::new();
        for name in registry.names() {
            let spec = registry.get(name)?.clone();
            let proto = xla::HloModuleProto::from_text_file(
                spec.path
                    .to_str()
                    .ok_or_else(|| RuntimeError::BadMetadata("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            log_info!("runtime", "compiled artifact '{name}' from {:?}", spec.path);
            compiled.insert(
                name.to_string(),
                Compiled {
                    spec,
                    exe: Mutex::new(exe),
                },
            );
        }
        Ok(Executor { client, compiled })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn names(&self) -> Vec<&str> {
        self.compiled.keys().map(|s| s.as_str()).collect()
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.compiled
            .get(name)
            .map(|c| &c.spec)
            .ok_or_else(|| RuntimeError::ArtifactMissing(name.to_string()))
    }

    /// Execute artifact `name` on f32 inputs; returns the single tupled
    /// output. Validates shapes against the manifest.
    pub fn run_f32(&self, name: &str, inputs: &[TensorF32]) -> Result<TensorF32> {
        let c = self
            .compiled
            .get(name)
            .ok_or_else(|| RuntimeError::ArtifactMissing(name.to_string()))?;
        if inputs.len() != c.spec.input_shapes.len() {
            return Err(RuntimeError::BadMetadata(format!(
                "artifact '{name}' wants {} inputs, got {}",
                c.spec.input_shapes.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, t) in inputs.iter().enumerate() {
            if t.shape != c.spec.input_shapes[i] {
                return Err(RuntimeError::ShapeMismatch {
                    expected: c.spec.input_shapes[i].clone(),
                    got: t.shape.clone(),
                });
            }
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data).reshape(&dims)?;
            literals.push(lit);
        }
        let result = {
            let exe = c.exe.lock().unwrap();
            exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?
        };
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        if data.len() != c.spec.output_len() {
            return Err(RuntimeError::ShapeMismatch {
                expected: c.spec.output_shape.clone(),
                got: vec![data.len()],
            });
        }
        Ok(TensorF32::new(c.spec.output_shape.clone(), data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_invariants() {
        let t = TensorF32::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        let z = TensorF32::zeros(vec![4, 4]);
        assert_eq!(z.data.len(), 16);
        assert!(!z.is_empty());
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        TensorF32::new(vec![2, 2], vec![0.0; 5]);
    }

    // Executor integration tests live in rust/tests/runtime_e2e.rs and
    // require `make artifacts` to have run.
}
