//! PJRT execution runtime — loads the AOT artifacts `make artifacts`
//! produced (HLO *text*, see DESIGN.md and python/compile/aot.py) and
//! runs them from the Rust request path. Python never runs here.
//!
//! Layering: [`artifact`] resolves artifact files and their metadata,
//! [`executor`] owns the PJRT client and the compiled executables and
//! exposes a typed, thread-safe `run_f32` entry point the coordinator's
//! batcher calls.

pub mod artifact;
pub mod executor;
pub mod service;
pub mod xla_stub;

pub use artifact::{ArtifactRegistry, ArtifactSpec};
pub use executor::{Executor, TensorF32};
pub use service::{ExecHandle, ExecutorService};

use xla_stub as xla;

#[derive(Debug)]
pub enum RuntimeError {
    ArtifactMissing(String),
    BadMetadata(String),
    Xla(String),
    ShapeMismatch { expected: Vec<usize>, got: Vec<usize> },
    Io(std::io::Error),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::ArtifactMissing(name) => {
                write!(f, "artifact not found: {name} (run `make artifacts`)")
            }
            RuntimeError::BadMetadata(msg) => write!(f, "artifact metadata error: {msg}"),
            RuntimeError::Xla(msg) => write!(f, "xla error: {msg}"),
            RuntimeError::ShapeMismatch { expected, got } => {
                write!(f, "shape mismatch: expected {expected:?}, got {got:?}")
            }
            RuntimeError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;
