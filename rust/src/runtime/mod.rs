//! PJRT execution runtime — loads the AOT artifacts `make artifacts`
//! produced (HLO *text*, see DESIGN.md and python/compile/aot.py) and
//! runs them from the Rust request path. Python never runs here.
//!
//! Layering: [`artifact`] resolves artifact files and their metadata,
//! [`executor`] owns the PJRT client and the compiled executables and
//! exposes a typed, thread-safe `run_f32` entry point the coordinator's
//! batcher calls.

pub mod artifact;
pub mod executor;
pub mod service;

pub use artifact::{ArtifactRegistry, ArtifactSpec};
pub use executor::{Executor, TensorF32};
pub use service::{ExecHandle, ExecutorService};

use thiserror::Error;

#[derive(Debug, Error)]
pub enum RuntimeError {
    #[error("artifact not found: {0} (run `make artifacts`)")]
    ArtifactMissing(String),
    #[error("artifact metadata error: {0}")]
    BadMetadata(String),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("shape mismatch: expected {expected:?}, got {got:?}")]
    ShapeMismatch { expected: Vec<usize>, got: Vec<usize> },
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;
