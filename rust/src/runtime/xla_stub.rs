//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The build environment vendors no external crates, and the PJRT C API
//! shared library is not part of the image, so the real `xla` crate
//! cannot be linked. This module mirrors the exact API surface
//! [`super::executor`] uses so the runtime layer type-checks and the
//! artifact/registry/service plumbing stays fully tested; creating a
//! client reports a clean [`Error`] at runtime instead. Swapping the
//! `use xla_stub as xla` aliases in `runtime/{mod,executor}.rs` for the
//! real crate restores execution without further source changes (see
//! DESIGN.md §Substitutions).

use std::fmt;

/// Error type mirroring `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Error {
        Error(
            "PJRT backend unavailable: this build uses the offline xla stub \
             (vendor the `xla` crate and the PJRT CPU plugin to enable)"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Stand-in for `xla::PjRtClient`. Construction always fails in the
/// stub, so every downstream method is unreachable in practice; they
/// still return well-typed values to satisfy the executor.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// Stand-in for `xla::HloModuleProto` (HLO text form).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        // Match the real crate's behavior of failing on unreadable input
        // so registry-level errors surface identically.
        std::fs::metadata(path).map_err(|e| Error(format!("cannot read {path}: {e}")))?;
        Ok(HloModuleProto)
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_reports_stub_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub client must not exist");
        assert!(err.to_string().contains("stub"), "{err}");
    }

    #[test]
    fn proto_loading_requires_readable_file() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
