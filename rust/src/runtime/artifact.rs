//! Artifact registry: discovers `artifacts/*.hlo.txt` plus their
//! sidecar metadata (`artifacts/manifest.json`, written by aot.py) and
//! hands validated specs to the executor.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

use super::{Result, RuntimeError};

/// Metadata for one compiled computation, as recorded by aot.py.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    /// Input shapes, row-major (e.g. [[64,16,8],[64,16,8]]).
    pub input_shapes: Vec<Vec<usize>>,
    /// Output shape of the single (tupled) result.
    pub output_shape: Vec<usize>,
}

impl ArtifactSpec {
    /// Total f32 element count of input `i`.
    pub fn input_len(&self, i: usize) -> usize {
        self.input_shapes[i].iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// Registry over an artifacts directory.
#[derive(Debug, Default)]
pub struct ArtifactRegistry {
    specs: BTreeMap<String, ArtifactSpec>,
}

impl ArtifactRegistry {
    /// Load the manifest from `dir` ("artifacts" by default).
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest_path = dir.join("manifest.json");
        if !manifest_path.exists() {
            return Err(RuntimeError::ArtifactMissing(
                manifest_path.display().to_string(),
            ));
        }
        let text = std::fs::read_to_string(&manifest_path)?;
        let doc = json::parse(&text)
            .map_err(|e| RuntimeError::BadMetadata(e.to_string()))?;
        let arr = doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| RuntimeError::BadMetadata("missing 'artifacts' array".into()))?;
        let mut specs = BTreeMap::new();
        for a in arr {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| RuntimeError::BadMetadata("artifact missing 'name'".into()))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| RuntimeError::BadMetadata("artifact missing 'file'".into()))?;
            let path = dir.join(file);
            if !path.exists() {
                return Err(RuntimeError::ArtifactMissing(path.display().to_string()));
            }
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .map(|rows| {
                        rows.iter()
                            .map(|row| {
                                row.as_arr()
                                    .map(|dims| {
                                        dims.iter()
                                            .filter_map(Json::as_u64)
                                            .map(|d| d as usize)
                                            .collect()
                                    })
                                    .ok_or_else(|| {
                                        RuntimeError::BadMetadata(format!("bad {key}"))
                                    })
                            })
                            .collect()
                    })
                    .unwrap_or_else(|| Err(RuntimeError::BadMetadata(format!("missing {key}"))))
            };
            let input_shapes = shapes("input_shapes")?;
            let output_shape = shapes("output_shapes")?
                .into_iter()
                .next()
                .ok_or_else(|| RuntimeError::BadMetadata("empty output_shapes".into()))?;
            specs.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    path,
                    input_shapes,
                    output_shape,
                },
            );
        }
        Ok(ArtifactRegistry { specs })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.specs
            .get(name)
            .ok_or_else(|| RuntimeError::ArtifactMissing(name.to_string()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.specs.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }
}

/// Default artifacts directory: `$SIMPLEXMAP_ARTIFACTS` or `artifacts/`
/// relative to the workspace root.
pub fn default_dir() -> PathBuf {
    std::env::var("SIMPLEXMAP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("smx-artifact-test-ok");
        write_manifest(
            &dir,
            r#"{"artifacts":[{"name":"edm","file":"edm.hlo.txt",
                "input_shapes":[[4,2,3],[4,2,3]],"output_shapes":[[4,2,2]]}]}"#,
        );
        std::fs::write(dir.join("edm.hlo.txt"), "HloModule fake").unwrap();
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.len(), 1);
        let spec = reg.get("edm").unwrap();
        assert_eq!(spec.input_shapes, vec![vec![4, 2, 3], vec![4, 2, 3]]);
        assert_eq!(spec.output_shape, vec![4, 2, 2]);
        assert_eq!(spec.input_len(0), 24);
        assert_eq!(spec.output_len(), 16);
        assert_eq!(reg.names(), vec!["edm"]);
    }

    #[test]
    fn missing_manifest_is_artifact_missing() {
        let dir = std::env::temp_dir().join("smx-artifact-test-none");
        let _ = std::fs::remove_dir_all(&dir);
        match ArtifactRegistry::load(&dir) {
            Err(RuntimeError::ArtifactMissing(_)) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn missing_hlo_file_is_detected() {
        let dir = std::env::temp_dir().join("smx-artifact-test-nofile");
        write_manifest(
            &dir,
            r#"{"artifacts":[{"name":"x","file":"x.hlo.txt",
                "input_shapes":[[1]],"output_shapes":[[1]]}]}"#,
        );
        let _ = std::fs::remove_file(dir.join("x.hlo.txt"));
        assert!(matches!(
            ArtifactRegistry::load(&dir),
            Err(RuntimeError::ArtifactMissing(_))
        ));
    }

    #[test]
    fn malformed_manifest_is_bad_metadata() {
        let dir = std::env::temp_dir().join("smx-artifact-test-bad");
        write_manifest(&dir, r#"{"artifacts":[{"name":"x"}]}"#);
        assert!(matches!(
            ArtifactRegistry::load(&dir),
            Err(RuntimeError::BadMetadata(_))
        ));
    }

    #[test]
    fn unknown_artifact_name_errors() {
        let dir = std::env::temp_dir().join("smx-artifact-test-ok2");
        write_manifest(&dir, r#"{"artifacts":[]}"#);
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert!(reg.is_empty());
        assert!(matches!(
            reg.get("nope"),
            Err(RuntimeError::ArtifactMissing(_))
        ));
    }
}
