//! `simplexlint` — run the in-tree static-analysis pass over the
//! repository and exit non-zero on any unsuppressed finding.
//!
//! Usage: `cargo run --release --bin simplexlint [repo-root]`
//! With no argument the repo root is found by walking up from the
//! current directory (so it works from `rust/` and from the root).
//! CI gates on this binary in the `lint` job; the rule set and the
//! allow-annotation grammar are documented in DESIGN.md §Static
//! Analysis.

use simplexmap::lint;

fn main() {
    let root = match std::env::args().nth(1) {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            let cwd = std::env::current_dir().expect("current dir");
            match lint::find_root(&cwd) {
                Some(r) => r,
                None => {
                    eprintln!(
                        "simplexlint: no repo root (rust/src + EXPERIMENTS.md) above {}",
                        cwd.display()
                    );
                    std::process::exit(2);
                }
            }
        }
    };
    match lint::run(&root) {
        Ok(report) => {
            print!("{}", report.render());
            std::process::exit(if report.clean() { 0 } else { 1 });
        }
        Err(e) => {
            eprintln!("simplexlint: IO error: {e}");
            std::process::exit(2);
        }
    }
}
