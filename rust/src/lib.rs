//! # simplexmap
//!
//! Reproduction of *"Possibilities of Recursive GPU Mapping for
//! Discrete Orthogonal Simplices"* (Navarro, Bustos, Hitschfeld, 2016):
//! O(1) block-space thread maps `λ: Z^m → Z^m` from compact orthotope
//! parallel spaces onto discrete orthogonal m-simplex data domains,
//! plus the full surrounding system — a simulated GPU grid launcher, a
//! coordinator with a batched PJRT execution runtime, the paper's
//! workloads (EDM, collision culling, n-body, triple interactions,
//! cellular automata, triangular matrices), baseline maps from the
//! related work, and the §III.D general-m parameter study.
//!
//! See DESIGN.md for the architecture and the experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! ## Quick tour
//!
//! ```
//! use simplexmap::maps::{ThreadMap, Lambda2Map, space_efficiency};
//!
//! let map = Lambda2Map;
//! let nb = 64; // blocks per side
//! // λ2 wastes zero blocks: efficiency 1.0 (BB would be ~0.5).
//! assert!((space_efficiency(&map, nb) - 1.0).abs() < 1e-12);
//! let d = map.map_block(nb, 0, [3, 5, 0]).unwrap();
//! assert!(d[0] <= d[1] && d[1] < nb);
//! ```

pub mod analysis;
pub mod coordinator;
pub mod gensearch;
pub mod grid;
pub mod lint;
pub mod maps;
pub mod runtime;
pub mod simplex;
pub mod workloads;
pub mod util;
