//! Small statistics helpers used by the bench harness and the
//! coordinator's metrics: mean, stddev, percentiles, a streaming
//! histogram, and throughput formatting.

/// Summary statistics over a sample of f64 measurements.
///
/// The honest zero-sample representation is [`Summary::empty`]:
/// `count = 0` with NaN statistics (so `empty() != empty()` under
/// `PartialEq` — compare `count` when emptiness is the question) that
/// serialize as `null` through [`Summary::to_json`].
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub p999: f64,
}

impl Summary {
    /// Compute a summary; sorts a copy of the input.
    pub fn from_samples(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let count = sorted.len();
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var = if count > 1 {
            sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (count - 1) as f64
        } else {
            0.0
        };
        Some(Summary {
            count,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[count - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            p999: percentile_sorted(&sorted, 0.999),
        })
    }

    /// The zero-sample summary: `count = 0`, every statistic NaN.
    /// Replaces the old pattern of faking a `[0.0]` sample when a run
    /// completed nothing — zero completions now report as zero.
    pub fn empty() -> Summary {
        Summary {
            count: 0,
            mean: f64::NAN,
            stddev: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
            p50: f64::NAN,
            p90: f64::NAN,
            p99: f64::NAN,
            p999: f64::NAN,
        }
    }

    /// JSON with non-finite statistics (the empty summary, or inf from
    /// degenerate inputs) rendered as `null` rather than as invalid
    /// JSON literals.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let num = |v: f64| if v.is_finite() { v.into() } else { Json::Null };
        Json::obj(vec![
            ("count", self.count.into()),
            ("mean", num(self.mean)),
            ("stddev", num(self.stddev)),
            ("min", num(self.min)),
            ("max", num(self.max)),
            ("p50", num(self.p50)),
            ("p90", num(self.p90)),
            ("p99", num(self.p99)),
            ("p999", num(self.p999)),
        ])
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Welford online mean/variance — used where we do not want to keep the
/// whole sample (e.g. per-request latency in the server).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Human formatting for element counts ("1.23 G", "45.6 M").
pub fn fmt_count(x: f64) -> String {
    let (v, suffix) = if x >= 1e9 {
        (x / 1e9, "G")
    } else if x >= 1e6 {
        (x / 1e6, "M")
    } else if x >= 1e3 {
        (x / 1e3, "K")
    } else {
        (x, "")
    };
    format!("{v:.2}{suffix}")
}

/// Human formatting for durations given seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_samples() {
        let s = Summary::from_samples(&[5.0; 10]).unwrap();
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::from_samples(&[]).is_none());
    }

    #[test]
    fn summary_quantiles_are_ordered_including_p999() {
        let xs: Vec<f64> = (0..1000).map(|i| (i * 7 % 1000) as f64).collect();
        let s = Summary::from_samples(&xs).unwrap();
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.p999);
        assert!(s.p999 <= s.max);
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((s.p999 - percentile_sorted(&sorted, 0.999)).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_reports_zero_count_and_null_json() {
        use crate::util::json::Json;
        let s = Summary::empty();
        assert_eq!(s.count, 0);
        assert!(s.p50.is_nan() && s.p999.is_nan() && s.mean.is_nan());
        let j = s.to_json();
        assert_eq!(j.get("count").and_then(Json::as_u64), Some(0));
        assert_eq!(j.get("p50"), Some(&Json::Null));
        assert_eq!(j.get("p999"), Some(&Json::Null));
        let text = j.to_string_compact();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
    }

    #[test]
    fn nonempty_summary_json_is_numeric() {
        use crate::util::json::Json;
        let s = Summary::from_samples(&[1.0, 2.0, 3.0]).unwrap();
        let j = s.to_json();
        assert_eq!(j.get("p50").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("count").and_then(Json::as_u64), Some(3));
    }

    #[test]
    fn percentile_linear_interpolation() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 0.5), 5.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn percentile_median_odd() {
        let sorted = [1.0, 2.0, 3.0, 4.0, 100.0];
        assert_eq!(percentile_sorted(&sorted, 0.5), 3.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::from_samples(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.stddev() - s.stddev).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 6.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_count(1_500_000.0), "1.50M");
        assert_eq!(fmt_count(12.0), "12.00");
        assert_eq!(fmt_secs(0.002), "2.000ms");
        assert_eq!(fmt_secs(2.5), "2.500s");
    }
}
