//! Work-queue thread pool (the vendor set lacks `tokio`/`rayon`).
//!
//! This is the execution substrate of the grid launch simulator and the
//! coordinator: a fixed set of workers pulling boxed jobs from a shared
//! queue, plus a `scope`-style parallel-for used by the launcher to
//! process block ranges. Shutdown is explicit and idempotent; panics in
//! jobs are contained per-job and surfaced as counted failures (the GPU
//! analogy: a faulted block does not take down the device).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

struct Shared {
    queue: Mutex<std::collections::VecDeque<Message>>,
    available: Condvar,
    in_flight: AtomicUsize,
    panics: AtomicU64,
    idle: Condvar,
    idle_lock: Mutex<()>,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool of `size` workers (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
            in_flight: AtomicUsize::new(0),
            panics: AtomicU64::new(0),
            idle: Condvar::new(),
            idle_lock: Mutex::new(()),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("smx-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            shared,
            workers,
            size,
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job. Panics inside the job are contained and counted.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Message::Run(Box::new(f)));
        drop(q);
        self.shared.available.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle.wait(guard).unwrap();
        }
    }

    /// Number of jobs that panicked since pool creation.
    pub fn panic_count(&self) -> u64 {
        self.shared.panics.load(Ordering::SeqCst)
    }

    /// Parallel-for over `0..len` in `chunks` contiguous ranges. Blocks
    /// until all chunks complete. `f` receives (chunk_index, range).
    pub fn for_each_chunk<F>(&self, len: usize, chunks: usize, f: F)
    where
        F: Fn(usize, std::ops::Range<usize>) + Send + Sync + 'static,
    {
        if len == 0 {
            return;
        }
        let chunks = chunks.clamp(1, len);
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<()>();
        let chunk_size = len.div_ceil(chunks);
        let mut issued = 0;
        for c in 0..chunks {
            let lo = c * chunk_size;
            if lo >= len {
                break;
            }
            let hi = ((c + 1) * chunk_size).min(len);
            let f = Arc::clone(&f);
            let tx = tx.clone();
            issued += 1;
            self.execute(move || {
                f(c, lo..hi);
                let _ = tx.send(());
            });
        }
        drop(tx);
        for _ in 0..issued {
            // A panicked chunk drops its sender; treat as completion
            // (panic is already counted by the worker loop).
            if rx.recv().is_err() {
                break;
            }
        }
        self.wait_idle();
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let msg = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(m) = q.pop_front() {
                    break m;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match msg {
            Message::Shutdown => break,
            Message::Run(job) => {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    shared.panics.fetch_add(1, Ordering::SeqCst);
                }
                if shared.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = shared.idle_lock.lock().unwrap();
                    shared.idle.notify_all();
                }
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..self.workers.len() {
                q.push_back(Message::Shutdown);
            }
        }
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn for_each_chunk_covers_range_exactly_once() {
        let pool = ThreadPool::new(3);
        let hits = Arc::new(Mutex::new(vec![0u8; 1017]));
        let h = Arc::clone(&hits);
        pool.for_each_chunk(1017, 8, move |_c, range| {
            let mut v = h.lock().unwrap();
            for i in range {
                v[i] += 1;
            }
        });
        let v = hits.lock().unwrap();
        assert!(v.iter().all(|&x| x == 1), "every index hit exactly once");
    }

    #[test]
    fn for_each_chunk_empty_is_noop() {
        let pool = ThreadPool::new(2);
        pool.for_each_chunk(0, 4, |_, _| panic!("must not run"));
    }

    #[test]
    fn panics_are_contained_and_counted() {
        let pool = ThreadPool::new(2);
        for _ in 0..5 {
            pool.execute(|| panic!("boom"));
        }
        pool.execute(|| {}); // pool still functional
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 5);
    }

    #[test]
    fn wait_idle_with_no_jobs_returns() {
        let pool = ThreadPool::new(1);
        pool.wait_idle();
    }

    #[test]
    fn chunk_count_larger_than_len() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.for_each_chunk(3, 100, move |_c, range| {
            c.fetch_add(range.len() as u64, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 3);
    }
}
