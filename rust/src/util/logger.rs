//! Leveled stderr logger (no `env_logger` in the vendor set).
//!
//! Level is taken from `SIMPLEXMAP_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`. Timestamps are monotonic seconds since process
//! start — good enough for correlating coordinator events.
//!
//! Output format is selected by `SIMPLEXMAP_LOG_FORMAT`: the default
//! `text` keeps the human `[  t LEVEL target] msg` lines; `json` emits
//! structured JSONL — one `{"level","target","ts","msg"}` object per
//! line, every string escaped through [`crate::util::json`] so targets
//! and messages containing quotes or backslashes stay parseable.
//!
//! Memory-ordering policy: the level and format cells are plain
//! last-write-wins configuration bytes — no data is published through
//! them — so loads and stores are Relaxed.
// lint: atomics(Relaxed)

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    /// Lowercase name for structured output (no padding).
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// Log line rendering: human text (default) or one-object-per-line
/// JSON for machine consumption.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LogFormat {
    Text = 0,
    Json = 1,
}

impl LogFormat {
    pub fn parse(s: &str) -> Option<LogFormat> {
        match s.to_ascii_lowercase().as_str() {
            "text" => Some(LogFormat::Text),
            "json" => Some(LogFormat::Json),
            _ => None,
        }
    }
}

static START: OnceLock<Instant> = OnceLock::new();
static LEVEL: OnceLock<AtomicU8> = OnceLock::new();
static FORMAT: OnceLock<AtomicU8> = OnceLock::new();

fn start() -> &'static Instant {
    START.get_or_init(Instant::now)
}

fn level_cell() -> &'static AtomicU8 {
    LEVEL.get_or_init(|| {
        let lvl = std::env::var("SIMPLEXMAP_LOG")
            .ok()
            .and_then(|s| Level::parse(&s))
            .unwrap_or(Level::Info);
        AtomicU8::new(lvl as u8)
    })
}

pub fn set_level(level: Level) {
    level_cell().store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match level_cell().load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

fn format_cell() -> &'static AtomicU8 {
    FORMAT.get_or_init(|| {
        let f = std::env::var("SIMPLEXMAP_LOG_FORMAT")
            .ok()
            .and_then(|s| LogFormat::parse(&s))
            .unwrap_or(LogFormat::Text);
        AtomicU8::new(f as u8)
    })
}

pub fn set_format(f: LogFormat) {
    format_cell().store(f as u8, Ordering::Relaxed);
}

pub fn format() -> LogFormat {
    match format_cell().load(Ordering::Relaxed) {
        1 => LogFormat::Json,
        _ => LogFormat::Text,
    }
}

/// Render one structured JSONL record. Pure (no clock, no I/O) so the
/// escaping behaviour is unit-testable; all strings pass through the
/// [`crate::util::json`] writer.
pub fn json_line(l: Level, target: &str, ts: f64, msg: &str) -> String {
    Json::obj(vec![
        ("level", l.name().into()),
        ("target", target.into()),
        ("ts", ts.into()),
        ("msg", msg.into()),
    ])
    .to_string_compact()
}

pub fn log(l: Level, target: &str, msg: &str) {
    if enabled(l) {
        let t = start().elapsed().as_secs_f64();
        match format() {
            LogFormat::Text => eprintln!("[{t:9.3} {} {target}] {msg}", l.tag()),
            LogFormat::Json => eprintln!("{}", json_line(l, target, t, msg)),
        }
    }
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_ordering_gates_output() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn log_format_parses() {
        assert_eq!(LogFormat::parse("json"), Some(LogFormat::Json));
        assert_eq!(LogFormat::parse("TEXT"), Some(LogFormat::Text));
        assert_eq!(LogFormat::parse("yaml"), None);
    }

    #[test]
    fn json_line_escapes_quotes_and_backslashes() {
        // Regression for the satellite requirement: a map name like
        // `lam"bda\2` in a log message must survive the JSON writer.
        let line = json_line(Level::Info, r#"sched"uler\x"#, 1.25, r#"map lam"bda\2 resolved"#);
        let v = crate::util::json::parse(&line).expect("log line must be valid JSON");
        assert_eq!(v.get("level").and_then(crate::util::json::Json::as_str), Some("info"));
        assert_eq!(
            v.get("target").and_then(crate::util::json::Json::as_str),
            Some(r#"sched"uler\x"#)
        );
        assert_eq!(v.get("ts").and_then(crate::util::json::Json::as_f64), Some(1.25));
        assert_eq!(
            v.get("msg").and_then(crate::util::json::Json::as_str),
            Some(r#"map lam"bda\2 resolved"#)
        );
        // One object per line: no embedded newlines.
        assert!(!line.contains('\n'));
    }
}
