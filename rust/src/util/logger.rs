//! Leveled stderr logger (no `env_logger` in the vendor set).
//!
//! Level is taken from `SIMPLEXMAP_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`. Timestamps are monotonic seconds since process
//! start — good enough for correlating coordinator events.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static START: OnceLock<Instant> = OnceLock::new();
static LEVEL: OnceLock<AtomicU8> = OnceLock::new();

fn start() -> &'static Instant {
    START.get_or_init(Instant::now)
}

fn level_cell() -> &'static AtomicU8 {
    LEVEL.get_or_init(|| {
        let lvl = std::env::var("SIMPLEXMAP_LOG")
            .ok()
            .and_then(|s| Level::parse(&s))
            .unwrap_or(Level::Info);
        AtomicU8::new(lvl as u8)
    })
}

pub fn set_level(level: Level) {
    level_cell().store(level as u8, Ordering::SeqCst);
}

pub fn level() -> Level {
    match level_cell().load(Ordering::SeqCst) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, target: &str, msg: &str) {
    if enabled(l) {
        let t = start().elapsed().as_secs_f64();
        eprintln!("[{t:9.3} {} {target}] {msg}", l.tag());
    }
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_ordering_gates_output() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
