//! Configuration file support (TOML subset; the vendor set has no
//! `toml` crate). Covers what the launcher/server need: sections,
//! `key = value` with strings, integers, floats and booleans, `#`
//! comments. CLI flags override file values (documented precedence).
//!
//! ```text
//! # simplexmap.toml
//! [coordinator]
//! workers = 8
//! rho2 = 16
//! rho3 = 8
//!
//! [server]
//! addr = "127.0.0.1:7070"
//!
//! [runtime]
//! artifacts = "artifacts"
//! pool = 2
//! ```

use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed config: `section.key → value` (top-level keys use "" section).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<(String, String), Value>,
}

#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(ConfigError {
                    line: i + 1,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or(ConfigError {
                line: i + 1,
                msg: "expected key = value".into(),
            })?;
            let key = k.trim().to_string();
            if key.is_empty() {
                return Err(ConfigError {
                    line: i + 1,
                    msg: "empty key".into(),
                });
            }
            let value = parse_value(v.trim()).ok_or(ConfigError {
                line: i + 1,
                msg: format!("cannot parse value '{}'", v.trim()),
            })?;
            values.insert((section.clone(), key), value);
        }
        Ok(Config { values })
    }

    pub fn load(path: &Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Config::parse(&text).map_err(|e| e.to_string())
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key).and_then(Value::as_int)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key).and_then(Value::as_str)
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key).and_then(Value::as_bool)
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

fn parse_value(s: &str) -> Option<Value> {
    if let Some(rest) = s.strip_prefix('"') {
        return rest.strip_suffix('"').map(|v| Value::Str(v.to_string()));
    }
    match s {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Some(Value::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a comment
top = 1

[coordinator]
workers = 8          # trailing comment
rho2 = 16
enabled = true
scale = 1.5

[server]
addr = "127.0.0.1:7070"
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_int("", "top"), Some(1));
        assert_eq!(c.get_int("coordinator", "workers"), Some(8));
        assert_eq!(c.get_bool("coordinator", "enabled"), Some(true));
        assert_eq!(
            c.get("coordinator", "scale").unwrap().as_float(),
            Some(1.5)
        );
        assert_eq!(c.get_str("server", "addr"), Some("127.0.0.1:7070"));
        assert_eq!(c.len(), 6);
    }

    #[test]
    fn missing_keys_are_none() {
        let c = Config::parse(SAMPLE).unwrap();
        assert!(c.get("server", "port").is_none());
        assert!(c.get("nope", "addr").is_none());
        // Type mismatches are None, not panics.
        assert_eq!(c.get_int("server", "addr"), None);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("no equals sign here").is_err());
        assert!(Config::parse("= valuewithoutkey").is_err());
        assert!(Config::parse("key = @garbage").is_err());
    }

    #[test]
    fn int_vs_float_distinction() {
        let c = Config::parse("a = 3\nb = 3.0").unwrap();
        assert_eq!(c.get("", "a"), Some(&Value::Int(3)));
        assert_eq!(c.get("", "b"), Some(&Value::Float(3.0)));
        // as_float accepts both.
        assert_eq!(c.get("", "a").unwrap().as_float(), Some(3.0));
    }

    #[test]
    fn empty_config_is_valid() {
        let c = Config::parse("  \n# only comments\n").unwrap();
        assert!(c.is_empty());
    }
}
