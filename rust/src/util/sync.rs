//! Poison-recovering lock helpers for the serving paths.
//!
//! `Mutex::lock().unwrap()` turns one panicked holder into a cascade:
//! every later lock attempt panics on the poison flag, so a single bug
//! anywhere under a lock takes the whole serving tier down with it.
//! The serving paths are lint-enforced panic-free (`simplexlint`'s
//! `panic` rule, DESIGN.md §Static Analysis), which makes poisoning
//! doubly wrong there: it cannot happen from our own code, and if a
//! future bug does poison a lock the right degradation is to keep
//! serving with the last-written state — all data guarded by these
//! locks (queue lanes, result rows, reply mailboxes) is valid at every
//! lock release point.
//!
//! These helpers recover the guard from a poisoned lock instead of
//! panicking. They are the blessed replacement everywhere the `panic`
//! rule forbids `.lock().unwrap()`.

use std::sync::{
    Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

/// Lock, recovering from poison (see module docs for why this is the
/// correct degradation on the panic-free serving paths).
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Condvar wait, recovering the guard from poison.
pub fn wait_unpoisoned<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// RwLock read, recovering from poison.
pub fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// RwLock write, recovering from poison.
pub fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u64));
        let m2 = Arc::clone(&m);
        // Poison the mutex by panicking while holding it.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_unpoisoned(&m), 7);
        *lock_unpoisoned(&m) = 9;
        assert_eq!(*lock_unpoisoned(&m), 9);
    }

    #[test]
    fn rwlock_recovers_from_poison() {
        let l = Arc::new(RwLock::new(1u64));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison");
        })
        .join();
        assert_eq!(*read_unpoisoned(&l), 1);
        *write_unpoisoned(&l) = 2;
        assert_eq!(*read_unpoisoned(&l), 2);
    }

    #[test]
    fn wait_returns_after_notify() {
        use std::sync::Condvar;
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let t = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut g = lock_unpoisoned(m);
            while !*g {
                g = wait_unpoisoned(cv, g);
            }
        });
        {
            let (m, cv) = &*pair;
            *lock_unpoisoned(m) = true;
            cv.notify_all();
        }
        t.join().unwrap();
    }
}
