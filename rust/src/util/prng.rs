//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set lacks the `rand` crate, so we carry our own
//! small, well-known generators: SplitMix64 for seeding and
//! xoshiro256++ for the main stream. Both are public-domain algorithms
//! (Blackman & Vigna). Determinism matters here: workload generators and
//! property tests must be reproducible from a single `u64` seed.

/// SplitMix64 — used to expand a single seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed from a single u64 via SplitMix64 (the construction the
    /// xoshiro authors recommend).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range_u64(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range_u64 bound must be > 0");
        // 128-bit multiply keeps the distribution unbiased.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.gen_range_u64((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.gen_f32() * (hi - lo)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box-Muller (used by workload generators).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (from the public-domain
        // reference implementation).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism: same seed, same sequence.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_is_deterministic() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn gen_range_respects_bounds() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = rng.gen_range(3, 17);
            assert!((3..17).contains(&x));
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[rng.gen_range(0, 8)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit: {seen:?}");
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        for _ in 0..10_000 {
            let x = rng.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_f64_mean_is_about_half() {
        let mut rng = Xoshiro256::seed_from_u64(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(17);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_has_roughly_unit_variance() {
        let mut rng = Xoshiro256::seed_from_u64(19);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
