//! Dependency-free infrastructure: PRNG, stats, JSON, CLI parsing,
//! logging, property-test driver and bench harness.
//!
//! These exist because the build environment is fully offline and the
//! vendored crate set does not include `rand`, `serde`, `clap`,
//! `tokio`, `rayon`, `proptest` or `criterion`. Each module is a small,
//! well-tested replacement scoped to exactly what this repo needs (see
//! DESIGN.md §Substitutions).

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod histogram;
pub mod isqrt;
pub mod json;
pub mod logger;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod sync;
