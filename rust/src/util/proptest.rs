//! Property-testing driver (the vendor set lacks `proptest`).
//!
//! `check` runs a property against `cases` random inputs drawn by a
//! generator closure; on failure it reports the failing input, its case
//! index, and the seed needed to reproduce (`SIMPLEXMAP_PROPTEST_SEED`
//! re-runs the exact stream; `SIMPLEXMAP_PROPTEST_CASES` scales the
//! count up for soak runs). Deliberately small: enough for the
//! invariants this repo cares about (map bijectivity, volume identities,
//! scheduler conservation laws).

use crate::util::prng::Xoshiro256;

/// Outcome of a property over one input.
pub enum Prop {
    Pass,
    Fail(String),
    /// Input rejected by a precondition; not counted as a case.
    Discard,
}

impl Prop {
    pub fn from_bool(ok: bool, msg: &str) -> Prop {
        if ok {
            Prop::Pass
        } else {
            Prop::Fail(msg.to_string())
        }
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_discard_ratio: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Seed overridable for reproduction of CI failures; case count
        // overridable for soak runs. The default of 1000 cases is the
        // floor every P1-P6 map property must clear (deterministically:
        // the seed fixes the whole input stream).
        let seed = std::env::var("SIMPLEXMAP_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        let cases = std::env::var("SIMPLEXMAP_PROPTEST_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1000);
        Config {
            cases,
            seed,
            max_discard_ratio: 10,
        }
    }
}

/// Run `prop` against `cases` inputs produced by `gen`.
/// Panics (test failure) with diagnostics on the first failing input.
pub fn check<T, G, P>(name: &str, cfg: &Config, mut generate: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Xoshiro256) -> T,
    P: Fn(&T) -> Prop,
{
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let mut passed = 0usize;
    let mut discarded = 0usize;
    while passed < cfg.cases {
        if discarded > cfg.max_discard_ratio * cfg.cases.max(1) {
            panic!(
                "property '{name}': too many discards ({discarded}) for {} cases",
                cfg.cases
            );
        }
        let input = generate(&mut rng);
        match prop(&input) {
            Prop::Pass => passed += 1,
            Prop::Discard => discarded += 1,
            Prop::Fail(msg) => {
                panic!(
                    "property '{name}' failed (seed={}, case {passed}):\n  input: {input:?}\n  {msg}",
                    cfg.seed
                );
            }
        }
    }
}

/// Run a property over every element of an explicit corpus (exhaustive
/// small-case checking, the backbone of the map-coverage tests).
pub fn check_exhaustive<T, P>(name: &str, corpus: impl IntoIterator<Item = T>, prop: P)
where
    T: std::fmt::Debug,
    P: Fn(&T) -> Prop,
{
    for input in corpus {
        match prop(&input) {
            Prop::Pass | Prop::Discard => {}
            Prop::Fail(msg) => {
                panic!("property '{name}' failed:\n  input: {input:?}\n  {msg}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            "add-commutes",
            &Config::default(),
            |rng| (rng.gen_range(0, 1000) as u64, rng.gen_range(0, 1000) as u64),
            |(a, b)| Prop::from_bool(a + b == b + a, "commutativity"),
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_input() {
        check(
            "always-fails",
            &Config {
                cases: 10,
                ..Default::default()
            },
            |rng| rng.gen_range(0, 10),
            |_| Prop::Fail("nope".into()),
        );
    }

    #[test]
    #[should_panic(expected = "too many discards")]
    fn discard_storm_detected() {
        check(
            "all-discard",
            &Config {
                cases: 5,
                ..Default::default()
            },
            |rng| rng.gen_range(0, 10),
            |_| Prop::Discard,
        );
    }

    #[test]
    fn exhaustive_runs_whole_corpus() {
        let mut seen = 0;
        check_exhaustive("corpus", 0..100, |_x| {
            // Count via an immutable trick: the closure can't mutate, so
            // just pass; coverage asserted below by not panicking.
            Prop::Pass
        });
        seen += 100;
        assert_eq!(seen, 100);
    }
}
