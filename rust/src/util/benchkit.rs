//! Micro/throughput benchmark harness (the vendor set lacks `criterion`).
//!
//! `cargo bench` benches in this repo use `harness = false` and call
//! into this module: warmup, fixed-target-time measurement loops,
//! outlier-robust summaries, and a uniform one-line-per-row report that
//! EXPERIMENTS.md quotes directly. A `black_box` shim prevents the
//! optimizer from deleting measured work.
//!
//! With `SIMPLEXMAP_BENCH_JSON=<path>` set, every measurement also
//! appends one JSON line to `<path>` — CI uploads the accumulated file
//! as the per-PR perf-trajectory artifact (BENCH_pr*.json).

use std::hint;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::{fmt_count, fmt_secs, Summary};

/// Optimizer barrier.
#[inline]
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// One benchmark measurement: samples of seconds-per-iteration.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Items processed per iteration (for throughput reporting).
    pub items_per_iter: u64,
    pub secs_per_iter: Summary,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        self.items_per_iter as f64 / self.secs_per_iter.p50
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  p50 {:>10}  mean {:>10}  ±{:>9}  thrpt {:>12}/s",
            self.name,
            fmt_count(self.items_per_iter as f64),
            fmt_secs(self.secs_per_iter.p50),
            fmt_secs(self.secs_per_iter.mean),
            fmt_secs(self.secs_per_iter.stddev),
            fmt_count(self.throughput()),
        )
    }

    /// One machine-readable JSON line (the perf-trajectory format).
    pub fn json_line(&self) -> String {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("items_per_iter", self.items_per_iter.into()),
            ("p50_secs", self.secs_per_iter.p50.into()),
            ("mean_secs", self.secs_per_iter.mean.into()),
            ("stddev_secs", self.secs_per_iter.stddev.into()),
            ("samples", (self.secs_per_iter.count as u64).into()),
            ("throughput_per_sec", self.throughput().into()),
        ])
        .to_string_compact()
    }

    /// Append the JSON line to `path`. Benches never *fail* on export
    /// problems (a read-only artifact dir must not kill a measurement
    /// run), but they no longer stay silent either: the PR 3 perf
    /// trajectory was lost precisely because an unresolvable
    /// `SIMPLEXMAP_BENCH_JSON` path (missing parent directory on the
    /// runner) dropped every line without a word and CI then uploaded
    /// nothing. Parent directories are created on demand and any
    /// failure is reported once per line on stderr.
    pub fn export_json(&self, path: &str) {
        use std::io::Write as _;
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() && !dir.exists() {
                if let Err(e) = std::fs::create_dir_all(dir) {
                    eprintln!("benchkit: cannot create {} for bench export: {e}", dir.display());
                }
            }
        }
        match std::fs::OpenOptions::new().create(true).append(true).open(path) {
            Ok(mut f) => {
                if let Err(e) = writeln!(f, "{}", self.json_line()) {
                    eprintln!("benchkit: bench export write to {path} failed: {e}");
                }
            }
            Err(e) => eprintln!("benchkit: bench export to {path} failed: {e}"),
        }
    }
}

/// Benchmark runner with a time budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Respect a global knob so `make bench` can run quick or thorough.
        let scale: f64 = std::env::var("SIMPLEXMAP_BENCH_SECS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1.0);
        Bencher {
            warmup: Duration::from_secs_f64(0.2 * scale),
            measure: Duration::from_secs_f64(1.0 * scale),
            min_samples: 10,
            max_samples: 2000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Measure `f` (one logical iteration over `items` items) repeatedly.
    pub fn bench<F: FnMut()>(&mut self, name: &str, items: u64, mut f: F) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Measurement.
        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.measure || samples.len() < self.min_samples)
            && samples.len() < self.max_samples
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            items_per_iter: items,
            secs_per_iter: Summary::from_samples(&samples).expect("at least one sample"),
        };
        println!("{}", result.report_line());
        if let Ok(path) = std::env::var("SIMPLEXMAP_BENCH_JSON") {
            if !path.is_empty() {
                result.export_json(&path);
            }
        }
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a comparison table using the first result as baseline.
    pub fn print_speedups(&self, title: &str) {
        if self.results.is_empty() {
            return;
        }
        println!("\n== {title}: relative throughput (baseline = {}) ==", self.results[0].name);
        let base = self.results[0].throughput();
        for r in &self.results {
            println!("  {:<44} {:>8.3}x", r.name, r.throughput() / base);
        }
    }
}

/// Section header printer for bench binaries.
pub fn section(title: &str) {
    println!("\n==== {title} ====");
}

/// One benchmark row recovered from a perf-trajectory JSONL file.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    pub name: String,
    pub throughput_per_sec: f64,
    pub p50_secs: f64,
}

/// Parse the JSONL perf-trajectory format ([`BenchResult::json_line`]
/// per line). Lines that fail to parse or lack the required fields are
/// skipped — trajectory files accumulate across PRs and tool versions,
/// and one stale line must not invalidate a comparison. When the same
/// name appears multiple times (re-runs append), the *last* line wins.
pub fn parse_trajectory(text: &str) -> Vec<BenchRecord> {
    let mut out: Vec<BenchRecord> = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(j) = crate::util::json::parse(line) else {
            continue;
        };
        let (Some(name), Some(thrpt), Some(p50)) = (
            j.get("name").and_then(Json::as_str),
            j.get("throughput_per_sec").and_then(Json::as_f64),
            j.get("p50_secs").and_then(Json::as_f64),
        ) else {
            continue;
        };
        let rec = BenchRecord {
            name: name.to_string(),
            throughput_per_sec: thrpt,
            p50_secs: p50,
        };
        match out.iter_mut().find(|r| r.name == rec.name) {
            Some(existing) => *existing = rec,
            None => out.push(rec),
        }
    }
    out
}

/// One benchmark compared against its baseline row.
#[derive(Clone, Debug)]
pub struct BenchDelta {
    pub name: String,
    pub baseline_thrpt: f64,
    pub current_thrpt: f64,
}

impl BenchDelta {
    /// Current / baseline throughput (>1 is faster).
    pub fn ratio(&self) -> f64 {
        if self.baseline_thrpt <= 0.0 {
            return f64::INFINITY;
        }
        self.current_thrpt / self.baseline_thrpt
    }

    /// Whether this row regressed below `min_ratio` of the baseline
    /// throughput (e.g. 0.8 = flag anything >20% slower).
    pub fn regressed(&self, min_ratio: f64) -> bool {
        self.ratio() < min_ratio
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} baseline {:>12}/s  current {:>12}/s  {:>7.3}x",
            self.name,
            fmt_count(self.baseline_thrpt),
            fmt_count(self.current_thrpt),
            self.ratio(),
        )
    }
}

/// Multi-snapshot perf trajectory: one row per benchmark name showing
/// first/last throughput and their ratio across labelled JSONL
/// snapshots (oldest first — the `obs bench-trajectory` CLI passes
/// `BENCH_*.json` files sorted by name). Pure on `(label, content)`
/// pairs so it is testable without a filesystem; an empty input
/// answers with guidance instead of an empty table.
pub fn trajectory_report(snapshots: &[(String, String)]) -> String {
    if snapshots.is_empty() {
        return "no BENCH_*.json snapshots found — run `make bench-export` (or CI's bench \
                job) to produce one\n"
            .to_string();
    }
    let parsed: Vec<(&str, Vec<BenchRecord>)> = snapshots
        .iter()
        .map(|(label, text)| (label.as_str(), parse_trajectory(text)))
        .collect();
    let mut out = format!("perf trajectory over {} snapshot(s):\n", parsed.len());
    for (label, recs) in &parsed {
        out.push_str(&format!("  {label}: {} row(s)\n", recs.len()));
    }
    // Benchmark names in first-seen order across snapshots.
    let mut names: Vec<&str> = Vec::new();
    for (_, recs) in &parsed {
        for r in recs {
            if !names.iter().any(|n| *n == r.name) {
                names.push(&r.name);
            }
        }
    }
    out.push('\n');
    for name in names {
        let series: Vec<f64> = parsed
            .iter()
            .filter_map(|(_, recs)| {
                recs.iter()
                    .find(|r| r.name == name)
                    .map(|r| r.throughput_per_sec)
            })
            .collect();
        let (first, last) = (series[0], *series.last().unwrap());
        let ratio = if first > 0.0 { last / first } else { f64::NAN };
        out.push_str(&format!(
            "{name:<44} first {:>12}/s  last {:>12}/s  {ratio:>7.3}x over {} snapshot(s)\n",
            fmt_count(first),
            fmt_count(last),
            series.len(),
        ));
    }
    out
}

/// Join two trajectory files by benchmark name (rows present in both).
/// Names only in the baseline (retired benches) or only in the current
/// run (new benches) have no meaningful ratio and are omitted.
pub fn compare_trajectories(baseline: &str, current: &str) -> Vec<BenchDelta> {
    let base = parse_trajectory(baseline);
    parse_trajectory(current)
        .into_iter()
        .filter_map(|cur| {
            base.iter().find(|b| b.name == cur.name).map(|b| BenchDelta {
                name: cur.name.clone(),
                baseline_thrpt: b.throughput_per_sec,
                current_thrpt: cur.throughput_per_sec,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_samples: 3,
            max_samples: 50,
            results: Vec::new(),
        }
    }

    #[test]
    fn bench_produces_samples_and_throughput() {
        let mut b = quick();
        let r = b.bench("noop-loop", 1000, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.secs_per_iter.count >= 3);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn results_accumulate_in_order() {
        let mut b = quick();
        b.bench("a", 1, || {});
        b.bench("b", 1, || {});
        let names: Vec<_> = b.results().iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b"]);
    }

    #[test]
    fn report_line_contains_name_and_throughput() {
        let mut b = quick();
        let r = b.bench("fmt-check", 100, || {});
        let line = r.report_line();
        assert!(line.contains("fmt-check"));
        assert!(line.contains("/s"));
    }

    #[test]
    fn json_line_parses_and_carries_the_fields() {
        let mut b = quick();
        let r = b.bench("json-check", 100, || {}).clone();
        let j = crate::util::json::parse(&r.json_line()).expect("valid json");
        assert_eq!(j.get("name").unwrap().as_str(), Some("json-check"));
        assert_eq!(j.get("items_per_iter").unwrap().as_u64(), Some(100));
        assert!(j.get("throughput_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("samples").unwrap().as_u64().unwrap() >= 3);
    }

    #[test]
    fn export_json_appends_one_line_per_result() {
        let mut b = quick();
        let r = b.bench("export-check", 10, || {}).clone();
        let path = std::env::temp_dir().join(format!(
            "simplexmap_benchkit_test_{}.jsonl",
            std::process::id()
        ));
        let path_str = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        r.export_json(&path_str);
        r.export_json(&path_str);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            assert!(crate::util::json::parse(line).is_ok(), "{line}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn export_json_creates_missing_parent_dirs() {
        // The PR 3 trajectory-loss regression: a path whose parent does
        // not exist must still land on disk, not vanish silently.
        let mut b = quick();
        let r = b.bench("mkdir-check", 10, || {}).clone();
        let dir = std::env::temp_dir().join(format!(
            "simplexmap_benchkit_nested_{}/deeper",
            std::process::id()
        ));
        let path = dir.join("BENCH_test.json");
        let _ = std::fs::remove_dir_all(dir.parent().unwrap());
        let path_str = path.to_str().unwrap().to_string();
        r.export_json(&path_str);
        let text = std::fs::read_to_string(&path).expect("export must land");
        assert_eq!(text.lines().count(), 1);
        assert!(crate::util::json::parse(text.lines().next().unwrap()).is_ok());
        let _ = std::fs::remove_dir_all(dir.parent().unwrap());
    }

    fn line(name: &str, thrpt: f64, p50: f64) -> String {
        Json::obj(vec![
            ("name", name.into()),
            ("throughput_per_sec", thrpt.into()),
            ("p50_secs", p50.into()),
        ])
        .to_string_compact()
    }

    #[test]
    fn parse_trajectory_skips_garbage_and_keeps_the_last_rerun() {
        let text = format!(
            "{}\nnot json at all\n{{\"name\":\"missing-fields\"}}\n\n{}\n{}\n",
            line("a", 100.0, 0.01),
            line("b", 50.0, 0.02),
            line("a", 200.0, 0.005), // re-run: supersedes the first "a"
        );
        let recs = parse_trajectory(&text);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].name, "a");
        assert_eq!(recs[0].throughput_per_sec, 200.0);
        assert_eq!(recs[0].p50_secs, 0.005);
        assert_eq!(recs[1].name, "b");
    }

    #[test]
    fn compare_joins_by_name_and_flags_regressions() {
        let baseline = format!("{}\n{}\n{}", line("x", 100.0, 0.1), line("y", 10.0, 1.0), line("retired", 5.0, 2.0));
        let current = format!("{}\n{}\n{}", line("x", 90.0, 0.111), line("y", 30.0, 0.33), line("brand-new", 7.0, 0.5));
        let deltas = compare_trajectories(&baseline, &current);
        // "retired" and "brand-new" have no counterpart — omitted.
        assert_eq!(deltas.len(), 2);
        let x = &deltas[0];
        assert_eq!(x.name, "x");
        assert!((x.ratio() - 0.9).abs() < 1e-12);
        assert!(x.regressed(0.95));
        assert!(!x.regressed(0.8));
        let y = &deltas[1];
        assert!((y.ratio() - 3.0).abs() < 1e-12);
        assert!(!y.regressed(0.95));
        assert!(x.report_line().contains('x'));
    }

    #[test]
    fn bench_json_env_exports_a_parseable_file() {
        // Satellite regression for the offline `make bench-export`
        // path: pointing SIMPLEXMAP_BENCH_JSON at a path must leave a
        // parseable JSONL file behind. Other tests may bench while the
        // var is set (lib tests share a process), so the assertion is
        // containment, not an exact line count.
        let path = std::env::temp_dir().join(format!(
            "simplexmap_bench_export_env_{}.jsonl",
            std::process::id()
        ));
        let path_str = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        std::env::set_var("SIMPLEXMAP_BENCH_JSON", &path_str);
        quick().bench("env-export-check", 10, || {});
        std::env::remove_var("SIMPLEXMAP_BENCH_JSON");
        let text = std::fs::read_to_string(&path).expect("bench export must land");
        let mut seen = false;
        for line in text.lines() {
            let j = crate::util::json::parse(line).expect("every line parses");
            if j.get("name").and_then(Json::as_str) == Some("env-export-check") {
                assert!(j.get("throughput_per_sec").unwrap().as_f64().is_some());
                seen = true;
            }
        }
        assert!(seen, "exported line missing from {text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn trajectory_report_tracks_first_to_last_throughput() {
        let snaps = vec![
            (
                "BENCH_pr1.json".to_string(),
                format!("{}\n{}", line("a", 100.0, 0.01), line("b", 10.0, 0.1)),
            ),
            ("BENCH_pr2.json".to_string(), line("a", 150.0, 0.0066)),
            (
                "BENCH_pr3.json".to_string(),
                format!("{}\n{}", line("a", 200.0, 0.005), line("b", 5.0, 0.2)),
            ),
        ];
        let report = trajectory_report(&snaps);
        assert!(report.contains("3 snapshot(s)"), "{report}");
        assert!(report.contains("BENCH_pr2.json"), "{report}");
        // "a" doubled (100 → 200), "b" halved (10 → 5).
        let a_row = report.lines().find(|l| l.starts_with('a')).unwrap();
        assert!(a_row.contains("2.000x"), "{a_row}");
        assert!(a_row.contains("3 snapshot(s)"), "{a_row}");
        let b_row = report.lines().find(|l| l.starts_with('b')).unwrap();
        assert!(b_row.contains("0.500x"), "{b_row}");
        assert!(b_row.contains("2 snapshot(s)"), "{b_row}");
    }

    #[test]
    fn trajectory_report_on_no_snapshots_gives_guidance() {
        let report = trajectory_report(&[]);
        assert!(report.contains("no BENCH_*.json"), "{report}");
        assert!(report.contains("make bench-export"), "{report}");
    }

    #[test]
    fn compare_tolerates_a_zero_throughput_baseline() {
        let deltas =
            compare_trajectories(&line("z", 0.0, 0.0), &line("z", 10.0, 0.1));
        assert_eq!(deltas.len(), 1);
        assert!(deltas[0].ratio().is_infinite());
        assert!(!deltas[0].regressed(0.8));
    }
}
