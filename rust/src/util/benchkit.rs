//! Micro/throughput benchmark harness (the vendor set lacks `criterion`).
//!
//! `cargo bench` benches in this repo use `harness = false` and call
//! into this module: warmup, fixed-target-time measurement loops,
//! outlier-robust summaries, and a uniform one-line-per-row report that
//! EXPERIMENTS.md quotes directly. A `black_box` shim prevents the
//! optimizer from deleting measured work.

use std::hint;
use std::time::{Duration, Instant};

use crate::util::stats::{fmt_count, fmt_secs, Summary};

/// Optimizer barrier.
#[inline]
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// One benchmark measurement: samples of seconds-per-iteration.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Items processed per iteration (for throughput reporting).
    pub items_per_iter: u64,
    pub secs_per_iter: Summary,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        self.items_per_iter as f64 / self.secs_per_iter.p50
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  p50 {:>10}  mean {:>10}  ±{:>9}  thrpt {:>12}/s",
            self.name,
            fmt_count(self.items_per_iter as f64),
            fmt_secs(self.secs_per_iter.p50),
            fmt_secs(self.secs_per_iter.mean),
            fmt_secs(self.secs_per_iter.stddev),
            fmt_count(self.throughput()),
        )
    }
}

/// Benchmark runner with a time budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Respect a global knob so `make bench` can run quick or thorough.
        let scale: f64 = std::env::var("SIMPLEXMAP_BENCH_SECS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1.0);
        Bencher {
            warmup: Duration::from_secs_f64(0.2 * scale),
            measure: Duration::from_secs_f64(1.0 * scale),
            min_samples: 10,
            max_samples: 2000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Measure `f` (one logical iteration over `items` items) repeatedly.
    pub fn bench<F: FnMut()>(&mut self, name: &str, items: u64, mut f: F) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Measurement.
        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.measure || samples.len() < self.min_samples)
            && samples.len() < self.max_samples
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            items_per_iter: items,
            secs_per_iter: Summary::from_samples(&samples).expect("at least one sample"),
        };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a comparison table using the first result as baseline.
    pub fn print_speedups(&self, title: &str) {
        if self.results.is_empty() {
            return;
        }
        println!("\n== {title}: relative throughput (baseline = {}) ==", self.results[0].name);
        let base = self.results[0].throughput();
        for r in &self.results {
            println!("  {:<44} {:>8.3}x", r.name, r.throughput() / base);
        }
    }
}

/// Section header printer for bench binaries.
pub fn section(title: &str) {
    println!("\n==== {title} ====");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_samples: 3,
            max_samples: 50,
            results: Vec::new(),
        }
    }

    #[test]
    fn bench_produces_samples_and_throughput() {
        let mut b = quick();
        let r = b.bench("noop-loop", 1000, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.secs_per_iter.count >= 3);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn results_accumulate_in_order() {
        let mut b = quick();
        b.bench("a", 1, || {});
        b.bench("b", 1, || {});
        let names: Vec<_> = b.results().iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b"]);
    }

    #[test]
    fn report_line_contains_name_and_throughput() {
        let mut b = quick();
        let r = b.bench("fmt-check", 100, || {});
        let line = r.report_line();
        assert!(line.contains("fmt-check"));
        assert!(line.contains("/s"));
    }
}
