//! Exact integer roots by Newton descent — the float-free inverses the
//! sqrt-based maps need (ISSUE 5 / the follow-up paper's precision fix).
//!
//! The 2016 paper's related work computes map inverses with `f64::sqrt`
//! / `f64::cbrt` and repairs the rounding with ±1 fix-ups. That repair
//! is *not* sufficient for thread-space maps at large n (the Avril f64
//! discriminant loses to catastrophic cancellation around n ≈ 2^28 —
//! see `maps::avril`), and it silently couples every map's correctness
//! to IEEE details. This module provides the exact alternative used by
//! λ_S, ENUM2/ENUM3 and the Avril block path:
//!
//! - [`isqrt_u128`] / [`isqrt_u64`] — floor square root. Newton from a
//!   power-of-two seed `≥ √x` descends monotonically and stops exactly
//!   at `⌊√x⌋` (the classic integer-Newton invariant: while `r > ⌊√x⌋`
//!   the iterate strictly decreases; the first non-decreasing step is
//!   the answer).
//! - [`icbrt_u128`] — floor cube root: same descent with a bounded
//!   (≤ 2 step) fix-up walk, because the floored cube iteration may
//!   land one below the true floor.
//! - [`triangular_root`] / [`tetrahedral_root`] — the simplex
//!   enumeration inverses built on them, exact for every `u64` input:
//!   `8k+1 ∈ [(2r+1)², (2r+3)²)` ⇒ `⌊(isqrt(8k+1)−1)/2⌋ = r` with no
//!   fix-up at all.
//!
//! Cross-verified against `math.isqrt` and brute force by the PR's
//! python port (exhaustive to 10^5 plus the 2^24..2^128 boundary set).

/// Floor square root of a `u64` (exact for every input).
#[inline]
pub fn isqrt_u64(x: u64) -> u64 {
    // lint: allow(cast, sqrt of a u64 is below 2^32)
    isqrt_u128(x as u128) as u64
}

/// Floor square root of a `u128` by integer Newton descent.
#[inline]
pub fn isqrt_u128(x: u128) -> u128 {
    if x < 2 {
        return x;
    }
    // Seed 2^⌈bits/2⌉ ≥ √x: x < 2^bits ⇒ √x < 2^(bits/2) ≤ seed.
    let bits = 128 - x.leading_zeros();
    let mut r = 1u128 << bits.div_ceil(2);
    loop {
        let next = (r + x / r) / 2;
        if next >= r {
            return r;
        }
        r = next;
    }
}

/// Floor cube root of a `u128` by Newton descent plus a bounded walk.
#[inline]
pub fn icbrt_u128(x: u128) -> u128 {
    if x == 0 {
        return 0;
    }
    if x < 8 {
        return 1;
    }
    let bits = 128 - x.leading_zeros();
    let mut r = 1u128 << bits.div_ceil(3);
    loop {
        let next = (2 * r + x / (r * r)) / 3;
        if next >= r {
            break;
        }
        r = next;
    }
    // The floored iteration can stop a step off either way; walk to
    // exact (never more than a couple of steps, python-cross-checked).
    // Cubes are probed with checked arithmetic: near x = u128::MAX the
    // candidate's cube itself can overflow, and an overflowing cube is
    // by definition > x.
    let cube = |v: u128| v.checked_mul(v).and_then(|sq| sq.checked_mul(v));
    while cube(r).is_none_or(|c| c > x) {
        r -= 1;
    }
    while cube(r + 1).is_some_and(|c| c <= x) {
        r += 1;
    }
    r
}

/// Largest `r` with `r(r+1)/2 ≤ k` — the inverse triangular number,
/// exact for every `u64` input with no floating point anywhere:
/// `8k+1 ∈ [(2r+1)², (2r+3)²)` makes `isqrt(8k+1) ∈ {2r+1, 2r+2}`,
/// and `(s−1)/2` floors both to `r`.
#[inline]
pub fn triangular_root(k: u64) -> u64 {
    // lint: allow(cast, isqrt of 8k+1 < 2^34; halved it fits u64)
    ((isqrt_u128(8 * k as u128 + 1) - 1) / 2) as u64
}

/// `c(c+1)(c+2)/6` in u128 (no overflow for any u64-rooted argument).
#[inline]
pub fn tetrahedron(c: u64) -> u128 {
    let c = c as u128;
    c * (c + 1) * (c + 2) / 6
}

/// Largest `c` with `c(c+1)(c+2)/6 ≤ k` — the inverse tetrahedral
/// number: integer cube-root seed, then a bounded walk (the seed is
/// within O(1) of the answer because `c³ ≤ c(c+1)(c+2) < (c+2)³`).
#[inline]
pub fn tetrahedral_root(k: u64) -> u64 {
    // lint: allow(cast, cbrt of 6k < 2^23 for k in u64)
    let mut c = icbrt_u128(6 * k as u128) as u64;
    while c > 0 && tetrahedron(c) > k as u128 {
        c -= 1;
    }
    while tetrahedron(c + 1) <= k as u128 {
        c += 1;
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isqrt_exhaustive_small() {
        let mut r = 0u64;
        for x in 0..100_000u64 {
            if (r + 1) * (r + 1) <= x {
                r += 1;
            }
            assert_eq!(isqrt_u64(x), r, "x={x}");
        }
    }

    #[test]
    fn isqrt_boundary_squares_at_large_magnitudes() {
        // Around perfect squares at every magnitude the maps reach —
        // the crossing where a rounded float sqrt flips the floor.
        for s in [1u128 << 12, 1 << 24, 1 << 31, 1 << 32, 1 << 52, (1 << 63) - 25] {
            assert_eq!(isqrt_u128(s * s), s);
            assert_eq!(isqrt_u128(s * s - 1), s - 1);
            assert_eq!(isqrt_u128(s * s + 1), s);
            assert_eq!(isqrt_u128(s * s + 2 * s), s);
            assert_eq!(isqrt_u128(s * s + 2 * s + 1), s + 1);
        }
        assert_eq!(isqrt_u128(u128::MAX), (1 << 64) - 1);
        assert_eq!(isqrt_u64(u64::MAX), (1 << 32) - 1);
    }

    #[test]
    fn isqrt_trivial_inputs() {
        assert_eq!(isqrt_u128(0), 0);
        assert_eq!(isqrt_u128(1), 1);
        assert_eq!(isqrt_u128(2), 1);
        assert_eq!(isqrt_u128(3), 1);
        assert_eq!(isqrt_u128(4), 2);
    }

    #[test]
    fn icbrt_exhaustive_small() {
        for x in 0..20_000u128 {
            let c = icbrt_u128(x);
            assert!(c * c * c <= x, "x={x} c={c}");
            assert!((c + 1) * (c + 1) * (c + 1) > x, "x={x} c={c}");
        }
    }

    #[test]
    fn icbrt_boundary_cubes_at_large_magnitudes() {
        for c in [1u128 << 8, 1 << 21, 1 << 31, 1 << 40, 1 << 42] {
            assert_eq!(icbrt_u128(c * c * c), c);
            assert_eq!(icbrt_u128(c * c * c - 1), c - 1);
            assert_eq!(icbrt_u128(c * c * c + 1), c);
        }
        // The overflow guard: near u128::MAX the candidate cubes do
        // not fit u128 — the checked probe must treat them as > x.
        let c = icbrt_u128(u128::MAX);
        assert_eq!(c, 6_981_463_658_331);
        assert!(c * c * c <= u128::MAX - 1);
        assert_eq!(icbrt_u128(c * c * c), c);
        assert_eq!(icbrt_u128(c * c * c - 1), c - 1);
    }

    #[test]
    fn triangular_root_exhaustive_small() {
        for r in 0..600u64 {
            for k in r * (r + 1) / 2..(r + 1) * (r + 2) / 2 {
                assert_eq!(triangular_root(k), r, "k={k}");
            }
        }
    }

    #[test]
    fn triangular_root_exact_where_naive_f64_flips() {
        // The naive float inverse ⌊(√(8k+1)−1)/2⌋ evaluated in f64
        // rounds UP across the block boundary at k = T(2^27) − 1
        // (python-verified: it returns 2^27 there, one row high). The
        // integer-Newton root stays exact at that k and at every
        // boundary in the 2^24..2^32 row range the maps address.
        let flip_r = 1u64 << 27;
        let flip_k = flip_r * (flip_r + 1) / 2 - 1; // 9007199321849855
        assert_eq!(flip_k, 9_007_199_321_849_855);
        assert_eq!(triangular_root(flip_k), flip_r - 1, "the f64 flip point");
        assert_eq!(triangular_root(flip_k + 1), flip_r);
        for r in [1u64 << 24, 1 << 25, (1 << 31) - 1, (1 << 32) - 1, 3_000_000_000] {
            let k = r * (r + 1) / 2;
            assert_eq!(triangular_root(k - 1), r - 1, "r={r}");
            assert_eq!(triangular_root(k), r, "r={r}");
            assert_eq!(triangular_root(k + r), r, "r={r}");
            assert_eq!(triangular_root(k + r + 1), r + 1, "r={r}");
        }
    }

    #[test]
    fn triangular_root_at_the_u64_edge() {
        // Largest r with T(r) ≤ u64::MAX. T(r) fits u64 but the
        // intermediate r(r+1) does not — compute it in u128.
        let r = 6_074_000_999u64;
        let k = (r as u128 * (r as u128 + 1) / 2) as u64;
        assert_eq!(triangular_root(k), r);
        assert_eq!(triangular_root(k - 1), r - 1);
        assert_eq!(triangular_root(u64::MAX), r);
    }

    #[test]
    fn tetrahedral_root_exhaustive_small() {
        for c in 0..200u64 {
            let lo = tetrahedron(c) as u64;
            let hi = tetrahedron(c + 1) as u64;
            for k in lo..hi {
                assert_eq!(tetrahedral_root(k), c, "k={k}");
            }
        }
    }

    #[test]
    fn tetrahedral_root_boundaries_at_large_magnitudes() {
        for c in [2_000_000u64, 1 << 21, 1 << 22, 4_800_000] {
            assert_eq!(tetrahedral_root(tetrahedron(c) as u64), c);
            assert_eq!(tetrahedral_root(tetrahedron(c) as u64 - 1), c - 1);
            assert_eq!(tetrahedral_root((tetrahedron(c + 1) - 1) as u64), c);
        }
    }

    #[test]
    fn tetrahedron_matches_the_volume_closed_form() {
        // Two spellings of c(c+1)(c+2)/6 exist (this leaf-infra copy
        // and simplex::volume::tetrahedral's binomial form, which util
        // cannot import outside tests) — pin them together.
        for n in [0u64, 1, 2, 5, 100, 4096, 4_800_000] {
            assert_eq!(tetrahedron(n), crate::simplex::volume::tetrahedral(n), "n={n}");
        }
    }

    #[test]
    fn roots_agree_with_the_enumeration_module() {
        // The shared helpers back maps::enumeration — same results.
        for k in (0..5_000_000u64).step_by(9973) {
            assert_eq!(
                triangular_root(k),
                crate::maps::enumeration::triangular_root(k)
            );
            assert_eq!(
                tetrahedral_root(k),
                crate::maps::enumeration::tetrahedral_root(k)
            );
        }
    }
}
