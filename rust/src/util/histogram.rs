//! Lock-free log-bucketed latency histogram.
//!
//! The value domain is `u64` nanoseconds. Buckets 0..32 are exact
//! (1 ns each); every octave above that splits into 16 sub-buckets
//! (`SUB_BITS = 4`), so the relative bucket width is at most 1/16
//! (≤ 6.25%) everywhere — quantile estimates carry at most that
//! relative error, and in practice much less because the walk
//! interpolates linearly inside the landing bucket. The full `u64`
//! range fits in [`N_BUCKETS`] = 976 buckets (~8 KB of atomics).
//!
//! Recording is a handful of relaxed atomic adds — no locks, safe from
//! any thread, mergeable across histograms ([`Histogram::merge`]).
//! Reads ([`Histogram::quantile_secs`], [`Histogram::to_json`]) snapshot
//! the bucket array non-atomically: concurrent recording can tear a
//! snapshot by a few samples, which is fine for metrics-grade
//! reporting (quantiles within one snapshot stay mutually consistent
//! because they share one snapshot).
//!
//! Bucket arithmetic (for `v ≥ 32`, with `exp = floor(log2 v)`):
//!
//! ```text
//! index(v)  = (exp - 3)·16 + ((v >> (exp - 4)) & 15)
//! bounds(i) = low = (16 + i%16) << (i/16 - 1),  width = 1 << (i/16 - 1)
//! ```
//!
//! which is continuous with the exact region (`index(31) = 31`,
//! `index(32) = 32`) and monotone in `v`.
//!
//! Memory-ordering policy: bucket counters and the min/max cells are
//! statistically merged by readers that tolerate torn snapshots (a
//! quantile over a live histogram is approximate by nature) — every
//! access is Relaxed.
// lint: atomics(Relaxed)

use std::sync::atomic::{AtomicU64, Ordering};

use super::json::Json;

/// Sub-bucket resolution: each power-of-two octave splits into
/// `2^SUB_BITS` buckets.
pub const SUB_BITS: u32 = 4;
const SUBS: usize = 1 << SUB_BITS;

/// Total bucket count covering the full `u64` ns range:
/// `index(u64::MAX) = (63 - 3)·16 + 15 = 975`.
pub const N_BUCKETS: usize = 976;

/// A mergeable, lock-free latency histogram over nanosecond values.
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    min_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
            max_ns: AtomicU64::new(0),
        }
    }

    /// Bucket index for a nanosecond value. Monotone nondecreasing,
    /// exact below 32, gapless (consecutive values differ by ≤ 1
    /// bucket), and `< N_BUCKETS` for every `u64`.
    pub fn bucket_index(ns: u64) -> usize {
        if ns < 2 * SUBS as u64 {
            return ns as usize;
        }
        let exp = 63 - ns.leading_zeros();
        let sub = ((ns >> (exp - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
        (exp - SUB_BITS + 1) as usize * SUBS + sub
    }

    /// Half-open value range `[low, high)` covered by a bucket, in
    /// `u128` because the top bucket's bound is exactly `2^64`.
    pub fn bucket_bounds(idx: usize) -> (u128, u128) {
        if idx < 2 * SUBS {
            return (idx as u128, idx as u128 + 1);
        }
        let exp = (idx / SUBS) as u32 + SUB_BITS - 1;
        let sub = (idx % SUBS) as u128;
        let low = (SUBS as u128 + sub) << (exp - SUB_BITS);
        (low, low + (1u128 << (exp - SUB_BITS)))
    }

    /// Record a duration in seconds. Negative and NaN inputs land in
    /// bucket 0 (the float→int cast saturates); values beyond the u64
    /// ns range clamp to the top bucket.
    pub fn record_secs(&self, secs: f64) {
        self.record_ns((secs * 1e9) as u64);
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[Self::bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_secs(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn mean_secs(&self) -> Option<f64> {
        let n = self.count();
        (n > 0).then(|| self.sum_secs() / n as f64)
    }

    pub fn min_secs(&self) -> Option<f64> {
        (self.count() > 0).then(|| self.min_ns.load(Ordering::Relaxed) as f64 / 1e9)
    }

    pub fn max_secs(&self) -> Option<f64> {
        (self.count() > 0).then(|| self.max_ns.load(Ordering::Relaxed) as f64 / 1e9)
    }

    /// Fold another histogram's tallies into this one.
    pub fn merge(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let c = theirs.load(Ordering::Relaxed);
            if c > 0 {
                mine.fetch_add(c, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_ns
            .fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        // A fresh histogram's min is u64::MAX and max is 0 — both
        // merge as no-ops, so empty sources need no special case.
        self.min_ns
            .fetch_min(other.min_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_ns
            .fetch_max(other.max_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    fn snapshot(&self) -> (Vec<u64>, u64) {
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let n = counts.iter().sum();
        (counts, n)
    }

    fn quantile_from(counts: &[u64], n: u64, q: f64) -> f64 {
        let t = q.clamp(0.0, 1.0) * (n - 1) as f64;
        let mut cum = 0u64;
        for (idx, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 > t {
                let (lo, hi) = Self::bucket_bounds(idx);
                // Midpoint-of-rank interpolation inside the bucket:
                // a single-sample bucket reports its center.
                let pos = ((t - cum as f64 + 0.5) / c as f64).clamp(0.0, 1.0);
                return (lo as f64 + pos * (hi - lo) as f64) / 1e9;
            }
            cum += c;
        }
        // Unreachable when n came from the same snapshot; a defensive
        // answer for a zero snapshot.
        0.0
    }

    /// Estimated quantile in seconds (`q` in [0, 1]); `None` when
    /// empty. Error is bounded by the ≤ 1/16 relative bucket width.
    pub fn quantile_secs(&self, q: f64) -> Option<f64> {
        let (counts, n) = self.snapshot();
        (n > 0).then(|| Self::quantile_from(&counts, n, q))
    }

    /// `[p50, p90, p99, p99.9]` in seconds from a single snapshot (so
    /// the four are mutually monotone even under concurrent writes);
    /// `None` when empty.
    pub fn summary_quantiles_secs(&self) -> Option<[f64; 4]> {
        let (counts, n) = self.snapshot();
        if n == 0 {
            return None;
        }
        Some([0.5, 0.9, 0.99, 0.999].map(|q| Self::quantile_from(&counts, n, q)))
    }

    /// Metrics-exposition JSON: count plus mean/quantiles/max in
    /// seconds; the latter are `null` when the histogram is empty.
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::from).unwrap_or(Json::Null);
        let qs = self.summary_quantiles_secs();
        let at = |i: usize| opt(qs.map(|q| q[i]));
        Json::obj(vec![
            ("count", self.count().into()),
            ("mean_secs", opt(self.mean_secs())),
            ("p50_secs", at(0)),
            ("p90_secs", at(1)),
            ("p99_secs", at(2)),
            ("p999_secs", at(3)),
            ("min_secs", opt(self.min_secs())),
            ("max_secs", opt(self.max_secs())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;
    use crate::util::stats::percentile_sorted;

    #[test]
    fn bucket_index_is_exact_below_32() {
        for v in 0..32u64 {
            assert_eq!(Histogram::bucket_index(v), v as usize);
            let (lo, hi) = Histogram::bucket_bounds(v as usize);
            assert_eq!((lo, hi), (v as u128, v as u128 + 1));
        }
    }

    #[test]
    fn bucket_index_is_monotone_gapless_and_contained() {
        let mut prev = 0usize;
        for v in 0..200_000u64 {
            let idx = Histogram::bucket_index(v);
            assert!(idx >= prev, "index regressed at {v}");
            assert!(idx - prev <= 1, "index gap at {v}");
            let (lo, hi) = Histogram::bucket_bounds(idx);
            assert!(lo <= v as u128 && (v as u128) < hi, "{v} not in [{lo},{hi})");
            prev = idx;
        }
        // Spot-check the extremes and the octave seams.
        assert_eq!(Histogram::bucket_index(31), 31);
        assert_eq!(Histogram::bucket_index(32), 32);
        assert_eq!(Histogram::bucket_index(u64::MAX), N_BUCKETS - 1);
        let (lo, hi) = Histogram::bucket_bounds(N_BUCKETS - 1);
        assert!(lo <= u64::MAX as u128 && (u64::MAX as u128) < hi);
        assert_eq!(hi, 1u128 << 64);
    }

    #[test]
    fn bucket_relative_width_is_bounded() {
        for idx in 32..N_BUCKETS {
            let (lo, hi) = Histogram::bucket_bounds(idx);
            assert!(
                (hi - lo) * 16 <= lo,
                "bucket {idx} wider than 1/16: [{lo},{hi})"
            );
        }
    }

    #[test]
    fn random_values_land_in_their_bucket() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        for _ in 0..100_000 {
            let v = rng.next_u64() >> (rng.next_u64() % 60);
            let (lo, hi) = Histogram::bucket_bounds(Histogram::bucket_index(v));
            assert!(lo <= v as u128 && (v as u128) < hi);
        }
    }

    #[test]
    fn count_sum_min_max_track_samples() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        for ns in [100u64, 5_000, 42] {
            h.record_ns(ns);
        }
        assert_eq!(h.count(), 3);
        assert!((h.sum_secs() - 5_142e-9).abs() < 1e-15);
        assert_eq!(h.min_secs(), Some(42e-9));
        assert_eq!(h.max_secs(), Some(5_000e-9));
        assert!((h.mean_secs().unwrap() - 1_714e-9).abs() < 1e-12);
    }

    #[test]
    fn record_secs_saturates_bad_inputs() {
        let h = Histogram::new();
        h.record_secs(-1.0); // negative → 0 ns
        h.record_secs(f64::NAN); // NaN → 0 ns
        h.record_secs(1e300); // overflow → top bucket
        assert_eq!(h.count(), 3);
        assert_eq!(h.min_secs(), Some(0.0));
        assert_eq!(h.max_secs(), Some(u64::MAX as f64 / 1e9));
    }

    #[test]
    fn quantiles_match_sorted_oracle_within_bucket_error() {
        // Log-uniform-ish samples spanning 100 ns .. 1 s: the regime
        // where log bucketing must hold its ≤ 1/16 relative error.
        let mut rng = Xoshiro256::seed_from_u64(7);
        let h = Histogram::new();
        let mut samples = Vec::new();
        for _ in 0..20_000 {
            let u = rng.gen_f64();
            let ns = (100.0f64 * (1e9f64 / 100.0).powf(u)) as u64;
            h.record_ns(ns);
            samples.push(ns as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let est = h.quantile_secs(q).unwrap() * 1e9;
            let oracle = percentile_sorted(&samples, q);
            let rel = (est - oracle).abs() / oracle.max(1.0);
            assert!(rel < 0.07, "q={q}: est={est} oracle={oracle} rel={rel}");
        }
    }

    #[test]
    fn summary_quantiles_are_monotone() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let h = Histogram::new();
        for _ in 0..5_000 {
            h.record_ns(rng.next_u64() % 10_000_000);
        }
        let [p50, p90, p99, p999] = h.summary_quantiles_secs().unwrap();
        assert!(p50 <= p90 && p90 <= p99 && p99 <= p999);
    }

    #[test]
    fn merge_matches_recording_everything_in_one() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let (a, b, all) = (Histogram::new(), Histogram::new(), Histogram::new());
        for i in 0..4_000u64 {
            let ns = rng.next_u64() % 1_000_000;
            let target = if i % 2 == 0 { &a } else { &b };
            target.record_ns(ns);
            all.record_ns(ns);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.sum_secs(), all.sum_secs());
        assert_eq!(a.min_secs(), all.min_secs());
        assert_eq!(a.max_secs(), all.max_secs());
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert_eq!(a.quantile_secs(q), all.quantile_secs(q));
        }
    }

    #[test]
    fn merging_an_empty_histogram_changes_nothing() {
        let (a, empty) = (Histogram::new(), Histogram::new());
        a.record_ns(1234);
        a.merge(&empty);
        assert_eq!(a.count(), 1);
        assert_eq!(a.min_secs(), Some(1234e-9));
        assert_eq!(a.max_secs(), Some(1234e-9));
    }

    #[test]
    fn empty_histogram_reports_none_and_null_json() {
        let h = Histogram::new();
        assert_eq!(h.quantile_secs(0.5), None);
        assert_eq!(h.summary_quantiles_secs(), None);
        assert_eq!(h.mean_secs(), None);
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(Json::as_u64), Some(0));
        assert_eq!(j.get("p50_secs"), Some(&Json::Null));
        assert_eq!(j.get("p999_secs"), Some(&Json::Null));
        // And the whole thing round-trips through the parser.
        let text = j.to_string_compact();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
    }

    #[test]
    fn constant_samples_quantile_within_bucket_width() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record_ns(1_000_000); // 1 ms
        }
        for q in [0.0, 0.5, 1.0] {
            let est = h.quantile_secs(q).unwrap();
            let rel = (est - 1e-3).abs() / 1e-3;
            assert!(rel <= 1.0 / 16.0, "q={q}: est={est} rel={rel}");
        }
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(Json::as_u64), Some(100));
        assert!(j.get("p50_secs").and_then(Json::as_f64).is_some());
    }
}
