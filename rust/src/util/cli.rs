//! Tiny CLI argument parser (the vendor set lacks `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed getters and a usage printer. Each binary
//! declares its options; unknown options are an error so typos fail
//! loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// true if this option takes a value; false for boolean flags.
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Debug, Clone)]
pub struct Args {
    program: String,
    about: &'static str,
    specs: Vec<ArgSpec>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    Invalid(String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => write!(f, "option --{name} requires a value"),
            CliError::Invalid(name, val) => write!(f, "invalid value for --{name}: {val}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse `argv` (without the program name) against `specs`.
    pub fn parse(
        program: &str,
        about: &'static str,
        specs: Vec<ArgSpec>,
        argv: &[String],
    ) -> Result<Args, CliError> {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(key.clone()))?
                        }
                    };
                    values.insert(key, val);
                } else {
                    flags.push(key);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args {
            program: program.to_string(),
            about,
            specs,
            values,
            flags,
            positional,
        })
    }

    /// Parse from the process environment.
    pub fn from_env(
        about: &'static str,
        specs: Vec<ArgSpec>,
    ) -> Result<Args, CliError> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let program = std::env::args().next().unwrap_or_else(|| "prog".into());
        Self::parse(&program, about, specs, &argv)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str()).or_else(|| {
            self.specs
                .iter()
                .find(|s| s.name == name)
                .and_then(|s| s.default)
        })
    }

    pub fn get_string(&self, name: &str) -> Option<String> {
        self.get(name).map(|s| s.to_string())
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        self.parse_with(name, |s| s.parse::<usize>().ok())
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        self.parse_with(name, |s| s.parse::<u64>().ok())
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        self.parse_with(name, |s| s.parse::<f64>().ok())
    }

    /// Parse "a..b" (inclusive) or a single value into a range.
    pub fn get_range(&self, name: &str) -> Result<Option<(usize, usize)>, CliError> {
        self.parse_with(name, |s| {
            if let Some((a, b)) = s.split_once("..") {
                Some((a.parse().ok()?, b.parse().ok()?))
            } else {
                let v = s.parse().ok()?;
                Some((v, v))
            }
        })
    }

    fn parse_with<T>(
        &self,
        name: &str,
        f: impl Fn(&str) -> Option<T>,
    ) -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => f(s)
                .map(Some)
                .ok_or_else(|| CliError::Invalid(name.to_string(), s.to_string())),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{}\n\nUsage: {} [options]\n\nOptions:\n", self.about, self.program);
        for s in &self.specs {
            let val = if s.takes_value { " <value>" } else { "" };
            let def = s
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            out.push_str(&format!("  --{}{val}\n      {}{def}\n", s.name, s.help));
        }
        out
    }
}

/// Convenience macro-free spec builder.
pub fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> ArgSpec {
    ArgSpec {
        name,
        help,
        takes_value: true,
        default,
    }
}

pub fn flag(name: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec {
        name,
        help,
        takes_value: false,
        default: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn specs() -> Vec<ArgSpec> {
        vec![
            opt("n", "problem size", Some("64")),
            opt("map", "map name", None),
            flag("verbose", "chatty output"),
        ]
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = Args::parse("p", "t", specs(), &argv(&["--n", "128", "--map=lambda2"])).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), Some(128));
        assert_eq!(a.get("map"), Some("lambda2"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse("p", "t", specs(), &argv(&[])).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), Some(64));
        assert_eq!(a.get("map"), None);
    }

    #[test]
    fn flags_and_positionals() {
        let a = Args::parse("p", "t", specs(), &argv(&["run", "--verbose", "x"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["run".to_string(), "x".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(matches!(
            Args::parse("p", "t", specs(), &argv(&["--bogus"])),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn missing_value_errors() {
        assert!(matches!(
            Args::parse("p", "t", specs(), &argv(&["--map"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn invalid_number_errors() {
        let a = Args::parse("p", "t", specs(), &argv(&["--n", "abc"])).unwrap();
        assert!(matches!(a.get_usize("n"), Err(CliError::Invalid(_, _))));
    }

    #[test]
    fn range_parsing() {
        let s = vec![opt("m", "dims", None)];
        let a = Args::parse("p", "t", s.clone(), &argv(&["--m", "2..10"])).unwrap();
        assert_eq!(a.get_range("m").unwrap(), Some((2, 10)));
        let a = Args::parse("p", "t", s, &argv(&["--m", "4"])).unwrap();
        assert_eq!(a.get_range("m").unwrap(), Some((4, 4)));
    }

    #[test]
    fn usage_mentions_options() {
        let a = Args::parse("p", "about text", specs(), &argv(&[])).unwrap();
        let u = a.usage();
        assert!(u.contains("--n"));
        assert!(u.contains("default: 64"));
    }
}
