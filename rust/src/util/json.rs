//! Minimal JSON value, parser and writer.
//!
//! The offline vendor set lacks `serde`/`serde_json`; the coordinator's
//! wire protocol (JSON-lines over TCP) and the report emitters need a
//! small, dependency-free JSON implementation. Supports the full JSON
//! grammar except `\u` surrogate pairs outside the BMP are passed
//! through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept ordered (BTreeMap) so emitted
/// documents are deterministic — important for golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly (single line — suitable for JSON-lines).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out);
        out
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9.007_199_254_740_992e15 {
                // Integral values print without the trailing ".0".
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    escape_into(s, out);
    out.push('"');
}

/// Append the JSON string-escaped form of `s` (without surrounding
/// quotes) to `out`. This is the single escaping routine for every
/// string the crate emits — the JSON writer above, JSONL log lines,
/// and the Prometheus exposition (whose label-value escapes, `\\`,
/// `\"` and `\n`, are a subset of JSON's) all route through it so no
/// caller hand-rolls `format!` escaping.
pub fn escape_into(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// [`escape_into`] returning a fresh `String`.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    escape_into(s, &mut out);
    out
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document. Trailing whitespace allowed; trailing
/// garbage is an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn parse_value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.parse_lit("null", Json::Null),
            Some(b't') => self.parse_lit("true", Json::Bool(true)),
            Some(b'f') => self.parse_lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.parse_string()?)),
            Some(b'[') => self.parse_array(),
            Some(b'{') => self.parse_object(),
            Some(_) => self.parse_number(),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn parse_number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn parse_string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn parse_array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.parse_value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn parse_object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.parse_value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Capped incremental framing (newline-delimited JSON over sockets)
// ---------------------------------------------------------------------

/// Default per-frame cap. A legitimate request is a few hundred bytes;
/// 1 MiB leaves room for pathological-but-honest sweeps while bounding
/// what an untrusted peer can make the server buffer.
pub const DEFAULT_MAX_FRAME: usize = 1 << 20;

/// One decoded item from a [`FrameBuffer`].
#[derive(Debug, PartialEq, Eq)]
pub enum Frame {
    /// A complete newline-terminated line (`\n` stripped, and a
    /// trailing `\r` with it, so CRLF peers work unmodified).
    Line(String),
    /// A frame exceeded the cap. The offending bytes are dropped and
    /// the stream resynchronizes at the next newline — exactly one
    /// `Oversized` is reported per overlong frame, the moment the cap
    /// trips, so the peer gets a prompt error instead of a hang.
    Oversized { limit: usize },
}

/// Incremental capped reader for newline-delimited frames: feed raw
/// socket bytes with [`push`](FrameBuffer::push), pull complete frames
/// with [`next_frame`](FrameBuffer::next_frame). Hostile input can
/// neither grow the buffer past the cap (overlong frames are discarded
/// as they arrive, not accumulated) nor desynchronize it (partial
/// reads reassemble; decoding resumes at the newline after a rejected
/// frame). This is the SNIPPETS.md capped-reader shape, adapted to a
/// non-blocking reactor: `push` never blocks and `next_frame` never
/// waits.
pub struct FrameBuffer {
    buf: Vec<u8>,
    max_frame: usize,
    /// Inside an overlong frame whose terminating newline has not
    /// arrived yet (its `Oversized` is already emitted): drop bytes
    /// until the newline resynchronizes the stream.
    skipping: bool,
}

impl FrameBuffer {
    pub fn new(max_frame: usize) -> FrameBuffer {
        FrameBuffer {
            buf: Vec::new(),
            max_frame: max_frame.max(1),
            skipping: false,
        }
    }

    /// Append raw bytes. While skipping an overlong frame, everything
    /// up to the resynchronizing newline is dropped without buffering.
    pub fn push(&mut self, bytes: &[u8]) {
        if self.skipping {
            match bytes.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    self.skipping = false;
                    self.buf.extend_from_slice(&bytes[i + 1..]);
                }
                None => {} // still inside the oversized frame
            }
        } else {
            self.buf.extend_from_slice(bytes);
        }
    }

    /// Pull the next complete frame, if any. Call in a loop after each
    /// `push` — one push can complete several frames.
    pub fn next_frame(&mut self) -> Option<Frame> {
        match self.buf.iter().position(|&b| b == b'\n') {
            Some(i) if i <= self.max_frame => {
                let mut line: Vec<u8> = self.buf.drain(..=i).collect();
                line.pop(); // the newline
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                Some(Frame::Line(String::from_utf8_lossy(&line).into_owned()))
            }
            Some(i) => {
                // Complete but overlong (arrived in one push): drop it
                // whole and resynchronize immediately. `skipping` is
                // never set here — a skipping buffer holds no
                // pre-newline bytes by construction.
                self.buf.drain(..=i);
                Some(Frame::Oversized {
                    limit: self.max_frame,
                })
            }
            None if self.buf.len() > self.max_frame => {
                // Cap tripped mid-frame: report once now (prompt error
                // even if the newline never comes), then skip until
                // the newline arrives — `push` clears `skipping`.
                self.buf.clear();
                self.skipping = true;
                Some(Frame::Oversized {
                    limit: self.max_frame,
                })
            }
            None => None,
        }
    }

    /// Bytes currently buffered (≤ cap + one read's worth by
    /// construction, when frames are drained after every push).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod frame_tests {
    use super::*;

    fn drain(fb: &mut FrameBuffer) -> Vec<Frame> {
        let mut out = Vec::new();
        while let Some(f) = fb.next_frame() {
            out.push(f);
        }
        out
    }

    #[test]
    fn frames_reassemble_across_partial_reads() {
        let mut fb = FrameBuffer::new(64);
        fb.push(b"{\"cmd\":\"pi");
        assert_eq!(drain(&mut fb), vec![]);
        fb.push(b"ng\"}\n{\"cmd\":");
        assert_eq!(
            drain(&mut fb),
            vec![Frame::Line("{\"cmd\":\"ping\"}".into())]
        );
        fb.push(b"\"maps\"}\r\n");
        assert_eq!(
            drain(&mut fb),
            vec![Frame::Line("{\"cmd\":\"maps\"}".into())]
        );
        assert_eq!(fb.buffered(), 0);
    }

    #[test]
    fn one_push_can_complete_many_frames() {
        let mut fb = FrameBuffer::new(64);
        fb.push(b"a\nb\nc\n");
        assert_eq!(
            drain(&mut fb),
            vec![
                Frame::Line("a".into()),
                Frame::Line("b".into()),
                Frame::Line("c".into())
            ]
        );
    }

    #[test]
    fn oversized_frame_rejected_promptly_not_on_newline() {
        // The cap trips mid-frame: the error is reported immediately
        // (no hang waiting for a newline the peer may never send) and
        // memory stays bounded while the rest of the frame streams in.
        let mut fb = FrameBuffer::new(16);
        fb.push(&[b'x'; 17]);
        assert_eq!(drain(&mut fb), vec![Frame::Oversized { limit: 16 }]);
        for _ in 0..64 {
            fb.push(&[b'x'; 1024]);
            assert_eq!(drain(&mut fb), vec![]);
            assert_eq!(fb.buffered(), 0, "skipped bytes must not accumulate");
        }
        // Resynchronizes at the newline; the next frame decodes clean.
        fb.push(b"tail\nok\n");
        assert_eq!(drain(&mut fb), vec![Frame::Line("ok".into())]);
    }

    #[test]
    fn oversized_frame_in_one_push_reports_once_and_resyncs() {
        let mut fb = FrameBuffer::new(8);
        let mut hostile = vec![b'y'; 100];
        hostile.push(b'\n');
        hostile.extend_from_slice(b"{\"ok\":1}\n");
        fb.push(&hostile);
        assert_eq!(
            drain(&mut fb),
            vec![
                Frame::Oversized { limit: 8 },
                Frame::Line("{\"ok\":1}".into())
            ]
        );
    }

    #[test]
    fn frame_exactly_at_cap_passes() {
        let mut fb = FrameBuffer::new(4);
        fb.push(b"abcd\nabcde\n");
        assert_eq!(
            drain(&mut fb),
            vec![Frame::Line("abcd".into()), Frame::Oversized { limit: 4 }]
        );
    }

    #[test]
    fn empty_lines_and_crlf_are_distinct_frames() {
        let mut fb = FrameBuffer::new(8);
        fb.push(b"\n\r\nx\n");
        assert_eq!(
            drain(&mut fb),
            vec![
                Frame::Line(String::new()),
                Frame::Line(String::new()),
                Frame::Line("x".into())
            ]
        );
    }

    #[test]
    fn hostile_seeded_fuzz_recovers_every_valid_frame() {
        // Deterministic fuzz: interleave valid frames with overlong
        // garbage runs, then replay the byte stream in seeded random
        // chunk sizes. Every valid frame must come back exactly once,
        // in order; every garbage run must produce exactly one
        // Oversized; nothing may panic and the buffer must stay
        // bounded.
        use crate::util::prng::Xoshiro256;
        let cap = 128usize;
        for seed in 0..8u64 {
            let mut rng = Xoshiro256::seed_from_u64(0x9e3779b9 ^ seed);
            let mut stream = Vec::new();
            let mut expect = Vec::new();
            for i in 0..50 {
                if rng.gen_range(0, 4) == 0 {
                    // Garbage run past the cap (binary bytes, no
                    // newline until the end).
                    let len = cap + 1 + rng.gen_range(0, 512);
                    for _ in 0..len {
                        let b = rng.next_u32() as u8;
                        stream.push(if b == b'\n' { b'.' } else { b });
                    }
                    stream.push(b'\n');
                    expect.push(Frame::Oversized { limit: cap });
                } else {
                    let body =
                        format!("{{\"i\":{i},\"pad\":\"{}\"}}", "p".repeat(rng.gen_range(0, 64)));
                    assert!(body.len() <= cap);
                    stream.extend_from_slice(body.as_bytes());
                    stream.push(b'\n');
                    expect.push(Frame::Line(body));
                }
            }
            let mut fb = FrameBuffer::new(cap);
            let mut got = Vec::new();
            let mut off = 0;
            while off < stream.len() {
                let n = (1 + rng.gen_range(0, 97)).min(stream.len() - off);
                fb.push(&stream[off..off + n]);
                off += n;
                got.extend(drain(&mut fb));
                assert!(
                    fb.buffered() <= cap + 97,
                    "seed {seed}: buffer grew past cap+chunk: {}",
                    fb.buffered()
                );
            }
            assert_eq!(got, expect, "seed {seed}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_handles_quotes_backslashes_and_controls() {
        assert_eq!(escape(r#"lam"bda\2"#), r#"lam\"bda\\2"#);
        assert_eq!(escape("a\nb\tc"), "a\\nb\\tc");
        assert_eq!(escape("\u{1}"), "\\u0001");
        assert_eq!(escape("plain"), "plain");
        // The writer and the helper must agree: a Json::Str built from
        // the raw string parses back to the same raw string.
        let raw = "q\"uote\\slash\nnl";
        let v = Json::Str(raw.to_string());
        let emitted = v.to_string_compact();
        assert_eq!(emitted, format!("\"{}\"", escape(raw)));
        assert_eq!(parse(&emitted).unwrap().as_str().unwrap(), raw);
    }

    #[test]
    fn roundtrip_scalar_values() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":true}"#;
        let v = parse(src).unwrap();
        let emitted = v.to_string_compact();
        assert_eq!(parse(&emitted).unwrap(), v);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn integral_numbers_print_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.5).to_string_compact(), "5.5");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("42 xyz").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn whitespace_tolerated() {
        let v = parse(" { \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn obj_builder_and_accessors() {
        let v = Json::obj(vec![("n", 4u64.into()), ("name", "edm".into())]);
        assert_eq!(v.get("n").unwrap().as_u64(), Some(4));
        assert_eq!(v.get("name").unwrap().as_str(), Some("edm"));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn deterministic_key_order() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string_compact(), r#"{"a":2,"z":1}"#);
    }
}
