//! Minimal JSON value, parser and writer.
//!
//! The offline vendor set lacks `serde`/`serde_json`; the coordinator's
//! wire protocol (JSON-lines over TCP) and the report emitters need a
//! small, dependency-free JSON implementation. Supports the full JSON
//! grammar except `\u` surrogate pairs outside the BMP are passed
//! through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept ordered (BTreeMap) so emitted
/// documents are deterministic — important for golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly (single line — suitable for JSON-lines).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out);
        out
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9.007_199_254_740_992e15 {
                // Integral values print without the trailing ".0".
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    escape_into(s, out);
    out.push('"');
}

/// Append the JSON string-escaped form of `s` (without surrounding
/// quotes) to `out`. This is the single escaping routine for every
/// string the crate emits — the JSON writer above, JSONL log lines,
/// and the Prometheus exposition (whose label-value escapes, `\\`,
/// `\"` and `\n`, are a subset of JSON's) all route through it so no
/// caller hand-rolls `format!` escaping.
pub fn escape_into(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// [`escape_into`] returning a fresh `String`.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    escape_into(s, &mut out);
    out
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document. Trailing whitespace allowed; trailing
/// garbage is an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn parse_value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.parse_lit("null", Json::Null),
            Some(b't') => self.parse_lit("true", Json::Bool(true)),
            Some(b'f') => self.parse_lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.parse_string()?)),
            Some(b'[') => self.parse_array(),
            Some(b'{') => self.parse_object(),
            Some(_) => self.parse_number(),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn parse_number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn parse_string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn parse_array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.parse_value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn parse_object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.parse_value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_handles_quotes_backslashes_and_controls() {
        assert_eq!(escape(r#"lam"bda\2"#), r#"lam\"bda\\2"#);
        assert_eq!(escape("a\nb\tc"), "a\\nb\\tc");
        assert_eq!(escape("\u{1}"), "\\u0001");
        assert_eq!(escape("plain"), "plain");
        // The writer and the helper must agree: a Json::Str built from
        // the raw string parses back to the same raw string.
        let raw = "q\"uote\\slash\nnl";
        let v = Json::Str(raw.to_string());
        let emitted = v.to_string_compact();
        assert_eq!(emitted, format!("\"{}\"", escape(raw)));
        assert_eq!(parse(&emitted).unwrap().as_str().unwrap(), raw);
    }

    #[test]
    fn roundtrip_scalar_values() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":true}"#;
        let v = parse(src).unwrap();
        let emitted = v.to_string_compact();
        assert_eq!(parse(&emitted).unwrap(), v);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn integral_numbers_print_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.5).to_string_compact(), "5.5");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("42 xyz").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn whitespace_tolerated() {
        let v = parse(" { \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn obj_builder_and_accessors() {
        let v = Json::obj(vec![("n", 4u64.into()), ("name", "edm".into())]);
        assert_eq!(v.get("n").unwrap().as_u64(), Some(4));
        assert_eq!(v.get("name").unwrap().as_str(), Some("edm"));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn deterministic_key_order() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string_compact(), r#"{"a":2,"z":1}"#);
    }
}
