//! `simplexlint` — the repo's in-tree static-analysis pass.
//!
//! Mechanizes the correctness invariants every efficiency claim rests
//! on (DESIGN.md §Static Analysis): panic-freedom on the serving
//! paths, a declared atomic-ordering policy per file, checked casts in
//! the exact-rank arithmetic, a two-way env-knob registry against
//! EXPERIMENTS.md, and a `SAFETY:`-documented unsafe inventory. The
//! binary (`cargo run --bin simplexlint`) walks `rust/src`, `benches`
//! and `examples`, runs every rule, and exits non-zero on any
//! unsuppressed finding — gated in CI as the `lint` job.
//!
//! Zero dependencies by design (no syn): [`scanner`] is a token-level
//! Rust scanner that is exactly strong enough for the rule set, and
//! [`rules`] documents each rule's matching contract and escape hatch
//! (`// lint: allow(<rule>, <reason>)` — counted, reported, reasons
//! mandatory).

pub mod rules;
pub mod scanner;

use rules::Finding;
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

/// Everything one lint run produced.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    pub fn unsuppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_none())
    }

    pub fn suppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_some())
    }

    /// True when the tree is clean (CI gate condition).
    pub fn clean(&self) -> bool {
        self.unsuppressed().next().is_none()
    }

    /// Render the human report: unsuppressed findings first, then the
    /// suppression inventory, then per-rule totals.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in self.unsuppressed() {
            out.push_str(&format!(
                "{}:{}: [{}] {}\n",
                f.path, f.line, f.rule, f.msg
            ));
        }
        let nsup = self.suppressed().count();
        if nsup > 0 {
            out.push_str(&format!("\n{nsup} suppressed by allow-annotations:\n"));
            for f in self.suppressed() {
                out.push_str(&format!(
                    "  {}:{}: [{}] allowed: {}\n",
                    f.path,
                    f.line,
                    f.rule,
                    f.suppressed.as_deref().unwrap_or("")
                ));
            }
        }
        out.push_str(&format!(
            "\n{} files scanned; per rule (unsuppressed/suppressed):\n",
            self.files_scanned
        ));
        for rule in rules::RULES {
            let open = self.unsuppressed().filter(|f| f.rule == rule).count();
            let sup = self.suppressed().filter(|f| f.rule == rule).count();
            out.push_str(&format!("  {rule:<8} {open}/{sup}\n"));
        }
        out.push_str(if self.clean() {
            "simplexlint: clean\n"
        } else {
            "simplexlint: FAILED\n"
        });
        out
    }
}

/// Recursively collect `.rs` files under `dir`, sorted for stable
/// reports.
fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let mut paths: Vec<_> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            rust_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// Run the full lint over a repo checkout. `root` is the repository
/// root (the directory holding `rust/`, `benches/`, `examples/` and
/// `EXPERIMENTS.md`).
pub fn run(root: &Path) -> std::io::Result<Report> {
    let mut files = Vec::new();
    for sub in ["rust/src", "benches", "examples"] {
        rust_files(&root.join(sub), &mut files);
    }
    let mut report = Report::default();
    let mut env_reads: BTreeSet<String> = BTreeSet::new();
    let mut env_sites: BTreeMap<String, (String, usize)> = BTreeMap::new();
    for path in &files {
        let src = std::fs::read_to_string(path)?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let scanned = scanner::scan(&rel, &src);
        report.findings.extend(rules::check_file(&scanned));
        for knob in rules::env_reads(&scanned) {
            // Remember the first read site per knob for reporting.
            let line = scanned
                .toks
                .iter()
                .find(|t| t.kind == scanner::TokKind::Str && t.text.contains(&knob))
                .map(|t| t.line)
                .unwrap_or(0);
            env_sites.entry(knob.clone()).or_insert((rel.clone(), line));
            env_reads.insert(knob);
        }
        report.files_scanned += 1;
    }
    let registry_path = "EXPERIMENTS.md";
    let registry = std::fs::read_to_string(root.join(registry_path)).unwrap_or_default();
    report.findings.extend(rules::check_env_registry(
        &env_reads,
        &env_sites,
        &registry,
        registry_path,
    ));
    report
        .findings
        .sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    Ok(report)
}

/// Locate the repo root by walking up from `start` until a directory
/// holding both `rust/src` and `EXPERIMENTS.md` appears.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut dir = Some(start.to_path_buf());
    while let Some(d) = dir {
        if d.join("rust/src").is_dir() && d.join("EXPERIMENTS.md").is_file() {
            return Some(d);
        }
        dir = d.parent().map(Path::to_path_buf);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_clean_and_failed_states() {
        let mut r = Report::default();
        assert!(r.clean());
        assert!(r.render().contains("simplexlint: clean"));
        r.findings.push(Finding {
            rule: "panic",
            path: "src/coordinator/queue.rs".into(),
            line: 3,
            msg: "x".into(),
            suppressed: None,
        });
        r.findings.push(Finding {
            rule: "cast",
            path: "src/maps/m.rs".into(),
            line: 9,
            msg: "y".into(),
            suppressed: Some("proved".into()),
        });
        assert!(!r.clean());
        let text = r.render();
        assert!(text.contains("simplexlint: FAILED"));
        assert!(text.contains("1 suppressed"));
        assert!(text.contains("queue.rs:3"));
    }

    #[test]
    fn find_root_walks_up_from_a_nested_dir() {
        let here = std::env::current_dir().expect("cwd");
        let root = find_root(&here).expect("repo root from test cwd");
        assert!(root.join("EXPERIMENTS.md").is_file());
        assert!(root.join("rust/src/lint/mod.rs").is_file());
    }
}
