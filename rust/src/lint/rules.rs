//! The five `simplexlint` rule families (see DESIGN.md §Static
//! Analysis for the contract and the extension recipe).
//!
//! Every rule reports [`Finding`]s; a finding whose line (or the line
//! directly above it) carries a matching `// lint: allow(<rule>,
//! <reason>)` annotation is *suppressed* — still counted and printed
//! in the report summary, but not gating. The reason is mandatory:
//! `allow(panic)` without one does not suppress.

use super::scanner::{Scanned, TokKind};
use std::collections::BTreeSet;

/// Rule identifiers — the `<rule>` token of the allow grammar.
pub const RULES: [&str; 5] = ["panic", "atomics", "cast", "env", "unsafe"];

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
    /// Set when an allow-annotation covers the site; the reason is
    /// carried so the report can surface *why* each suppression
    /// exists.
    pub suppressed: Option<String>,
}

impl Finding {
    fn new(rule: &'static str, sc: &Scanned, line: usize, msg: String) -> Finding {
        Finding {
            rule,
            path: sc.path.clone(),
            line,
            msg,
            suppressed: allow_reason(sc, rule, line),
        }
    }
}

/// Parse `lint: allow(<rule>, <reason>)` out of the comment channel on
/// `line` or the line above. Returns the reason when present.
fn allow_reason(sc: &Scanned, rule: &str, line: usize) -> Option<String> {
    for l in [line, line.saturating_sub(1)] {
        if l == 0 {
            continue;
        }
        if let Some(r) = parse_allow(sc.comment(l), rule) {
            return Some(r);
        }
    }
    None
}

/// Extract the reason from one comment string, if it carries a
/// matching `lint: allow(rule, reason)`.
pub fn parse_allow(comment: &str, rule: &str) -> Option<String> {
    let mut rest = comment;
    while let Some(pos) = rest.find("lint: allow(") {
        let body = &rest[pos + "lint: allow(".len()..];
        let close = body.find(')')?;
        let inner = &body[..close];
        if let Some((r, reason)) = inner.split_once(',') {
            if r.trim() == rule && !reason.trim().is_empty() {
                return Some(reason.trim().to_string());
            }
        }
        rest = &body[close..];
    }
    None
}

/// Parse a `lint: atomics(Relaxed, AcqRel, ...)` policy header from a
/// whole file's comment channel. Returns the declared ordering set, or
/// `None` when the file declares no policy.
pub fn atomics_policy(sc: &Scanned) -> Option<BTreeSet<String>> {
    for line in 1..=sc.lines {
        let c = sc.comment(line);
        if let Some(pos) = c.find("lint: atomics(") {
            let body = &c[pos + "lint: atomics(".len()..];
            let close = body.find(')')?;
            return Some(
                body[..close]
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect(),
            );
        }
    }
    None
}

const ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// Keywords that can directly precede `[` in *type* position — an
/// ident from this set followed by `[` is a slice type, not an index
/// expression.
const TYPE_POSITION_KEYWORDS: [&str; 20] = [
    "mut", "ref", "dyn", "as", "in", "return", "break", "continue", "else", "match", "move",
    "static", "const", "box", "await", "loop", "while", "if", "impl", "where",
];

/// Is `rel_path` one of the serving-path files under the panic rule?
pub fn panic_scope(rel_path: &str) -> bool {
    [
        "coordinator/reactor.rs",
        "coordinator/queue.rs",
        "coordinator/server.rs",
        "coordinator/results_store.rs",
    ]
    .iter()
    .any(|s| rel_path.ends_with(s))
}

/// Is `rel_path` in the exact-arithmetic scope of the cast rule?
pub fn cast_scope(rel_path: &str) -> bool {
    rel_path.contains("src/maps/")
        || rel_path.contains("src/simplex/")
        || rel_path.ends_with("util/isqrt.rs")
}

/// Run every per-file rule over one scanned file.
pub fn check_file(sc: &Scanned) -> Vec<Finding> {
    let mut out = Vec::new();
    if panic_scope(&sc.path) {
        rule_panic(sc, &mut out);
    }
    rule_atomics(sc, &mut out);
    if cast_scope(&sc.path) {
        rule_cast(sc, &mut out);
    }
    rule_unsafe(sc, &mut out);
    out
}

/// Rule `panic`: no `.unwrap()` / `.expect(` / panicking macros /
/// slice-index expressions in the serving-path files.
fn rule_panic(sc: &Scanned, out: &mut Vec<Finding>) {
    let toks = &sc.toks;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.in_test {
            continue;
        }
        match t.kind {
            TokKind::Ident if matches!(t.text.as_str(), "unwrap" | "expect") => {
                let after_dot =
                    i > 0 && toks[i - 1].kind == TokKind::Punct && toks[i - 1].text == ".";
                let called = toks
                    .get(i + 1)
                    .is_some_and(|n| n.kind == TokKind::Punct && n.text == "(");
                if after_dot && called {
                    out.push(Finding::new(
                        "panic",
                        sc,
                        t.line,
                        format!(".{}() may panic on a serving path", t.text),
                    ));
                }
            }
            TokKind::Ident
                if matches!(
                    t.text.as_str(),
                    "panic" | "unreachable" | "todo" | "unimplemented" | "assert" | "assert_eq"
                        | "assert_ne"
                ) =>
            {
                let is_macro = toks
                    .get(i + 1)
                    .is_some_and(|n| n.kind == TokKind::Punct && n.text == "!");
                if is_macro {
                    out.push(Finding::new(
                        "panic",
                        sc,
                        t.line,
                        format!("{}! may panic on a serving path", t.text),
                    ));
                }
            }
            TokKind::Punct if t.text == "[" && i > 0 => {
                let p = &toks[i - 1];
                let indexes = match p.kind {
                    TokKind::Ident => !TYPE_POSITION_KEYWORDS.contains(&p.text.as_str()),
                    TokKind::Punct => p.text == ")" || p.text == "]",
                    _ => false,
                };
                if indexes {
                    out.push(Finding::new(
                        "panic",
                        sc,
                        t.line,
                        format!(
                            "slice index `{}[..]` may panic on a serving path (use .get())",
                            p.text
                        ),
                    ));
                }
            }
            _ => {}
        }
    }
}

/// Rule `atomics`: every `Ordering::<variant>` use must match the
/// file's declared `lint: atomics(...)` policy header. A file that
/// uses atomics with no header, or uses a variant outside the declared
/// set (the classic undeclared-SeqCst default), is flagged.
fn rule_atomics(sc: &Scanned, out: &mut Vec<Finding>) {
    let policy = atomics_policy(sc);
    let toks = &sc.toks;
    let mut missing_header_reported = false;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.in_test || t.kind != TokKind::Ident || t.text != "Ordering" {
            continue;
        }
        // Match `Ordering` `:` `:` `<variant>`.
        let (Some(c1), Some(c2), Some(v)) = (toks.get(i + 1), toks.get(i + 2), toks.get(i + 3))
        else {
            continue;
        };
        if !(c1.text == ":" && c2.text == ":" && v.kind == TokKind::Ident) {
            continue;
        }
        if !ORDERINGS.contains(&v.text.as_str()) {
            continue; // `cmp::Ordering::Less` etc — not an atomic use.
        }
        match &policy {
            None => {
                if !missing_header_reported {
                    out.push(Finding::new(
                        "atomics",
                        sc,
                        v.line,
                        format!(
                            "file uses Ordering::{} without a `lint: atomics(...)` policy header",
                            v.text
                        ),
                    ));
                    missing_header_reported = true;
                }
            }
            Some(set) if !set.contains(&v.text) => {
                out.push(Finding::new(
                    "atomics",
                    sc,
                    v.line,
                    format!(
                        "Ordering::{} is outside this file's declared policy ({})",
                        v.text,
                        set.iter().cloned().collect::<Vec<_>>().join(", ")
                    ),
                ));
            }
            Some(_) => {}
        }
    }
}

/// Rule `cast`: in the exact-arithmetic scope, every `as u64` /
/// `as usize` (the narrowing directions out of the u128 rank domain)
/// must be `try_into` or carry an allow-annotation with the range
/// proof. The scanner is type-blind, so the rule is deliberately
/// over-broad: widening casts in scope pay a one-line annotation too.
fn rule_cast(sc: &Scanned, out: &mut Vec<Finding>) {
    let toks = &sc.toks;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.in_test || t.kind != TokKind::Ident || t.text != "as" {
            continue;
        }
        let Some(n) = toks.get(i + 1) else { continue };
        if n.kind == TokKind::Ident && matches!(n.text.as_str(), "u64" | "usize") {
            out.push(Finding::new(
                "cast",
                sc,
                t.line,
                format!(
                    "bare `as {}` in exact-arithmetic scope (use try_into or prove the range)",
                    n.text
                ),
            ));
        }
    }
}

/// Rule `unsafe`: every `unsafe` token must have a `SAFETY:` comment
/// on the same line or within the 3 lines above it.
fn rule_unsafe(sc: &Scanned, out: &mut Vec<Finding>) {
    for t in &sc.toks {
        if t.in_test || t.kind != TokKind::Ident || t.text != "unsafe" {
            continue;
        }
        let documented = (t.line.saturating_sub(3)..=t.line)
            .any(|l| l > 0 && sc.comment(l).contains("SAFETY:"));
        if !documented {
            out.push(Finding::new(
                "unsafe",
                sc,
                t.line,
                "unsafe without a `// SAFETY:` comment".to_string(),
            ));
        }
    }
}

/// Collect every `SIMPLEXMAP_*` name read in a file's production
/// string literals (the env-knob registry rule's source side).
pub fn env_reads(sc: &Scanned) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for t in &sc.toks {
        if t.in_test || t.kind != TokKind::Str {
            continue;
        }
        collect_knob_names(&t.text, &mut out);
    }
    out
}

/// Pull `SIMPLEXMAP_[A-Z0-9_]+` words out of arbitrary text.
pub fn collect_knob_names(text: &str, out: &mut BTreeSet<String>) {
    let b = text.as_bytes();
    let mut i = 0usize;
    while let Some(pos) = text[i..].find("SIMPLEXMAP_") {
        let start = i + pos;
        // Must not be preceded by an identifier char (e.g. a longer
        // name embedding the prefix).
        if start > 0 && (b[start - 1].is_ascii_alphanumeric() || b[start - 1] == b'_') {
            i = start + 1;
            continue;
        }
        let mut end = start + "SIMPLEXMAP_".len();
        while end < b.len()
            && (b[end].is_ascii_uppercase() || b[end].is_ascii_digit() || b[end] == b'_')
        {
            end += 1;
        }
        // Trim trailing underscores (prose like `SIMPLEXMAP_` alone).
        let name = text[start..end].trim_end_matches('_');
        if name.len() > "SIMPLEXMAP_".len() {
            out.insert(name.to_string());
        }
        i = end;
    }
}

/// Rule `env`, registry side: two-way parity between the knobs read in
/// source and the names mentioned in the EXPERIMENTS.md registry text.
pub fn check_env_registry(
    reads: &BTreeSet<String>,
    read_sites: &std::collections::BTreeMap<String, (String, usize)>,
    registry_text: &str,
    registry_path: &str,
) -> Vec<Finding> {
    let mut documented = BTreeSet::new();
    collect_knob_names(registry_text, &mut documented);
    let mut out = Vec::new();
    for knob in reads {
        if !documented.contains(knob) {
            let (path, line) = read_sites
                .get(knob)
                .cloned()
                .unwrap_or_else(|| (registry_path.to_string(), 0));
            out.push(Finding {
                rule: "env",
                path,
                line,
                msg: format!(
                    "{knob} is read in source but missing from the {registry_path} knob table"
                ),
                suppressed: None,
            });
        }
    }
    for knob in &documented {
        if !reads.contains(knob) {
            out.push(Finding {
                rule: "env",
                path: registry_path.to_string(),
                line: 0,
                msg: format!(
                    "{knob} is in the {registry_path} knob table but nothing in source reads it"
                ),
                suppressed: None,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::scanner::scan;
    use super::*;
    use std::collections::BTreeMap;

    fn findings(path: &str, src: &str) -> Vec<Finding> {
        check_file(&scan(path, src))
    }

    fn unsuppressed(f: &[Finding]) -> usize {
        f.iter().filter(|x| x.suppressed.is_none()).count()
    }

    #[test]
    fn panic_rule_flags_unwrap_expect_macros_and_indexing() {
        let src = "fn f(v: &[u64], m: std::sync::Mutex<u8>) {\n\
                   let a = m.lock().unwrap();\n\
                   let b = v.first().expect(\"x\");\n\
                   panic!(\"boom\");\n\
                   let c = v[0];\n\
                   }";
        let f = findings("src/coordinator/queue.rs", src);
        assert_eq!(f.len(), 4, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "panic"));
        assert_eq!(unsuppressed(&f), 4);
    }

    #[test]
    fn panic_rule_scope_is_the_serving_files_only() {
        let src = "fn f(v: &[u64]) -> u64 { v[0] }";
        assert!(findings("src/coordinator/scheduler.rs", src).is_empty());
        assert!(!findings("src/coordinator/reactor.rs", src).is_empty());
    }

    #[test]
    fn panic_rule_ignores_test_code_and_non_panicking_lookalikes() {
        let src = "fn f(v: &[u64]) -> u64 { v.first().copied().unwrap_or(0) }\n\
                   #[cfg(test)]\nmod tests { fn t(v: &[u64]) { v[0]; x.unwrap(); assert!(true); } }";
        let f = findings("src/coordinator/server.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn slice_type_positions_are_not_index_expressions() {
        let src = "fn f(x: &mut [u8], y: [u64; 4]) -> Vec<u8> { vec![0; 4] }\n#[derive(Debug)]\nstruct S;";
        let f = findings("src/coordinator/reactor.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn allow_annotation_suppresses_and_carries_reason() {
        let src = "fn f() {\n\
                   // lint: allow(panic, startup-fatal by design)\n\
                   let t = spawn().expect(\"spawn\");\n\
                   let u = other().unwrap();\n\
                   }";
        let f = findings("src/coordinator/queue.rs", src);
        assert_eq!(f.len(), 2);
        assert_eq!(
            f[0].suppressed.as_deref(),
            Some("startup-fatal by design"),
            "{f:?}"
        );
        assert!(f[1].suppressed.is_none());
    }

    #[test]
    fn allow_without_reason_does_not_suppress() {
        let src = "fn f() { x.unwrap(); } // lint: allow(panic, )";
        let f = findings("src/coordinator/queue.rs", src);
        assert_eq!(unsuppressed(&f), 1);
    }

    #[test]
    fn atomics_rule_requires_header_and_declared_variants() {
        let src = "use std::sync::atomic::Ordering;\n\
                   fn f(a: &std::sync::atomic::AtomicU64) { a.load(Ordering::SeqCst); }";
        let f = findings("src/util/x.rs", src);
        assert_eq!(f.len(), 1);
        assert!(f[0].msg.contains("no `lint: atomics"));

        let src2 = "// lint: atomics(Relaxed)\n\
                    use std::sync::atomic::Ordering;\n\
                    fn f(a: &std::sync::atomic::AtomicU64) {\n\
                    a.load(Ordering::Relaxed);\n\
                    a.store(1, Ordering::SeqCst);\n\
                    }";
        let f2 = findings("src/util/x.rs", src2);
        assert_eq!(f2.len(), 1, "{f2:?}");
        assert!(f2[0].msg.contains("SeqCst is outside"));
    }

    #[test]
    fn atomics_rule_ignores_cmp_ordering() {
        let src = "fn f(a: u64, b: u64) -> std::cmp::Ordering { if a < b { std::cmp::Ordering::Less } else { std::cmp::Ordering::Greater } }";
        assert!(findings("src/util/x.rs", src).is_empty());
    }

    #[test]
    fn cast_rule_flags_scope_and_honours_allow() {
        let src = "fn f(x: u128) -> u64 { x as u64 }";
        assert_eq!(unsuppressed(&findings("src/maps/m.rs", src)), 1);
        assert_eq!(unsuppressed(&findings("src/simplex/s.rs", src)), 1);
        assert_eq!(unsuppressed(&findings("src/util/isqrt.rs", src)), 1);
        // Out of scope: coordinator, grid, other util files.
        assert!(findings("src/util/histogram.rs", src).is_empty());
        assert!(findings("src/grid/launcher.rs", src).is_empty());

        let allowed = "fn f(x: u128) -> u64 {\n\
                       x as u64 // lint: allow(cast, x <= T(nb) <= u64::MAX by supports())\n\
                       }";
        let f = findings("src/maps/m.rs", allowed);
        assert_eq!(f.len(), 1);
        assert!(f[0].suppressed.is_some());
    }

    #[test]
    fn cast_rule_ignores_widening_and_test_code() {
        let src = "fn f(x: u64) -> u128 { x as u128 }\n\
                   #[cfg(test)]\nmod tests { fn t(x: u128) -> u64 { x as u64 } }";
        assert!(findings("src/maps/m.rs", src).is_empty());
    }

    #[test]
    fn unsafe_rule_wants_safety_comment() {
        let src = "fn f() { unsafe { libc_call(); } }";
        assert_eq!(unsuppressed(&findings("src/coordinator/reactor.rs", src)), 1);
        let ok = "fn f() {\n\
                  // SAFETY: fds points at len initialized pollfd structs.\n\
                  unsafe { libc_call(); }\n\
                  }";
        assert!(findings("src/coordinator/reactor.rs", ok).is_empty());
    }

    #[test]
    fn env_reads_come_from_production_strings_only() {
        let src = "fn f() { std::env::var(\"SIMPLEXMAP_KNOB_A\"); }\n\
                   #[cfg(test)]\nmod tests { fn t() { std::env::var(\"SIMPLEXMAP_KNOB_B\"); } }";
        let reads = env_reads(&scan("src/x.rs", src));
        assert!(reads.contains("SIMPLEXMAP_KNOB_A"));
        assert!(!reads.contains("SIMPLEXMAP_KNOB_B"));
    }

    #[test]
    fn env_registry_drift_is_flagged_both_ways() {
        let mut reads = BTreeSet::new();
        reads.insert("SIMPLEXMAP_READ_ONLY".to_string());
        reads.insert("SIMPLEXMAP_BOTH".to_string());
        let mut sites = BTreeMap::new();
        sites.insert(
            "SIMPLEXMAP_READ_ONLY".to_string(),
            ("src/x.rs".to_string(), 7),
        );
        let registry = "| `SIMPLEXMAP_BOTH` | doc |\n| `SIMPLEXMAP_DOC_ONLY` | doc |";
        let f = check_env_registry(&reads, &sites, registry, "EXPERIMENTS.md");
        assert_eq!(f.len(), 2, "{f:?}");
        let read_only = f
            .iter()
            .find(|x| x.msg.contains("SIMPLEXMAP_READ_ONLY"))
            .expect("read-only drift");
        assert_eq!(read_only.path, "src/x.rs");
        assert_eq!(read_only.line, 7);
        assert!(f
            .iter()
            .any(|x| x.msg.contains("SIMPLEXMAP_DOC_ONLY") && x.path == "EXPERIMENTS.md"));
        let clean = check_env_registry(
            &reads,
            &sites,
            "`SIMPLEXMAP_BOTH` and `SIMPLEXMAP_READ_ONLY`",
            "EXPERIMENTS.md",
        );
        assert!(clean.is_empty());
    }

    #[test]
    fn knob_names_do_not_match_inside_longer_identifiers() {
        let mut out = BTreeSet::new();
        collect_knob_names("XSIMPLEXMAP_NOT_A_KNOB but SIMPLEXMAP_REAL ok", &mut out);
        assert_eq!(out.len(), 1);
        assert!(out.contains("SIMPLEXMAP_REAL"));
    }
}
