//! Token-level Rust scanner for `simplexlint` — zero dependencies (no
//! syn/proc-macro, matching the repo's offline-safe policy; DESIGN.md
//! §Substitutions).
//!
//! The scanner is deliberately *not* a full Rust lexer: it produces
//! exactly what the five rule families need and nothing more —
//!
//! - a flat token stream (`Tok`) with line numbers, where comments and
//!   string-literal bodies can never masquerade as code;
//! - a per-line *comment channel* (doc comments included), which is
//!   where `// lint: allow(...)`, `// lint: atomics(...)` and
//!   `// SAFETY:` annotations live;
//! - string-literal *values* (for the `SIMPLEXMAP_*` env-knob
//!   registry rule);
//! - `#[cfg(test)]`-gated regions, marked so every rule can skip test
//!   code (test-only panics/casts are free to be blunt).
//!
//! Handled syntax: line comments, nested block comments, string /
//! raw-string / byte-string / char literals, lifetimes vs char
//! literals, identifiers, numbers, single-char punctuation. That is
//! sufficient for every construct the rules match on (`.unwrap()`,
//! `panic!`, `expr[`, `as u64`, `Ordering::SeqCst`, `unsafe`).

/// Token kinds the rules distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unwrap`, `as`, `Ordering`, ...).
    Ident,
    /// Numeric literal (`0`, `0x1f`, `1_000`).
    Num,
    /// String / raw-string / byte-string literal (value stored).
    Str,
    /// Char literal (`'a'`, `'\n'`).
    Char,
    /// Lifetime (`'a` in `&'a str`).
    Lifetime,
    /// Any single punctuation character (`.`, `[`, `!`, `:`, ...).
    Punct,
}

/// One token: kind, text (literal *value* for `Str`), 1-based line,
/// and whether it sits inside a `#[cfg(test)]`-gated block.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
    pub in_test: bool,
}

/// A fully scanned source file.
#[derive(Debug)]
pub struct Scanned {
    /// Repo-relative path with forward slashes (rule scoping key).
    pub path: String,
    /// The code token stream (comments and literals resolved).
    pub toks: Vec<Tok>,
    /// Comment text per 1-based line (all comments on that line,
    /// concatenated; block comments contribute to every line they
    /// touch). Index 0 is unused.
    pub comments: Vec<String>,
    /// Number of source lines.
    pub lines: usize,
}

impl Scanned {
    /// Comment text on `line` (1-based); empty when out of range.
    pub fn comment(&self, line: usize) -> &str {
        self.comments.get(line).map(String::as_str).unwrap_or("")
    }
}

/// Scan `src` into tokens + comment channel. `path` is carried through
/// for reporting and rule scoping.
pub fn scan(path: &str, src: &str) -> Scanned {
    let bytes: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let nlines = src.lines().count();
    let mut comments = vec![String::new(); nlines + 2];
    let mut line = 1usize;
    let mut i = 0usize;

    let push_comment = |comments: &mut Vec<String>, line: usize, text: &str| {
        if line < comments.len() {
            comments[line].push_str(text);
            comments[line].push(' ');
        }
    };

    while i < bytes.len() {
        let c = bytes[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            '/' if i + 1 < bytes.len() && bytes[i + 1] == '/' => {
                // Line comment (covers /// and //! doc forms).
                let start = i;
                while i < bytes.len() && bytes[i] != '\n' {
                    i += 1;
                }
                let text: String = bytes[start..i].iter().collect();
                push_comment(&mut comments, line, &text);
            }
            '/' if i + 1 < bytes.len() && bytes[i + 1] == '*' => {
                // Block comment, possibly nested, possibly multi-line.
                let mut depth = 1usize;
                let mut seg = String::from("/*");
                i += 2;
                while i < bytes.len() && depth > 0 {
                    if bytes[i] == '/' && i + 1 < bytes.len() && bytes[i + 1] == '*' {
                        depth += 1;
                        seg.push_str("/*");
                        i += 2;
                    } else if bytes[i] == '*' && i + 1 < bytes.len() && bytes[i + 1] == '/' {
                        depth -= 1;
                        seg.push_str("*/");
                        i += 2;
                    } else {
                        if bytes[i] == '\n' {
                            push_comment(&mut comments, line, &seg);
                            seg.clear();
                            line += 1;
                        } else {
                            seg.push(bytes[i]);
                        }
                        i += 1;
                    }
                }
                push_comment(&mut comments, line, &seg);
            }
            '"' => {
                let (value, consumed, newlines) = scan_string(&bytes[i..]);
                toks.push(Tok {
                    kind: TokKind::Str,
                    text: value,
                    line,
                    in_test: false,
                });
                line += newlines;
                i += consumed;
            }
            'r' | 'b' if starts_raw_or_byte_string(&bytes[i..]) => {
                let (value, consumed, newlines) = scan_raw_or_byte(&bytes[i..]);
                toks.push(Tok {
                    kind: TokKind::Str,
                    text: value,
                    line,
                    in_test: false,
                });
                line += newlines;
                i += consumed;
            }
            '\'' => {
                // Lifetime vs char literal: `'ident` not followed by a
                // closing quote is a lifetime (`&'a str`, `'static`).
                let is_lifetime = i + 1 < bytes.len()
                    && (bytes[i + 1].is_alphanumeric() || bytes[i + 1] == '_')
                    && {
                        let mut j = i + 1;
                        while j < bytes.len() && (bytes[j].is_alphanumeric() || bytes[j] == '_') {
                            j += 1;
                        }
                        !(j < bytes.len() && bytes[j] == '\'')
                    };
                if is_lifetime {
                    let mut j = i + 1;
                    while j < bytes.len() && (bytes[j].is_alphanumeric() || bytes[j] == '_') {
                        j += 1;
                    }
                    toks.push(Tok {
                        kind: TokKind::Lifetime,
                        text: bytes[i..j].iter().collect(),
                        line,
                        in_test: false,
                    });
                    i = j;
                } else {
                    // Char literal: 'x', '\n', '\'', '\u{1F600}'.
                    let mut j = i + 1;
                    while j < bytes.len() {
                        if bytes[j] == '\\' {
                            j += 2;
                        } else if bytes[j] == '\'' {
                            j += 1;
                            break;
                        } else {
                            j += 1;
                        }
                    }
                    toks.push(Tok {
                        kind: TokKind::Char,
                        text: bytes[i..j.min(bytes.len())].iter().collect(),
                        line,
                        in_test: false,
                    });
                    i = j;
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                let mut j = i;
                while j < bytes.len() && (bytes[j].is_alphanumeric() || bytes[j] == '_') {
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Ident,
                    text: bytes[i..j].iter().collect(),
                    line,
                    in_test: false,
                });
                i = j;
            }
            c if c.is_ascii_digit() => {
                let mut j = i;
                while j < bytes.len()
                    && (bytes[j].is_alphanumeric() || bytes[j] == '_' || bytes[j] == '.')
                {
                    // `0..n` range: stop the number before `..`.
                    if bytes[j] == '.' && j + 1 < bytes.len() && bytes[j + 1] == '.' {
                        break;
                    }
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Num,
                    text: bytes[i..j].iter().collect(),
                    line,
                    in_test: false,
                });
                i = j;
            }
            c if c.is_whitespace() => {
                i += 1;
            }
            _ => {
                toks.push(Tok {
                    kind: TokKind::Punct,
                    text: c.to_string(),
                    line,
                    in_test: false,
                });
                i += 1;
            }
        }
    }

    mark_test_regions(&mut toks);
    Scanned {
        path: path.to_string(),
        toks,
        comments,
        lines: nlines,
    }
}

/// `r"..."`, `r#"..."#`, `b"..."`, `br#"..."#` lookahead.
fn starts_raw_or_byte_string(s: &[char]) -> bool {
    let mut j = 0;
    if s[j] == 'b' {
        j += 1;
    }
    if j < s.len() && s[j] == 'r' {
        j += 1;
        while j < s.len() && s[j] == '#' {
            j += 1;
        }
    }
    j > 0 && j < s.len() && s[j] == '"' && (s[0] == 'r' || s[0] == 'b')
}

/// Scan a plain `"..."` literal starting at `s[0] == '"'`.
/// Returns (unescaped-ish value, chars consumed, embedded newlines).
fn scan_string(s: &[char]) -> (String, usize, usize) {
    let mut value = String::new();
    let mut newlines = 0usize;
    let mut j = 1usize;
    while j < s.len() {
        match s[j] {
            '\\' if j + 1 < s.len() => {
                // Keep escapes opaque — the env rule only needs plain
                // ASCII names, which never contain escapes.
                value.push(s[j + 1]);
                j += 2;
            }
            '"' => {
                j += 1;
                break;
            }
            '\n' => {
                newlines += 1;
                value.push('\n');
                j += 1;
            }
            c => {
                value.push(c);
                j += 1;
            }
        }
    }
    (value, j, newlines)
}

/// Scan `r#*"..."#*` / `b"..."` starting at `s[0]` ∈ {r, b}.
fn scan_raw_or_byte(s: &[char]) -> (String, usize, usize) {
    let mut j = 0usize;
    let is_raw;
    if s[j] == 'b' {
        j += 1;
    }
    if j < s.len() && s[j] == 'r' {
        is_raw = true;
        j += 1;
    } else {
        is_raw = false;
    }
    let mut hashes = 0usize;
    while j < s.len() && s[j] == '#' {
        hashes += 1;
        j += 1;
    }
    // s[j] == '"'
    j += 1;
    let mut value = String::new();
    let mut newlines = 0usize;
    while j < s.len() {
        if !is_raw && s[j] == '\\' && j + 1 < s.len() {
            value.push(s[j + 1]);
            j += 2;
            continue;
        }
        if s[j] == '"' {
            // Raw strings close only on `"` followed by the right
            // number of `#`s.
            let mut k = j + 1;
            let mut seen = 0usize;
            while seen < hashes && k < s.len() && s[k] == '#' {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return (value, k, newlines);
            }
            value.push('"');
            j += 1;
            continue;
        }
        if s[j] == '\n' {
            newlines += 1;
        }
        value.push(s[j]);
        j += 1;
    }
    (value, j, newlines)
}

/// Mark every token inside a `#[cfg(test)]`-gated item as test code.
///
/// Grammar matched: `#` `[` `cfg` `(` ... `test` ... `)` `]` followed
/// by an item; the gated region runs from the attribute to the close
/// of the item's first brace block (covers `mod tests { ... }` and
/// `#[cfg(test)] fn helper() { ... }` alike). `cfg(all(test, ...))`
/// and `cfg(any(..., test))` count as gated — over-approximating the
/// test region only ever *relaxes* the lint, never tightens it.
fn mark_test_regions(toks: &mut [Tok]) {
    let mut i = 0usize;
    while i < toks.len() {
        if is_cfg_test_attr(toks, i) {
            // Find the end of the attribute (`]` closing the `#[`).
            let mut j = i + 1; // at '['
            let mut bracket = 0i32;
            while j < toks.len() {
                match (toks[j].kind, toks[j].text.as_str()) {
                    (TokKind::Punct, "[") => bracket += 1,
                    (TokKind::Punct, "]") => {
                        bracket -= 1;
                        if bracket == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            // Walk to the item's opening brace, then match braces.
            let mut k = j + 1;
            while k < toks.len() && !(toks[k].kind == TokKind::Punct && toks[k].text == "{") {
                // A `;` before any `{` means a braceless item
                // (`#[cfg(test)] use ...;`) — gate just up to it.
                if toks[k].kind == TokKind::Punct && toks[k].text == ";" {
                    break;
                }
                k += 1;
            }
            let mut depth = 0i32;
            let mut end = k;
            while end < toks.len() {
                if toks[end].kind == TokKind::Punct {
                    match toks[end].text.as_str() {
                        "{" => depth += 1,
                        "}" => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        ";" if depth == 0 => break,
                        _ => {}
                    }
                }
                end += 1;
            }
            for t in toks[i..=end.min(toks.len() - 1)].iter_mut() {
                t.in_test = true;
            }
            i = end + 1;
        } else {
            i += 1;
        }
    }
}

/// Does the token at `i` start `#[cfg(... test ...)]`?
fn is_cfg_test_attr(toks: &[Tok], i: usize) -> bool {
    if !(toks[i].kind == TokKind::Punct && toks[i].text == "#") {
        return false;
    }
    let Some(t1) = toks.get(i + 1) else {
        return false;
    };
    let Some(t2) = toks.get(i + 2) else {
        return false;
    };
    if !(t1.kind == TokKind::Punct && t1.text == "[") {
        return false;
    }
    if !(t2.kind == TokKind::Ident && t2.text == "cfg") {
        return false;
    }
    // Scan the cfg(...) argument list for a bare `test` ident.
    let mut depth = 0i32;
    let mut j = i + 3;
    while j < toks.len() {
        match (toks[j].kind, toks[j].text.as_str()) {
            (TokKind::Punct, "(") => depth += 1,
            (TokKind::Punct, ")") => {
                depth -= 1;
                if depth == 0 {
                    return false;
                }
            }
            (TokKind::Ident, "test") if depth >= 1 => return true,
            (TokKind::Punct, "]") => return false,
            _ => {}
        }
        j += 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_never_reach_the_token_stream() {
        let s = scan(
            "x.rs",
            "let a = \"no // comment .unwrap()\"; // real comment\n/* block\nspans */ let b = 1;",
        );
        // The string body is a Str token, not idents.
        assert!(s
            .toks
            .iter()
            .all(|t| !(t.kind == TokKind::Ident && t.text == "unwrap")));
        assert!(s.comment(1).contains("real comment"));
        assert!(s.comment(2).contains("block"));
        assert!(s.comment(3).contains("spans"));
        // Code after the block comment still tokenizes.
        assert!(s
            .toks
            .iter()
            .any(|t| t.kind == TokKind::Ident && t.text == "b" && t.line == 3));
    }

    #[test]
    fn raw_strings_and_chars_and_lifetimes() {
        let s = scan(
            "x.rs",
            "let r = r#\"raw \"quoted\" body\"#; let c = '\\''; fn f<'a>(x: &'a str) {}",
        );
        let strs: Vec<_> = s.toks.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert_eq!(strs[0].text, "raw \"quoted\" body");
        assert!(s.toks.iter().any(|t| t.kind == TokKind::Char));
        assert!(s
            .toks
            .iter()
            .any(|t| t.kind == TokKind::Lifetime && t.text == "'a"));
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "fn prod() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\nfn prod2() {}";
        let s = scan("x.rs", src);
        let unwraps: Vec<_> = s
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident && t.text == "unwrap")
            .collect();
        assert_eq!(unwraps.len(), 2);
        assert!(!unwraps[0].in_test);
        assert!(unwraps[1].in_test);
        let prod2 = s
            .toks
            .iter()
            .find(|t| t.text == "prod2")
            .expect("prod2 token");
        assert!(!prod2.in_test);
    }

    #[test]
    fn cfg_all_test_counts_as_gated() {
        let src = "#[cfg(all(test, unix))]\nmod t { fn g() { a.unwrap(); } }";
        let s = scan("x.rs", src);
        assert!(s
            .toks
            .iter()
            .filter(|t| t.text == "unwrap")
            .all(|t| t.in_test));
    }

    #[test]
    fn cfg_not_test_is_not_gated() {
        // `test` inside the cfg still gates — over-approximation is
        // documented; `cfg(unix)` alone must NOT gate.
        let src = "#[cfg(unix)]\nfn g() { a.unwrap(); }";
        let s = scan("x.rs", src);
        assert!(s
            .toks
            .iter()
            .filter(|t| t.text == "unwrap")
            .all(|t| !t.in_test));
    }

    #[test]
    fn nested_block_comments() {
        let s = scan("x.rs", "/* outer /* inner */ still comment */ let x = 1;");
        assert!(s
            .toks
            .iter()
            .any(|t| t.kind == TokKind::Ident && t.text == "x"));
        assert!(!s.toks.iter().any(|t| t.text == "outer"));
    }
}
