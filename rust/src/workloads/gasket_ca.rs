//! Cellular automaton on the embedded Sierpiński gasket — the first
//! non-simplex workload, exercising the fractal-domain maps end to end
//! (the "computation on fractal domains" scenario of arXiv:1706.04552).
//!
//! Rule: a mod-sum neighbour automaton. Every gasket cell holds a value
//! in `[0, MOD)`; one step replaces it with `(self + Σ neighbours) mod
//! MOD`, where neighbours are the ≤8 surrounding cells *that are
//! themselves gasket cells* (everything off-gasket reads as 0). Exact
//! integer arithmetic, so every map/mode must agree bit-for-bit.
//!
//! Storage is dense in *rank space*: the state vector has `3^K` bytes
//! (K = thread-level order) indexed by [`gasket_rank`], and the rank
//! composition `rank_K(cell) = rank_k(block)·3^s + rank_s(local)` gives
//! every ρ×ρ block (ρ = 2^s) a contiguous `3^s`-slot slice — disjoint
//! writes per block, exactly like the triangular CA exploits map
//! bijectivity.
//!
//! Block-level domain: the gasket block set `G(k) ⊂ B2(nb)`. Under the
//! gasket maps every kernel block is a gasket block (3^s live threads,
//! `ρ² − 3^s` predicated off). Under a *simplex* m=2 map the kernel
//! also sees the triangle's non-gasket blocks: they do no work and
//! report all `ρ²` threads predicated off — correct results, more
//! waste, which is precisely the comparison the gasket maps exist to
//! win.

use crate::coordinator::batcher::{TileBatcher, TileInput};
use crate::grid::MappedBlock;
use crate::runtime::ExecHandle;
use crate::simplex::gasket::{gasket_cell, gasket_rank, gasket_volume, in_gasket};
use crate::util::prng::Xoshiro256;
use crate::workloads::{Accum, PjrtRun, Workload};

/// The automaton's value modulus.
pub const MOD: u8 = 5;

pub struct GasketCAWorkload {
    /// Blocks per grid side (2^k).
    pub nb: u64,
    pub rho: u32,
    /// Block-level gasket order (nb = 2^k).
    pub k: u32,
    /// Intra-block order (ρ = 2^s).
    pub s: u32,
    /// Dense rank-indexed state, `3^(k+s)` cells, values in `[0, MOD)`.
    pub state: Vec<u8>,
}

impl GasketCAWorkload {
    pub fn generate(nb: u64, rho: u32, seed: u64) -> GasketCAWorkload {
        assert!(nb.is_power_of_two(), "gasket needs nb = 2^k, got {nb}");
        assert!(
            rho >= 1 && rho.is_power_of_two(),
            "gasket needs ρ = 2^s, got {rho}"
        );
        let k = nb.trailing_zeros();
        let s = rho.trailing_zeros();
        let cells = gasket_volume(k + s) as usize;
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x6A5E);
        let state = (0..cells).map(|_| rng.gen_range_u64(MOD as u64) as u8).collect();
        GasketCAWorkload { nb, rho, k, s, state }
    }

    /// Thread-level problem size n = nb·ρ.
    #[inline]
    pub fn n(&self) -> u64 {
        self.nb * self.rho as u64
    }

    /// Thread-level gasket order K = k + s.
    #[inline]
    pub fn order(&self) -> u32 {
        self.k + self.s
    }

    /// Cell value at (col, row); off-gasket reads as 0.
    #[inline]
    pub fn get(&self, col: u64, row: u64) -> u8 {
        if in_gasket(self.n(), col, row) {
            self.state[gasket_rank(self.order(), col, row) as usize]
        } else {
            0
        }
    }

    /// One cell's next value under the mod-sum rule.
    #[inline]
    pub fn next_cell(&self, col: u64, row: u64) -> u8 {
        let mut total = self.get(col, row) as u32;
        for dr in -1i64..=1 {
            for dc in -1i64..=1 {
                if dr == 0 && dc == 0 {
                    continue;
                }
                let (r, c) = (row as i64 + dr, col as i64 + dc);
                if r >= 0 && c >= 0 {
                    total += self.get(c as u64, r as u64) as u32;
                }
            }
        }
        (total % MOD as u32) as u8
    }

    /// Sequential per-cell reference step (rank order).
    pub fn step_reference(&self) -> Vec<u8> {
        let kk = self.order();
        (0..gasket_volume(kk) as u64)
            .map(|t| {
                let (col, row) = gasket_cell(kk, t);
                self.next_cell(col, row)
            })
            .collect()
    }

    /// Compute one gasket block's next values into `out` (the block's
    /// contiguous `3^s` rank slots).
    pub fn tile_next(&self, bc: u64, br: u64, out: &mut [u8]) {
        debug_assert!(in_gasket(self.nb, bc, br));
        debug_assert_eq!(out.len() as u128, gasket_volume(self.s));
        let rho = self.rho as u64;
        for (u, slot) in out.iter_mut().enumerate() {
            let (lc, lr) = gasket_cell(self.s, u as u64);
            *slot = self.next_cell(bc * rho + lc, br * rho + lr);
        }
    }

    /// Σ of all cell values (exact).
    pub fn sum(&self) -> u64 {
        self.state.iter().map(|&v| v as u64).sum()
    }

    /// Flatten one gasket block into the (ρ+2)×(ρ+2) halo patch the
    /// `gasket_tile` artifact consumes: row-major f32, patch cell
    /// `(pi, pj)` holding the value at global `(col, row) =
    /// (bc·ρ + pj − 1, br·ρ + pi − 1)`, with everything off-gasket or
    /// off-grid reading as 0 — so the dense kernel's mod-sum over the
    /// interior ρ×ρ window is exact for every live cell.
    pub fn halo_patch(&self, bc: u64, br: u64) -> Vec<f32> {
        let rho = self.rho as u64;
        let side = rho + 2;
        let mut patch = vec![0f32; (side * side) as usize];
        for pi in 0..side {
            for pj in 0..side {
                let (r, c) = (
                    (br * rho + pi) as i64 - 1,
                    (bc * rho + pj) as i64 - 1,
                );
                if r >= 0 && c >= 0 {
                    patch[(pi * side + pj) as usize] = self.get(c as u64, r as u64) as f32;
                }
            }
        }
        patch
    }

    /// Scatter one dense ρ×ρ kernel output tile into a block's
    /// contiguous `3^s` rank slots, keeping only the gasket cells
    /// (the kernel computes junk at off-gasket lattice positions; the
    /// rank composition never reads them).
    pub fn scatter_tile(&self, tile: &[f32], out: &mut [u8]) {
        debug_assert_eq!(tile.len(), (self.rho as usize).pow(2));
        debug_assert_eq!(out.len() as u128, gasket_volume(self.s));
        let rho = self.rho as u64;
        for (u, slot) in out.iter_mut().enumerate() {
            let (lc, lr) = gasket_cell(self.s, u as u64);
            *slot = tile[(lr * rho + lc) as usize] as u8;
        }
    }

    fn outputs_for(&self, next: &[u8]) -> Vec<(String, f64)> {
        let sum_after: u64 = next.iter().map(|&v| v as u64).sum();
        // Position-weighted checksum: catches any permutation of the
        // next state that a plain sum would miss. Exact in f64.
        let checksum: u64 = next
            .iter()
            .enumerate()
            .map(|(t, &v)| v as u64 * ((t as u64 % 97) + 1))
            .sum();
        vec![
            ("cells".into(), self.state.len() as f64),
            ("sum_before".into(), self.sum() as f64),
            ("sum_after".into(), sum_after as f64),
            ("checksum_after".into(), checksum as f64),
        ]
    }
}

/// Per-lane next-state buffer. Blocks write disjoint rank slices and 0
/// is the empty default, so lanes merge with a plain max.
struct GasketAccum {
    next: Vec<u8>,
}

impl Workload for GasketCAWorkload {
    fn name(&self) -> &'static str {
        "gasket"
    }

    fn m(&self) -> u32 {
        2
    }

    fn new_accum(&self) -> Accum {
        Box::new(GasketAccum {
            next: vec![0u8; self.state.len()],
        })
    }

    fn process_block(&self, acc: &mut Accum, b: &MappedBlock) -> u64 {
        let rho2 = (self.rho as u64).pow(2);
        let (bc, br) = (b.data[0], b.data[1]);
        if !in_gasket(self.nb, bc, br) {
            // A simplex map handed us a triangle block outside the
            // gasket: nothing to compute, every thread predicated off.
            return rho2;
        }
        let a = acc.downcast_mut::<GasketAccum>().expect("gasket accum");
        let per_block = gasket_volume(self.s) as u64;
        let base = (gasket_rank(self.k, bc, br) * per_block) as usize;
        self.tile_next(bc, br, &mut a.next[base..base + per_block as usize]);
        rho2 - per_block
    }

    fn finish(&self, accs: Vec<Accum>) -> Vec<(String, f64)> {
        let mut next = vec![0u8; self.state.len()];
        for acc in accs {
            let a = acc.downcast::<GasketAccum>().expect("gasket accum");
            for (n, &v) in next.iter_mut().zip(&a.next) {
                *n = (*n).max(v);
            }
        }
        self.outputs_for(&next)
    }

    fn reference_outputs(&self) -> Vec<(String, f64)> {
        self.outputs_for(&self.step_reference())
    }

    fn supports_pjrt(&self) -> bool {
        // The gasket_tile artifact is compiled for ρ = 8 halo patches
        // (10×10 → 8×8); other ρ fall back to the Rust tile path.
        self.rho == 8
    }

    fn run_pjrt(
        &self,
        exe: ExecHandle,
        blocks: &[MappedBlock],
    ) -> crate::runtime::Result<PjrtRun> {
        let mut batcher = TileBatcher::new(exe, "gasket_tile")?;
        // Gasket blocks → dense halo-patch kernel; non-gasket blocks a
        // simplex map may hand us contribute nothing (all threads
        // predicated off) and are simply skipped.
        let per_block = gasket_volume(self.s) as usize;
        let mut tiles = Vec::new();
        for b in blocks {
            let (bc, br) = (b.data[0], b.data[1]);
            if in_gasket(self.nb, bc, br) {
                tiles.push(TileInput {
                    block_id: gasket_rank(self.k, bc, br),
                    inputs: vec![self.halo_patch(bc, br)],
                });
            }
        }
        let outs = batcher.run(&tiles)?;
        let mut next = vec![0u8; self.state.len()];
        for o in &outs {
            let base = o.block_id as usize * per_block;
            self.scatter_tile(&o.data, &mut next[base..base + per_block]);
        }
        Ok(PjrtRun {
            outputs: self.outputs_for(&next),
            batches_run: batcher.batches_run,
            tiles_padded: batcher.tiles_padded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simplex::gasket::enumerate_gasket;

    /// Sweep an explicit block list the way the engine would.
    fn sweep(w: &GasketCAWorkload, blocks: &[(u64, u64)]) -> (Vec<u8>, u64) {
        let mut next = vec![0u8; w.state.len()];
        let per_block = gasket_volume(w.s) as usize;
        let mut predicated = 0u64;
        for &(bc, br) in blocks {
            if !in_gasket(w.nb, bc, br) {
                predicated += (w.rho as u64).pow(2);
                continue;
            }
            let base = gasket_rank(w.k, bc, br) as usize * per_block;
            w.tile_next(bc, br, &mut next[base..base + per_block]);
            predicated += (w.rho as u64).pow(2) - per_block as u64;
        }
        (next, predicated)
    }

    #[test]
    fn block_sweep_matches_reference() {
        for (nb, rho) in [(4u64, 4u32), (8, 2), (2, 8)] {
            let w = GasketCAWorkload::generate(nb, rho, 7);
            let (next, predicated) = sweep(&w, &enumerate_gasket(nb));
            assert_eq!(next, w.step_reference(), "nb={nb} ρ={rho}");
            // Closed form: 3^k gasket blocks, each ρ² − 3^s off.
            let expect = gasket_volume(w.k) as u64
                * ((rho as u64).pow(2) - gasket_volume(w.s) as u64);
            assert_eq!(predicated, expect, "nb={nb} ρ={rho}");
        }
    }

    #[test]
    fn triangle_block_sweep_also_matches() {
        // Simplex maps feed the whole inclusive triangle: non-gasket
        // blocks contribute nothing but full-ρ² predication.
        let (nb, rho) = (8u64, 2u32);
        let w = GasketCAWorkload::generate(nb, rho, 9);
        let triangle: Vec<(u64, u64)> = (0..nb)
            .flat_map(|br| (0..=br).map(move |bc| (bc, br)))
            .collect();
        let (next, predicated) = sweep(&w, &triangle);
        assert_eq!(next, w.step_reference());
        let gasket_blocks = gasket_volume(w.k) as u64;
        let extra = triangle.len() as u64 - gasket_blocks;
        let expect = gasket_blocks * ((rho as u64).pow(2) - gasket_volume(w.s) as u64)
            + extra * (rho as u64).pow(2);
        assert_eq!(predicated, expect);
    }

    #[test]
    fn mod_sum_golden_k1_s1() {
        // Deterministic state 0..8 mod 5 on the 9-cell order-2 gasket
        // (Python-verified golden).
        let mut w = GasketCAWorkload::generate(2, 2, 0);
        w.state = (0..9u8).map(|t| t % MOD).collect();
        assert_eq!(w.step_reference(), vec![3, 1, 2, 0, 2, 0, 3, 1, 1]);
        let out = w.reference_outputs();
        assert_eq!(out[2], ("sum_after".to_string(), 13.0));
        assert_eq!(out[3], ("checksum_after".to_string(), 59.0));
    }

    #[test]
    fn zero_state_stays_zero() {
        let mut w = GasketCAWorkload::generate(4, 2, 1);
        w.state.fill(0);
        assert!(w.step_reference().iter().all(|&v| v == 0));
        assert_eq!(w.sum(), 0);
    }

    #[test]
    fn off_gasket_reads_as_dead() {
        let w = GasketCAWorkload::generate(4, 2, 2);
        assert_eq!(w.get(1, 2), 0, "(1,2) is not a gasket cell");
        assert_eq!(w.get(0, w.n()), 0, "outside the grid");
    }

    #[test]
    #[should_panic(expected = "nb = 2^k")]
    fn generate_rejects_non_pow2_nb() {
        GasketCAWorkload::generate(6, 2, 0);
    }

    #[test]
    #[should_panic(expected = "ρ = 2^s")]
    fn generate_rejects_non_pow2_rho() {
        GasketCAWorkload::generate(4, 3, 0);
    }

    /// What kernels/gasket.py computes per tile: the dense 3×3 mod-sum
    /// over the patch interior. Simulated here so the halo/scatter
    /// plumbing is testable without an executor.
    fn simulate_gasket_tile(patch: &[f32], rho: usize) -> Vec<f32> {
        let side = rho + 2;
        let mut out = vec![0f32; rho * rho];
        for i in 0..rho {
            for j in 0..rho {
                let mut total = 0f32;
                for di in 0..3 {
                    for dj in 0..3 {
                        total += patch[(i + di) * side + (j + dj)];
                    }
                }
                out[i * rho + j] = total % MOD as f32;
            }
        }
        out
    }

    #[test]
    fn halo_patch_kernel_path_matches_reference() {
        // Drive the PJRT data path (halo_patch → dense tile → scatter)
        // with the simulated kernel over every gasket block: the
        // reassembled next state must equal step_reference exactly.
        for (nb, rho) in [(4u64, 8u32), (8, 8), (2, 4)] {
            let w = GasketCAWorkload::generate(nb, rho, 11);
            let per_block = gasket_volume(w.s) as usize;
            let mut next = vec![0u8; w.state.len()];
            for (bc, br) in enumerate_gasket(nb) {
                let patch = w.halo_patch(bc, br);
                let tile = simulate_gasket_tile(&patch, rho as usize);
                let base = gasket_rank(w.k, bc, br) as usize * per_block;
                w.scatter_tile(&tile, &mut next[base..base + per_block]);
            }
            assert_eq!(next, w.step_reference(), "nb={nb} ρ={rho}");
        }
    }

    #[test]
    fn halo_patch_borders_read_off_gasket_as_zero() {
        let w = GasketCAWorkload::generate(4, 4, 5);
        // Block (0,0): top and left halo rows lie off-grid → all zero.
        let patch = w.halo_patch(0, 0);
        let side = w.rho as usize + 2;
        assert!(patch[..side].iter().all(|&v| v == 0.0), "top halo row");
        assert!((0..side).all(|i| patch[i * side] == 0.0), "left halo col");
        // Interior patch cells reproduce get() at the shifted coords.
        for pi in 0..side {
            for pj in 0..side {
                let want = if pi == 0 || pj == 0 {
                    0.0
                } else {
                    w.get(pj as u64 - 1, pi as u64 - 1) as f32
                };
                assert_eq!(patch[pi * side + pj], want, "({pi},{pj})");
            }
        }
    }

    #[test]
    fn pjrt_support_is_gated_on_the_artifact_rho() {
        assert!(GasketCAWorkload::generate(4, 8, 0).supports_pjrt());
        assert!(!GasketCAWorkload::generate(4, 4, 0).supports_pjrt());
    }

    #[test]
    fn state_values_respect_the_modulus() {
        let w = GasketCAWorkload::generate(8, 4, 3);
        assert_eq!(w.state.len() as u128, gasket_volume(w.order()));
        assert!(w.state.iter().all(|&v| v < MOD));
        assert!(w.step_reference().iter().all(|&v| v < MOD));
    }
}
