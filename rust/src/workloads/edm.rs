//! Euclidean distance matrix (EDM) — the canonical 2-simplex workload
//! ([13], [12], [14], [22]): all pairwise squared distances over n
//! points, of which only the strictly-lower triangle is computed
//! (symmetry), plus an ε-neighbour count (the DNA-distance use case).

use crate::coordinator::batcher::{TileBatcher, TileInput};
use crate::grid::MappedBlock;
use crate::runtime::ExecHandle;
use crate::util::prng::Xoshiro256;
use crate::workloads::{strict_pair_mask, strict_pair_predicated_off, Accum, PjrtRun, Workload};

/// Point dimensionality — fixed by the AOT artifact (aot.py D=8).
pub const EDM_DIM: usize = 8;

pub struct EdmWorkload {
    /// Flat row-major points, n × EDM_DIM.
    pub points: Vec<f32>,
    pub n: u64,
    pub rho: u32,
    /// Squared neighbour radius for the count output.
    pub r2: f32,
}

impl EdmWorkload {
    /// Deterministic synthetic point cloud: a mixture of Gaussian
    /// clusters (mimics the clustered structure of real EDM datasets).
    pub fn generate(nb: u64, rho: u32, seed: u64) -> EdmWorkload {
        let n = nb * rho as u64;
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let clusters = 8;
        let centers: Vec<[f32; EDM_DIM]> = (0..clusters)
            .map(|_| std::array::from_fn(|_| rng.gen_f32_range(-4.0, 4.0)))
            .collect();
        let mut points = Vec::with_capacity(n as usize * EDM_DIM);
        for _ in 0..n {
            let c = &centers[rng.gen_range(0, clusters)];
            for d in 0..EDM_DIM {
                points.push(c[d] + rng.gen_normal() as f32 * 0.5);
            }
        }
        EdmWorkload {
            points,
            n,
            rho,
            r2: 4.0,
        }
    }

    /// Chunk `c`'s flat point slice (ρ × D floats).
    pub fn chunk(&self, c: u64) -> &[f32] {
        let lo = c as usize * self.rho as usize * EDM_DIM;
        let hi = lo + self.rho as usize * EDM_DIM;
        &self.points[lo..hi]
    }

    #[inline]
    fn point(&self, idx: u64) -> &[f32] {
        &self.points[idx as usize * EDM_DIM..(idx as usize + 1) * EDM_DIM]
    }

    #[inline]
    fn d2(&self, a: u64, b: u64) -> f32 {
        let (pa, pb) = (self.point(a), self.point(b));
        let mut acc = 0.0;
        for d in 0..EDM_DIM {
            let diff = pa[d] - pb[d];
            acc += diff * diff;
        }
        acc
    }

    /// Pure-Rust tile kernel: squared distances of block (bc, br) into
    /// `out` (ρ×ρ, row-major [i][j] = d²(row_i, col_j)) — semantically
    /// identical to python/compile/kernels/edm.py.
    ///
    /// Walks both chunks as contiguous D-strided slices and writes each
    /// output row as one `chunks_exact_mut` slice, so the fixed-width
    /// (D = 8) difference/square reduction is bounds-check-free and
    /// auto-vectorizable.
    pub fn tile_rust(&self, bc: u64, br: u64, out: &mut [f32]) {
        let rho = self.rho as usize;
        let rows = self.chunk(br);
        let cols = self.chunk(bc);
        for (i, row_out) in out.chunks_exact_mut(rho).enumerate() {
            let p = &rows[i * EDM_DIM..i * EDM_DIM + EDM_DIM];
            for (q, o) in cols.chunks_exact(EDM_DIM).zip(row_out.iter_mut()) {
                let mut acc = 0f32;
                for d in 0..EDM_DIM {
                    let diff = p[d] - q[d];
                    acc += diff * diff;
                }
                *o = acc;
            }
        }
    }

    /// Aggregate one tile under the strict-pair predicate: returns
    /// (neighbour count, Σ d²) over valid pairs.
    pub fn aggregate_tile(&self, bc: u64, br: u64, tile: &[f32]) -> (u64, f64) {
        let rho = self.rho;
        let mut count = 0u64;
        let mut sum = 0f64;
        for (i, j) in strict_pair_mask(bc, br, rho) {
            let v = tile[(i * rho + j) as usize];
            sum += v as f64;
            if v <= self.r2 {
                count += 1;
            }
        }
        (count, sum)
    }

    /// Brute-force reference over all strict pairs.
    pub fn reference(&self) -> (u64, f64) {
        let mut count = 0u64;
        let mut sum = 0f64;
        for row in 0..self.n {
            for col in 0..row {
                let v = self.d2(row, col);
                sum += v as f64;
                if v <= self.r2 {
                    count += 1;
                }
            }
        }
        (count, sum)
    }
}

/// Per-lane streaming state: one reusable tile plus the partial
/// (count, Σd²) aggregates.
struct EdmAccum {
    tile: Vec<f32>,
    count: u64,
    sum: f64,
}

impl Workload for EdmWorkload {
    fn name(&self) -> &'static str {
        "edm"
    }

    fn m(&self) -> u32 {
        2
    }

    fn new_accum(&self) -> Accum {
        Box::new(EdmAccum {
            tile: vec![0f32; self.rho as usize * self.rho as usize],
            count: 0,
            sum: 0.0,
        })
    }

    fn process_block(&self, acc: &mut Accum, b: &MappedBlock) -> u64 {
        let a = acc.downcast_mut::<EdmAccum>().expect("edm accum");
        let (bc, br) = (b.data[0], b.data[1]);
        self.tile_rust(bc, br, &mut a.tile);
        let (c, s) = self.aggregate_tile(bc, br, &a.tile);
        a.count += c;
        a.sum += s;
        strict_pair_predicated_off(bc, br, self.rho)
    }

    fn finish(&self, accs: Vec<Accum>) -> Vec<(String, f64)> {
        let mut count = 0u64;
        let mut sum = 0f64;
        for acc in accs {
            let a = acc.downcast::<EdmAccum>().expect("edm accum");
            count += a.count;
            sum += a.sum;
        }
        vec![
            ("neighbour_count".into(), count as f64),
            ("sum_d2".into(), sum),
        ]
    }

    fn reference_outputs(&self) -> Vec<(String, f64)> {
        let (count, sum) = self.reference();
        vec![
            ("neighbour_count".into(), count as f64),
            ("sum_d2".into(), sum),
        ]
    }

    fn supports_pjrt(&self) -> bool {
        true
    }

    fn run_pjrt(
        &self,
        exe: ExecHandle,
        blocks: &[MappedBlock],
    ) -> crate::runtime::Result<PjrtRun> {
        let mut batcher = TileBatcher::new(exe, "edm_tile")?;
        let tiles: Vec<TileInput> = blocks
            .iter()
            .enumerate()
            .map(|(i, b)| TileInput {
                block_id: i as u64,
                inputs: vec![self.chunk(b.data[1]).to_vec(), self.chunk(b.data[0]).to_vec()],
            })
            .collect();
        let outs = batcher.run(&tiles)?;
        let mut count = 0u64;
        let mut sum = 0f64;
        for out in &outs {
            let b = &blocks[out.block_id as usize];
            let (c, s) = self.aggregate_tile(b.data[0], b.data[1], &out.data);
            count += c;
            sum += s;
        }
        Ok(PjrtRun {
            outputs: vec![
                ("neighbour_count".into(), count as f64),
                ("sum_d2".into(), sum),
            ],
            batches_run: batcher.batches_run,
            tiles_padded: batcher.tiles_padded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = EdmWorkload::generate(4, 8, 7);
        let b = EdmWorkload::generate(4, 8, 7);
        assert_eq!(a.points, b.points);
        assert_eq!(a.n, 32);
        let c = EdmWorkload::generate(4, 8, 8);
        assert_ne!(a.points, c.points);
    }

    #[test]
    fn tile_matches_pointwise_distances() {
        let w = EdmWorkload::generate(4, 4, 1);
        let mut tile = vec![0f32; 16];
        w.tile_rust(1, 2, &mut tile);
        for i in 0..4u64 {
            for j in 0..4u64 {
                let want = w.d2(2 * 4 + i, 4 + j);
                assert_eq!(tile[(i * 4 + j) as usize], want);
            }
        }
    }

    #[test]
    fn block_sweep_matches_reference() {
        // Sum tile aggregates over the whole inclusive block triangle
        // and compare with brute force — the core workload invariant.
        let w = EdmWorkload::generate(4, 4, 3);
        let nb = 4u64;
        let mut count = 0u64;
        let mut sum = 0f64;
        let mut tile = vec![0f32; 16];
        for br in 0..nb {
            for bc in 0..=br {
                w.tile_rust(bc, br, &mut tile);
                let (c, s) = w.aggregate_tile(bc, br, &tile);
                count += c;
                sum += s;
            }
        }
        let (rc, rs) = w.reference();
        assert_eq!(count, rc);
        assert!((sum - rs).abs() < 1e-3 * rs.abs().max(1.0), "{sum} vs {rs}");
    }

    #[test]
    fn diagonal_tiles_exclude_self_pairs() {
        let w = EdmWorkload::generate(2, 4, 5);
        let mut tile = vec![0f32; 16];
        w.tile_rust(0, 0, &mut tile);
        let (count, _) = w.aggregate_tile(0, 0, &tile);
        // At most 4·3/2 pairs can count within a diagonal tile.
        assert!(count <= 6);
    }

    #[test]
    fn chunk_slicing() {
        let w = EdmWorkload::generate(4, 8, 2);
        assert_eq!(w.chunk(0).len(), 8 * EDM_DIM);
        assert_eq!(w.chunk(3).len(), 8 * EDM_DIM);
        assert_eq!(w.chunk(1)[0], w.points[8 * EDM_DIM]);
    }
}
