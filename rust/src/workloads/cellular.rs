//! Cellular automaton on a triangular spatial domain — the workload
//! class of [4] the paper cites for simulations on triangular domains:
//! Conway's Life (B3/S23) restricted to the inclusive lower triangle
//! `{(row, col) : col ≤ row < n}`.
//!
//! The map-driven sweep exploits the bijectivity of the block maps:
//! because every data block is produced exactly once per step, blocks
//! write disjoint regions of the next-state buffer and the sweep needs
//! no synchronization beyond the step barrier. (With BB, the same
//! holds only after filler discard — same code path, more blocks.)

use crate::grid::MappedBlock;
use crate::util::prng::Xoshiro256;
use crate::workloads::{inclusive_pair_predicated_off, Accum, Workload};

pub struct CellularWorkload {
    pub n: u64,
    pub rho: u32,
    /// Inclusive lower triangle, row-major rows of length row+1,
    /// flattened; cell (row, col) at index row(row+1)/2 + col.
    pub state: Vec<u8>,
}

#[inline]
fn tri_index(row: u64, col: u64) -> usize {
    (row * (row + 1) / 2 + col) as usize
}

impl CellularWorkload {
    pub fn generate(nb: u64, rho: u32, seed: u64) -> CellularWorkload {
        let n = nb * rho as u64;
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xCE77);
        let cells = (n * (n + 1) / 2) as usize;
        let state = (0..cells).map(|_| (rng.gen_f32() < 0.35) as u8).collect();
        CellularWorkload { n, rho, state }
    }

    #[inline]
    pub fn get(&self, row: u64, col: u64) -> u8 {
        if col <= row && row < self.n {
            self.state[tri_index(row, col)]
        } else {
            0 // outside the triangle counts as dead
        }
    }

    /// Life rule for one cell from its ≤8 in-triangle neighbours.
    #[inline]
    pub fn next_cell(&self, row: u64, col: u64) -> u8 {
        let mut alive = 0u32;
        for dr in -1i64..=1 {
            for dc in -1i64..=1 {
                if dr == 0 && dc == 0 {
                    continue;
                }
                let (r, c) = (row as i64 + dr, col as i64 + dc);
                if r >= 0 && c >= 0 {
                    alive += self.get(r as u64, c as u64) as u32;
                }
            }
        }
        match (self.state[tri_index(row, col)], alive) {
            (1, 2) | (1, 3) | (0, 3) => 1,
            _ => 0,
        }
    }

    /// Compute the next state of one data block (bc, br) into `out`
    /// (ρ×ρ, row-major; cells outside the triangle left 0).
    pub fn tile_next(&self, bc: u64, br: u64, out: &mut [f32]) {
        let rho = self.rho as u64;
        for i in 0..rho {
            for j in 0..rho {
                let (row, col) = (br * rho + i, bc * rho + j);
                out[(i * rho + j) as usize] = if col <= row && row < self.n {
                    self.next_cell(row, col) as f32
                } else {
                    0.0
                };
            }
        }
    }

    /// Scatter a computed tile into a next-state buffer.
    pub fn scatter_tile(&self, bc: u64, br: u64, tile: &[f32], next: &mut [u8]) {
        let rho = self.rho as u64;
        for i in 0..rho {
            for j in 0..rho {
                let (row, col) = (br * rho + i, bc * rho + j);
                if col <= row && row < self.n {
                    next[tri_index(row, col)] = (tile[(i * rho + j) as usize] > 0.5) as u8;
                }
            }
        }
    }

    /// Sequential reference step.
    pub fn step_reference(&self) -> Vec<u8> {
        let mut next = vec![0u8; self.state.len()];
        for row in 0..self.n {
            for col in 0..=row {
                next[tri_index(row, col)] = self.next_cell(row, col);
            }
        }
        next
    }

    pub fn population(&self) -> u64 {
        self.state.iter().map(|&c| c as u64).sum()
    }
}

/// Per-lane state: a tile plus this lane's slice of the next-state
/// buffer. The maps are bijective at block level, so every cell is
/// written by exactly one block — lane buffers merge with a plain OR
/// (unwritten stays 0, and a written dead cell is also 0).
struct CellularAccum {
    tile: Vec<f32>,
    next: Vec<u8>,
}

impl Workload for CellularWorkload {
    fn name(&self) -> &'static str {
        "cellular"
    }

    fn m(&self) -> u32 {
        2
    }

    fn new_accum(&self) -> Accum {
        Box::new(CellularAccum {
            tile: vec![0f32; self.rho as usize * self.rho as usize],
            next: vec![0u8; self.state.len()],
        })
    }

    fn process_block(&self, acc: &mut Accum, b: &MappedBlock) -> u64 {
        let a = acc.downcast_mut::<CellularAccum>().expect("cellular accum");
        let (bc, br) = (b.data[0], b.data[1]);
        self.tile_next(bc, br, &mut a.tile);
        self.scatter_tile(bc, br, &a.tile, &mut a.next);
        inclusive_pair_predicated_off(bc, br, self.rho)
    }

    fn finish(&self, accs: Vec<Accum>) -> Vec<(String, f64)> {
        let mut next = vec![0u8; self.state.len()];
        for acc in accs {
            let a = acc.downcast::<CellularAccum>().expect("cellular accum");
            for (n, v) in next.iter_mut().zip(&a.next) {
                *n |= v;
            }
        }
        let pop: u64 = next.iter().map(|&c| c as u64).sum();
        vec![
            ("population_before".into(), self.population() as f64),
            ("population_after".into(), pop as f64),
        ]
    }

    fn reference_outputs(&self) -> Vec<(String, f64)> {
        let pop: u64 = self.step_reference().iter().map(|&c| c as u64).sum();
        vec![
            ("population_before".into(), self.population() as f64),
            ("population_after".into(), pop as f64),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tri_indexing_is_dense() {
        // Rows pack contiguously: index(row, row) + 1 == index(row+1, 0).
        for row in 0..20u64 {
            assert_eq!(tri_index(row, row) + 1, tri_index(row + 1, 0));
        }
    }

    #[test]
    fn block_sweep_step_matches_reference() {
        let w = CellularWorkload::generate(4, 4, 1);
        let nb = 4u64;
        let mut next = vec![0u8; w.state.len()];
        let mut tile = vec![0f32; 16];
        for br in 0..nb {
            for bc in 0..=br {
                w.tile_next(bc, br, &mut tile);
                w.scatter_tile(bc, br, &tile, &mut next);
            }
        }
        assert_eq!(next, w.step_reference());
    }

    #[test]
    fn outside_triangle_is_dead() {
        let w = CellularWorkload::generate(2, 4, 2);
        assert_eq!(w.get(0, 5), 0);
        assert_eq!(w.get(w.n, 0), 0);
    }

    #[test]
    fn blinker_oscillates_far_from_diagonal() {
        // Classic Life sanity: a horizontal blinker deep inside the
        // triangle flips to vertical.
        let mut w = CellularWorkload::generate(4, 8, 3);
        w.state.fill(0);
        let (r, c) = (20u64, 4u64);
        for dc in 0..3 {
            w.state[tri_index(r, c + dc)] = 1;
        }
        let next = w.step_reference();
        assert_eq!(next[tri_index(r - 1, c + 1)], 1);
        assert_eq!(next[tri_index(r, c + 1)], 1);
        assert_eq!(next[tri_index(r + 1, c + 1)], 1);
        assert_eq!(next[tri_index(r, c)], 0);
        assert_eq!(next[tri_index(r, c + 2)], 0);
    }

    #[test]
    fn population_conserved_by_still_life() {
        // A 2x2 block is a still life.
        let mut w = CellularWorkload::generate(4, 8, 4);
        w.state.fill(0);
        for (r, c) in [(10, 3), (10, 4), (11, 3), (11, 4)] {
            w.state[tri_index(r, c)] = 1;
        }
        let next = w.step_reference();
        assert_eq!(next, w.state);
    }
}
