//! Pairwise gravitational n-body — the 2-simplex workload of [23],
//! [2], [7]: accumulate softened accelerations over unique pairs,
//! applying each tile both ways (Newton's third law is what makes the
//! triangular domain sufficient).

use crate::coordinator::batcher::{TileBatcher, TileInput};
use crate::grid::MappedBlock;
use crate::runtime::ExecHandle;
use crate::util::prng::Xoshiro256;
use crate::workloads::{Accum, PjrtRun, Workload};

/// Floats per particle: (x, y, z, mass) — matches the AOT artifact.
pub const PARTICLE_DIM: usize = 4;
/// Plummer softening — must match kernels/nbody.py EPS.
pub const EPS: f32 = 1e-3;

pub struct NBodyWorkload {
    /// Flat particles, n × PARTICLE_DIM.
    pub particles: Vec<f32>,
    pub n: u64,
    pub rho: u32,
}

impl NBodyWorkload {
    /// Plummer-ish sphere with log-uniform masses.
    pub fn generate(nb: u64, rho: u32, seed: u64) -> NBodyWorkload {
        let n = nb * rho as u64;
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xB0D7);
        let mut particles = Vec::with_capacity(n as usize * PARTICLE_DIM);
        for _ in 0..n {
            particles.push(rng.gen_normal() as f32);
            particles.push(rng.gen_normal() as f32);
            particles.push(rng.gen_normal() as f32);
            particles.push((2.0f32).powf(rng.gen_f32_range(-2.0, 2.0)));
        }
        NBodyWorkload { particles, n, rho }
    }

    pub fn chunk(&self, c: u64) -> &[f32] {
        let lo = c as usize * self.rho as usize * PARTICLE_DIM;
        &self.particles[lo..lo + self.rho as usize * PARTICLE_DIM]
    }

    #[inline]
    fn p(&self, idx: u64) -> &[f32] {
        &self.particles[idx as usize * PARTICLE_DIM..(idx as usize + 1) * PARTICLE_DIM]
    }

    /// Acceleration contribution of particle `b` on particle `a`.
    #[inline]
    pub fn pair_accel(&self, a: u64, b: u64) -> [f32; 3] {
        let (pa, pb) = (self.p(a), self.p(b));
        let dx = pb[0] - pa[0];
        let dy = pb[1] - pa[1];
        let dz = pb[2] - pa[2];
        let r2 = dx * dx + dy * dy + dz * dz + EPS;
        let w = pb[3] * r2.powf(-1.5);
        [dx * w, dy * w, dz * w]
    }

    /// Pure-Rust tile kernel mirroring kernels/nbody.py: acceleration
    /// on the ρ row-chunk particles from the ρ col-chunk particles,
    /// into `out` (ρ × 3). Self-pairs contribute exactly zero (d = 0).
    pub fn tile_rust(&self, bc: u64, br: u64, out: &mut [f32]) {
        let rho = self.rho as u64;
        out.fill(0.0);
        for i in 0..rho {
            let mut acc = [0f32; 3];
            for j in 0..rho {
                let a = self.pair_accel(br * rho + i, bc * rho + j);
                acc[0] += a[0];
                acc[1] += a[1];
                acc[2] += a[2];
            }
            out[(i * 3) as usize] = acc[0];
            out[(i * 3 + 1) as usize] = acc[1];
            out[(i * 3 + 2) as usize] = acc[2];
        }
    }

    /// Brute-force reference: full O(n²) accelerations.
    pub fn reference(&self) -> Vec<f32> {
        let mut acc = vec![0f32; self.n as usize * 3];
        for a in 0..self.n {
            for b in 0..self.n {
                if a != b {
                    let f = self.pair_accel(a, b);
                    acc[a as usize * 3] += f[0];
                    acc[a as usize * 3 + 1] += f[1];
                    acc[a as usize * 3 + 2] += f[2];
                }
            }
        }
        acc
    }

    /// Checksum of an acceleration field: Σ ||a_i||₁ (order-insensitive
    /// within f32 tolerance; used as the job's scalar output).
    pub fn checksum(acc: &[f32]) -> f64 {
        acc.iter().map(|x| x.abs() as f64).sum()
    }
}

/// Per-lane state: a tile and this lane's partial acceleration field
/// (merged elementwise in [`Workload::finish`] — Newton's third law
/// means off-diagonal tiles are applied both ways right here).
struct NBodyAccum {
    tile: Vec<f32>,
    acc: Vec<f32>,
}

impl Workload for NBodyWorkload {
    fn name(&self) -> &'static str {
        "nbody"
    }

    fn m(&self) -> u32 {
        2
    }

    fn new_accum(&self) -> Accum {
        Box::new(NBodyAccum {
            tile: vec![0f32; self.rho as usize * 3],
            acc: vec![0f32; self.n as usize * 3],
        })
    }

    fn process_block(&self, acc: &mut Accum, b: &MappedBlock) -> u64 {
        let a = acc.downcast_mut::<NBodyAccum>().expect("nbody accum");
        let (bc, br) = (b.data[0], b.data[1]);
        let rho = self.rho as u64;
        self.tile_rust(bc, br, &mut a.tile);
        for i in 0..rho {
            for d in 0..3u64 {
                a.acc[((br * rho + i) * 3 + d) as usize] += a.tile[(i * 3 + d) as usize];
            }
        }
        if bc != br {
            self.tile_rust(br, bc, &mut a.tile);
            for i in 0..rho {
                for d in 0..3u64 {
                    a.acc[((bc * rho + i) * 3 + d) as usize] += a.tile[(i * 3 + d) as usize];
                }
            }
            0
        } else {
            rho // the i == j self-pair threads contribute nothing
        }
    }

    fn finish(&self, accs: Vec<Accum>) -> Vec<(String, f64)> {
        let mut total = vec![0f32; self.n as usize * 3];
        for acc in accs {
            let a = acc.downcast::<NBodyAccum>().expect("nbody accum");
            for (t, v) in total.iter_mut().zip(&a.acc) {
                *t += v;
            }
        }
        vec![("accel_checksum".into(), NBodyWorkload::checksum(&total))]
    }

    fn reference_outputs(&self) -> Vec<(String, f64)> {
        vec![(
            "accel_checksum".into(),
            NBodyWorkload::checksum(&self.reference()),
        )]
    }

    fn supports_pjrt(&self) -> bool {
        true
    }

    fn run_pjrt(
        &self,
        exe: ExecHandle,
        blocks: &[MappedBlock],
    ) -> crate::runtime::Result<PjrtRun> {
        let mut batcher = TileBatcher::new(exe, "nbody_tile")?;
        // Two directed tiles per off-diagonal block, one per diagonal.
        let mut tiles = Vec::new();
        let mut targets = Vec::new(); // chunk receiving the acceleration
        for b in blocks {
            let (bc, br) = (b.data[0], b.data[1]);
            tiles.push(TileInput {
                block_id: targets.len() as u64,
                inputs: vec![self.chunk(br).to_vec(), self.chunk(bc).to_vec()],
            });
            targets.push(br);
            if bc != br {
                tiles.push(TileInput {
                    block_id: targets.len() as u64,
                    inputs: vec![self.chunk(bc).to_vec(), self.chunk(br).to_vec()],
                });
                targets.push(bc);
            }
        }
        let outs = batcher.run(&tiles)?;
        let rho = self.rho as u64;
        let mut acc = vec![0f32; self.n as usize * 3];
        for out in &outs {
            let chunk_row = targets[out.block_id as usize];
            for i in 0..rho {
                for d in 0..3u64 {
                    acc[((chunk_row * rho + i) * 3 + d) as usize] +=
                        out.data[(i * 3 + d) as usize];
                }
            }
        }
        Ok(PjrtRun {
            outputs: vec![("accel_checksum".into(), NBodyWorkload::checksum(&acc))],
            batches_run: batcher.batches_run,
            tiles_padded: batcher.tiles_padded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_accel_antisymmetric_for_equal_masses() {
        let mut w = NBodyWorkload::generate(1, 4, 1);
        // Force equal masses.
        for i in 0..w.n as usize {
            w.particles[i * 4 + 3] = 1.0;
        }
        let f_ab = w.pair_accel(0, 1);
        let f_ba = w.pair_accel(1, 0);
        for d in 0..3 {
            assert!((f_ab[d] + f_ba[d]).abs() < 1e-6);
        }
    }

    #[test]
    fn self_pair_contributes_zero() {
        let w = NBodyWorkload::generate(1, 4, 2);
        assert_eq!(w.pair_accel(2, 2), [0.0, 0.0, 0.0]);
    }

    #[test]
    fn triangular_sweep_with_symmetry_matches_reference() {
        // Apply each off-diagonal tile both ways + diagonal tiles once:
        // must equal the full O(n²) reference.
        let w = NBodyWorkload::generate(4, 4, 3);
        let nb = 4u64;
        let rho = 4u64;
        let mut acc = vec![0f32; w.n as usize * 3];
        let mut tile = vec![0f32; (rho * 3) as usize];
        for br in 0..nb {
            for bc in 0..=br {
                w.tile_rust(bc, br, &mut tile);
                for i in 0..rho {
                    for d in 0..3 {
                        acc[((br * rho + i) * 3 + d) as usize] += tile[(i * 3 + d) as usize];
                    }
                }
                if bc != br {
                    w.tile_rust(br, bc, &mut tile);
                    for i in 0..rho {
                        for d in 0..3 {
                            acc[((bc * rho + i) * 3 + d) as usize] +=
                                tile[(i * 3 + d) as usize];
                        }
                    }
                }
            }
        }
        let want = w.reference();
        for (a, b) in acc.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn checksum_positive_and_deterministic() {
        let w = NBodyWorkload::generate(2, 8, 4);
        let r = w.reference();
        assert!(NBodyWorkload::checksum(&r) > 0.0);
        assert_eq!(NBodyWorkload::checksum(&r), NBodyWorkload::checksum(&r));
    }
}
