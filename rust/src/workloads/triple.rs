//! Triple-interaction (Axilrod–Teller) energy — the 3-simplex workload
//! of [11] and [6]: sum the triple-dipole dispersion energy over all
//! unique particle triples `k < j < i < n`, an O(n³) sweep whose
//! domain is exactly the discrete orthogonal tetrahedron.
//!
//! Block-level: data blocks arrive in simplex coordinates (the map
//! output); [`TripleWorkload::block_chunks`] converts them to ordered
//! chunk triples `ci ≥ cj ≥ ck`. Strictly-ordered blocks are full
//! tiles (the Pallas kernel's case); blocks with repeated chunks
//! predicate per-thread and run on the Rust path.

use crate::coordinator::batcher::{TileBatcher, TileInput};
use crate::grid::MappedBlock;
use crate::runtime::ExecHandle;
use crate::simplex::block_m::BlockM;
use crate::util::prng::Xoshiro256;
use crate::workloads::{Accum, KTupleWorkload, PjrtRun, Workload};

/// Plummer softening — must match kernels/triple.py EPS.
pub const EPS: f32 = 1e-3;

pub struct TripleWorkload {
    /// Flat positions, n × 3.
    pub pos: Vec<f32>,
    pub n: u64,
    pub rho: u32,
}

impl TripleWorkload {
    pub fn generate(nb: u64, rho: u32, seed: u64) -> TripleWorkload {
        let n = nb * rho as u64;
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x731E);
        let pos = (0..n * 3).map(|_| rng.gen_normal() as f32).collect();
        TripleWorkload { pos, n, rho }
    }

    pub fn chunk(&self, c: u64) -> &[f32] {
        let lo = c as usize * self.rho as usize * 3;
        &self.pos[lo..lo + self.rho as usize * 3]
    }

    /// Convert a simplex-coordinate data block to the ordered chunk
    /// triple `(ci, cj, ck)` with `ci ≥ cj ≥ ck` (DESIGN.md block
    /// domain: x=ck, y=cj-ck, z=NB-1-ci).
    #[inline]
    pub fn block_chunks(nb: u64, d: [u64; 3]) -> (u64, u64, u64) {
        let ck = d[0];
        let cj = d[0] + d[1];
        let ci = nb - 1 - d[2];
        debug_assert!(ck <= cj && cj <= ci && ci < nb);
        (ci, cj, ck)
    }

    #[inline]
    fn p(&self, idx: u64) -> [f32; 3] {
        let i = idx as usize * 3;
        [self.pos[i], self.pos[i + 1], self.pos[i + 2]]
    }

    /// Axilrod–Teller energy of one triple (ν = 1, softened).
    #[inline]
    pub fn at_energy(&self, i: u64, j: u64, k: u64) -> f64 {
        let (pi, pj, pk) = (self.p(i), self.p(j), self.p(k));
        let sub = |a: [f32; 3], b: [f32; 3]| [a[0] - b[0], a[1] - b[1], a[2] - b[2]];
        let dot = |a: [f32; 3], b: [f32; 3]| a[0] * b[0] + a[1] * b[1] + a[2] * b[2];
        let dij = sub(pi, pj);
        let dik = sub(pi, pk);
        let djk = sub(pj, pk);
        let r2ij = dot(dij, dij) + EPS;
        let r2ik = dot(dik, dik) + EPS;
        let r2jk = dot(djk, djk) + EPS;
        let dot_i = dot(dij, dik) as f64;
        let dot_j = (-dij[0] * djk[0] - dij[1] * djk[1] - dij[2] * djk[2]) as f64;
        let dot_k = dot(dik, djk) as f64;
        let r2prod = r2ij as f64 * r2ik as f64 * r2jk as f64;
        let denom = r2prod.powf(1.5);
        (1.0 + 3.0 * dot_i * dot_j * dot_k / r2prod) / denom
    }

    /// Pure-Rust tile: total energy over the valid triples of the
    /// chunk triple — full R³ when strictly ordered, per-thread
    /// predicate `gi > gj > gk` otherwise (mirrors kernels/triple.py
    /// for the strict case).
    pub fn tile_rust(&self, ci: u64, cj: u64, ck: u64) -> f64 {
        let rho = self.rho as u64;
        let strict = ci > cj && cj > ck;
        let mut e = 0f64;
        for a in 0..rho {
            let gi = ci * rho + a;
            for b in 0..rho {
                let gj = cj * rho + b;
                if !strict && gj >= gi {
                    continue;
                }
                for c in 0..rho {
                    let gk = ck * rho + c;
                    if !strict && gk >= gj {
                        continue;
                    }
                    e += self.at_energy(gi, gj, gk);
                }
            }
        }
        e
    }

    /// Whether the Pallas kernel (full-tile reduction) is valid for
    /// this block — i.e. no per-thread predication needed.
    #[inline]
    pub fn block_is_strict(ci: u64, cj: u64, ck: u64) -> bool {
        ci > cj && cj > ck
    }

    /// Brute-force reference: Σ over all k < j < i.
    pub fn reference(&self) -> f64 {
        let mut e = 0f64;
        for i in 0..self.n {
            for j in 0..i {
                for k in 0..j {
                    e += self.at_energy(i, j, k);
                }
            }
        }
        e
    }
}

struct TripleAccum {
    energy: f64,
}

impl Workload for TripleWorkload {
    fn name(&self) -> &'static str {
        "triple"
    }

    fn m(&self) -> u32 {
        3
    }

    fn new_accum(&self) -> Accum {
        Box::new(TripleAccum { energy: 0.0 })
    }

    fn process_block(&self, acc: &mut Accum, b: &MappedBlock) -> u64 {
        let a = acc.downcast_mut::<TripleAccum>().expect("triple accum");
        let nb = self.n / self.rho as u64;
        let (ci, cj, ck) = TripleWorkload::block_chunks(nb, b.data.to_fixed3());
        a.energy += self.tile_rust(ci, cj, ck);
        // Same closed form as the m-tuple workload at m = 3.
        KTupleWorkload::predicated_off(&BlockM::from_slice(&[ci, cj, ck]), self.rho)
    }

    fn finish(&self, accs: Vec<Accum>) -> Vec<(String, f64)> {
        let energy: f64 = accs
            .into_iter()
            .map(|acc| acc.downcast::<TripleAccum>().expect("triple accum").energy)
            .sum();
        vec![("at_energy".into(), energy)]
    }

    fn reference_outputs(&self) -> Vec<(String, f64)> {
        vec![("at_energy".into(), self.reference())]
    }

    fn supports_pjrt(&self) -> bool {
        true
    }

    fn run_pjrt(
        &self,
        exe: ExecHandle,
        blocks: &[MappedBlock],
    ) -> crate::runtime::Result<PjrtRun> {
        let mut batcher = TileBatcher::new(exe, "triple_tile")?;
        // Strictly-ordered blocks → full-tile Pallas kernel; blocks
        // with repeated chunks → Rust per-thread predication (o(n²) of
        // the n³ work; see module doc).
        let nb = self.n / self.rho as u64;
        let mut strict_tiles = Vec::new();
        let mut energy = 0f64;
        for b in blocks {
            let (ci, cj, ck) = TripleWorkload::block_chunks(nb, b.data.to_fixed3());
            if TripleWorkload::block_is_strict(ci, cj, ck) {
                strict_tiles.push(TileInput {
                    block_id: strict_tiles.len() as u64,
                    inputs: vec![
                        self.chunk(ci).to_vec(),
                        self.chunk(cj).to_vec(),
                        self.chunk(ck).to_vec(),
                    ],
                });
            } else {
                energy += self.tile_rust(ci, cj, ck);
            }
        }
        let outs = batcher.run(&strict_tiles)?;
        energy += outs.iter().map(|o| o.data[0] as f64).sum::<f64>();
        Ok(PjrtRun {
            outputs: vec![("at_energy".into(), energy)],
            batches_run: batcher.batches_run,
            tiles_padded: batcher.tiles_padded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{domain_volume, in_domain};

    #[test]
    fn block_chunks_bijective_over_domain() {
        let nb = 8u64;
        let mut seen = std::collections::HashSet::new();
        for x in 0..nb {
            for y in 0..nb {
                for z in 0..nb {
                    if in_domain(nb, 3, [x, y, z]) {
                        let (ci, cj, ck) = TripleWorkload::block_chunks(nb, [x, y, z]);
                        assert!(ck <= cj && cj <= ci && ci < nb);
                        assert!(seen.insert((ci, cj, ck)));
                    }
                }
            }
        }
        assert_eq!(seen.len() as u128, domain_volume(nb, 3));
    }

    #[test]
    fn energy_is_permutation_invariant() {
        let w = TripleWorkload::generate(1, 8, 1);
        let e1 = w.at_energy(5, 3, 1);
        let e2 = w.at_energy(3, 5, 1);
        let e3 = w.at_energy(1, 3, 5);
        assert!((e1 - e2).abs() < 1e-9 * e1.abs().max(1.0));
        assert!((e1 - e3).abs() < 1e-9 * e1.abs().max(1.0));
    }

    #[test]
    fn block_sweep_matches_reference() {
        // Sweep all simplex blocks of a small problem: total energy
        // must equal brute force over unique triples.
        let w = TripleWorkload::generate(4, 2, 2);
        let nb = 4u64;
        let mut total = 0f64;
        for x in 0..nb {
            for y in 0..nb {
                for z in 0..nb {
                    if in_domain(nb, 3, [x, y, z]) {
                        let (ci, cj, ck) = TripleWorkload::block_chunks(nb, [x, y, z]);
                        total += w.tile_rust(ci, cj, ck);
                    }
                }
            }
        }
        let want = w.reference();
        assert!(
            (total - want).abs() < 1e-6 * want.abs().max(1.0),
            "{total} vs {want}"
        );
    }

    #[test]
    fn strict_block_detection() {
        assert!(TripleWorkload::block_is_strict(3, 2, 1));
        assert!(!TripleWorkload::block_is_strict(3, 3, 1));
        assert!(!TripleWorkload::block_is_strict(3, 2, 2));
    }
}
