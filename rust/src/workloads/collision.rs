//! Broad-phase AABB collision culling — the 2-simplex workload of
//! Avril et al. [1]: count (and report) overlapping axis-aligned
//! bounding-box pairs among n boxes, testing only unique pairs.

use crate::coordinator::batcher::{TileBatcher, TileInput};
use crate::grid::MappedBlock;
use crate::runtime::ExecHandle;
use crate::util::prng::Xoshiro256;
use crate::workloads::{strict_pair_mask, strict_pair_predicated_off, Accum, PjrtRun, Workload};

/// Floats per box: (xmin, ymin, zmin, xmax, ymax, zmax) — matches the
/// AOT artifact layout (aot.py, kernels/collision.py).
pub const BOX_DIM: usize = 6;

pub struct CollisionWorkload {
    /// Flat boxes, n × BOX_DIM.
    pub boxes: Vec<f32>,
    pub n: u64,
    pub rho: u32,
}

impl CollisionWorkload {
    /// Synthetic scene: boxes uniform in a cube whose side scales with
    /// ∛n so the expected number of overlaps stays Θ(n) — the regime
    /// broad-phase collision detection is designed for.
    pub fn generate(nb: u64, rho: u32, seed: u64) -> CollisionWorkload {
        let n = nb * rho as u64;
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xC011);
        let world = (n as f32).cbrt() * 2.0;
        let mut boxes = Vec::with_capacity(n as usize * BOX_DIM);
        for _ in 0..n {
            let cx = rng.gen_f32_range(0.0, world);
            let cy = rng.gen_f32_range(0.0, world);
            let cz = rng.gen_f32_range(0.0, world);
            let hx = rng.gen_f32_range(0.2, 1.0);
            let hy = rng.gen_f32_range(0.2, 1.0);
            let hz = rng.gen_f32_range(0.2, 1.0);
            boxes.extend_from_slice(&[cx - hx, cy - hy, cz - hz, cx + hx, cy + hy, cz + hz]);
        }
        CollisionWorkload { boxes, n, rho }
    }

    pub fn chunk(&self, c: u64) -> &[f32] {
        let lo = c as usize * self.rho as usize * BOX_DIM;
        &self.boxes[lo..lo + self.rho as usize * BOX_DIM]
    }

    #[inline]
    fn bx(&self, idx: u64) -> &[f32] {
        &self.boxes[idx as usize * BOX_DIM..(idx as usize + 1) * BOX_DIM]
    }

    #[inline]
    pub fn overlaps(&self, a: u64, b: u64) -> bool {
        let (pa, pb) = (self.bx(a), self.bx(b));
        pa[0] <= pb[3]
            && pb[0] <= pa[3]
            && pa[1] <= pb[4]
            && pb[1] <= pa[4]
            && pa[2] <= pb[5]
            && pb[2] <= pa[5]
    }

    /// Pure-Rust tile kernel: 0/1 overlap flags for block (bc, br),
    /// mirroring kernels/collision.py.
    pub fn tile_rust(&self, bc: u64, br: u64, out: &mut [f32]) {
        let rho = self.rho as u64;
        for i in 0..rho {
            for j in 0..rho {
                out[(i * rho + j) as usize] =
                    self.overlaps(br * rho + i, bc * rho + j) as u32 as f32;
            }
        }
    }

    /// Count overlapping valid (strict) pairs in one tile.
    pub fn aggregate_tile(&self, bc: u64, br: u64, tile: &[f32]) -> u64 {
        strict_pair_mask(bc, br, self.rho)
            .filter(|&(i, j)| tile[(i * self.rho + j) as usize] > 0.5)
            .count() as u64
    }

    /// Brute-force overlap count over unique pairs.
    pub fn reference(&self) -> u64 {
        let mut count = 0;
        for a in 0..self.n {
            for b in 0..a {
                if self.overlaps(a, b) {
                    count += 1;
                }
            }
        }
        count
    }
}

struct CollisionAccum {
    tile: Vec<f32>,
    count: u64,
}

impl Workload for CollisionWorkload {
    fn name(&self) -> &'static str {
        "collision"
    }

    fn m(&self) -> u32 {
        2
    }

    fn new_accum(&self) -> Accum {
        Box::new(CollisionAccum {
            tile: vec![0f32; self.rho as usize * self.rho as usize],
            count: 0,
        })
    }

    fn process_block(&self, acc: &mut Accum, b: &MappedBlock) -> u64 {
        let a = acc.downcast_mut::<CollisionAccum>().expect("collision accum");
        let (bc, br) = (b.data[0], b.data[1]);
        self.tile_rust(bc, br, &mut a.tile);
        a.count += self.aggregate_tile(bc, br, &a.tile);
        strict_pair_predicated_off(bc, br, self.rho)
    }

    fn finish(&self, accs: Vec<Accum>) -> Vec<(String, f64)> {
        let count: u64 = accs
            .into_iter()
            .map(|acc| acc.downcast::<CollisionAccum>().expect("collision accum").count)
            .sum();
        vec![("overlap_count".into(), count as f64)]
    }

    fn reference_outputs(&self) -> Vec<(String, f64)> {
        vec![("overlap_count".into(), self.reference() as f64)]
    }

    fn supports_pjrt(&self) -> bool {
        true
    }

    fn run_pjrt(
        &self,
        exe: ExecHandle,
        blocks: &[MappedBlock],
    ) -> crate::runtime::Result<PjrtRun> {
        let mut batcher = TileBatcher::new(exe, "collision_tile")?;
        let tiles: Vec<TileInput> = blocks
            .iter()
            .enumerate()
            .map(|(i, b)| TileInput {
                block_id: i as u64,
                inputs: vec![self.chunk(b.data[1]).to_vec(), self.chunk(b.data[0]).to_vec()],
            })
            .collect();
        let outs = batcher.run(&tiles)?;
        let count: u64 = outs
            .iter()
            .map(|out| {
                let b = &blocks[out.block_id as usize];
                self.aggregate_tile(b.data[0], b.data[1], &out.data)
            })
            .sum();
        Ok(PjrtRun {
            outputs: vec![("overlap_count".into(), count as f64)],
            batches_run: batcher.batches_run,
            tiles_padded: batcher.tiles_padded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_is_symmetric_and_reflexive() {
        let w = CollisionWorkload::generate(2, 8, 1);
        for a in 0..w.n {
            assert!(w.overlaps(a, a));
            for b in 0..w.n {
                assert_eq!(w.overlaps(a, b), w.overlaps(b, a));
            }
        }
    }

    #[test]
    fn block_sweep_matches_reference() {
        let w = CollisionWorkload::generate(4, 4, 9);
        let mut total = 0u64;
        let mut tile = vec![0f32; 16];
        for br in 0..4u64 {
            for bc in 0..=br {
                w.tile_rust(bc, br, &mut tile);
                total += w.aggregate_tile(bc, br, &tile);
            }
        }
        assert_eq!(total, w.reference());
    }

    #[test]
    fn scene_has_some_but_not_all_overlaps() {
        let w = CollisionWorkload::generate(8, 8, 2);
        let c = w.reference();
        let pairs = w.n * (w.n - 1) / 2;
        assert!(c > 0, "expected some collisions");
        assert!(c < pairs / 2, "scene too dense: {c}/{pairs}");
    }

    #[test]
    fn generation_deterministic() {
        assert_eq!(
            CollisionWorkload::generate(2, 8, 3).boxes,
            CollisionWorkload::generate(2, 8, 3).boxes
        );
    }
}
