//! Triangular matrix–vector product — stand-in for the
//! triangular-matrix kernels the paper cites (inversion [21],
//! LU/Cholesky [5]): `y = L·x` with L lower-triangular (diagonal
//! included), swept block-by-block over the inclusive triangle.
//!
//! Unlike the pair workloads, every block contributes *partial sums*
//! to its row range; aggregation is a reduction over blocks — the same
//! access pattern as the update step of a blocked triangular solver.

use crate::grid::MappedBlock;
use crate::util::prng::Xoshiro256;
use crate::workloads::{inclusive_pair_predicated_off, Accum, Workload};

pub struct TriMatVecWorkload {
    pub n: u64,
    pub rho: u32,
    /// Dense row-major storage for simplicity of verification (the
    /// packed variant is exercised by the cellular workload's tri
    /// indexing); entries above the diagonal are zero.
    pub l: Vec<f32>,
    pub x: Vec<f32>,
}

impl TriMatVecWorkload {
    pub fn generate(nb: u64, rho: u32, seed: u64) -> TriMatVecWorkload {
        let n = nb * rho as u64;
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x7213);
        let mut l = vec![0f32; (n * n) as usize];
        for r in 0..n {
            for c in 0..=r {
                l[(r * n + c) as usize] = rng.gen_f32_range(-1.0, 1.0);
            }
        }
        let x = (0..n).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
        TriMatVecWorkload { n, rho, l, x }
    }

    /// Partial products of block (bc, br) into `out` (ρ floats): the
    /// contribution of columns [bcρ, bcρ+ρ) to rows [brρ, brρ+ρ),
    /// honouring the triangular mask col ≤ row.
    pub fn tile_rust(&self, bc: u64, br: u64, out: &mut [f32]) {
        let rho = self.rho as u64;
        for i in 0..rho {
            let row = br * rho + i;
            let mut acc = 0f32;
            for j in 0..rho {
                let col = bc * rho + j;
                if col <= row {
                    acc += self.l[(row * self.n + col) as usize] * self.x[col as usize];
                }
            }
            out[i as usize] = acc;
        }
    }

    /// Reference y = L·x.
    pub fn reference(&self) -> Vec<f32> {
        let mut y = vec![0f32; self.n as usize];
        for r in 0..self.n {
            let mut acc = 0f32;
            for c in 0..=r {
                acc += self.l[(r * self.n + c) as usize] * self.x[c as usize];
            }
            y[r as usize] = acc;
        }
        y
    }

    pub fn checksum(y: &[f32]) -> f64 {
        y.iter().map(|v| v.abs() as f64).sum()
    }
}

/// Per-lane state: a ρ-row tile plus this lane's partial y vector
/// (blocks contribute partial sums to their row range; lanes merge by
/// elementwise addition).
struct TriMatVecAccum {
    tile: Vec<f32>,
    y: Vec<f32>,
}

impl Workload for TriMatVecWorkload {
    fn name(&self) -> &'static str {
        "trimatvec"
    }

    fn m(&self) -> u32 {
        2
    }

    fn new_accum(&self) -> Accum {
        Box::new(TriMatVecAccum {
            tile: vec![0f32; self.rho as usize],
            y: vec![0f32; self.n as usize],
        })
    }

    fn process_block(&self, acc: &mut Accum, b: &MappedBlock) -> u64 {
        let a = acc.downcast_mut::<TriMatVecAccum>().expect("trimat accum");
        let (bc, br) = (b.data[0], b.data[1]);
        let rho = self.rho as u64;
        self.tile_rust(bc, br, &mut a.tile);
        for i in 0..rho {
            a.y[(br * rho + i) as usize] += a.tile[i as usize];
        }
        inclusive_pair_predicated_off(bc, br, self.rho)
    }

    fn finish(&self, accs: Vec<Accum>) -> Vec<(String, f64)> {
        let mut y = vec![0f32; self.n as usize];
        for acc in accs {
            let a = acc.downcast::<TriMatVecAccum>().expect("trimat accum");
            for (t, v) in y.iter_mut().zip(&a.y) {
                *t += v;
            }
        }
        vec![("y_checksum".into(), TriMatVecWorkload::checksum(&y))]
    }

    fn reference_outputs(&self) -> Vec<(String, f64)> {
        vec![(
            "y_checksum".into(),
            TriMatVecWorkload::checksum(&self.reference()),
        )]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_sweep_matches_reference() {
        let w = TriMatVecWorkload::generate(4, 4, 5);
        let nb = 4u64;
        let rho = 4u64;
        let mut y = vec![0f32; w.n as usize];
        let mut tile = vec![0f32; rho as usize];
        for br in 0..nb {
            for bc in 0..=br {
                w.tile_rust(bc, br, &mut tile);
                for i in 0..rho {
                    y[(br * rho + i) as usize] += tile[i as usize];
                }
            }
        }
        let want = w.reference();
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn upper_triangle_is_zero() {
        let w = TriMatVecWorkload::generate(2, 4, 6);
        for r in 0..w.n {
            for c in r + 1..w.n {
                assert_eq!(w.l[(r * w.n + c) as usize], 0.0);
            }
        }
    }

    #[test]
    fn diagonal_block_masks_partial_columns() {
        let w = TriMatVecWorkload::generate(2, 4, 7);
        let mut tile = vec![0f32; 4];
        w.tile_rust(0, 0, &mut tile);
        // Row 0 of the diagonal block only sees column 0.
        assert!((tile[0] - w.l[0] * w.x[0]).abs() < 1e-6);
    }
}
