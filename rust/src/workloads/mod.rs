//! The paper's motivating workloads (§I), each runnable under any
//! registered map and under two tile backends (pure Rust, and the AOT
//! Pallas kernels via PJRT).
//!
//! Every workload implements the [`Workload`] trait — the single
//! contract the unified execution engine dispatches on:
//!
//! - `generate(nb, rho, seed)` (inherent, per type) — deterministic
//!   synthetic data sized to the block grid (the substituted "real"
//!   dataset; see DESIGN.md §Substitutions), reached uniformly through
//!   [`build`],
//! - [`Workload::new_accum`] / [`Workload::process_block`] — the fused
//!   block kernel: one per-lane accumulator (tile scratch + partial
//!   aggregates) advanced in place while the launcher sweeps the map,
//!   applying the *thread-level* domain predicate and reporting the
//!   predicated-off thread count,
//! - [`Workload::finish`] — fold the per-lane accumulators (in lane
//!   order, deterministically) into the job's scalar outputs,
//! - [`Workload::reference_outputs`] — the brute-force reference used
//!   by the correctness tests,
//! - [`Workload::run_pjrt`] — the batched AOT tile path, for the
//!   workloads that ship artifacts ([`Workload::supports_pjrt`]).
//!
//! Thread-level domains: EDM/collision/n-body consume unique pairs
//! `col < row < n`; triple consumes unique triples `k < j < i < n`;
//! cellular/trimatvec consume the inclusive triangle `col ≤ row`;
//! ktuple consumes unique m-tuples `g_m < … < g_1 < n` (any
//! 2 ≤ m ≤ 8 — at m = 2 it is the pair-style regression workload);
//! gasket_ca consumes the embedded Sierpiński gasket `col & !row == 0`
//! (the non-simplex domain — see [`crate::simplex::gasket`]).

pub mod cellular;
pub mod collision;
pub mod edm;
pub mod gasket_ca;
pub mod ktuple;
pub mod nbody;
pub mod triple;
pub mod trimat;

use std::any::Any;

pub use cellular::CellularWorkload;
pub use collision::CollisionWorkload;
pub use edm::EdmWorkload;
pub use gasket_ca::GasketCAWorkload;
pub use ktuple::KTupleWorkload;
pub use nbody::NBodyWorkload;
pub use triple::TripleWorkload;
pub use trimat::TriMatVecWorkload;

use crate::coordinator::job::WorkloadKind;
use crate::grid::MappedBlock;
use crate::runtime::ExecHandle;

/// Type-erased per-lane streaming state (tile scratch + partial
/// aggregates). Each launcher lane owns exactly one; implementations
/// downcast to their concrete accumulator.
pub type Accum = Box<dyn Any + Send>;

/// Result of a batched PJRT execution.
pub struct PjrtRun {
    pub outputs: Vec<(String, f64)>,
    pub batches_run: u64,
    pub tiles_padded: u64,
}

/// One workload, pluggable into the unified execution engine: the
/// engine resolves a map, sweeps it with the fused block kernel
/// (streaming) or over a collected block list (opt-in collect mode /
/// PJRT batching), and folds accumulators into outputs — no
/// per-workload code in the scheduler.
pub trait Workload: Send + Sync {
    /// Stable name (matches [`WorkloadKind::name`] for the base arity).
    fn name(&self) -> &'static str;

    /// Simplex dimensionality of the block-level domain.
    fn m(&self) -> u32;

    /// Fresh per-lane accumulator.
    fn new_accum(&self) -> Accum;

    /// Fused block kernel: execute mapped block `b` into `acc`,
    /// returning the number of threads predicated off by the
    /// thread-level domain predicate.
    fn process_block(&self, acc: &mut Accum, b: &MappedBlock) -> u64;

    /// Fold the per-lane accumulators (passed in lane order) into the
    /// job's scalar outputs.
    fn finish(&self, accs: Vec<Accum>) -> Vec<(String, f64)>;

    /// Brute-force reference, shaped like [`Workload::finish`] output.
    fn reference_outputs(&self) -> Vec<(String, f64)>;

    /// Whether this workload ships an AOT Pallas artifact.
    fn supports_pjrt(&self) -> bool {
        false
    }

    /// Batched AOT tile path over the collected (deterministically
    /// ordered) blocks. Only called when [`Workload::supports_pjrt`].
    fn run_pjrt(
        &self,
        _exe: ExecHandle,
        _blocks: &[MappedBlock],
    ) -> crate::runtime::Result<PjrtRun> {
        Err(crate::runtime::RuntimeError::Xla(format!(
            "workload '{}' has no pjrt artifact",
            self.name()
        )))
    }
}

/// The one factory the engine uses: generate the workload for a job.
pub fn build(kind: WorkloadKind, nb: u64, rho: u32, seed: u64) -> Box<dyn Workload> {
    match kind {
        WorkloadKind::Edm => Box::new(EdmWorkload::generate(nb, rho, seed)),
        WorkloadKind::Collision => Box::new(CollisionWorkload::generate(nb, rho, seed)),
        WorkloadKind::NBody => Box::new(NBodyWorkload::generate(nb, rho, seed)),
        WorkloadKind::Triple => Box::new(TripleWorkload::generate(nb, rho, seed)),
        WorkloadKind::Cellular => Box::new(CellularWorkload::generate(nb, rho, seed)),
        WorkloadKind::TriMatVec => Box::new(TriMatVecWorkload::generate(nb, rho, seed)),
        WorkloadKind::KTuple(m) => Box::new(KTupleWorkload::generate(nb, rho, m, seed)),
        WorkloadKind::GasketCA => Box::new(GasketCAWorkload::generate(nb, rho, seed)),
    }
}

/// Iterate the thread-level pairs of a 2-simplex data block `(bc, br)`
/// that satisfy the strict predicate `col < row`, yielding local
/// `(i, j)` tile coordinates (row-local i, col-local j).
///
/// Off-diagonal blocks (`bc < br`) pass everything; diagonal blocks
/// pass the strictly-lower local triangle — this is the predication
/// the paper charges to diagonal blocks (`≤ ρ²n ∈ o(n²)` threads).
#[inline]
pub fn strict_pair_mask(bc: u64, br: u64, rho: u32) -> impl Iterator<Item = (u32, u32)> {
    let rho = rho;
    (0..rho).flat_map(move |i| {
        (0..rho).filter_map(move |j| {
            let col = bc * rho as u64 + j as u64;
            let row = br * rho as u64 + i as u64;
            if col < row {
                Some((i, j))
            } else {
                None
            }
        })
    })
}

/// Threads predicated off in a ρ×ρ tile under the *strict* pair
/// predicate `col < row`: zero off-diagonal, the inclusive upper
/// triangle `ρ(ρ+1)/2` on the diagonal. Closed form of
/// `ρ² − |strict_pair_mask|`.
#[inline]
pub fn strict_pair_predicated_off(bc: u64, br: u64, rho: u32) -> u64 {
    let r = rho as u64;
    if bc == br {
        r * (r + 1) / 2
    } else {
        0
    }
}

/// Threads predicated off under the *inclusive* pair predicate
/// `col ≤ row` (cellular, trimatvec): the strict upper triangle
/// `ρ(ρ-1)/2` on the diagonal.
#[inline]
pub fn inclusive_pair_predicated_off(bc: u64, br: u64, rho: u32) -> u64 {
    let r = rho as u64;
    if bc == br {
        r * (r - 1) / 2
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_diagonal_blocks_pass_all_threads() {
        let n: usize = strict_pair_mask(0, 1, 8).count();
        assert_eq!(n, 64);
        assert_eq!(strict_pair_predicated_off(0, 1, 8), 0);
    }

    #[test]
    fn diagonal_blocks_pass_strict_lower_triangle() {
        let n: usize = strict_pair_mask(3, 3, 8).count();
        assert_eq!(n, 28); // 8·7/2
        assert_eq!(strict_pair_predicated_off(3, 3, 8), 64 - 28);
    }

    #[test]
    fn adjacent_blocks_fully_inside() {
        // (bc=1, br=2) with rho=4: min row 8 > max col 7.
        assert_eq!(strict_pair_mask(1, 2, 4).count(), 16);
    }

    #[test]
    fn inclusive_predication_counts_strict_upper_triangle() {
        // Diagonal tile: ρ(ρ+1)/2 cells satisfy col ≤ row.
        for rho in [1u32, 4, 8] {
            let r = rho as u64;
            assert_eq!(inclusive_pair_predicated_off(2, 2, rho), r * r - r * (r + 1) / 2);
        }
        assert_eq!(inclusive_pair_predicated_off(0, 3, 8), 0);
    }

    #[test]
    fn build_covers_every_workload_kind() {
        for kind in WorkloadKind::ALL {
            let w = build(*kind, 4, 2, 7);
            assert_eq!(w.m(), kind.m(), "{}", kind.name());
            assert!(!w.reference_outputs().is_empty(), "{}", kind.name());
        }
    }
}
