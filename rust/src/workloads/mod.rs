//! The paper's motivating workloads (§I), each runnable under any
//! registered [`ThreadMap`](crate::maps::ThreadMap) and under two tile
//! backends (pure Rust, and the AOT Pallas kernels via PJRT).
//!
//! Every workload follows the same structure:
//! - `generate(nb, rho, seed)` — deterministic synthetic data sized to
//!   the block grid (the substituted "real" dataset; see DESIGN.md
//!   §Substitutions),
//! - a pure-Rust tile kernel semantically identical to the Pallas one,
//! - `aggregate` logic that applies the *thread-level* domain predicate
//!   (diagonal blocks are only partially inside the strict domain),
//! - a brute-force `reference` used by the correctness tests.
//!
//! Thread-level domains: EDM/collision/n-body consume unique pairs
//! `col < row < n`; triple consumes unique triples `k < j < i < n`;
//! cellular/trimatvec consume the inclusive triangle `col ≤ row`;
//! ktuple consumes unique m-tuples `g_m < … < g_1 < n` (the general-m
//! subsystem's workload, any 2 ≤ m ≤ 8).

pub mod cellular;
pub mod collision;
pub mod edm;
pub mod ktuple;
pub mod nbody;
pub mod triple;
pub mod trimat;

pub use cellular::CellularWorkload;
pub use collision::CollisionWorkload;
pub use edm::EdmWorkload;
pub use ktuple::KTupleWorkload;
pub use nbody::NBodyWorkload;
pub use triple::TripleWorkload;
pub use trimat::TriMatVecWorkload;

/// Iterate the thread-level pairs of a 2-simplex data block `(bc, br)`
/// that satisfy the strict predicate `col < row`, yielding local
/// `(i, j)` tile coordinates (row-local i, col-local j).
///
/// Off-diagonal blocks (`bc < br`) pass everything; diagonal blocks
/// pass the strictly-lower local triangle — this is the predication
/// the paper charges to diagonal blocks (`≤ ρ²n ∈ o(n²)` threads).
#[inline]
pub fn strict_pair_mask(bc: u64, br: u64, rho: u32) -> impl Iterator<Item = (u32, u32)> {
    let rho = rho;
    (0..rho).flat_map(move |i| {
        (0..rho).filter_map(move |j| {
            let col = bc * rho as u64 + j as u64;
            let row = br * rho as u64 + i as u64;
            if col < row {
                Some((i, j))
            } else {
                None
            }
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_diagonal_blocks_pass_all_threads() {
        let n: usize = strict_pair_mask(0, 1, 8).count();
        assert_eq!(n, 64);
    }

    #[test]
    fn diagonal_blocks_pass_strict_lower_triangle() {
        let n: usize = strict_pair_mask(3, 3, 8).count();
        assert_eq!(n, 28); // 8·7/2
    }

    #[test]
    fn adjacent_blocks_fully_inside() {
        // (bc=1, br=2) with rho=4: min row 8 > max col 7.
        assert_eq!(strict_pair_mask(1, 2, 4).count(), 16);
    }
}
