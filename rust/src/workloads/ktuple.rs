//! Unique k-tuple interaction — the m-dimensional generalization of
//! the triple workload: sum a softened all-pairs-within-tuple energy
//! over all unique particle m-tuples `g_m < … < g_2 < g_1 < n`, an
//! O(n^m) sweep whose domain is exactly the discrete orthogonal
//! m-simplex. This is the workload that makes λ_m's ≈m! parallel-space
//! advantage (§III.D) observable end to end.
//!
//! Block-level: data blocks arrive in simplex coordinates (the
//! [`crate::maps::MThreadMap`] output); [`KTupleWorkload::block_chunks`]
//! converts them to the ordered chunk tuple `c_1 ≥ c_2 ≥ … ≥ c_m` by
//! prefix sums — the same bijection the triple workload uses at m = 3.
//! Blocks with strictly decreasing chunks are full ρ^m tiles; repeated
//! chunks predicate per-thread (the o(n^m) diagonal charge).

use crate::coordinator::batcher::{TileBatcher, TileInput};
use crate::grid::MappedBlock;
use crate::runtime::ExecHandle;
use crate::simplex::block_m::BlockM;
use crate::simplex::volume::binomial;
use crate::util::prng::Xoshiro256;
use crate::workloads::{Accum, PjrtRun, Workload};

/// Plummer-style softening of the pairwise-distance denominator.
pub const EPS: f32 = 1e-3;

pub struct KTupleWorkload {
    /// Flat positions, n × 3 (particles live in 3-space; the *tuple*
    /// arity m is what scales, not the embedding dimension).
    pub pos: Vec<f32>,
    pub n: u64,
    pub rho: u32,
    pub m: u32,
}

impl KTupleWorkload {
    pub fn generate(nb: u64, rho: u32, m: u32, seed: u64) -> KTupleWorkload {
        assert!(m >= 2, "tuples need arity ≥ 2");
        let n = nb * rho as u64;
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x517A ^ ((m as u64) << 32));
        let pos = (0..n * 3).map(|_| rng.gen_normal() as f32).collect();
        KTupleWorkload { pos, n, rho, m }
    }

    pub fn chunk(&self, c: u64) -> &[f32] {
        let lo = c as usize * self.rho as usize * 3;
        &self.pos[lo..lo + self.rho as usize * 3]
    }

    /// Convert a data block to the ordered chunk tuple
    /// `c_1 ≥ c_2 ≥ … ≥ c_m` (descending).
    ///
    /// For m ≥ 3 blocks arrive in simplex coordinates: `c_{m-i}` is the
    /// prefix sum `d_0 + … + d_i`, and `c_1 = nb - 1 - d_{m-1}` — the
    /// m-dim generalization of the triple workload's block conversion,
    /// a bijection from `Bm(nb)` onto ordered chunk tuples.
    ///
    /// m = 2 is special: the 2-simplex block domain is the *inclusive
    /// lower-triangle pair* convention `(bc, br)` with `bc ≤ br` (see
    /// [`crate::maps`] module doc), not simplex coordinates, so the
    /// descending chunk pair is simply `(br, bc)`. (Feeding pairs
    /// through the simplex formula was the ρ-selection bug surface the
    /// old `run_ktuple` carried: it asserted the map's m but converted
    /// with the wrong convention.)
    #[inline]
    pub fn block_chunks(nb: u64, d: &BlockM) -> BlockM {
        let m = d.m() as usize;
        if m == 2 {
            debug_assert!(d[0] <= d[1] && d[1] < nb);
            return BlockM::from_slice(&[d[1], d[0]]);
        }
        let mut c = BlockM::zeros(m as u32);
        let mut prefix = 0u64;
        for i in 0..m - 1 {
            prefix += d[i];
            c[m - 1 - i] = prefix;
        }
        c[0] = nb - 1 - d[m - 1];
        debug_assert!((0..m - 1).all(|i| c[i] >= c[i + 1]) && c[0] < nb);
        c
    }

    /// Closed-form count of threads predicated off in the ρ^m tile of
    /// a descending chunk tuple: local tuples survive iff they are
    /// strictly decreasing within every run of equal chunks, so the
    /// survivors are `Π C(ρ, s_i)` over the run lengths `s_i` and the
    /// predicated count is `ρ^m − Π C(ρ, s_i)` (zero for strictly
    /// decreasing blocks, where every run has length 1).
    pub fn predicated_off(chunks: &BlockM, rho: u32) -> u64 {
        let s = chunks.as_slice();
        let rho = rho as u128;
        let mut valid = 1u128;
        let mut i = 0;
        while i < s.len() {
            let mut j = i + 1;
            while j < s.len() && s[j] == s[i] {
                j += 1;
            }
            valid *= binomial(rho, (j - i) as u128);
            i = j;
        }
        (rho.pow(s.len() as u32) - valid) as u64
    }

    /// Whether all chunks are strictly decreasing — i.e. the whole
    /// ρ^m tile is inside the strict domain (no predication needed).
    #[inline]
    pub fn block_is_strict(chunks: &BlockM) -> bool {
        let s = chunks.as_slice();
        s.windows(2).all(|w| w[0] > w[1])
    }

    #[inline]
    fn p(&self, idx: u64) -> [f32; 3] {
        let i = idx as usize * 3;
        [self.pos[i], self.pos[i + 1], self.pos[i + 2]]
    }

    /// Softened inverse-power energy of one m-tuple: with
    /// `S = Σ_{a<b} |p_a - p_b|²` over the tuple's pairs,
    /// `e = (S + ε)^{-3/2}` — permutation-invariant, singular only at
    /// full coincidence, and O(m²) like the Axilrod–Teller triple term.
    #[inline]
    pub fn energy(&self, g: &[u64]) -> f64 {
        let mut s = 0f32;
        for a in 0..g.len() {
            let pa = self.p(g[a]);
            for b in a + 1..g.len() {
                let pb = self.p(g[b]);
                let d = [pa[0] - pb[0], pa[1] - pb[1], pa[2] - pb[2]];
                s += d[0] * d[0] + d[1] * d[1] + d[2] * d[2];
            }
        }
        1.0 / (s as f64 + EPS as f64).powf(1.5)
    }

    /// Pure-Rust tile: total energy over the valid m-tuples of one
    /// chunk tuple — the full ρ^m sweep when strictly ordered, the
    /// per-thread predicate `g_1 > g_2 > … > g_m` otherwise.
    pub fn tile_rust(&self, chunks: &BlockM) -> f64 {
        let m = self.m as usize;
        debug_assert_eq!(chunks.m() as usize, m);
        let rho = self.rho as u64;
        let strict = Self::block_is_strict(chunks);
        let mut local = [0u64; crate::simplex::block_m::M_MAX];
        let mut g = [0u64; crate::simplex::block_m::M_MAX];
        let mut e = 0f64;
        'tile: loop {
            for a in 0..m {
                g[a] = chunks[a] * rho + local[a];
            }
            if strict || g[..m].windows(2).all(|w| w[0] > w[1]) {
                e += self.energy(&g[..m]);
            }
            // Odometer over the ρ^m tile, axis 0 fastest.
            let mut i = 0;
            loop {
                if i == m {
                    break 'tile;
                }
                local[i] += 1;
                if local[i] < rho {
                    break;
                }
                local[i] = 0;
                i += 1;
            }
        }
        e
    }

    /// Brute-force reference: Σ over all `g_1 > g_2 > … > g_m`.
    pub fn reference(&self) -> f64 {
        let mut acc = 0f64;
        let mut tuple = Vec::with_capacity(self.m as usize);
        self.reference_rec(self.m, self.n, &mut tuple, &mut acc);
        acc
    }

    fn reference_rec(&self, remaining: u32, max_excl: u64, tuple: &mut Vec<u64>, acc: &mut f64) {
        if remaining == 0 {
            *acc += self.energy(tuple);
            return;
        }
        // Leave room for the (remaining - 1) strictly smaller indices.
        for g in (remaining as u64 - 1..max_excl).rev() {
            tuple.push(g);
            self.reference_rec(remaining - 1, g, tuple, acc);
            tuple.pop();
        }
    }
}

struct KTupleAccum {
    energy: f64,
}

impl Workload for KTupleWorkload {
    fn name(&self) -> &'static str {
        "ktuple"
    }

    fn m(&self) -> u32 {
        self.m
    }

    fn new_accum(&self) -> Accum {
        Box::new(KTupleAccum { energy: 0.0 })
    }

    fn process_block(&self, acc: &mut Accum, b: &MappedBlock) -> u64 {
        let a = acc.downcast_mut::<KTupleAccum>().expect("ktuple accum");
        let nb = self.n / self.rho as u64;
        let chunks = KTupleWorkload::block_chunks(nb, &b.data);
        a.energy += self.tile_rust(&chunks);
        KTupleWorkload::predicated_off(&chunks, self.rho)
    }

    fn finish(&self, accs: Vec<Accum>) -> Vec<(String, f64)> {
        let energy: f64 = accs
            .into_iter()
            .map(|acc| acc.downcast::<KTupleAccum>().expect("ktuple accum").energy)
            .sum();
        vec![("ktuple_energy".into(), energy)]
    }

    fn reference_outputs(&self) -> Vec<(String, f64)> {
        vec![("ktuple_energy".into(), self.reference())]
    }

    fn supports_pjrt(&self) -> bool {
        // Artifacts carry fixed shapes: ktuple_tile is lowered at
        // m = 4 chunks of R = rho_m points (python/compile/aot.py).
        // Every other arity honestly reports no pjrt path instead of
        // silently falling back.
        self.m == 4
    }

    fn run_pjrt(
        &self,
        exe: ExecHandle,
        blocks: &[MappedBlock],
    ) -> crate::runtime::Result<PjrtRun> {
        let mut batcher = TileBatcher::new(exe, "ktuple_tile")?;
        // Same split as the triple workload: strictly-decreasing chunk
        // tuples are full ρ^m tiles for the batched kernel; blocks with
        // repeated chunks predicate per-thread on the Rust path.
        let nb = self.n / self.rho as u64;
        let mut strict_tiles = Vec::new();
        let mut energy = 0f64;
        for b in blocks {
            let chunks = KTupleWorkload::block_chunks(nb, &b.data);
            if KTupleWorkload::block_is_strict(&chunks) {
                strict_tiles.push(TileInput {
                    block_id: strict_tiles.len() as u64,
                    inputs: chunks
                        .as_slice()
                        .iter()
                        .map(|&c| self.chunk(c).to_vec())
                        .collect(),
                });
            } else {
                energy += self.tile_rust(&chunks);
            }
        }
        let outs = batcher.run(&strict_tiles)?;
        energy += outs.iter().map(|o| o.data[0] as f64).sum::<f64>();
        Ok(PjrtRun {
            outputs: vec![("ktuple_energy".into(), energy)],
            batches_run: batcher.batches_run,
            tiles_padded: batcher.tiles_padded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{domain_volume, in_domain_m};
    use crate::simplex::block_m::OrthotopeM;

    fn simplex_blocks(nb: u64, m: u32) -> Vec<BlockM> {
        let dims = vec![nb; m as usize];
        OrthotopeM::new(&dims)
            .iter()
            .filter(|d| in_domain_m(nb, m, d))
            .collect()
    }

    #[test]
    fn block_chunks_bijective_over_domain() {
        for m in [3u32, 4, 5] {
            let nb = 5u64;
            let mut seen = std::collections::HashSet::new();
            for d in simplex_blocks(nb, m) {
                let c = KTupleWorkload::block_chunks(nb, &d);
                assert!(c[0] < nb, "{d:?} → {c:?}");
                for w in c.as_slice().windows(2) {
                    assert!(w[0] >= w[1], "{d:?} → {c:?} not descending");
                }
                assert!(seen.insert(c), "{d:?} duplicates {c:?}");
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, m), "m={m}");
        }
    }

    #[test]
    fn block_chunks_m2_uses_the_pair_convention() {
        // m=2 blocks are inclusive lower-triangle pairs (bc ≤ br), not
        // simplex coordinates; chunks are simply (br, bc), bijectively.
        let nb = 6u64;
        let mut seen = std::collections::HashSet::new();
        for d in simplex_blocks(nb, 2) {
            let c = KTupleWorkload::block_chunks(nb, &d);
            assert_eq!(c.as_slice(), &[d[1], d[0]], "{d:?}");
            assert!(seen.insert(c));
        }
        assert_eq!(seen.len() as u128, domain_volume(nb, 2));
    }

    #[test]
    fn pair_sweep_matches_reference_at_m2() {
        let (nb, rho) = (4u64, 4u32);
        let w = KTupleWorkload::generate(nb, rho, 2, 7);
        let mut total = 0f64;
        for d in simplex_blocks(nb, 2) {
            total += w.tile_rust(&KTupleWorkload::block_chunks(nb, &d));
        }
        let want = w.reference();
        assert!(
            (total - want).abs() < 1e-9 * want.abs().max(1.0),
            "{total} vs {want}"
        );
    }

    #[test]
    fn predicated_off_matches_brute_force() {
        // ρ^m − (strictly decreasing survivors), counted the slow way.
        fn brute(chunks: &BlockM, rho: u32) -> u64 {
            let m = chunks.m() as usize;
            let rho = rho as u64;
            let mut off = 0u64;
            let mut local = vec![0u64; m];
            let mut g = vec![0u64; m];
            'tile: loop {
                for a in 0..m {
                    g[a] = chunks[a] * rho + local[a];
                }
                if !g.windows(2).all(|w| w[0] > w[1]) {
                    off += 1;
                }
                let mut i = 0;
                loop {
                    if i == m {
                        break 'tile;
                    }
                    local[i] += 1;
                    if local[i] < rho {
                        break;
                    }
                    local[i] = 0;
                    i += 1;
                }
            }
            off
        }
        for (chunks, rho) in [
            (vec![3u64, 2, 1], 2u32),
            (vec![3, 3, 1], 2),
            (vec![2, 2, 2], 3),
            (vec![5, 3, 3, 0], 2),
            (vec![4, 4, 4, 4], 2),
            (vec![7, 2], 4),
            (vec![2, 2], 4),
        ] {
            let b = BlockM::from_slice(&chunks);
            assert_eq!(
                KTupleWorkload::predicated_off(&b, rho),
                brute(&b, rho),
                "{chunks:?} ρ={rho}"
            );
        }
    }

    #[test]
    fn block_chunks_agrees_with_triple_at_m3() {
        let nb = 6u64;
        for d in simplex_blocks(nb, 3) {
            let c = KTupleWorkload::block_chunks(nb, &d);
            let (ci, cj, ck) =
                crate::workloads::TripleWorkload::block_chunks(nb, d.to_fixed3());
            assert_eq!(c.as_slice(), &[ci, cj, ck], "{d:?}");
        }
    }

    #[test]
    fn energy_is_permutation_invariant() {
        let w = KTupleWorkload::generate(1, 8, 4, 3);
        let e1 = w.energy(&[6, 4, 2, 0]);
        let e2 = w.energy(&[0, 2, 4, 6]);
        let e3 = w.energy(&[4, 0, 6, 2]);
        assert!((e1 - e2).abs() < 1e-12 * e1.abs().max(1.0));
        assert!((e1 - e3).abs() < 1e-12 * e1.abs().max(1.0));
    }

    #[test]
    fn block_sweep_matches_reference() {
        // Sweeping every simplex block must reproduce the brute force
        // over all C(n, m) unique tuples — m = 4 and m = 5.
        for (m, nb, rho) in [(4u32, 4u64, 2u32), (5, 3, 2), (4, 3, 3)] {
            let w = KTupleWorkload::generate(nb, rho, m, 7);
            let mut total = 0f64;
            for d in simplex_blocks(nb, m) {
                let c = KTupleWorkload::block_chunks(nb, &d);
                total += w.tile_rust(&c);
            }
            let want = w.reference();
            assert!(
                (total - want).abs() < 1e-9 * want.abs().max(1.0),
                "m={m} nb={nb} ρ={rho}: {total} vs {want}"
            );
        }
    }

    #[test]
    fn pjrt_split_partitions_blocks_without_loss_or_double_count() {
        // The run_pjrt strict/non-strict split, executor-free: strict
        // blocks (the artifact's share) number exactly C(nb, m), and
        // the two partitions' energies sum to the brute-force
        // reference — no block lost, none double-counted.
        let (nb, rho, m) = (4u64, 2u32, 4u32);
        let w = KTupleWorkload::generate(nb, rho, m, 7);
        let (mut strict_e, mut pred_e, mut strict_n) = (0f64, 0f64, 0u128);
        for d in simplex_blocks(nb, m) {
            let c = KTupleWorkload::block_chunks(nb, &d);
            if KTupleWorkload::block_is_strict(&c) {
                strict_e += w.tile_rust(&c);
                strict_n += 1;
            } else {
                pred_e += w.tile_rust(&c);
            }
        }
        assert_eq!(strict_n, crate::simplex::volume::binomial(nb as u128, m as u128));
        let want = w.reference();
        let got = strict_e + pred_e;
        assert!(
            (got - want).abs() < 1e-9 * want.abs().max(1.0),
            "{got} vs {want}"
        );
    }

    #[test]
    fn strict_block_detection() {
        assert!(KTupleWorkload::block_is_strict(&BlockM::from_slice(&[
            5, 3, 2, 0
        ])));
        assert!(!KTupleWorkload::block_is_strict(&BlockM::from_slice(&[
            5, 3, 3, 0
        ])));
    }

    #[test]
    fn reference_visits_binomial_many_tuples() {
        // With a counting "energy" stand-in: the recursion must visit
        // exactly C(n, m) tuples. Verified through the domain identity
        // |Bm(nb)| = C(nb+m-1, m) and the per-block predicate instead.
        let m = 4u32;
        let (nb, rho) = (3u64, 2u32);
        let w = KTupleWorkload::generate(nb, rho, m, 1);
        let mut tuples = 0u64;
        for d in simplex_blocks(nb, m) {
            let c = KTupleWorkload::block_chunks(nb, &d);
            if KTupleWorkload::block_is_strict(&c) {
                tuples += (rho as u64).pow(m);
            } else {
                // Count predicated survivors the slow way.
                let rho64 = rho as u64;
                let mut local = [0u64; 8];
                let mut g = [0u64; 8];
                let md = m as usize;
                'tile: loop {
                    for a in 0..md {
                        g[a] = c[a] * rho64 + local[a];
                    }
                    if g[..md].windows(2).all(|p| p[0] > p[1]) {
                        tuples += 1;
                    }
                    let mut i = 0;
                    loop {
                        if i == md {
                            break 'tile;
                        }
                        local[i] += 1;
                        if local[i] < rho64 {
                            break;
                        }
                        local[i] = 0;
                        i += 1;
                    }
                }
            }
        }
        // C(6, 4) = 15.
        assert_eq!(tuples, 15, "n={} m={m}", w.n);
    }
}
