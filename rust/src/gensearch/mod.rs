//! §III.D — the general-m parameter study.
//!
//! The paper leaves the choice of reduction factor r and arity β as an
//! open optimization problem: minimize both `(1/r^m - β) - m!` (waste)
//! and `β^{log_{1/r} n}` (the correction term that delays coverage).
//! This module runs that optimization as a grid search and produces
//! the E8/E9 tables:
//!
//! - `table_eq29` — the r=1/2, β=2 waste blow-up (m! / (2^m-2) - 1),
//! - `search` — for each (m, β) with `r = m!^{-1/m}`: n₀, waste limit,
//!   finite waste at the first covered size,
//! - `pareto` — the (n₀, waste) Pareto frontier over β for each m.

use crate::simplex::recursive_set::{alpha_limit_half_beta2, GeneralSetParams};
use crate::simplex::volume::factorial;
use crate::util::json::Json;

/// One row of the parameter search.
#[derive(Clone, Debug)]
pub struct SearchRow {
    pub m: u32,
    pub beta: f64,
    pub r: f64,
    pub n0: Option<u64>,
    /// First size the *integer-discretized* set (the one `maps::lambda_m`
    /// actually launches) covers — the executable counterpart of n₀.
    pub n0_exec: Option<u64>,
    /// Asymptotic waste β/(m!-β).
    pub waste_limit: f64,
    /// Efficiency multiple over bounding-box: (m!-β)·(1 - o(1)).
    pub efficiency_vs_bb: f64,
}

impl SearchRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("m", self.m.into()),
            ("beta", self.beta.into()),
            ("r", self.r.into()),
            (
                "n0",
                self.n0.map(|v| Json::from(v)).unwrap_or(Json::Null),
            ),
            (
                "n0_exec",
                self.n0_exec.map(|v| Json::from(v)).unwrap_or(Json::Null),
            ),
            ("waste_limit", self.waste_limit.into()),
            ("efficiency_vs_bb", self.efficiency_vs_bb.into()),
        ])
    }
}

/// Run the (m, β) grid search with the paper parametrization.
pub fn search(m_range: (u32, u32), betas: &[f64], horizon: u64) -> Vec<SearchRow> {
    let mut rows = Vec::new();
    for m in m_range.0..=m_range.1 {
        for &beta in betas {
            if beta < 2.0 || beta >= factorial(m) as f64 {
                continue;
            }
            let p = GeneralSetParams::for_paper(m, beta);
            // Discrete scans need integer β and a u128-safe bound.
            let n0_exec = if beta.fract() == 0.0 {
                p.first_covered(2, horizon.min(4096))
            } else {
                None
            };
            rows.push(SearchRow {
                m,
                beta,
                r: p.r,
                n0: p.n0(horizon),
                n0_exec,
                waste_limit: p.waste_limit(),
                efficiency_vs_bb: factorial(m) as f64 / (1.0 + p.waste_limit()),
            });
        }
    }
    rows
}

/// The eq. 29 table: r=1/2, β=2 asymptotic waste for m = 2..=m_max.
pub fn table_eq29(m_max: u32) -> Vec<(u32, f64)> {
    (2..=m_max).map(|m| (m, alpha_limit_half_beta2(m))).collect()
}

/// Pareto frontier over β for one m: rows not dominated in both n₀ and
/// waste (smaller is better for both).
pub fn pareto(rows: &[SearchRow], m: u32) -> Vec<SearchRow> {
    let mut of_m: Vec<&SearchRow> = rows
        .iter()
        .filter(|r| r.m == m && r.n0.is_some())
        .collect();
    of_m.sort_by(|a, b| a.n0.cmp(&b.n0));
    let mut front: Vec<SearchRow> = Vec::new();
    let mut best_waste = f64::INFINITY;
    for r in of_m {
        if r.waste_limit < best_waste {
            best_waste = r.waste_limit;
            front.push(r.clone());
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq29_table_values() {
        let t = table_eq29(7);
        let get = |m: u32| t.iter().find(|(mm, _)| *mm == m).unwrap().1;
        assert!(get(2).abs() < 1e-12);
        assert!(get(3).abs() < 1e-12);
        assert!((get(5) - 3.0).abs() < 1e-12);
        assert!((get(7) - 39.0).abs() < 1e-12);
    }

    #[test]
    fn search_reproduces_cross_checked_n0() {
        // Cross-checked against an independent python evaluation:
        // (m=4, β=2) → 32; (m=5, β=2) → 512; (m=7, β=32) → 4096.
        let rows = search((4, 7), &[2.0, 8.0, 32.0], 1 << 40);
        let find = |m: u32, b: f64| {
            rows.iter()
                .find(|r| r.m == m && r.beta == b)
                .unwrap()
                .n0
                .unwrap()
        };
        assert_eq!(find(4, 2.0), 32);
        assert_eq!(find(5, 2.0), 512);
        assert_eq!(find(5, 8.0), 128);
        assert_eq!(find(7, 2.0), 65536);
        assert_eq!(find(7, 32.0), 4096);
    }

    #[test]
    fn n0_exec_matches_discrete_cross_check() {
        // Executable (integer-plan) first-covered sizes, python-checked:
        // (m=4, β=2) → 28; (m=5, β=16) → 17; (m=5, β=32) → 4.
        let rows = search((4, 5), &[2.0, 16.0, 32.0], 1 << 40);
        let find = |m: u32, b: f64| {
            rows.iter()
                .find(|r| r.m == m && r.beta == b)
                .unwrap()
                .n0_exec
                .unwrap()
        };
        assert_eq!(find(4, 2.0), 28);
        assert_eq!(find(5, 16.0), 17);
        assert_eq!(find(5, 32.0), 4);
    }

    #[test]
    fn n0_monotone_in_beta_and_waste_tradeoff() {
        let rows = search((5, 5), &[2.0, 4.0, 8.0, 16.0, 32.0, 64.0], 1 << 40);
        for w in rows.windows(2) {
            assert!(
                w[0].n0.unwrap() >= w[1].n0.unwrap(),
                "n0 must not grow with β"
            );
            assert!(w[0].waste_limit < w[1].waste_limit, "waste grows with β");
        }
    }

    #[test]
    fn efficiency_approaches_m_factorial_for_small_beta() {
        // "parallel space is practically m! times more efficient than a
        // bounding box" — for β ≪ m!.
        let rows = search((6, 6), &[2.0], 1 << 40);
        let eff = rows[0].efficiency_vs_bb;
        let mfact = factorial(6) as f64;
        assert!(eff > 0.99 * mfact, "eff={eff} vs m!={mfact}");
    }

    #[test]
    fn pareto_front_is_monotone() {
        let rows = search((5, 5), &[2.0, 4.0, 8.0, 16.0, 32.0], 1 << 40);
        let front = pareto(&rows, 5);
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[0].n0 <= w[1].n0);
            assert!(w[0].waste_limit > w[1].waste_limit);
        }
    }

    #[test]
    fn rows_serialize_to_json() {
        let rows = search((4, 4), &[2.0], 1 << 20);
        let j = rows[0].to_json();
        assert_eq!(j.get("m").unwrap().as_u64(), Some(4));
        assert!(j.get("n0").is_some());
    }
}
