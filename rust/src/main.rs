//! `simplexmap` CLI — leader entrypoint.
//!
//! Subcommands:
//!   report <volumes|maps|arity3|launches|general|avril|ries|nonpow2>
//!   search   --m 2..10 --betas 2,4,8,16,32 --horizon 2^40
//!   verify   --map <name> --nb <2^k> [--m 4..8]  exhaustive coverage check
//!   run      --workload edm --nb 64 --map lambda2 --backend serial|parallel|pjrt
//!            (--workload ktuple --m 4..8 runs the general-m subsystem;
//!             --workload gasket runs the Sierpiński-gasket CA)
//!   serve    --addr 127.0.0.1:7070 --mode reactor|threaded
//!            JSON-lines job server: the poll reactor multiplexes
//!            thousands of connections on one thread (default);
//!            threaded keeps one blocking thread per connection
//!   sweep    --workload edm --nb 64           all maps side by side
//!   client   run|sweep --addr 127.0.0.1:7070  wire client: submit a
//!            job or a sweep fan-out (--workload a,b --nbs 8,16
//!            --maps lambda2,bb --priority high --window 16) and
//!            stream the per-job frames; --no-stream polls paginated
//!            `results` pages instead; --resume <token> reattaches to
//!            a sweep from any connection (the ack prints the token)
//!   obs      snapshot|watch|bench-trajectory  observability client:
//!            snapshot/watch pull `{"cmd":"metrics"}` from a running
//!            server (--format prometheus for text exposition);
//!            bench-trajectory reports throughput across accumulated
//!            BENCH_*.json files in --dir
//!
//! `--help` prints the options.

use std::sync::Arc;

use simplexmap::analysis;
use simplexmap::coordinator::server::Server;
use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
use simplexmap::maps::{map2_by_name, map3_by_name, MThreadMap as _, ThreadMap};
use simplexmap::runtime::{artifact, ExecutorService};
use simplexmap::util::benchkit;
use simplexmap::util::cli::{flag, opt, Args};
use simplexmap::util::json::Json;

fn main() {
    let specs = vec![
        opt("nb", "problem size in blocks per side", Some("64")),
        opt("n", "reference n for volume tables", Some("4096")),
        opt("m", "dimension range for search, e.g. 2..10", Some("2..8")),
        opt("map", "thread map name", None),
        opt(
            "workload",
            "edm|collision|nbody|triple|cellular|trimatvec|ktuple[2-8]|gasket",
            Some("edm"),
        ),
        opt(
            "backend",
            "serial|parallel|pjrt (rust = legacy alias for parallel)",
            Some("parallel"),
        ),
        opt("seed", "workload RNG seed", Some("42")),
        opt("betas", "comma-separated arity values", Some("2,4,8,16,32")),
        opt("horizon", "n0 scan horizon", Some("1099511627776")),
        opt("addr", "server bind address", Some("127.0.0.1:7070")),
        opt("mode", "serve loop: reactor|threaded", Some("reactor")),
        opt("nbs", "client sweep sizes, comma-separated (default: --nb)", None),
        opt("maps", "client sweep maps, comma-separated (default: full roster)", None),
        opt("priority", "job priority: high|normal|low", Some("normal")),
        opt("window", "client sweep in-flight window", Some("16")),
        opt("limit", "client results page size", Some("64")),
        opt(
            "resume",
            "client: page an existing sweep by durable token (submits nothing)",
            None,
        ),
        flag("no-stream", "client sweep: poll paginated results instead of streaming"),
        opt("dir", "directory scanned for BENCH_*.json (obs)", Some(".")),
        opt("interval", "seconds between obs watch samples", Some("2")),
        opt("count", "obs watch samples before exit (0 = forever)", Some("0")),
        opt("format", "metrics exposition: json|prometheus", Some("json")),
        opt("workers", "worker threads", None),
        opt("artifacts", "artifacts directory", Some("artifacts")),
        opt("config", "TOML config file (CLI flags take precedence)", None),
        flag("help", "print usage"),
    ];
    let args = match Args::from_env(
        "simplexmap — recursive GPU maps for discrete orthogonal simplices",
        specs,
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.positional().is_empty() {
        eprintln!("{}", args.usage());
        eprintln!(
            "subcommands: report <table> | show | search | verify | run | sweep | serve | \
             client | obs"
        );
        std::process::exit(if args.flag("help") { 0 } else { 2 });
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<(), String> {
    match args.positional()[0].as_str() {
        "report" => report(args),
        "show" => show(args),
        "search" => search(args),
        "verify" => verify(args),
        "run" => run(args, false),
        "sweep" => run(args, true),
        "serve" => serve(args),
        "client" => client(args),
        "obs" => obs(args),
        other => Err(format!("unknown subcommand '{other}'")),
    }
}

fn report(args: &Args) -> Result<(), String> {
    let table = args
        .positional()
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("volumes");
    let n = args.get_u64("n").map_err(|e| e.to_string())?.unwrap();
    let nb = args.get_u64("nb").map_err(|e| e.to_string())?.unwrap();
    let out = match table {
        "volumes" => analysis::report_volumes(n, 8),
        "maps" => analysis::report_maps(nb),
        "arity3" => analysis::report_arity3(14),
        "launches" => analysis::report_launches(12),
        "general" => analysis::report_general(8),
        "avril" => analysis::report_avril(),
        "nonpow2" => analysis::report_nonpow2(),
        "ries" => analysis::report_ries(12),
        other => return Err(format!("unknown report '{other}'")),
    };
    println!("{out}");
    Ok(())
}

/// Render a map's coverage of the data simplex (Figs. 4, 6, 7).
fn show(args: &Args) -> Result<(), String> {
    let nb = args.get_u64("nb").map_err(|e| e.to_string())?.unwrap().min(64);
    let name = args.get("map").unwrap_or("lambda2").to_string();
    let map: Box<dyn ThreadMap> = map2_by_name(&name)
        .or_else(|| map3_by_name(&name))
        .ok_or(format!("unknown map '{name}'"))?;
    if !map.supports(nb) {
        return Err(format!("map {name} does not support nb={nb}"));
    }
    let rendered = if map.m() == 2 {
        simplexmap::analysis::viz::render_m2(map.as_ref(), nb)
    } else {
        simplexmap::analysis::viz::render_m3(map.as_ref(), nb)
    };
    println!("{name} coverage of the {}-simplex, nb={nb} (label = recursion level):", map.m());
    println!("{rendered}");
    Ok(())
}

fn search(args: &Args) -> Result<(), String> {
    let (lo, hi) = args
        .get_range("m")
        .map_err(|e| e.to_string())?
        .unwrap_or((2, 8));
    let betas: Vec<f64> = args
        .get("betas")
        .unwrap()
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let horizon = args.get_u64("horizon").map_err(|e| e.to_string())?.unwrap();
    println!(
        "{}",
        analysis::report_search(lo as u32, hi as u32, &betas, horizon)
    );
    Ok(())
}

/// Exhaustive coverage verification of a map at a given size — every
/// domain block covered exactly once, filler counted (E2/E6). With
/// `--m 4..8` the general-m registry is verified instead (E13).
fn verify(args: &Args) -> Result<(), String> {
    let nb = args.get_u64("nb").map_err(|e| e.to_string())?.unwrap();
    let name = args
        .get("map")
        .ok_or("verify needs --map <name>")?
        .to_string();
    // `--m <k>` (single value) pins the dimension: ≥ 4 goes through the
    // general-m registry, 2/3 disambiguate names registered at both
    // fixed dimensions (bb, enum, lambda-s, …). Without it, m=2 wins.
    let mut pinned_m: Option<u32> = None;
    if let Some((lo, hi)) = args.get_range("m").map_err(|e| e.to_string())? {
        if lo == hi && lo >= 4 {
            return verify_m(lo as u32, &name, nb);
        }
        if lo == hi {
            pinned_m = Some(lo as u32);
        }
    }
    if name.contains("gasket") {
        return verify_gasket(&name, nb);
    }
    let map: Box<dyn ThreadMap> = match pinned_m {
        Some(2) => map2_by_name(&name),
        Some(3) => map3_by_name(&name),
        Some(m) => return Err(format!("--m {m} is not a verifiable dimension (2..=8)")),
        None => map2_by_name(&name).or_else(|| map3_by_name(&name)),
    }
    .ok_or(format!("unknown map '{name}'"))?;
    if !map.supports(nb) {
        return Err(format!("map {name} does not support nb={nb}"));
    }
    let mut seen = std::collections::HashSet::new();
    let mut filler = 0u64;
    let mut dups = 0u64;
    let mut escaped = 0u64;
    for pass in 0..map.passes(nb) {
        for w in map.grid(nb, pass).iter() {
            match map.map_block(nb, pass, w) {
                None => filler += 1,
                Some(d) => {
                    if !simplexmap::maps::in_domain(nb, map.m(), d) {
                        escaped += 1;
                    } else if !seen.insert(d) {
                        dups += 1;
                    }
                }
            }
        }
    }
    let domain = simplexmap::maps::domain_volume(nb, map.m());
    let covered = seen.len() as u128;
    println!(
        "map={name} nb={nb}: domain={domain} covered={covered} dups={dups} \
         escaped={escaped} filler={filler} parallel={} passes={}",
        map.parallel_volume(nb),
        map.passes(nb)
    );
    if covered == domain && dups == 0 && escaped == 0 {
        println!("VERIFY OK: exact coverage");
        Ok(())
    } else {
        Err("coverage verification FAILED".into())
    }
}

/// Gasket-domain counterpart of `verify`: every mapped block must be a
/// gasket cell, each covered exactly once (E15).
fn verify_gasket(name: &str, nb: u64) -> Result<(), String> {
    use simplexmap::simplex::gasket;
    let map = simplexmap::maps::map_by_name(2, name)
        .filter(|m| m.domain() == gasket::DomainKind::Gasket)
        .ok_or(format!("unknown gasket map '{name}'"))?;
    if !map.supports(nb) {
        return Err(format!("map {name} does not support nb={nb} (needs 2^k)"));
    }
    let mut seen = std::collections::HashSet::new();
    let mut filler = 0u64;
    let mut dups = 0u64;
    let mut escaped = 0u64;
    for pass in 0..map.passes(nb) {
        for w in map.grid(nb, pass).iter() {
            match map.map_block(nb, pass, &w) {
                None => filler += 1,
                Some(d) => {
                    if !gasket::in_gasket(nb, d[0], d[1]) {
                        escaped += 1;
                    } else if !seen.insert((d[0], d[1])) {
                        dups += 1;
                    }
                }
            }
        }
    }
    let domain = map.domain_volume(nb);
    let covered = seen.len() as u128;
    println!(
        "map={name} domain=gasket nb={nb}: domain={domain} covered={covered} dups={dups} \
         escaped={escaped} filler={filler} parallel={} passes={}",
        map.parallel_volume(nb),
        map.passes(nb)
    );
    if covered == domain && dups == 0 && escaped == 0 {
        println!("VERIFY OK: exact coverage");
        Ok(())
    } else {
        Err("coverage verification FAILED".into())
    }
}

/// General-m counterpart of `verify` over the unified registry.
fn verify_m(m: u32, name: &str, nb: u64) -> Result<(), String> {
    let map = simplexmap::maps::map_by_name(m, name)
        .ok_or(format!("unknown map '{name}' for m={m}"))?;
    if !map.supports(nb) {
        return Err(format!("map {name} does not support nb={nb} at m={m}"));
    }
    let mut seen = std::collections::HashSet::new();
    let mut filler = 0u64;
    let mut dups = 0u64;
    let mut escaped = 0u64;
    for pass in 0..map.passes(nb) {
        for w in map.grid(nb, pass).iter() {
            match map.map_block(nb, pass, &w) {
                None => filler += 1,
                Some(d) => {
                    if !simplexmap::maps::in_domain_m(nb, m, &d) {
                        escaped += 1;
                    } else if !seen.insert(d) {
                        dups += 1;
                    }
                }
            }
        }
    }
    let domain = simplexmap::maps::domain_volume(nb, m);
    let covered = seen.len() as u128;
    println!(
        "map={name} m={m} nb={nb}: domain={domain} covered={covered} dups={dups} \
         escaped={escaped} filler={filler} parallel={} passes={}",
        map.parallel_volume(nb),
        map.passes(nb)
    );
    if covered == domain && dups == 0 && escaped == 0 {
        println!("VERIFY OK: exact coverage");
        Ok(())
    } else {
        Err("coverage verification FAILED".into())
    }
}

fn build_scheduler(
    args: &Args,
    need_pjrt: bool,
) -> Result<(Option<ExecutorService>, Scheduler), String> {
    // Precedence: CLI flag > config file > built-in default.
    let cfg = match args.get("config") {
        Some(path) => simplexmap::util::config::Config::load(std::path::Path::new(path))?,
        None => simplexmap::util::config::Config::default(),
    };
    let workers = args
        .get_usize("workers")
        .map_err(|e| e.to_string())?
        .or_else(|| cfg.get_int("coordinator", "workers").map(|v| v as usize))
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        });
    let pool = cfg.get_int("runtime", "pool").unwrap_or(2).max(1) as usize;
    let service = if need_pjrt {
        let dir = args
            .get("artifacts")
            .map(std::path::PathBuf::from)
            .or_else(|| cfg.get_str("runtime", "artifacts").map(Into::into))
            .unwrap_or_else(artifact::default_dir);
        Some(ExecutorService::spawn_pool(&dir, pool).map_err(|e| e.to_string())?)
    } else {
        None
    };
    let handle = service.as_ref().map(|s| s.handle());
    let mut sched = Scheduler::new(workers, handle);
    if let Some(r) = cfg.get_int("coordinator", "rho2") {
        sched.rho.rho2 = r as u32;
    }
    if let Some(r) = cfg.get_int("coordinator", "rho3") {
        sched.rho.rho3 = r as u32;
    }
    if let Some(r) = cfg.get_int("coordinator", "rho_m") {
        sched.rho.rho_m = r as u32;
    }
    if let Some(r) = cfg.get_int("coordinator", "rho_gasket") {
        sched.rho.rho_gasket = r as u32;
    }
    Ok((service, sched))
}

fn run(args: &Args, sweep: bool) -> Result<(), String> {
    let mut workload =
        WorkloadKind::parse(args.get("workload").unwrap()).ok_or("unknown workload")?;
    // `--m 4..8` (single value) retargets the ktuple arity, so
    // `run --workload ktuple --m 5` is the CLI door to the m-axis.
    if let WorkloadKind::KTuple(_) = workload {
        if let Some((lo, hi)) = args.get_range("m").map_err(|e| e.to_string())? {
            if lo == hi {
                workload = WorkloadKind::ktuple(lo as u32)
                    .ok_or(format!("ktuple arity {lo} outside 3..=8"))?;
            }
        }
    }
    let backend = Backend::parse(args.get("backend").unwrap()).ok_or("unknown backend")?;
    let nb = args.get_u64("nb").map_err(|e| e.to_string())?.unwrap();
    let seed = args.get_u64("seed").map_err(|e| e.to_string())?.unwrap();
    let (_svc, sched) = build_scheduler(args, backend == Backend::Pjrt)?;

    let gasket = workload.domain() == simplexmap::maps::DomainKind::Gasket;
    let maps: Vec<String> = if sweep {
        workload.sweep_maps()
    } else {
        let default = if gasket {
            "lambda-gasket"
        } else if workload.m() >= 4 {
            "lambda-m"
        } else {
            "lambda2"
        };
        vec![args.get("map").unwrap_or(default).to_string()]
    };

    for map in maps {
        let job = Job {
            workload,
            nb,
            map: map.clone(),
            backend,
            seed,
        };
        match sched.run(&job) {
            Ok(r) => println!("{}", r.to_json().to_string_compact()),
            Err(e) => eprintln!("map {map}: {e}"),
        }
    }
    Ok(())
}

/// Observability client: pull metrics from a running server, or report
/// the offline perf trajectory from accumulated bench exports.
fn obs(args: &Args) -> Result<(), String> {
    let action = args
        .positional()
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("snapshot");
    match action {
        "snapshot" => {
            println!("{}", obs_fetch(args)?);
            Ok(())
        }
        "watch" => {
            let interval: f64 = args
                .get("interval")
                .unwrap()
                .parse()
                .map_err(|_| "bad --interval (seconds)".to_string())?;
            let count = args.get_u64("count").map_err(|e| e.to_string())?.unwrap();
            let mut done = 0u64;
            loop {
                match obs_fetch(args) {
                    Ok(text) => println!("{text}"),
                    Err(e) => eprintln!("obs: {e}"),
                }
                done += 1;
                if count > 0 && done >= count {
                    return Ok(());
                }
                std::thread::sleep(std::time::Duration::from_secs_f64(interval.max(0.1)));
            }
        }
        "bench-trajectory" => {
            let dir = args.get("dir").unwrap();
            let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
                .map_err(|e| format!("cannot read {dir}: {e}"))?
                .filter_map(|entry| entry.ok())
                .map(|entry| entry.path())
                .filter(|p| {
                    p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                })
                .collect();
            files.sort();
            let snapshots: Vec<(String, String)> = files
                .iter()
                .filter_map(|p| {
                    let label = p.file_name()?.to_str()?.to_string();
                    std::fs::read_to_string(p).ok().map(|text| (label, text))
                })
                .collect();
            // An empty directory is a state, not an error: the report
            // says how to produce snapshots and we exit 0.
            print!("{}", benchkit::trajectory_report(&snapshots));
            Ok(())
        }
        other => Err(format!(
            "unknown obs action '{other}' (snapshot|watch|bench-trajectory)"
        )),
    }
}

/// One metrics request against `--addr`, rendered per `--format`.
fn obs_fetch(args: &Args) -> Result<String, String> {
    use std::io::{BufRead, BufReader, Write};
    let addr = args.get("addr").unwrap();
    let format = args.get("format").unwrap().to_string();
    let stream =
        std::net::TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let mut writer = stream.try_clone().map_err(|e| e.to_string())?;
    let req = if format == "prometheus" {
        "{\"cmd\":\"metrics\",\"format\":\"prometheus\"}\n"
    } else {
        "{\"cmd\":\"metrics\"}\n"
    };
    writer.write_all(req.as_bytes()).map_err(|e| e.to_string())?;
    writer.flush().map_err(|e| e.to_string())?;
    let mut line = String::new();
    BufReader::new(stream)
        .read_line(&mut line)
        .map_err(|e| e.to_string())?;
    let reply =
        simplexmap::util::json::parse(line.trim()).map_err(|e| format!("bad reply: {e}"))?;
    if reply.get("ok").and_then(Json::as_bool) != Some(true) {
        return Err(format!("server refused metrics request: {}", line.trim()));
    }
    if format == "prometheus" {
        Ok(reply
            .get("text")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string())
    } else {
        Ok(reply
            .get("metrics")
            .map(Json::to_string_compact)
            .unwrap_or_default())
    }
}

fn serve(args: &Args) -> Result<(), String> {
    // Load PJRT if artifacts are present; otherwise serve rust-only.
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifact::default_dir);
    let have_artifacts = dir.join("manifest.json").exists();
    let (_svc, sched) = build_scheduler(args, have_artifacts)?;
    if !have_artifacts {
        eprintln!("note: artifacts missing — pjrt backend disabled for this server");
    }
    let addr = args.get("addr").unwrap();
    let sched = Arc::new(sched);
    match args.get("mode").unwrap() {
        "threaded" => Server::new(sched)
            .serve(addr, |bound| eprintln!("listening on {bound} (threaded)"))
            .map_err(|e| e.to_string()),
        "reactor" => {
            let cfg = simplexmap::coordinator::ReactorConfig::from_env();
            simplexmap::coordinator::Reactor::with_config(sched, cfg)
                .serve(addr, |bound| eprintln!("listening on {bound} (reactor)"))
                .map_err(|e| e.to_string())
        }
        other => Err(format!("unknown serve mode '{other}' (reactor|threaded)")),
    }
}

/// Wire client: submit one `run` or a `sweep` fan-out over a single
/// connection and print each reply frame as it arrives.
fn client(args: &Args) -> Result<(), String> {
    use std::io::{BufRead, BufReader};
    let action = args.positional().get(1).map(|s| s.as_str()).unwrap_or("sweep");
    let addr = args.get("addr").unwrap();
    let stream =
        std::net::TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone().map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(stream);
    let mut read_frame = |what: &str| -> Result<Json, String> {
        let mut line = String::new();
        let n = reader
            .read_line(&mut line)
            .map_err(|e| format!("read {what}: {e}"))?;
        if n == 0 {
            return Err(format!("server closed the connection awaiting {what}"));
        }
        simplexmap::util::json::parse(line.trim()).map_err(|e| format!("bad {what}: {e}"))
    };

    // --resume <token>: reattach to a sweep started on a previous
    // (possibly dead) connection and page its stored rows by the
    // durable token. Submits nothing; works from any connection.
    if let Some(token) = args.get("resume") {
        let token = token.to_string();
        let limit = args.get_u64("limit").map_err(|e| e.to_string())?.unwrap();
        let mut cursor = 0u64;
        loop {
            let req = Json::obj(vec![
                ("cmd", "results".into()),
                ("token", token.clone().into()),
                ("cursor", cursor.into()),
                ("limit", limit.into()),
            ]);
            send_line(&mut writer, &req)?;
            let page = read_frame("results page")?;
            ok_or_err(&page)?;
            let jobs = page.get("jobs").and_then(Json::as_u64).unwrap_or(0);
            let rows = page.get("results").and_then(Json::as_arr).unwrap_or(&[]);
            let mut advanced = false;
            for row in rows {
                if matches!(row, Json::Null) {
                    break;
                }
                println!("{}", row.to_string_compact());
                cursor += 1;
                advanced = true;
            }
            if cursor >= jobs {
                return Ok(());
            }
            if !advanced {
                std::thread::sleep(std::time::Duration::from_millis(25));
            }
        }
    }

    let nb = args.get_u64("nb").map_err(|e| e.to_string())?.unwrap();
    let seed = args.get_u64("seed").map_err(|e| e.to_string())?.unwrap();
    let priority = args.get("priority").unwrap().to_string();
    let backend = args.get("backend").unwrap().to_string();
    match action {
        "run" => {
            let workload = args.get("workload").unwrap().to_string();
            let map = args.get("map").unwrap_or("lambda2").to_string();
            let req = Json::obj(vec![
                ("cmd", "run".into()),
                ("workload", workload.into()),
                ("nb", nb.into()),
                ("map", map.into()),
                ("backend", backend.into()),
                ("seed", seed.into()),
                ("priority", priority.into()),
            ]);
            send_line(&mut writer, &req)?;
            let reply = read_frame("reply")?;
            println!("{}", reply.to_string_compact());
            ok_or_err(&reply)
        }
        "sweep" => {
            let comma = |key: &str| -> Option<Vec<Json>> {
                args.get(key).map(|s| {
                    s.split(',')
                        .map(|p| Json::from(p.trim()))
                        .collect::<Vec<Json>>()
                })
            };
            let workloads =
                comma("workload").ok_or("client sweep needs --workload a[,b,…]")?;
            let nbs: Vec<Json> = match args.get("nbs") {
                None => vec![nb.into()],
                Some(s) => {
                    let mut out = Vec::new();
                    for p in s.split(',') {
                        let v: u64 = p
                            .trim()
                            .parse()
                            .map_err(|_| format!("bad --nbs entry '{}'", p.trim()))?;
                        out.push(v.into());
                    }
                    out
                }
            };
            let window = args.get_u64("window").map_err(|e| e.to_string())?.unwrap();
            let stream_frames = !args.flag("no-stream");
            let mut pairs = vec![
                ("cmd", Json::from("sweep")),
                ("workloads", Json::Arr(workloads)),
                ("nbs", Json::Arr(nbs)),
                ("backend", backend.into()),
                ("seed", seed.into()),
                ("priority", priority.into()),
                ("window", window.into()),
                ("stream", stream_frames.into()),
            ];
            if let Some(maps) = comma("maps") {
                pairs.push(("maps", Json::Arr(maps)));
            }
            send_line(&mut writer, &Json::obj(pairs))?;
            let ack = read_frame("sweep ack")?;
            println!("{}", ack.to_string_compact());
            ok_or_err(&ack)?;
            let sid = ack.get("sweep").and_then(Json::as_u64).ok_or("ack has no sweep id")?;
            let jobs = ack.get("jobs").and_then(Json::as_u64).unwrap_or(0);
            if stream_frames {
                loop {
                    let frame = read_frame("stream frame")?;
                    println!("{}", frame.to_string_compact());
                    if frame.get("done").and_then(Json::as_bool) == Some(true) {
                        return Ok(());
                    }
                }
            }
            // --no-stream: walk the paginated `results` pages, printing
            // the monotone prefix of completed rows until all arrive.
            let limit = args.get_u64("limit").map_err(|e| e.to_string())?.unwrap();
            let mut cursor = 0u64;
            while cursor < jobs {
                let req = Json::obj(vec![
                    ("cmd", "results".into()),
                    ("sweep", sid.into()),
                    ("cursor", cursor.into()),
                    ("limit", limit.into()),
                ]);
                send_line(&mut writer, &req)?;
                let page = read_frame("results page")?;
                ok_or_err(&page)?;
                let rows = page.get("results").and_then(Json::as_arr).unwrap_or(&[]);
                let mut advanced = false;
                for row in rows {
                    if matches!(row, Json::Null) {
                        break;
                    }
                    println!("{}", row.to_string_compact());
                    cursor += 1;
                    advanced = true;
                }
                if !advanced {
                    std::thread::sleep(std::time::Duration::from_millis(25));
                }
            }
            Ok(())
        }
        other => Err(format!("unknown client action '{other}' (run|sweep)")),
    }
}

fn send_line(writer: &mut std::net::TcpStream, req: &Json) -> Result<(), String> {
    use std::io::Write;
    let mut line = req.to_string_compact();
    line.push('\n');
    writer
        .write_all(line.as_bytes())
        .and_then(|()| writer.flush())
        .map_err(|e| format!("send: {e}"))
}

fn ok_or_err(reply: &Json) -> Result<(), String> {
    if reply.get("ok").and_then(Json::as_bool) == Some(true) {
        Ok(())
    } else {
        Err(reply
            .get("error")
            .and_then(Json::as_str)
            .unwrap_or("server refused the request")
            .to_string())
    }
}
