//! Tile batcher: the bridge between λ-mapped blocks and the fixed-shape
//! AOT executables.
//!
//! The artifacts are compiled for a fixed batch `B` of tiles (see
//! python/compile/aot.py); real workloads produce an arbitrary number
//! of mapped blocks. The batcher packs tile operands into `B`-sized
//! batches, zero-pads the last one, executes, and hands each tile's
//! output slice back with its block identity. Padding tiles are
//! computed and discarded — exactly like the filler threads of a
//! bounding-box launch, but bounded by `B-1` tiles per job.
//!
//! The packing arithmetic lives in [`BatchPlan`], separated from the
//! executor handle so the zero-padding, scalar passthrough and
//! `tiles_padded` accounting are unit-testable without artifacts (the
//! executor-backed path is exercised by rust/tests/coordinator_e2e.rs).

use crate::runtime::{ArtifactSpec, ExecHandle, Result, TensorF32};

/// One tile's operands: `inputs[i]` is the flat f32 chunk for artifact
/// input `i` (length = per-tile element count of that input).
#[derive(Clone, Debug)]
pub struct TileInput {
    pub block_id: u64,
    pub inputs: Vec<Vec<f32>>,
}

/// One tile's output slice.
#[derive(Clone, Debug)]
pub struct TileOutput {
    pub block_id: u64,
    pub data: Vec<f32>,
}

/// The pure packing arithmetic of one artifact: batch size, per-tile
/// element counts, and batch assembly with zero padding and scalar
/// passthrough. No executor, no I/O.
struct BatchPlan {
    batch: usize,
    per_tile_in: Vec<usize>,
    per_tile_out: usize,
    /// Extra leading inputs shared by every tile (e.g. the scalar
    /// threshold of edm_threshold), passed through unbatched.
    scalar_inputs: Vec<TensorF32>,
}

impl BatchPlan {
    /// Derive the plan from an artifact spec: batched inputs are the
    /// *leading run* of inputs whose first dimension equals the
    /// output's batch dimension; everything after them is a shared
    /// (unbatched) trailing input. Only the leading run counts — a
    /// batch-shaped input *after* a scalar belongs to the scalar tail,
    /// and counting it (the pre-PR-6 `filter(...).count()`) would slice
    /// the scalar into the batched prefix and corrupt the plan.
    fn from_spec(spec: &ArtifactSpec) -> BatchPlan {
        let batch = spec.output_shape[0];
        let batched = spec
            .input_shapes
            .iter()
            .take_while(|s| !s.is_empty() && s[0] == batch)
            .count();
        let per_tile_in = spec.input_shapes[..batched]
            .iter()
            .map(|s| s[1..].iter().product::<usize>())
            .collect();
        let per_tile_out = spec.output_shape[1..].iter().product::<usize>().max(1);
        BatchPlan {
            batch,
            per_tile_in,
            per_tile_out,
            scalar_inputs: Vec::new(),
        }
    }

    /// Tiles zero-padded when a chunk of `chunk_len` tiles fills one
    /// batch (0 except possibly for the last chunk).
    fn padding(&self, chunk_len: usize) -> u64 {
        debug_assert!(chunk_len <= self.batch && chunk_len > 0);
        (self.batch - chunk_len) as u64
    }

    /// Pack one chunk (≤ batch tiles) into the artifact's input
    /// tensors: batched inputs are tile chunks back to back with the
    /// tail left zero, then every scalar input appended untouched.
    fn assemble(&self, input_shapes: &[Vec<usize>], chunk: &[TileInput]) -> Vec<TensorF32> {
        let n_batched = self.per_tile_in.len();
        let mut inputs: Vec<TensorF32> =
            Vec::with_capacity(n_batched + self.scalar_inputs.len());
        for (i, &per_tile) in self.per_tile_in.iter().enumerate() {
            let mut flat = vec![0f32; self.batch * per_tile];
            for (t, tile) in chunk.iter().enumerate() {
                debug_assert_eq!(tile.inputs[i].len(), per_tile);
                flat[t * per_tile..(t + 1) * per_tile].copy_from_slice(&tile.inputs[i]);
            }
            inputs.push(TensorF32::new(input_shapes[i].clone(), flat));
        }
        inputs.extend(self.scalar_inputs.iter().cloned());
        inputs
    }
}

/// Batches tiles through one artifact.
pub struct TileBatcher {
    exe: ExecHandle,
    artifact: String,
    plan: BatchPlan,
    pub batches_run: u64,
    pub tiles_padded: u64,
}

impl TileBatcher {
    /// `artifact` must have all batched inputs shaped (B, ...) and the
    /// output shaped (B, ...); trailing scalar inputs are configured
    /// via `with_scalar`.
    pub fn new(exe: ExecHandle, artifact: &str) -> Result<TileBatcher> {
        let plan = BatchPlan::from_spec(exe.spec(artifact)?);
        Ok(TileBatcher {
            exe,
            artifact: artifact.to_string(),
            plan,
            batches_run: 0,
            tiles_padded: 0,
        })
    }

    /// Append a shared (unbatched) trailing input.
    pub fn with_scalar(mut self, t: TensorF32) -> Self {
        self.plan.scalar_inputs.push(t);
        self
    }

    /// Tiles per executable call.
    pub fn batch_size(&self) -> usize {
        self.plan.batch
    }

    /// Execute all tiles, preserving input order in the output.
    ///
    /// Batches are *dispatched asynchronously* and round-robin over the
    /// executor pool, so up to `pool_size` batches run concurrently
    /// while this thread assembles the next operands (§Perf: 2.1x on
    /// a 4-thread pool vs the serial loop).
    pub fn run(&mut self, tiles: &[TileInput]) -> Result<Vec<TileOutput>> {
        let spec = self.exe.spec(&self.artifact)?.clone();
        let mut pending = Vec::new();
        for chunk in tiles.chunks(self.plan.batch) {
            let inputs = self.plan.assemble(&spec.input_shapes, chunk);
            let rx = self.exe.run_f32_async(&self.artifact, inputs)?;
            self.batches_run += 1;
            self.tiles_padded += self.plan.padding(chunk.len());
            pending.push((chunk, rx));
        }
        let mut out = Vec::with_capacity(tiles.len());
        for (chunk, rx) in pending {
            let result = rx
                .recv()
                .map_err(|_| crate::runtime::RuntimeError::Xla("executor dropped reply".into()))??;
            let per_out = self.plan.per_tile_out;
            out.extend(chunk.iter().enumerate().map(|(t, tile)| TileOutput {
                block_id: tile.block_id,
                data: result.data[t * per_out..(t + 1) * per_out].to_vec(),
            }));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    // Pure packing-logic tests on BatchPlan — no executor, no
    // artifacts. The executor-backed end-to-end path lives in
    // rust/tests/coordinator_e2e.rs.
    use super::*;

    fn spec(input_shapes: Vec<Vec<usize>>, output_shape: Vec<usize>) -> ArtifactSpec {
        ArtifactSpec {
            name: "test".into(),
            path: std::path::PathBuf::from("test.hlo.txt"),
            input_shapes,
            output_shape,
        }
    }

    fn tile(block_id: u64, inputs: Vec<Vec<f32>>) -> TileInput {
        TileInput { block_id, inputs }
    }

    #[test]
    fn plan_derives_batched_and_scalar_split_from_spec() {
        // Two batched (B=4) inputs of 6 and 2 elements per tile, one
        // trailing scalar input: the plan batches exactly the first two.
        let s = spec(
            vec![vec![4, 2, 3], vec![4, 2], vec![1]],
            vec![4, 5],
        );
        let plan = BatchPlan::from_spec(&s);
        assert_eq!(plan.batch, 4);
        assert_eq!(plan.per_tile_in, vec![6, 2]);
        assert_eq!(plan.per_tile_out, 5);
        // Scalar-output artifact: per_tile_out floors at 1.
        let s1 = spec(vec![vec![8, 2]], vec![8]);
        assert_eq!(BatchPlan::from_spec(&s1).per_tile_out, 1);
    }

    #[test]
    fn interior_scalar_ends_the_batched_prefix() {
        // Regression (PR 6): an artifact shaped [B,..], [1], [B,..] —
        // a batch-shaped input *after* a scalar. The old
        // `filter(...).count()` counted both batch-shaped inputs (2)
        // and then sliced `input_shapes[..2]`, misclassifying the
        // scalar `[1]` as a 1-element batched input. Only the leading
        // run is batched; everything from the first non-batch input on
        // is the shared tail.
        let s = spec(vec![vec![4, 2], vec![1], vec![4, 3]], vec![4, 5]);
        let plan = BatchPlan::from_spec(&s);
        assert_eq!(plan.per_tile_in, vec![2], "only the leading run batches");
        // Assembly packs exactly one batched tensor; the tail inputs
        // are the caller's scalar_inputs, not sliced tile chunks.
        let chunk = [tile(0, vec![vec![1.0, 2.0]])];
        let inputs = plan.assemble(&s.input_shapes, &chunk);
        assert_eq!(inputs.len(), 1);
        assert_eq!(inputs[0].shape, vec![4, 2]);
        // A scalar-led spec batches nothing at all.
        let s = spec(vec![vec![1], vec![4, 2]], vec![4, 1]);
        assert_eq!(BatchPlan::from_spec(&s).per_tile_in, Vec::<usize>::new());
    }

    #[test]
    fn last_batch_is_zero_padded() {
        // 3 tiles into B=4: the 4th slot of every batched input must be
        // exactly zero, and the live slots must carry the tile data.
        let s = spec(vec![vec![4, 2]], vec![4, 1]);
        let plan = BatchPlan::from_spec(&s);
        let chunk = [
            tile(0, vec![vec![1.0, 2.0]]),
            tile(1, vec![vec![3.0, 4.0]]),
            tile(2, vec![vec![5.0, 6.0]]),
        ];
        let inputs = plan.assemble(&s.input_shapes, &chunk);
        assert_eq!(inputs.len(), 1);
        assert_eq!(inputs[0].shape, vec![4, 2]);
        assert_eq!(
            inputs[0].data,
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 0.0, 0.0],
            "tail slot zero-padded"
        );
        assert_eq!(plan.padding(chunk.len()), 1);
        assert_eq!(plan.padding(4), 0, "full batches pad nothing");
    }

    #[test]
    fn scalar_inputs_pass_through_unbatched() {
        let s = spec(vec![vec![2, 2], vec![1]], vec![2, 1]);
        let mut plan = BatchPlan::from_spec(&s);
        plan.scalar_inputs.push(TensorF32::new(vec![1], vec![0.25]));
        let chunk = [tile(7, vec![vec![1.0, 1.0]])];
        let inputs = plan.assemble(&s.input_shapes, &chunk);
        assert_eq!(inputs.len(), 2, "one batched + one scalar");
        assert_eq!(inputs[1].shape, vec![1]);
        assert_eq!(inputs[1].data, vec![0.25], "scalar untouched by padding");
        // The scalar rides along on *every* batch identically.
        let again = plan.assemble(&s.input_shapes, &chunk);
        assert_eq!(again[1].data, vec![0.25]);
    }

    #[test]
    fn tiles_padded_accounting_over_a_chunked_run() {
        // 130 tiles at B=64 → 3 batches; only the last pads (62): the
        // accounting loop `run` performs, driven without an executor.
        let s = spec(vec![vec![64, 1]], vec![64, 1]);
        let plan = BatchPlan::from_spec(&s);
        let tiles: Vec<TileInput> = (0..130).map(|i| tile(i, vec![vec![i as f32]])).collect();
        let mut batches = 0u64;
        let mut padded = 0u64;
        for chunk in tiles.chunks(plan.batch) {
            batches += 1;
            padded += plan.padding(chunk.len());
        }
        assert_eq!(batches, 3);
        assert_eq!(padded, 62);
        // Exact multiples pad zero tiles across all batches.
        let mut padded_exact = 0u64;
        for chunk in tiles[..128].chunks(plan.batch) {
            padded_exact += plan.padding(chunk.len());
        }
        assert_eq!(padded_exact, 0);
    }

    #[test]
    fn multi_input_tiles_pack_in_slot_order() {
        // Both batched inputs must land in the same tile slot.
        let s = spec(vec![vec![2, 1], vec![2, 2]], vec![2, 1]);
        let plan = BatchPlan::from_spec(&s);
        let chunk = [
            tile(0, vec![vec![10.0], vec![1.0, 2.0]]),
            tile(1, vec![vec![20.0], vec![3.0, 4.0]]),
        ];
        let inputs = plan.assemble(&s.input_shapes, &chunk);
        assert_eq!(inputs[0].data, vec![10.0, 20.0]);
        assert_eq!(inputs[1].data, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
