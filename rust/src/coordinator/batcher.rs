//! Tile batcher: the bridge between λ-mapped blocks and the fixed-shape
//! AOT executables.
//!
//! The artifacts are compiled for a fixed batch `B` of tiles (see
//! python/compile/aot.py); real workloads produce an arbitrary number
//! of mapped blocks. The batcher packs tile operands into `B`-sized
//! batches, zero-pads the last one, executes, and hands each tile's
//! output slice back with its block identity. Padding tiles are
//! computed and discarded — exactly like the filler threads of a
//! bounding-box launch, but bounded by `B-1` tiles per job.

use crate::runtime::{ExecHandle, Result, TensorF32};

/// One tile's operands: `inputs[i]` is the flat f32 chunk for artifact
/// input `i` (length = per-tile element count of that input).
#[derive(Clone, Debug)]
pub struct TileInput {
    pub block_id: u64,
    pub inputs: Vec<Vec<f32>>,
}

/// One tile's output slice.
#[derive(Clone, Debug)]
pub struct TileOutput {
    pub block_id: u64,
    pub data: Vec<f32>,
}

/// Batches tiles through one artifact.
pub struct TileBatcher {
    exe: ExecHandle,
    artifact: String,
    batch: usize,
    per_tile_in: Vec<usize>,
    per_tile_out: usize,
    /// Extra leading inputs shared by every tile (e.g. the scalar
    /// threshold of edm_threshold), passed through unbatched.
    scalar_inputs: Vec<TensorF32>,
    pub batches_run: u64,
    pub tiles_padded: u64,
}

impl TileBatcher {
    /// `artifact` must have all batched inputs shaped (B, ...) and the
    /// output shaped (B, ...); trailing scalar inputs are configured
    /// via `with_scalar`.
    pub fn new(exe: ExecHandle, artifact: &str) -> Result<TileBatcher> {
        let spec = exe.spec(artifact)?;
        let batch = spec.output_shape[0];
        let batched = spec
            .input_shapes
            .iter()
            .filter(|s| !s.is_empty() && s[0] == batch)
            .count();
        let per_tile_in = spec.input_shapes[..batched]
            .iter()
            .map(|s| s[1..].iter().product::<usize>())
            .collect();
        let per_tile_out = spec.output_shape[1..].iter().product::<usize>().max(1);
        Ok(TileBatcher {
            exe,
            artifact: artifact.to_string(),
            batch,
            per_tile_in,
            per_tile_out,
            scalar_inputs: Vec::new(),
            batches_run: 0,
            tiles_padded: 0,
        })
    }

    /// Append a shared (unbatched) trailing input.
    pub fn with_scalar(mut self, t: TensorF32) -> Self {
        self.scalar_inputs.push(t);
        self
    }

    /// Tiles per executable call.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Execute all tiles, preserving input order in the output.
    ///
    /// Batches are *dispatched asynchronously* and round-robin over the
    /// executor pool, so up to `pool_size` batches run concurrently
    /// while this thread assembles the next operands (§Perf: 2.1x on
    /// a 4-thread pool vs the serial loop).
    pub fn run(&mut self, tiles: &[TileInput]) -> Result<Vec<TileOutput>> {
        let spec = self.exe.spec(&self.artifact)?.clone();
        let mut pending = Vec::new();
        for chunk in tiles.chunks(self.batch) {
            let inputs = self.assemble(&spec, chunk)?;
            let rx = self.exe.run_f32_async(&self.artifact, inputs)?;
            self.batches_run += 1;
            self.tiles_padded += (self.batch - chunk.len()) as u64;
            pending.push((chunk, rx));
        }
        let mut out = Vec::with_capacity(tiles.len());
        for (chunk, rx) in pending {
            let result = rx
                .recv()
                .map_err(|_| crate::runtime::RuntimeError::Xla("executor dropped reply".into()))??;
            out.extend(chunk.iter().enumerate().map(|(t, tile)| TileOutput {
                block_id: tile.block_id,
                data: result.data[t * self.per_tile_out..(t + 1) * self.per_tile_out]
                    .to_vec(),
            }));
        }
        Ok(out)
    }

    fn assemble(
        &self,
        spec: &crate::runtime::ArtifactSpec,
        chunk: &[TileInput],
    ) -> Result<Vec<TensorF32>> {
        let n_batched = self.per_tile_in.len();
        let mut inputs: Vec<TensorF32> = Vec::with_capacity(n_batched + 1);
        for (i, &per_tile) in self.per_tile_in.iter().enumerate() {
            let mut flat = vec![0f32; self.batch * per_tile];
            for (t, tile) in chunk.iter().enumerate() {
                debug_assert_eq!(tile.inputs[i].len(), per_tile);
                flat[t * per_tile..(t + 1) * per_tile].copy_from_slice(&tile.inputs[i]);
            }
            inputs.push(TensorF32::new(spec.input_shapes[i].clone(), flat));
        }
        inputs.extend(self.scalar_inputs.iter().cloned());
        Ok(inputs)
    }
}

#[cfg(test)]
mod tests {
    // Pure logic tests for batch arithmetic; executor-backed tests are
    // in rust/tests/coordinator_e2e.rs (require artifacts).

    #[test]
    fn chunking_math() {
        // 130 tiles at B=64 → 3 batches, 62 padded in the last.
        let tiles = 130usize;
        let batch = 64usize;
        let batches = tiles.div_ceil(batch);
        assert_eq!(batches, 3);
        assert_eq!(batches * batch - tiles, 62);
    }
}
