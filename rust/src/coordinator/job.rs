//! Job model: what a client asks the coordinator to run, and what it
//! gets back. JSON-serializable (hand-rolled `util::json`) for the
//! TCP server and the CLI.

use crate::util::json::Json;

/// Which paper workload to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Euclidean distance matrix (2-simplex) — [13], [12], [22].
    Edm,
    /// AABB collision culling (2-simplex) — [1].
    Collision,
    /// Pairwise gravitational n-body (2-simplex) — [23], [2].
    NBody,
    /// Triple-interaction Axilrod–Teller (3-simplex) — [11], [6].
    Triple,
    /// Cellular automaton on a triangular domain (2-simplex) — [4].
    Cellular,
    /// Triangular matrix-vector product (2-simplex) — [21], [5].
    TriMatVec,
    /// Unique k-tuple interaction (m-simplex, 2 ≤ m ≤ 8) — the
    /// general-m workload; the payload is the tuple arity. Arity 2 is
    /// the pair-style regression case: it must share launch geometry
    /// with the dedicated pair workloads under the same map.
    KTuple(u32),
    /// Mod-sum cellular automaton on the embedded Sierpiński gasket —
    /// the first non-simplex domain (arXiv:1706.04552). Runs exactly on
    /// the gasket maps, or (with extra predication waste) under any
    /// m = 2 simplex map that covers the inclusive triangle.
    GasketCA,
}

impl WorkloadKind {
    pub fn parse(s: &str) -> Option<WorkloadKind> {
        match s {
            "edm" => Some(WorkloadKind::Edm),
            "collision" => Some(WorkloadKind::Collision),
            "nbody" => Some(WorkloadKind::NBody),
            "triple" => Some(WorkloadKind::Triple),
            "cellular" => Some(WorkloadKind::Cellular),
            "trimatvec" => Some(WorkloadKind::TriMatVec),
            "gasket" | "gasket-ca" => Some(WorkloadKind::GasketCA),
            // "ktuple" defaults to quadruples; "ktuple<m>" pins the arity.
            "ktuple" => Some(WorkloadKind::KTuple(4)),
            _ => {
                let m: u32 = s.strip_prefix("ktuple")?.parse().ok()?;
                WorkloadKind::ktuple(m)
            }
        }
    }

    /// The k-tuple workload at arity m, when m is executable.
    pub fn ktuple(m: u32) -> Option<WorkloadKind> {
        if (2..=8).contains(&m) {
            Some(WorkloadKind::KTuple(m))
        } else {
            None
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::Edm => "edm",
            WorkloadKind::Collision => "collision",
            WorkloadKind::NBody => "nbody",
            WorkloadKind::Triple => "triple",
            WorkloadKind::Cellular => "cellular",
            WorkloadKind::TriMatVec => "trimatvec",
            WorkloadKind::KTuple(2) => "ktuple2",
            WorkloadKind::KTuple(3) => "ktuple3",
            WorkloadKind::KTuple(4) => "ktuple4",
            WorkloadKind::KTuple(5) => "ktuple5",
            WorkloadKind::KTuple(6) => "ktuple6",
            WorkloadKind::KTuple(7) => "ktuple7",
            WorkloadKind::KTuple(_) => "ktuple8",
            WorkloadKind::GasketCA => "gasket",
        }
    }

    /// Dimensionality of this workload's block-level domain.
    pub fn m(&self) -> u32 {
        match self {
            WorkloadKind::Triple => 3,
            WorkloadKind::KTuple(m) => *m,
            _ => 2,
        }
    }

    /// Which block-level data domain the workload consumes. The
    /// scheduler uses this for ρ selection and to reject maps that
    /// cover a *smaller* domain than the workload needs.
    pub fn domain(&self) -> crate::simplex::gasket::DomainKind {
        match self {
            WorkloadKind::GasketCA => crate::simplex::gasket::DomainKind::Gasket,
            _ => crate::simplex::gasket::DomainKind::Simplex,
        }
    }

    /// The map roster a `sweep` runs this workload against — shared by
    /// the CLI `sweep` subcommand and the server's `{"cmd":"sweep"}`
    /// fan-out so wire and local sweeps stay row-for-row identical.
    pub fn sweep_maps(&self) -> Vec<String> {
        if self.domain() == crate::simplex::gasket::DomainKind::Gasket {
            // The dedicated gasket maps, plus two simplex covers to
            // show the predication waste they pay on a fractal domain.
            ["bb-gasket", "lambda-gasket", "bb", "lambda2"]
                .iter()
                .map(|s| s.to_string())
                .collect()
        } else if self.m() >= 4 {
            crate::maps::map_names(self.m())
        } else {
            let fixed: &[&str] = if self.m() == 2 {
                &["bb", "lambda2", "enum2", "rb", "ries", "lambda-s"]
            } else {
                &["bb", "lambda3", "enum3", "lambda-s", "lambda-sw"]
            };
            fixed.iter().map(|s| s.to_string()).collect()
        }
    }

    pub const ALL: &'static [WorkloadKind] = &[
        WorkloadKind::Edm,
        WorkloadKind::Collision,
        WorkloadKind::NBody,
        WorkloadKind::Triple,
        WorkloadKind::Cellular,
        WorkloadKind::TriMatVec,
        WorkloadKind::KTuple(4),
        WorkloadKind::KTuple(5),
        WorkloadKind::GasketCA,
    ];
}

/// Where a job executes: the launcher's execution axis
/// ([`BackendKind::Serial`] reference sweep, [`BackendKind::Parallel`]
/// worker pool, or the [`BackendKind::Pjrt`] tile path). The wire name
/// `"rust"` is accepted as a legacy alias for `"parallel"`.
pub use crate::grid::BackendKind;

/// Pre-PR-6 name for the execution axis, kept for callers that spell
/// `Backend::Pjrt` etc.
pub type Backend = BackendKind;

/// A job request.
#[derive(Clone, Debug)]
pub struct Job {
    pub workload: WorkloadKind,
    /// Problem size in blocks per side (threads = nb · ρ).
    pub nb: u64,
    pub map: String,
    pub backend: BackendKind,
    pub seed: u64,
}

impl Job {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workload", self.workload.name().into()),
            ("nb", self.nb.into()),
            ("map", self.map.as_str().into()),
            ("backend", self.backend.name().into()),
            ("seed", self.seed.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Job> {
        Some(Job {
            workload: WorkloadKind::parse(j.get("workload")?.as_str()?)?,
            nb: j.get("nb")?.as_u64()?,
            map: j.get("map")?.as_str()?.to_string(),
            backend: j
                .get("backend")
                .and_then(Json::as_str)
                .and_then(BackendKind::parse)
                .unwrap_or(BackendKind::Parallel),
            seed: j.get("seed").and_then(Json::as_u64).unwrap_or(42),
        })
    }
}

/// A completed job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub job: Job,
    /// Workload-specific scalar outputs (checksums, counts, energies).
    pub outputs: Vec<(String, f64)>,
    pub passes: u64,
    /// Serialized launch waves: ceil(passes / max_concurrent).
    pub launch_waves: u64,
    pub blocks_launched: u64,
    /// Blocks the map discarded before they reached the kernel.
    pub blocks_filler: u64,
    pub blocks_mapped: u64,
    pub threads_launched: u64,
    pub threads_mapped: u64,
    /// Threads the workload's thread-level predicate discarded
    /// (diagonal blocks) — identical across the serial/parallel
    /// backends' streaming and collect modes. The pjrt backend reports
    /// 0 (its predication happens tile-side; see `scheduler::run_pjrt`).
    pub threads_predicated_off: u64,
    pub wall_secs: f64,
    pub tile_batches: u64,
    /// Per-lane launcher profile — empty unless the scheduler ran with
    /// lane profiling on (`SIMPLEXMAP_PROFILE_LANES=1`).
    pub lane_profile: Vec<crate::grid::LaneProfile>,
    /// max/mean lane-busy ratio when profiled (`None` otherwise).
    pub lane_imbalance: Option<f64>,
}

impl JobResult {
    pub fn block_efficiency(&self) -> f64 {
        self.blocks_mapped as f64 / self.blocks_launched as f64
    }

    /// The eight launch-accounting fields, in
    /// [`LaunchStats::accounting`](crate::grid::LaunchStats::accounting)
    /// order — what backend/mode equivalence is asserted over.
    pub fn accounting(&self) -> [u64; 8] {
        [
            self.passes,
            self.launch_waves,
            self.blocks_launched,
            self.blocks_filler,
            self.blocks_mapped,
            self.threads_launched,
            self.threads_mapped,
            self.threads_predicated_off,
        ]
    }

    pub fn to_json(&self) -> Json {
        let outputs = Json::Obj(
            self.outputs
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        let mut fields = vec![
            ("job", self.job.to_json()),
            ("outputs", outputs),
            ("passes", self.passes.into()),
            ("launch_waves", self.launch_waves.into()),
            ("blocks_launched", self.blocks_launched.into()),
            ("blocks_filler", self.blocks_filler.into()),
            ("blocks_mapped", self.blocks_mapped.into()),
            ("threads_launched", self.threads_launched.into()),
            ("threads_mapped", self.threads_mapped.into()),
            ("threads_predicated_off", self.threads_predicated_off.into()),
            ("block_efficiency", self.block_efficiency().into()),
            ("wall_secs", self.wall_secs.into()),
            ("tile_batches", self.tile_batches.into()),
        ];
        if let Some(r) = self.lane_imbalance {
            fields.push(("lane_imbalance", r.into()));
        }
        if !self.lane_profile.is_empty() {
            let lanes: Vec<Json> = self
                .lane_profile
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("lane", p.lane.into()),
                        ("busy_ns", p.busy_ns.into()),
                        ("chunks_pulled", p.chunks_pulled.into()),
                        ("blocks_processed", p.blocks_processed.into()),
                    ])
                })
                .collect();
            fields.push(("lane_profile", Json::Arr(lanes)));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn workload_parse_roundtrip() {
        for w in WorkloadKind::ALL {
            assert_eq!(WorkloadKind::parse(w.name()), Some(*w));
        }
        assert_eq!(WorkloadKind::parse("bogus"), None);
    }

    #[test]
    fn workload_dimensionality() {
        assert_eq!(WorkloadKind::Edm.m(), 2);
        assert_eq!(WorkloadKind::Triple.m(), 3);
        assert_eq!(WorkloadKind::KTuple(5).m(), 5);
        assert_eq!(WorkloadKind::GasketCA.m(), 2);
    }

    #[test]
    fn workload_domains() {
        use crate::simplex::gasket::DomainKind;
        assert_eq!(WorkloadKind::GasketCA.domain(), DomainKind::Gasket);
        assert_eq!(WorkloadKind::parse("gasket"), Some(WorkloadKind::GasketCA));
        assert_eq!(
            WorkloadKind::parse("gasket-ca"),
            Some(WorkloadKind::GasketCA),
            "alias"
        );
        for w in WorkloadKind::ALL {
            if *w != WorkloadKind::GasketCA {
                assert_eq!(w.domain(), DomainKind::Simplex, "{}", w.name());
            }
        }
    }

    #[test]
    fn ktuple_parse_variants() {
        assert_eq!(WorkloadKind::parse("ktuple"), Some(WorkloadKind::KTuple(4)));
        assert_eq!(
            WorkloadKind::parse("ktuple6"),
            Some(WorkloadKind::KTuple(6))
        );
        assert_eq!(
            WorkloadKind::parse("ktuple2"),
            Some(WorkloadKind::KTuple(2)),
            "pair-style regression arity"
        );
        assert_eq!(WorkloadKind::parse("ktuple1"), None, "no 1-tuples");
        assert_eq!(WorkloadKind::parse("ktuple9"), None, "beyond M_MAX");
    }

    #[test]
    fn sweep_maps_cover_every_dimension() {
        assert_eq!(
            WorkloadKind::Edm.sweep_maps(),
            vec!["bb", "lambda2", "enum2", "rb", "ries", "lambda-s"]
        );
        assert_eq!(
            WorkloadKind::Triple.sweep_maps(),
            vec!["bb", "lambda3", "enum3", "lambda-s", "lambda-sw"]
        );
        assert_eq!(
            WorkloadKind::GasketCA.sweep_maps(),
            vec!["bb-gasket", "lambda-gasket", "bb", "lambda2"]
        );
        // m ≥ 4 pulls the live registry roster.
        assert_eq!(
            WorkloadKind::KTuple(5).sweep_maps(),
            crate::maps::map_names(5)
        );
        // Every advertised map must resolve for its workload's m.
        for w in WorkloadKind::ALL {
            for map in w.sweep_maps() {
                assert!(
                    crate::maps::map_by_name(w.m(), &map).is_some(),
                    "{} -> {map}",
                    w.name()
                );
            }
        }
    }

    #[test]
    fn job_json_roundtrip() {
        let j = Job {
            workload: WorkloadKind::Edm,
            nb: 64,
            map: "lambda2".into(),
            backend: Backend::Pjrt,
            seed: 7,
        };
        let parsed = Job::from_json(&json::parse(&j.to_json().to_string_compact()).unwrap())
            .expect("roundtrip");
        assert_eq!(parsed.workload, j.workload);
        assert_eq!(parsed.nb, j.nb);
        assert_eq!(parsed.map, j.map);
        assert_eq!(parsed.backend, j.backend);
        assert_eq!(parsed.seed, j.seed);
    }

    #[test]
    fn job_defaults_backend_and_seed() {
        let j = json::parse(r#"{"workload":"nbody","nb":16,"map":"bb"}"#).unwrap();
        let job = Job::from_json(&j).unwrap();
        assert_eq!(job.backend, BackendKind::Parallel);
        assert_eq!(job.seed, 42);
    }

    #[test]
    fn job_accepts_legacy_rust_backend_name() {
        // Pre-PR-6 clients send "rust" for the in-process path; it must
        // keep parsing as the parallel backend.
        let j =
            json::parse(r#"{"workload":"edm","nb":8,"map":"lambda2","backend":"rust"}"#).unwrap();
        assert_eq!(Job::from_json(&j).unwrap().backend, BackendKind::Parallel);
        let j = json::parse(r#"{"workload":"edm","nb":8,"map":"lambda2","backend":"serial"}"#)
            .unwrap();
        assert_eq!(Job::from_json(&j).unwrap().backend, BackendKind::Serial);
    }

    #[test]
    fn result_json_has_efficiency() {
        let r = JobResult {
            job: Job {
                workload: WorkloadKind::Edm,
                nb: 4,
                map: "bb".into(),
                backend: Backend::Parallel,
                seed: 1,
            },
            outputs: vec![("count".into(), 10.0)],
            passes: 1,
            launch_waves: 1,
            blocks_launched: 16,
            blocks_filler: 6,
            blocks_mapped: 10,
            threads_launched: 4096,
            threads_mapped: 2560,
            threads_predicated_off: 136,
            wall_secs: 0.5,
            tile_batches: 1,
            lane_profile: Vec::new(),
            lane_imbalance: None,
        };
        let j = r.to_json();
        assert!((j.get("block_efficiency").unwrap().as_f64().unwrap() - 0.625).abs() < 1e-12);
        assert_eq!(
            j.get("outputs").unwrap().get("count").unwrap().as_f64(),
            Some(10.0)
        );
        // All eight accounting fields are on the wire, in order.
        assert_eq!(j.get("launch_waves").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("blocks_filler").unwrap().as_u64(), Some(6));
        assert_eq!(j.get("threads_mapped").unwrap().as_u64(), Some(2560));
        assert_eq!(r.accounting(), [1, 1, 16, 6, 10, 4096, 2560, 136]);
        // Unprofiled jobs do not clutter the wire with lane fields.
        assert!(j.get("lane_profile").is_none());
        assert!(j.get("lane_imbalance").is_none());

        // A profiled result carries the per-lane tallies and the ratio.
        let mut r = r;
        r.lane_profile = vec![crate::grid::LaneProfile {
            lane: 0,
            busy_ns: 1000,
            chunks_pulled: 2,
            blocks_processed: 16,
        }];
        r.lane_imbalance = Some(1.25);
        let j = r.to_json();
        assert_eq!(j.get("lane_imbalance").unwrap().as_f64(), Some(1.25));
        let lanes = j.get("lane_profile").unwrap().as_arr().unwrap();
        assert_eq!(lanes.len(), 1);
        assert_eq!(lanes[0].get("busy_ns").unwrap().as_u64(), Some(1000));
        assert_eq!(lanes[0].get("blocks_processed").unwrap().as_u64(), Some(16));
    }
}
