//! The scheduler: runs a [`Job`] through map → tiles → aggregation.
//!
//! Two-phase execution, separately timed (the paper's claims are about
//! phase 1; phase 2 is identical work under every map — which is
//! exactly why parallel-space efficiency converts into end-to-end
//! throughput):
//!
//! 1. **Map phase** — the grid launcher applies the chosen map over
//!    the whole parallel space on the worker pool and collects the
//!    surviving blocks (the hot path the benches measure).
//! 2. **Execute phase** — per-block tiles run on the selected backend:
//!    `rust` (portable kernels) or `pjrt` (batched AOT Pallas kernels),
//!    then aggregate under the thread-level predicate.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::batcher::{TileBatcher, TileInput};
use crate::coordinator::job::{Backend, Job, JobResult, WorkloadKind};
use crate::coordinator::metrics::Metrics;
use crate::grid::{BlockShape, LaunchConfig, Launcher, MappedBlock};
use crate::maps::{map2_by_name, map3_by_name, MThreadMap as _, ThreadMap};
use crate::runtime::ExecHandle;
use crate::workloads::*;
use crate::{log_debug, log_info};

#[derive(Debug)]
pub enum ScheduleError {
    UnknownMap(String, u32),
    Unsupported(String, u64),
    NoExecutor(String),
    Runtime(crate::runtime::RuntimeError),
    NoPjrtPath(&'static str),
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::UnknownMap(name, m) => write!(f, "unknown map '{name}' for m={m}"),
            ScheduleError::Unsupported(name, nb) => {
                write!(f, "map '{name}' does not support nb={nb} (needs 2^k)")
            }
            ScheduleError::NoExecutor(msg) => {
                write!(f, "backend pjrt requires artifacts: {msg}")
            }
            ScheduleError::Runtime(e) => write!(f, "runtime: {e}"),
            ScheduleError::NoPjrtPath(w) => {
                write!(f, "workload '{w}' has no pjrt artifact; use --backend rust")
            }
        }
    }
}

impl std::error::Error for ScheduleError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ScheduleError::Runtime(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::runtime::RuntimeError> for ScheduleError {
    fn from(e: crate::runtime::RuntimeError) -> Self {
        ScheduleError::Runtime(e)
    }
}

pub struct Scheduler {
    pub workers: usize,
    /// ρ for 2-simplex workloads (must match artifact R when pjrt).
    pub rho2: u32,
    /// ρ for 3-simplex workloads.
    pub rho3: u32,
    /// ρ for general-m workloads (blocks are ρ^m threads, so small).
    pub rho_m: u32,
    executor: Option<ExecHandle>,
    pub metrics: Arc<Metrics>,
}

impl Scheduler {
    pub fn new(workers: usize, executor: Option<ExecHandle>) -> Scheduler {
        Scheduler {
            workers: workers.max(1),
            rho2: 16,
            rho3: 8,
            rho_m: 2,
            executor,
            metrics: Arc::new(Metrics::new()),
        }
    }

    fn resolve_map(&self, job: &Job) -> Result<Box<dyn ThreadMap>, ScheduleError> {
        let m = job.workload.m();
        let map = match m {
            2 => map2_by_name(&job.map),
            _ => map3_by_name(&job.map),
        }
        .ok_or_else(|| ScheduleError::UnknownMap(job.map.clone(), m))?;
        if !map.supports(job.nb) {
            return Err(ScheduleError::Unsupported(job.map.clone(), job.nb));
        }
        Ok(map)
    }

    fn executor(&self) -> Result<ExecHandle, ScheduleError> {
        self.executor
            .clone()
            .ok_or_else(|| ScheduleError::NoExecutor("executor not loaded".into()))
    }

    /// Phase 1: run the map over the grid, collecting mapped blocks.
    fn collect_blocks(
        &self,
        map: &dyn ThreadMap,
        nb: u64,
        rho: u32,
    ) -> (Vec<MappedBlock>, crate::grid::LaunchStats) {
        let mut cfg = LaunchConfig::new(BlockShape::new(rho, map.m()));
        cfg.launch_latency = std::time::Duration::from_micros(5);
        let launcher = Launcher::with_workers(self.workers, cfg);
        let blocks = Mutex::new(Vec::new());
        let stats = launcher.launch(map, nb, |b| {
            blocks.lock().unwrap().push(*b);
            0
        });
        let mut blocks = blocks.into_inner().unwrap();
        // Deterministic order for reproducible aggregation.
        blocks.sort_by_key(|b| (b.pass, b.data));
        (blocks, stats)
    }

    /// Run a job to completion.
    pub fn run(&self, job: &Job) -> Result<JobResult, ScheduleError> {
        if let WorkloadKind::KTuple(m) = job.workload {
            return self.run_ktuple(job, m);
        }
        let t0 = Instant::now();
        let map = self.resolve_map(job)?;
        let rho = if job.workload.m() == 2 {
            self.rho2
        } else {
            self.rho3
        };
        log_info!(
            "scheduler",
            "job {} nb={} map={} backend={}",
            job.workload.name(),
            job.nb,
            job.map,
            job.backend.name()
        );

        let tmap = Instant::now();
        let (blocks, stats) = self.collect_blocks(map.as_ref(), job.nb, rho);
        self.metrics.record_map_phase(tmap.elapsed().as_secs_f64());
        self.metrics
            .blocks_mapped
            .fetch_add(blocks.len() as u64, std::sync::atomic::Ordering::Relaxed);
        log_debug!("scheduler", "mapped {} blocks", blocks.len());

        let texec = Instant::now();
        let (outputs, batches) = self.execute(job, rho, &blocks)?;
        self.metrics
            .record_exec_phase(texec.elapsed().as_secs_f64());

        let wall = t0.elapsed().as_secs_f64();
        self.metrics.record_job(wall);
        Ok(JobResult {
            job: job.clone(),
            outputs,
            blocks_launched: stats.blocks_launched,
            blocks_mapped: stats.blocks_mapped,
            threads_launched: stats.threads_launched,
            wall_secs: wall,
            tile_batches: batches,
        })
    }

    fn execute(
        &self,
        job: &Job,
        rho: u32,
        blocks: &[MappedBlock],
    ) -> Result<(Vec<(String, f64)>, u64), ScheduleError> {
        match (job.workload, job.backend) {
            (WorkloadKind::Edm, Backend::Rust) => self.edm_rust(job, rho, blocks),
            (WorkloadKind::Edm, Backend::Pjrt) => self.edm_pjrt(job, rho, blocks),
            (WorkloadKind::Collision, Backend::Rust) => self.collision_rust(job, rho, blocks),
            (WorkloadKind::Collision, Backend::Pjrt) => self.collision_pjrt(job, rho, blocks),
            (WorkloadKind::NBody, Backend::Rust) => self.nbody_rust(job, rho, blocks),
            (WorkloadKind::NBody, Backend::Pjrt) => self.nbody_pjrt(job, rho, blocks),
            (WorkloadKind::Triple, Backend::Rust) => self.triple_rust(job, rho, blocks),
            (WorkloadKind::Triple, Backend::Pjrt) => self.triple_pjrt(job, rho, blocks),
            (WorkloadKind::Cellular, Backend::Rust) => self.cellular_rust(job, rho, blocks),
            (WorkloadKind::TriMatVec, Backend::Rust) => self.trimat_rust(job, rho, blocks),
            (WorkloadKind::Cellular, Backend::Pjrt) => Err(ScheduleError::NoPjrtPath("cellular")),
            (WorkloadKind::TriMatVec, Backend::Pjrt) => {
                Err(ScheduleError::NoPjrtPath("trimatvec"))
            }
            (WorkloadKind::KTuple(_), _) => {
                unreachable!("ktuple jobs take the general-m path in run()")
            }
        }
    }

    // ---- KTuple (general-m path) -------------------------------------

    /// The general-m pipeline: resolve through the unified registry,
    /// launch with [`Launcher::launch_m`], execute ρ^m tuple tiles.
    fn run_ktuple(&self, job: &Job, m: u32) -> Result<JobResult, ScheduleError> {
        if job.backend == Backend::Pjrt {
            return Err(ScheduleError::NoPjrtPath("ktuple"));
        }
        let map = crate::maps::map_by_name(m, &job.map)
            .ok_or_else(|| ScheduleError::UnknownMap(job.map.clone(), m))?;
        if !map.supports(job.nb) {
            return Err(ScheduleError::Unsupported(job.map.clone(), job.nb));
        }
        let rho = if m == 2 {
            self.rho2
        } else if m == 3 {
            self.rho3
        } else {
            self.rho_m
        };
        log_info!(
            "scheduler",
            "job {} nb={} map={} backend={} (general-m)",
            job.workload.name(),
            job.nb,
            job.map,
            job.backend.name()
        );
        let t0 = Instant::now();

        let tmap = Instant::now();
        let mut cfg = LaunchConfig::new(BlockShape::new(rho, m));
        cfg.launch_latency = std::time::Duration::from_micros(5);
        let launcher = Launcher::with_workers(self.workers, cfg);
        let blocks = Mutex::new(Vec::new());
        let stats = launcher.launch_m(map.as_ref(), job.nb, |b| {
            blocks.lock().unwrap().push(*b);
            0
        });
        let mut blocks = blocks.into_inner().unwrap();
        // Deterministic order for reproducible aggregation.
        blocks.sort_by(|a, b| (a.pass, a.data.as_slice()).cmp(&(b.pass, b.data.as_slice())));
        self.metrics.record_map_phase(tmap.elapsed().as_secs_f64());
        self.metrics
            .blocks_mapped
            .fetch_add(blocks.len() as u64, std::sync::atomic::Ordering::Relaxed);
        log_debug!("scheduler", "mapped {} blocks (m={m})", blocks.len());

        let texec = Instant::now();
        let w = KTupleWorkload::generate(job.nb, rho, m, job.seed);
        let partials: Vec<f64> = parallel_map_reduce(self.workers, &blocks, |batch| {
            batch
                .iter()
                .map(|b| w.tile_rust(&KTupleWorkload::block_chunks(job.nb, &b.data)))
                .sum()
        });
        self.metrics
            .record_exec_phase(texec.elapsed().as_secs_f64());

        let wall = t0.elapsed().as_secs_f64();
        self.metrics.record_job(wall);
        Ok(JobResult {
            job: job.clone(),
            outputs: vec![("ktuple_energy".into(), partials.iter().sum())],
            blocks_launched: stats.blocks_launched,
            blocks_mapped: stats.blocks_mapped,
            threads_launched: stats.threads_launched,
            wall_secs: wall,
            tile_batches: 0,
        })
    }

    // ---- EDM ---------------------------------------------------------

    fn edm_rust(
        &self,
        job: &Job,
        rho: u32,
        blocks: &[MappedBlock],
    ) -> Result<(Vec<(String, f64)>, u64), ScheduleError> {
        let w = EdmWorkload::generate(job.nb, rho, job.seed);
        let tile_len = (rho as usize) * (rho as usize);
        // Parallel over block ranges with per-thread partials.
        let chunks: Vec<(u64, f64)> = parallel_map_reduce(self.workers, blocks, |batch| {
            let mut tile = vec![0f32; tile_len];
            let mut count = 0u64;
            let mut sum = 0f64;
            for b in batch {
                let (bc, br) = (b.data[0], b.data[1]);
                w.tile_rust(bc, br, &mut tile);
                let (c, s) = w.aggregate_tile(bc, br, &tile);
                count += c;
                sum += s;
            }
            (count, sum)
        });
        let count: u64 = chunks.iter().map(|c| c.0).sum();
        let sum: f64 = chunks.iter().map(|c| c.1).sum();
        Ok((
            vec![
                ("neighbour_count".into(), count as f64),
                ("sum_d2".into(), sum),
            ],
            0,
        ))
    }

    fn edm_pjrt(
        &self,
        job: &Job,
        rho: u32,
        blocks: &[MappedBlock],
    ) -> Result<(Vec<(String, f64)>, u64), ScheduleError> {
        let exe = self.executor()?;
        let w = EdmWorkload::generate(job.nb, rho, job.seed);
        let mut batcher = TileBatcher::new(exe, "edm_tile")?;
        let tiles: Vec<TileInput> = blocks
            .iter()
            .enumerate()
            .map(|(i, b)| TileInput {
                block_id: i as u64,
                inputs: vec![w.chunk(b.data[1]).to_vec(), w.chunk(b.data[0]).to_vec()],
            })
            .collect();
        let outs = batcher.run(&tiles)?;
        let mut count = 0u64;
        let mut sum = 0f64;
        for out in &outs {
            let b = &blocks[out.block_id as usize];
            let (c, s) = w.aggregate_tile(b.data[0], b.data[1], &out.data);
            count += c;
            sum += s;
        }
        self.note_batches(&batcher);
        Ok((
            vec![
                ("neighbour_count".into(), count as f64),
                ("sum_d2".into(), sum),
            ],
            batcher.batches_run,
        ))
    }

    // ---- Collision ---------------------------------------------------

    fn collision_rust(
        &self,
        job: &Job,
        rho: u32,
        blocks: &[MappedBlock],
    ) -> Result<(Vec<(String, f64)>, u64), ScheduleError> {
        let w = CollisionWorkload::generate(job.nb, rho, job.seed);
        let tile_len = (rho as usize) * (rho as usize);
        let partials: Vec<u64> = parallel_map_reduce(self.workers, blocks, |batch| {
            let mut tile = vec![0f32; tile_len];
            let mut count = 0u64;
            for b in batch {
                w.tile_rust(b.data[0], b.data[1], &mut tile);
                count += w.aggregate_tile(b.data[0], b.data[1], &tile);
            }
            count
        });
        let count: u64 = partials.iter().sum();
        Ok((vec![("overlap_count".into(), count as f64)], 0))
    }

    fn collision_pjrt(
        &self,
        job: &Job,
        rho: u32,
        blocks: &[MappedBlock],
    ) -> Result<(Vec<(String, f64)>, u64), ScheduleError> {
        let exe = self.executor()?;
        let w = CollisionWorkload::generate(job.nb, rho, job.seed);
        let mut batcher = TileBatcher::new(exe, "collision_tile")?;
        let tiles: Vec<TileInput> = blocks
            .iter()
            .enumerate()
            .map(|(i, b)| TileInput {
                block_id: i as u64,
                inputs: vec![w.chunk(b.data[1]).to_vec(), w.chunk(b.data[0]).to_vec()],
            })
            .collect();
        let outs = batcher.run(&tiles)?;
        let count: u64 = outs
            .iter()
            .map(|out| {
                let b = &blocks[out.block_id as usize];
                w.aggregate_tile(b.data[0], b.data[1], &out.data)
            })
            .sum();
        self.note_batches(&batcher);
        Ok((
            vec![("overlap_count".into(), count as f64)],
            batcher.batches_run,
        ))
    }

    // ---- N-body ------------------------------------------------------

    fn nbody_rust(
        &self,
        job: &Job,
        rho: u32,
        blocks: &[MappedBlock],
    ) -> Result<(Vec<(String, f64)>, u64), ScheduleError> {
        let w = NBodyWorkload::generate(job.nb, rho, job.seed);
        let acc = Mutex::new(vec![0f32; w.n as usize * 3]);
        let rho64 = rho as u64;
        parallel_map_reduce(self.workers, blocks, |batch| {
            let mut tile = vec![0f32; rho as usize * 3];
            let mut local: Vec<(u64, Vec<f32>)> = Vec::new();
            for b in batch {
                let (bc, br) = (b.data[0], b.data[1]);
                w.tile_rust(bc, br, &mut tile);
                local.push((br, tile.clone()));
                if bc != br {
                    w.tile_rust(br, bc, &mut tile);
                    local.push((bc, tile.clone()));
                }
            }
            let mut acc = acc.lock().unwrap();
            for (chunk_row, t) in local {
                for i in 0..rho64 {
                    for d in 0..3 {
                        acc[((chunk_row * rho64 + i) * 3 + d) as usize] +=
                            t[(i * 3 + d) as usize];
                    }
                }
            }
        });
        let acc = acc.into_inner().unwrap();
        Ok((
            vec![("accel_checksum".into(), NBodyWorkload::checksum(&acc))],
            0,
        ))
    }

    fn nbody_pjrt(
        &self,
        job: &Job,
        rho: u32,
        blocks: &[MappedBlock],
    ) -> Result<(Vec<(String, f64)>, u64), ScheduleError> {
        let exe = self.executor()?;
        let w = NBodyWorkload::generate(job.nb, rho, job.seed);
        let mut batcher = TileBatcher::new(exe, "nbody_tile")?;
        // Two directed tiles per off-diagonal block, one per diagonal.
        let mut tiles = Vec::new();
        let mut targets = Vec::new(); // chunk receiving the acceleration
        for b in blocks {
            let (bc, br) = (b.data[0], b.data[1]);
            tiles.push(TileInput {
                block_id: targets.len() as u64,
                inputs: vec![w.chunk(br).to_vec(), w.chunk(bc).to_vec()],
            });
            targets.push(br);
            if bc != br {
                tiles.push(TileInput {
                    block_id: targets.len() as u64,
                    inputs: vec![w.chunk(bc).to_vec(), w.chunk(br).to_vec()],
                });
                targets.push(bc);
            }
        }
        let outs = batcher.run(&tiles)?;
        let rho64 = rho as u64;
        let mut acc = vec![0f32; w.n as usize * 3];
        for out in &outs {
            let chunk_row = targets[out.block_id as usize];
            for i in 0..rho64 {
                for d in 0..3 {
                    acc[((chunk_row * rho64 + i) * 3 + d) as usize] +=
                        out.data[(i * 3 + d) as usize];
                }
            }
        }
        self.note_batches(&batcher);
        Ok((
            vec![("accel_checksum".into(), NBodyWorkload::checksum(&acc))],
            batcher.batches_run,
        ))
    }

    // ---- Triple ------------------------------------------------------

    fn triple_rust(
        &self,
        job: &Job,
        rho: u32,
        blocks: &[MappedBlock],
    ) -> Result<(Vec<(String, f64)>, u64), ScheduleError> {
        let w = TripleWorkload::generate(job.nb, rho, job.seed);
        let partials: Vec<f64> = parallel_map_reduce(self.workers, blocks, |batch| {
            let mut e = 0f64;
            for b in batch {
                let (ci, cj, ck) = TripleWorkload::block_chunks(job.nb, b.data);
                e += w.tile_rust(ci, cj, ck);
            }
            e
        });
        Ok((vec![("at_energy".into(), partials.iter().sum())], 0))
    }

    fn triple_pjrt(
        &self,
        job: &Job,
        rho: u32,
        blocks: &[MappedBlock],
    ) -> Result<(Vec<(String, f64)>, u64), ScheduleError> {
        let exe = self.executor()?;
        let w = TripleWorkload::generate(job.nb, rho, job.seed);
        let mut batcher = TileBatcher::new(exe, "triple_tile")?;
        // Strictly-ordered blocks → full-tile Pallas kernel; blocks
        // with repeated chunks → Rust per-thread predication (o(n²) of
        // the n³ work; see module doc in workloads/triple.rs).
        let mut strict_tiles = Vec::new();
        let mut energy = 0f64;
        for b in blocks {
            let (ci, cj, ck) = TripleWorkload::block_chunks(job.nb, b.data);
            if TripleWorkload::block_is_strict(ci, cj, ck) {
                strict_tiles.push(TileInput {
                    block_id: strict_tiles.len() as u64,
                    inputs: vec![
                        w.chunk(ci).to_vec(),
                        w.chunk(cj).to_vec(),
                        w.chunk(ck).to_vec(),
                    ],
                });
            } else {
                energy += w.tile_rust(ci, cj, ck);
            }
        }
        let outs = batcher.run(&strict_tiles)?;
        energy += outs.iter().map(|o| o.data[0] as f64).sum::<f64>();
        self.note_batches(&batcher);
        Ok((
            vec![("at_energy".into(), energy)],
            batcher.batches_run,
        ))
    }

    // ---- Cellular / TriMatVec (rust backends) -------------------------

    fn cellular_rust(
        &self,
        job: &Job,
        rho: u32,
        blocks: &[MappedBlock],
    ) -> Result<(Vec<(String, f64)>, u64), ScheduleError> {
        let w = CellularWorkload::generate(job.nb, rho, job.seed);
        let tile_len = (rho as usize) * (rho as usize);
        let scatters: Vec<Vec<(u64, u64, Vec<f32>)>> =
            parallel_map_reduce(self.workers, blocks, |batch| {
                let mut out = Vec::with_capacity(batch.len());
                for b in batch {
                    let mut tile = vec![0f32; tile_len];
                    w.tile_next(b.data[0], b.data[1], &mut tile);
                    out.push((b.data[0], b.data[1], tile));
                }
                out
            });
        let mut next = vec![0u8; w.state.len()];
        for group in scatters {
            for (bc, br, tile) in group {
                w.scatter_tile(bc, br, &tile, &mut next);
            }
        }
        let pop: u64 = next.iter().map(|&c| c as u64).sum();
        Ok((
            vec![
                ("population_before".into(), w.population() as f64),
                ("population_after".into(), pop as f64),
            ],
            0,
        ))
    }

    fn trimat_rust(
        &self,
        job: &Job,
        rho: u32,
        blocks: &[MappedBlock],
    ) -> Result<(Vec<(String, f64)>, u64), ScheduleError> {
        let w = TriMatVecWorkload::generate(job.nb, rho, job.seed);
        let rho64 = rho as u64;
        let partials: Vec<Vec<(u64, Vec<f32>)>> =
            parallel_map_reduce(self.workers, blocks, |batch| {
                let mut out = Vec::with_capacity(batch.len());
                for b in batch {
                    let mut tile = vec![0f32; rho as usize];
                    w.tile_rust(b.data[0], b.data[1], &mut tile);
                    out.push((b.data[1], tile));
                }
                out
            });
        let mut y = vec![0f32; w.n as usize];
        for group in partials {
            for (br, tile) in group {
                for i in 0..rho64 {
                    y[(br * rho64 + i) as usize] += tile[i as usize];
                }
            }
        }
        Ok((
            vec![("y_checksum".into(), TriMatVecWorkload::checksum(&y))],
            0,
        ))
    }

    fn note_batches(&self, batcher: &TileBatcher) {
        self.metrics
            .tile_batches
            .fetch_add(batcher.batches_run, std::sync::atomic::Ordering::Relaxed);
        self.metrics
            .tiles_padded
            .fetch_add(batcher.tiles_padded, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Split `items` into per-worker contiguous batches, run `f` on each in
/// scoped threads, and collect the per-batch results.
fn parallel_map_reduce<T: Sync, R: Send>(
    workers: usize,
    items: &[T],
    f: impl Fn(&[T]) -> R + Sync,
) -> Vec<R> {
    if items.is_empty() {
        return Vec::new();
    }
    let workers = workers.clamp(1, items.len());
    let chunk = items.len().div_ceil(workers);
    let results = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for (i, batch) in items.chunks(chunk).enumerate() {
            let f = &f;
            let results = &results;
            scope.spawn(move || {
                let r = f(batch);
                results.lock().unwrap().push((i, r));
            });
        }
    });
    let mut out = results.into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(w: WorkloadKind, nb: u64, map: &str) -> Job {
        Job {
            workload: w,
            nb,
            map: map.into(),
            backend: Backend::Rust,
            seed: 11,
        }
    }

    #[test]
    fn edm_rust_matches_reference_under_all_maps() {
        let sched = Scheduler::new(4, None);
        let w = EdmWorkload::generate(8, sched.rho2, 11);
        let (want_count, want_sum) = w.reference();
        for map in ["bb", "lambda2", "enum2", "rb", "ries"] {
            let r = sched.run(&job(WorkloadKind::Edm, 8, map)).unwrap();
            assert_eq!(
                r.outputs[0].1 as u64, want_count,
                "map={map}: neighbour count"
            );
            let sum = r.outputs[1].1;
            assert!(
                (sum - want_sum).abs() < 1e-3 * want_sum.abs().max(1.0),
                "map={map}: {sum} vs {want_sum}"
            );
        }
    }

    #[test]
    fn collision_rust_matches_reference_under_all_maps() {
        let sched = Scheduler::new(4, None);
        let w = CollisionWorkload::generate(8, sched.rho2, 11);
        let want = w.reference() as f64;
        for map in ["bb", "lambda2", "enum2", "rb", "ries"] {
            let r = sched.run(&job(WorkloadKind::Collision, 8, map)).unwrap();
            assert_eq!(r.outputs[0].1, want, "map={map}");
        }
    }

    #[test]
    fn nbody_rust_matches_reference() {
        let sched = Scheduler::new(4, None);
        let w = NBodyWorkload::generate(4, sched.rho2, 11);
        let want = NBodyWorkload::checksum(&w.reference());
        for map in ["bb", "lambda2"] {
            let r = sched.run(&job(WorkloadKind::NBody, 4, map)).unwrap();
            let got = r.outputs[0].1;
            assert!(
                (got - want).abs() < 1e-3 * want,
                "map={map}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn triple_rust_matches_reference() {
        let sched = Scheduler::new(4, None);
        let w = TripleWorkload::generate(4, sched.rho3, 11);
        let want = w.reference();
        for map in ["bb", "lambda3", "enum3", "lambda3-rec"] {
            let r = sched.run(&job(WorkloadKind::Triple, 4, map)).unwrap();
            let got = r.outputs[0].1;
            assert!(
                (got - want).abs() < 1e-6 * want.abs().max(1.0),
                "map={map}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn cellular_step_population_matches_reference() {
        let sched = Scheduler::new(2, None);
        let w = CellularWorkload::generate(8, sched.rho2, 11);
        let want: u64 = w.step_reference().iter().map(|&c| c as u64).sum();
        for map in ["bb", "lambda2", "rb"] {
            let r = sched.run(&job(WorkloadKind::Cellular, 8, map)).unwrap();
            assert_eq!(r.outputs[1].1 as u64, want, "map={map}");
        }
    }

    #[test]
    fn trimat_matches_reference() {
        let sched = Scheduler::new(2, None);
        let w = TriMatVecWorkload::generate(4, sched.rho2, 11);
        let want = TriMatVecWorkload::checksum(&w.reference());
        let r = sched.run(&job(WorkloadKind::TriMatVec, 4, "lambda2")).unwrap();
        assert!((r.outputs[0].1 - want).abs() < 1e-3 * want.max(1.0));
    }

    #[test]
    fn ktuple_rust_matches_reference_under_bb_and_lambda_m() {
        let sched = Scheduler::new(4, None);
        for (m, nb) in [(4u32, 4u64), (5, 3)] {
            let w = KTupleWorkload::generate(nb, sched.rho_m, m, 11);
            let want = w.reference();
            for map in ["bb", "lambda-m"] {
                let r = sched
                    .run(&job(WorkloadKind::KTuple(m), nb, map))
                    .unwrap_or_else(|e| panic!("m={m} map={map}: {e}"));
                let got = r.outputs[0].1;
                assert!(
                    (got - want).abs() < 1e-9 * want.abs().max(1.0),
                    "m={m} map={map}: {got} vs {want}"
                );
                assert_eq!(
                    r.blocks_mapped as u128,
                    crate::maps::domain_volume(nb, m),
                    "m={m} map={map}"
                );
            }
        }
    }

    #[test]
    fn ktuple3_runs_on_the_adapted_fixed_maps() {
        // At m=3 the general-m path reuses the λ3 family via adapters.
        let sched = Scheduler::new(2, None);
        let w = KTupleWorkload::generate(4, sched.rho3, 3, 11);
        let want = w.reference();
        for map in ["bb", "lambda3", "enum3"] {
            let r = sched.run(&job(WorkloadKind::KTuple(3), 4, map)).unwrap();
            let got = r.outputs[0].1;
            assert!(
                (got - want).abs() < 1e-9 * want.abs().max(1.0),
                "map={map}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn ktuple_errors_cover_registry_and_backend() {
        let sched = Scheduler::new(1, None);
        assert!(matches!(
            sched.run(&job(WorkloadKind::KTuple(4), 4, "lambda3")),
            Err(ScheduleError::UnknownMap(_, 4))
        ));
        let mut j = job(WorkloadKind::KTuple(4), 4, "bb");
        j.backend = Backend::Pjrt;
        assert!(matches!(
            sched.run(&j),
            Err(ScheduleError::NoPjrtPath("ktuple"))
        ));
    }

    #[test]
    fn lambda2_launches_half_the_blocks_of_bb() {
        let sched = Scheduler::new(2, None);
        let bb = sched.run(&job(WorkloadKind::Edm, 16, "bb")).unwrap();
        let l2 = sched.run(&job(WorkloadKind::Edm, 16, "lambda2")).unwrap();
        assert_eq!(bb.blocks_mapped, l2.blocks_mapped);
        assert!(bb.blocks_launched > l2.blocks_launched * 18 / 10);
        assert_eq!(l2.block_efficiency(), 1.0);
    }

    #[test]
    fn unknown_map_and_unsupported_size_error() {
        let sched = Scheduler::new(1, None);
        assert!(matches!(
            sched.run(&job(WorkloadKind::Edm, 8, "nope")),
            Err(ScheduleError::UnknownMap(_, _))
        ));
        assert!(matches!(
            sched.run(&job(WorkloadKind::Edm, 17, "lambda2")),
            Err(ScheduleError::Unsupported(_, _))
        ));
    }

    #[test]
    fn pjrt_without_executor_errors() {
        let sched = Scheduler::new(1, None);
        let mut j = job(WorkloadKind::Edm, 8, "lambda2");
        j.backend = Backend::Pjrt;
        assert!(matches!(
            sched.run(&j),
            Err(ScheduleError::NoExecutor(_))
        ));
    }

    #[test]
    fn metrics_accumulate_across_jobs() {
        let sched = Scheduler::new(2, None);
        sched.run(&job(WorkloadKind::Edm, 8, "lambda2")).unwrap();
        sched.run(&job(WorkloadKind::Edm, 8, "bb")).unwrap();
        let snap = sched.metrics.snapshot();
        assert_eq!(snap.get("jobs_completed").unwrap().as_u64(), Some(2));
        assert!(snap.get("blocks_mapped").unwrap().as_u64().unwrap() > 0);
    }

    #[test]
    fn parallel_map_reduce_preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let sums = parallel_map_reduce(7, &items, |b| b.iter().sum::<u64>());
        assert_eq!(sums.iter().sum::<u64>(), 4950);
        assert!(sums.len() <= 8);
    }
}
