//! The unified execution engine: one pipeline for every workload at
//! every dimension 2 ≤ m ≤ 8.
//!
//! A job resolves through the all-dimensions map registry (behind a
//! scheduler-level layout cache), picks ρ from a single
//! [`RhoPolicy::rho_for`] policy, builds its [`Workload`] through the
//! one factory, and executes in one of two modes:
//!
//! - [`ExecMode::Streaming`] (default) — the workload's block kernel
//!   runs *inside* the map sweep on per-lane accumulators (fused
//!   map+execute): no block list is materialized, removing the
//!   O(blocks) collect-sort-execute detour from every job's hot path.
//! - [`ExecMode::Collect`] — the old two-phase flow, kept opt-in for
//!   trace capture, phase profiling, and the streaming-equivalence
//!   conformance tests: collect all mapped blocks, sort them
//!   deterministically, then execute. Same accumulators, same
//!   accounting (the predication counts are patched into the stats so
//!   both modes report identical [`LaunchStats`]).
//!
//! The PJRT backend necessarily collects (the tile batcher packs
//! fixed-size batches), and dispatches through
//! [`Workload::run_pjrt`] — no per-workload code lives here anymore.
//!
//! Memory-ordering policy: the scheduler only touches the metrics
//! counters/gauges (statistical, tolerate staleness) — Relaxed.
// lint: atomics(Relaxed)

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::job::{BackendKind, Job, JobResult};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::span;
use crate::grid::{BlockShape, LaunchConfig, LaunchStats, Launcher, MappedBlock};
use crate::maps::MThreadMap;
use crate::simplex::gasket::DomainKind;
use crate::runtime::ExecHandle;
use crate::workloads::{self, Accum, Workload};
use crate::{log_debug, log_info};

#[derive(Debug)]
pub enum ScheduleError {
    UnknownMap(String, u32),
    Unsupported(String, u64),
    NoExecutor(String),
    Runtime(crate::runtime::RuntimeError),
    NoPjrtPath(&'static str),
    /// The map covers a smaller block-level domain than the workload
    /// consumes (e.g. a gasket-only map under a simplex workload).
    DomainMismatch(String, &'static str),
    /// The gasket domain is only defined at power-of-two geometry
    /// (nb = 2^k, ρ = 2^s); the job's nb or the configured ρ is not.
    GasketGeometry(u64, u32),
    /// The bounded job queue refused the job (backpressure).
    QueueFull(usize),
    /// The job outlived its deadline while waiting in the queue; the
    /// payload is how long it waited, in milliseconds. (A job already
    /// running cannot be cancelled — expiry is an admission-to-start
    /// bound, not a wall-clock abort.)
    Expired(u64),
    /// The coordinator is shutting down; the job was not run.
    Shutdown,
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::UnknownMap(name, m) => write!(f, "unknown map '{name}' for m={m}"),
            ScheduleError::Unsupported(name, nb) => {
                write!(f, "map '{name}' does not support nb={nb} (needs 2^k)")
            }
            ScheduleError::NoExecutor(msg) => {
                write!(f, "backend pjrt requires artifacts: {msg}")
            }
            ScheduleError::Runtime(e) => write!(f, "runtime: {e}"),
            ScheduleError::NoPjrtPath(w) => {
                write!(
                    f,
                    "workload '{w}' has no pjrt artifact; use --backend parallel"
                )
            }
            ScheduleError::DomainMismatch(map, w) => {
                write!(
                    f,
                    "map '{map}' covers only the gasket domain; workload '{w}' needs the \
                     full simplex"
                )
            }
            ScheduleError::GasketGeometry(nb, rho) => {
                write!(
                    f,
                    "gasket workload needs power-of-two nb and ρ; got nb={nb}, ρ={rho}"
                )
            }
            ScheduleError::QueueFull(cap) => {
                write!(f, "job queue full (capacity {cap}); retry later")
            }
            ScheduleError::Expired(waited_ms) => {
                write!(f, "job expired in queue after {waited_ms} ms (deadline exceeded)")
            }
            ScheduleError::Shutdown => write!(f, "coordinator shutting down"),
        }
    }
}

impl std::error::Error for ScheduleError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ScheduleError::Runtime(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::runtime::RuntimeError> for ScheduleError {
    fn from(e: crate::runtime::RuntimeError) -> Self {
        ScheduleError::Runtime(e)
    }
}

/// How the engine executes a job's tiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Fused map+execute: the kernel runs inside the map sweep.
    Streaming,
    /// Two-phase: collect all mapped blocks, sort, then execute.
    Collect,
}

/// The single ρ policy: ρ per (domain, dimension), replacing the
/// scattered `rho2`/`rho3`/`rho_m` branches of the split pipelines.
/// Blocks are ρ^m threads, so higher dimensions take a smaller ρ; the
/// gasket takes its own ρ because its per-block useful work is `3^s`
/// of `ρ² = 4^s` threads (ρ must stay a power of two for the domain's
/// self-similarity).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RhoPolicy {
    /// ρ for 2-simplex jobs (must match artifact R when pjrt).
    pub rho2: u32,
    /// ρ for 3-simplex jobs.
    pub rho3: u32,
    /// ρ for m ≥ 4 jobs.
    pub rho_m: u32,
    /// ρ for gasket-domain jobs (must be a power of two).
    pub rho_gasket: u32,
}

impl Default for RhoPolicy {
    fn default() -> RhoPolicy {
        RhoPolicy {
            rho2: 16,
            rho3: 8,
            rho_m: 2,
            rho_gasket: 8,
        }
    }
}

impl RhoPolicy {
    /// ρ for a *simplex* workload of dimension m.
    pub fn rho_for(&self, m: u32) -> u32 {
        self.rho_for_domain(DomainKind::Simplex, m)
    }

    /// ρ for a (domain, dimension) pair — the one lookup the engine
    /// uses.
    pub fn rho_for_domain(&self, domain: DomainKind, m: u32) -> u32 {
        match (domain, m) {
            (DomainKind::Gasket, _) => self.rho_gasket,
            (DomainKind::Simplex, 2) => self.rho2,
            (DomainKind::Simplex, 3) => self.rho3,
            (DomainKind::Simplex, _) => self.rho_m,
        }
    }
}

pub struct Scheduler {
    pub workers: usize,
    /// The one ρ policy for every dimension.
    pub rho: RhoPolicy,
    /// Execution mode for the serial/parallel backends (pjrt always
    /// collects).
    pub exec_mode: ExecMode,
    executor: Option<ExecHandle>,
    pub metrics: Arc<Metrics>,
    /// Per-lane launcher profiling (busy time, chunks pulled, blocks
    /// processed) — off by default; enable via
    /// `SIMPLEXMAP_PROFILE_LANES=1` or by setting the field.
    pub profile_lanes: bool,
    /// Per-(map-name, m) resolved maps, shared across jobs: repeated
    /// jobs (sweeps, server traffic) reuse the λ_m level plans and
    /// per-nb layouts the map caches internally instead of re-deriving
    /// them per job.
    map_cache: Mutex<HashMap<(String, u32), Arc<dyn MThreadMap>>>,
}

impl Scheduler {
    pub fn new(workers: usize, executor: Option<ExecHandle>) -> Scheduler {
        let profile_lanes = std::env::var("SIMPLEXMAP_PROFILE_LANES")
            .map(|s| s == "1" || s.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
        Scheduler {
            workers: workers.max(1),
            rho: RhoPolicy::default(),
            exec_mode: ExecMode::Streaming,
            executor,
            metrics: Arc::new(Metrics::new()),
            profile_lanes,
            map_cache: Mutex::new(HashMap::new()),
        }
    }

    /// ρ for a job of dimension m (see [`RhoPolicy`]).
    pub fn rho_for(&self, m: u32) -> u32 {
        self.rho.rho_for(m)
    }

    /// Resolve a map through the layout cache.
    fn resolve_map(
        &self,
        name: &str,
        m: u32,
        nb: u64,
    ) -> Result<Arc<dyn MThreadMap>, ScheduleError> {
        let map = {
            let cache = self.map_cache.lock().unwrap();
            cache.get(&(name.to_string(), m)).map(Arc::clone)
        };
        let map = match map {
            Some(map) => {
                self.metrics.map_cache_hits.fetch_add(1, Ordering::Relaxed);
                map
            }
            None => {
                self.metrics
                    .map_cache_misses
                    .fetch_add(1, Ordering::Relaxed);
                let map: Arc<dyn MThreadMap> = Arc::from(
                    crate::maps::map_by_name(m, name)
                        .ok_or_else(|| ScheduleError::UnknownMap(name.to_string(), m))?,
                );
                self.map_cache
                    .lock()
                    .unwrap()
                    .insert((name.to_string(), m), Arc::clone(&map));
                map
            }
        };
        if !map.supports(nb) {
            return Err(ScheduleError::Unsupported(name.to_string(), nb));
        }
        Ok(map)
    }

    fn launcher(&self, rho: u32, m: u32, backend: BackendKind) -> Launcher {
        let mut cfg = LaunchConfig::new(BlockShape::new(rho, m));
        cfg.launch_latency = std::time::Duration::from_micros(5);
        cfg.backend = backend;
        cfg.profile_lanes = self.profile_lanes;
        // Accounting-only launch latency: the model stays in the
        // stats, the engine never sleeps for it.
        debug_assert!(!cfg.simulate_latency);
        // The serial backend is one lane by definition — a single
        // accumulator, deterministic sweep order.
        let workers = match backend {
            BackendKind::Serial => 1,
            BackendKind::Parallel | BackendKind::Pjrt => self.workers,
        };
        Launcher::with_workers(workers, cfg)
    }

    /// Run a job to completion — the one pipeline, any workload, any m,
    /// any domain.
    pub fn run(&self, job: &Job) -> Result<JobResult, ScheduleError> {
        let t0 = Instant::now();
        // Root span of the job lifecycle. A failed job drops the
        // handle unfinished (the span is simply lost — errors are
        // already observable through `jobs_failed` and the reply).
        let recorder = span::global();
        let job_span = recorder.start("scheduler", "job", 0);
        let job_id = job_span.id();
        let m = job.workload.m();
        let domain = job.workload.domain();
        let map = self.resolve_map(&job.map, m, job.nb)?;
        // A map may cover a *superset* of the workload's domain (the
        // gasket embeds in the simplex, so simplex maps serve gasket
        // jobs with extra predication) — never a smaller one.
        if map.domain() == DomainKind::Gasket && domain != DomainKind::Gasket {
            return Err(ScheduleError::DomainMismatch(
                job.map.clone(),
                job.workload.name(),
            ));
        }
        let rho = self.rho.rho_for_domain(domain, m);
        // Gasket geometry must be power-of-two on both axes; reject
        // here so a bad job (or a bad rho_gasket config) is a clean
        // client error, not a panic inside a queue worker — a simplex
        // cover map can accept an nb the gasket domain cannot.
        if domain == DomainKind::Gasket
            && (!job.nb.is_power_of_two() || !rho.is_power_of_two())
        {
            return Err(ScheduleError::GasketGeometry(job.nb, rho));
        }
        let w = workloads::build(job.workload, job.nb, rho, job.seed);
        log_info!(
            "scheduler",
            "job {} nb={} m={m} map={} backend={} mode={:?}",
            job.workload.name(),
            job.nb,
            job.map,
            job.backend.name(),
            self.exec_mode
        );

        let launcher = self.launcher(rho, m, job.backend);
        let (outputs, stats, batches) = match job.backend {
            BackendKind::Serial | BackendKind::Parallel => match self.exec_mode {
                ExecMode::Streaming => {
                    self.run_streaming(&launcher, map.as_ref(), w.as_ref(), job.nb, job_id)
                }
                ExecMode::Collect => {
                    self.run_collect(&launcher, map.as_ref(), w.as_ref(), job.nb, job_id)
                }
            },
            BackendKind::Pjrt => {
                self.run_pjrt(&launcher, map.as_ref(), w.as_ref(), job.nb, job_id)?
            }
        };

        let wall = t0.elapsed().as_secs_f64();
        self.metrics.record_job(wall);
        self.metrics
            .record_series(job.workload.name(), &job.map, job.backend.name(), wall);
        let lane_imbalance = stats.lane_imbalance();
        if let Some(ratio) = lane_imbalance {
            self.metrics.record_lane_imbalance(ratio);
        }
        recorder.finish_with(
            job_span,
            vec![
                ("workload", job.workload.name().to_string()),
                ("map", job.map.clone()),
                ("backend", job.backend.name().to_string()),
                ("nb", job.nb.to_string()),
            ],
        );
        Ok(JobResult {
            job: job.clone(),
            outputs,
            passes: stats.passes,
            launch_waves: stats.launch_waves,
            blocks_launched: stats.blocks_launched,
            blocks_filler: stats.blocks_filler,
            blocks_mapped: stats.blocks_mapped,
            threads_launched: stats.threads_launched,
            threads_mapped: stats.threads_mapped,
            threads_predicated_off: stats.threads_predicated_off,
            wall_secs: wall,
            tile_batches: batches,
            lane_profile: stats.lanes,
            lane_imbalance,
        })
    }

    /// Emit one child span per profiled lane under `parent`. Lane busy
    /// time is measured inside the launcher and comes back through
    /// [`LaunchStats::lanes`] after the fact, so the spans are
    /// reconstructed intervals anchored at the sweep start.
    fn record_lane_spans(&self, stats: &LaunchStats, parent: u64, sweep_start_ns: u64) {
        let recorder = span::global();
        if !recorder.enabled() {
            return;
        }
        for lane in &stats.lanes {
            recorder.record_interval(
                "engine",
                format!("lane-{}", lane.lane),
                parent,
                sweep_start_ns,
                sweep_start_ns + lane.busy_ns,
                vec![
                    ("chunks_pulled", lane.chunks_pulled.to_string()),
                    ("blocks_processed", lane.blocks_processed.to_string()),
                ],
            );
        }
    }

    /// Fused map+execute: per-lane accumulators advance inside the map
    /// sweep; nothing is materialized between the phases.
    fn run_streaming(
        &self,
        launcher: &Launcher,
        map: &dyn MThreadMap,
        w: &dyn Workload,
        nb: u64,
        parent: u64,
    ) -> (Vec<(String, f64)>, LaunchStats, u64) {
        let recorder = span::global();
        let sweep = recorder.start("engine", "fused_sweep", parent);
        let sweep_id = sweep.id();
        let sweep_start_ns = span::now_ns();
        let t = Instant::now();
        let accums: Vec<Mutex<Accum>> = (0..launcher.workers())
            .map(|_| Mutex::new(w.new_accum()))
            .collect();
        // The lane's mutex is uncontended by construction (the launcher
        // uses each lane index from one thread at a time); the lock is
        // only what makes the sharing safe Rust, at ~ns per block
        // against the µs-scale tile work behind it.
        let stats = launcher.launch(map, nb, |lane, b| {
            let mut acc = accums[lane].lock().unwrap();
            w.process_block(&mut acc, b)
        });
        let outputs = w.finish(
            accums
                .into_iter()
                .map(|a| a.into_inner().unwrap())
                .collect(),
        );
        self.metrics.record_fused_phase(t.elapsed().as_secs_f64());
        self.metrics
            .blocks_mapped
            .fetch_add(stats.blocks_mapped, Ordering::Relaxed);
        recorder.finish_with(
            sweep,
            vec![
                ("blocks_mapped", stats.blocks_mapped.to_string()),
                ("passes", stats.passes.to_string()),
                ("launch_waves", stats.launch_waves.to_string()),
            ],
        );
        self.record_lane_spans(&stats, sweep_id, sweep_start_ns);
        (outputs, stats, 0)
    }

    /// Phase 1 of the collect flows: run the map over the grid,
    /// gathering mapped blocks in deterministic order.
    fn collect_blocks(
        &self,
        launcher: &Launcher,
        map: &dyn MThreadMap,
        nb: u64,
        parent: u64,
    ) -> (Vec<MappedBlock>, LaunchStats) {
        let recorder = span::global();
        let sweep = recorder.start("engine", "map_sweep", parent);
        let sweep_id = sweep.id();
        let sweep_start_ns = span::now_ns();
        let t = Instant::now();
        let blocks: Mutex<Vec<MappedBlock>> = Mutex::new(Vec::new());
        let stats = launcher.launch(map, nb, |_lane, b| {
            blocks.lock().unwrap().push(*b);
            0
        });
        let mut blocks = blocks.into_inner().unwrap();
        // Deterministic order for reproducible aggregation.
        blocks.sort_by(|a, b| (a.pass, a.data.as_slice()).cmp(&(b.pass, b.data.as_slice())));
        self.metrics.record_map_phase(t.elapsed().as_secs_f64());
        self.metrics
            .blocks_mapped
            .fetch_add(stats.blocks_mapped, Ordering::Relaxed);
        recorder.finish_with(
            sweep,
            vec![
                ("blocks_mapped", stats.blocks_mapped.to_string()),
                ("passes", stats.passes.to_string()),
            ],
        );
        self.record_lane_spans(&stats, sweep_id, sweep_start_ns);
        log_debug!("scheduler", "mapped {} blocks", blocks.len());
        (blocks, stats)
    }

    /// Opt-in two-phase flow: collect, sort, then execute over the
    /// same accumulators. Reports the same stats as streaming.
    fn run_collect(
        &self,
        launcher: &Launcher,
        map: &dyn MThreadMap,
        w: &dyn Workload,
        nb: u64,
        parent: u64,
    ) -> (Vec<(String, f64)>, LaunchStats, u64) {
        let (blocks, mut stats) = self.collect_blocks(launcher, map, nb, parent);
        let recorder = span::global();
        let exec = recorder.start("engine", "exec", parent);
        let t = Instant::now();
        let (outputs, predicated) = self.execute_collected(w, &blocks, launcher.workers());
        stats.threads_predicated_off = predicated;
        self.metrics.record_exec_phase(t.elapsed().as_secs_f64());
        recorder.finish_with(exec, vec![("blocks", blocks.len().to_string())]);
        (outputs, stats, 0)
    }

    /// Execute a collected block list over per-lane accumulators.
    fn execute_collected(
        &self,
        w: &dyn Workload,
        blocks: &[MappedBlock],
        lanes: usize,
    ) -> (Vec<(String, f64)>, u64) {
        let lanes = lanes.max(1);
        let accums: Vec<Mutex<Accum>> = (0..lanes).map(|_| Mutex::new(w.new_accum())).collect();
        let predicated = AtomicU64::new(0);
        if !blocks.is_empty() {
            let chunk = blocks.len().div_ceil(lanes);
            std::thread::scope(|scope| {
                for (lane, batch) in blocks.chunks(chunk).enumerate() {
                    let accums = &accums;
                    let predicated = &predicated;
                    scope.spawn(move || {
                        let mut acc = accums[lane].lock().unwrap();
                        let mut pred = 0u64;
                        for b in batch {
                            pred += w.process_block(&mut acc, b);
                        }
                        predicated.fetch_add(pred, Ordering::Relaxed);
                    });
                }
            });
        }
        let outputs = w.finish(
            accums
                .into_iter()
                .map(|a| a.into_inner().unwrap())
                .collect(),
        );
        (outputs, predicated.load(Ordering::Relaxed))
    }

    /// PJRT backend: collect (the batcher packs fixed-size batches),
    /// then dispatch through the workload's batched tile path. The
    /// stats keep `threads_predicated_off = 0` — predication happens
    /// tile-side in the artifacts, not in the launch kernel.
    fn run_pjrt(
        &self,
        launcher: &Launcher,
        map: &dyn MThreadMap,
        w: &dyn Workload,
        nb: u64,
        parent: u64,
    ) -> Result<(Vec<(String, f64)>, LaunchStats, u64), ScheduleError> {
        if !w.supports_pjrt() {
            return Err(ScheduleError::NoPjrtPath(w.name()));
        }
        let exe = self
            .executor
            .clone()
            .ok_or_else(|| ScheduleError::NoExecutor("executor not loaded".into()))?;
        let (blocks, stats) = self.collect_blocks(launcher, map, nb, parent);
        let recorder = span::global();
        let exec = recorder.start("engine", "exec", parent);
        let t = Instant::now();
        let run = w.run_pjrt(exe, &blocks)?;
        self.metrics
            .tile_batches
            .fetch_add(run.batches_run, Ordering::Relaxed);
        self.metrics
            .tiles_padded
            .fetch_add(run.tiles_padded, Ordering::Relaxed);
        self.metrics.record_exec_phase(t.elapsed().as_secs_f64());
        recorder.finish_with(exec, vec![("tile_batches", run.batches_run.to_string())]);
        Ok((run.outputs, stats, run.batches_run))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::WorkloadKind;
    use crate::workloads::*;

    fn job(w: WorkloadKind, nb: u64, map: &str) -> Job {
        Job {
            workload: w,
            nb,
            map: map.into(),
            backend: BackendKind::Parallel,
            seed: 11,
        }
    }

    #[test]
    fn edm_rust_matches_reference_under_all_maps() {
        let sched = Scheduler::new(4, None);
        let w = EdmWorkload::generate(8, sched.rho_for(2), 11);
        let (want_count, want_sum) = w.reference();
        for map in ["bb", "lambda2", "enum2", "rb", "ries"] {
            let r = sched.run(&job(WorkloadKind::Edm, 8, map)).unwrap();
            assert_eq!(
                r.outputs[0].1 as u64, want_count,
                "map={map}: neighbour count"
            );
            let sum = r.outputs[1].1;
            assert!(
                (sum - want_sum).abs() < 1e-3 * want_sum.abs().max(1.0),
                "map={map}: {sum} vs {want_sum}"
            );
        }
    }

    #[test]
    fn collision_rust_matches_reference_under_all_maps() {
        let sched = Scheduler::new(4, None);
        let w = CollisionWorkload::generate(8, sched.rho_for(2), 11);
        let want = w.reference() as f64;
        for map in ["bb", "lambda2", "enum2", "rb", "ries"] {
            let r = sched.run(&job(WorkloadKind::Collision, 8, map)).unwrap();
            assert_eq!(r.outputs[0].1, want, "map={map}");
        }
    }

    #[test]
    fn nbody_rust_matches_reference() {
        let sched = Scheduler::new(4, None);
        let w = NBodyWorkload::generate(4, sched.rho_for(2), 11);
        let want = NBodyWorkload::checksum(&w.reference());
        for map in ["bb", "lambda2"] {
            let r = sched.run(&job(WorkloadKind::NBody, 4, map)).unwrap();
            let got = r.outputs[0].1;
            assert!(
                (got - want).abs() < 1e-3 * want,
                "map={map}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn triple_rust_matches_reference() {
        let sched = Scheduler::new(4, None);
        let w = TripleWorkload::generate(4, sched.rho_for(3), 11);
        let want = w.reference();
        for map in ["bb", "lambda3", "enum3", "lambda3-rec"] {
            let r = sched.run(&job(WorkloadKind::Triple, 4, map)).unwrap();
            let got = r.outputs[0].1;
            assert!(
                (got - want).abs() < 1e-6 * want.abs().max(1.0),
                "map={map}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn cellular_step_population_matches_reference() {
        let sched = Scheduler::new(2, None);
        let w = CellularWorkload::generate(8, sched.rho_for(2), 11);
        let want: u64 = w.step_reference().iter().map(|&c| c as u64).sum();
        for map in ["bb", "lambda2", "rb"] {
            let r = sched.run(&job(WorkloadKind::Cellular, 8, map)).unwrap();
            assert_eq!(r.outputs[1].1 as u64, want, "map={map}");
        }
    }

    #[test]
    fn trimat_matches_reference() {
        let sched = Scheduler::new(2, None);
        let w = TriMatVecWorkload::generate(4, sched.rho_for(2), 11);
        let want = TriMatVecWorkload::checksum(&w.reference());
        let r = sched.run(&job(WorkloadKind::TriMatVec, 4, "lambda2")).unwrap();
        assert!((r.outputs[0].1 - want).abs() < 1e-3 * want.max(1.0));
    }

    #[test]
    fn ktuple_rust_matches_reference_under_bb_and_lambda_m() {
        let sched = Scheduler::new(4, None);
        for (m, nb) in [(4u32, 4u64), (5, 3)] {
            let w = KTupleWorkload::generate(nb, sched.rho_for(m), m, 11);
            let want = w.reference();
            for map in ["bb", "lambda-m"] {
                let r = sched
                    .run(&job(WorkloadKind::KTuple(m), nb, map))
                    .unwrap_or_else(|e| panic!("m={m} map={map}: {e}"));
                let got = r.outputs[0].1;
                assert!(
                    (got - want).abs() < 1e-9 * want.abs().max(1.0),
                    "m={m} map={map}: {got} vs {want}"
                );
                assert_eq!(
                    r.blocks_mapped as u128,
                    crate::maps::domain_volume(nb, m),
                    "m={m} map={map}"
                );
            }
        }
    }

    #[test]
    fn ktuple3_runs_on_the_adapted_fixed_maps() {
        // At m=3 the unified pipeline reuses the λ3 family via adapters.
        let sched = Scheduler::new(2, None);
        let w = KTupleWorkload::generate(4, sched.rho_for(3), 3, 11);
        let want = w.reference();
        for map in ["bb", "lambda3", "enum3"] {
            let r = sched.run(&job(WorkloadKind::KTuple(3), 4, map)).unwrap();
            let got = r.outputs[0].1;
            assert!(
                (got - want).abs() < 1e-9 * want.abs().max(1.0),
                "map={map}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn ktuple2_shares_launch_geometry_with_edm() {
        // The ρ-selection regression: a pair-style (m=2) ktuple job
        // must run with rho2 under the same m=2 maps as edm — same
        // blocks launched, same blocks mapped, same thread count.
        let sched = Scheduler::new(2, None);
        for map in ["bb", "lambda2", "rb"] {
            let pair = sched.run(&job(WorkloadKind::KTuple(2), 8, map)).unwrap();
            let edm = sched.run(&job(WorkloadKind::Edm, 8, map)).unwrap();
            assert_eq!(pair.blocks_launched, edm.blocks_launched, "map={map}");
            assert_eq!(pair.blocks_mapped, edm.blocks_mapped, "map={map}");
            assert_eq!(pair.threads_launched, edm.threads_launched, "map={map}");
        }
        // And its energy is correct under the pair block convention.
        let w = KTupleWorkload::generate(8, sched.rho_for(2), 2, 11);
        let want = w.reference();
        let got = sched
            .run(&job(WorkloadKind::KTuple(2), 8, "lambda2"))
            .unwrap()
            .outputs[0]
            .1;
        assert!(
            (got - want).abs() < 1e-9 * want.abs().max(1.0),
            "{got} vs {want}"
        );
    }

    #[test]
    fn ktuple3_shares_launch_geometry_with_triple() {
        let sched = Scheduler::new(2, None);
        for map in ["bb", "lambda3"] {
            let kt = sched.run(&job(WorkloadKind::KTuple(3), 4, map)).unwrap();
            let tr = sched.run(&job(WorkloadKind::Triple, 4, map)).unwrap();
            assert_eq!(kt.blocks_launched, tr.blocks_launched, "map={map}");
            assert_eq!(kt.blocks_mapped, tr.blocks_mapped, "map={map}");
            assert_eq!(kt.threads_launched, tr.threads_launched, "map={map}");
        }
    }

    #[test]
    fn ktuple_errors_cover_registry_and_backend() {
        let sched = Scheduler::new(1, None);
        assert!(matches!(
            sched.run(&job(WorkloadKind::KTuple(4), 4, "lambda3")),
            Err(ScheduleError::UnknownMap(_, 4))
        ));
        // Quadruples carry a fixed-shape artifact (ktuple_tile), so the
        // pjrt gate passes and the error is the missing executor; every
        // other arity has no artifact and reports the honest NoPjrtPath.
        let mut j = job(WorkloadKind::KTuple(4), 4, "bb");
        j.backend = BackendKind::Pjrt;
        assert!(matches!(sched.run(&j), Err(ScheduleError::NoExecutor(_))));
        let mut j = job(WorkloadKind::KTuple(5), 3, "bb");
        j.backend = BackendKind::Pjrt;
        assert!(matches!(
            sched.run(&j),
            Err(ScheduleError::NoPjrtPath("ktuple"))
        ));
    }

    #[test]
    fn gasket_ca_matches_reference_under_gasket_and_simplex_maps() {
        // The gasket CA is exact integer arithmetic: every covering map
        // must reproduce the brute-force reference bit for bit.
        let sched = Scheduler::new(4, None);
        let nb = 8u64;
        let rho = sched.rho.rho_for_domain(DomainKind::Gasket, 2);
        let w = crate::workloads::GasketCAWorkload::generate(nb, rho, 11);
        let want = w.reference_outputs();
        for map in ["lambda-gasket", "bb-gasket", "bb", "lambda2", "rb", "enum2"] {
            let r = sched.run(&job(WorkloadKind::GasketCA, nb, map)).unwrap();
            assert_eq!(r.outputs, want, "map={map}");
        }
    }

    #[test]
    fn gasket_launch_accounting_matches_closed_forms() {
        // k = 3, s = 3 (ρ = 8): λ_Δ launches exactly 3^k blocks (zero
        // filler), bb-gasket launches 4^k with 4^k − 3^k filler; both
        // predicate 3^k·(ρ² − 3^s) threads off inside gasket blocks.
        let sched = Scheduler::new(2, None);
        let nb = 8u64;
        let pred_gasket: u64 = 27 * (64 - 27);
        let lam = sched
            .run(&job(WorkloadKind::GasketCA, nb, "lambda-gasket"))
            .unwrap();
        assert_eq!(lam.blocks_launched, 27);
        assert_eq!(lam.blocks_mapped, 27);
        assert_eq!(lam.threads_predicated_off, pred_gasket);
        let bb_job = job(WorkloadKind::GasketCA, nb, "bb-gasket");
        let bb = sched.run(&bb_job).unwrap();
        assert_eq!(bb.blocks_launched, 64);
        assert_eq!(bb.blocks_mapped, 27);
        assert_eq!(bb.threads_predicated_off, pred_gasket);
        // A simplex map maps the whole triangle: the 9 non-gasket
        // triangle blocks reach the kernel and predicate off entirely.
        let l2 = sched.run(&job(WorkloadKind::GasketCA, nb, "lambda2")).unwrap();
        assert_eq!(l2.blocks_mapped, 36);
        assert_eq!(l2.threads_predicated_off, pred_gasket + 9 * 64);
    }

    #[test]
    fn gasket_maps_reject_simplex_workloads() {
        let sched = Scheduler::new(1, None);
        for map in ["lambda-gasket", "bb-gasket"] {
            match sched.run(&job(WorkloadKind::Edm, 8, map)) {
                Err(ScheduleError::DomainMismatch(m, w)) => {
                    assert_eq!(m, map);
                    assert_eq!(w, "edm");
                }
                other => panic!("map={map}: expected DomainMismatch, got {other:?}"),
            }
        }
        // Error text reaches clients verbatim through the server.
        let j = job(WorkloadKind::Edm, 8, "lambda-gasket");
        let e = sched.run(&j).unwrap_err();
        assert!(e.to_string().contains("gasket domain"), "{e}");
    }

    #[test]
    fn gasket_geometry_is_rejected_cleanly_not_panicked() {
        // A simplex cover map accepts nb=6, but the gasket domain does
        // not exist there: the job must fail with a client error, not
        // panic the (queue-worker) thread running it.
        let sched = Scheduler::new(1, None);
        match sched.run(&job(WorkloadKind::GasketCA, 6, "bb")) {
            Err(ScheduleError::GasketGeometry(nb, rho)) => {
                assert_eq!(nb, 6);
                assert_eq!(rho, sched.rho.rho_gasket);
            }
            other => panic!("expected GasketGeometry, got {other:?}"),
        }
        // Same guard covers a bad rho_gasket from the config file.
        let mut sched = Scheduler::new(1, None);
        sched.rho.rho_gasket = 6;
        let e = sched.run(&job(WorkloadKind::GasketCA, 8, "bb")).unwrap_err();
        assert!(matches!(e, ScheduleError::GasketGeometry(8, 6)));
        assert!(e.to_string().contains("power-of-two"), "{e}");
        // Simplex workloads at nb=6 are untouched by the guard.
        let sched = Scheduler::new(1, None);
        assert!(sched.run(&job(WorkloadKind::Edm, 6, "bb")).is_ok());
    }

    #[test]
    fn gasket_jobs_use_rho_gasket_and_the_layout_cache() {
        let mut sched = Scheduler::new(2, None);
        sched.rho.rho_gasket = 4;
        let r = sched
            .run(&job(WorkloadKind::GasketCA, 4, "lambda-gasket"))
            .unwrap();
        // 3^2 blocks of ρ² = 16 threads each.
        assert_eq!(r.threads_launched, 9 * 16);
        sched
            .run(&job(WorkloadKind::GasketCA, 8, "lambda-gasket"))
            .unwrap();
        assert_eq!(sched.metrics.map_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(
            sched.metrics.map_cache_hits.load(Ordering::Relaxed),
            1,
            "second gasket job reuses the cached map"
        );
    }

    #[test]
    fn streaming_and_collect_agree_on_stats_and_outputs() {
        // Smoke-level equivalence (the exhaustive per-map sweep lives
        // in tests/engine_conformance.rs).
        let streaming = Scheduler::new(3, None);
        let mut collect = Scheduler::new(3, None);
        collect.exec_mode = ExecMode::Collect;
        for (w, nb, map) in [
            (WorkloadKind::Edm, 8u64, "lambda2"),
            (WorkloadKind::Triple, 4, "bb"),
            (WorkloadKind::KTuple(4), 4, "lambda-m"),
            (WorkloadKind::GasketCA, 8, "lambda-gasket"),
        ] {
            let a = streaming.run(&job(w, nb, map)).unwrap();
            let b = collect.run(&job(w, nb, map)).unwrap();
            assert_eq!(a.blocks_launched, b.blocks_launched, "{}", w.name());
            assert_eq!(a.blocks_mapped, b.blocks_mapped, "{}", w.name());
            for ((ka, va), (kb, vb)) in a.outputs.iter().zip(&b.outputs) {
                assert_eq!(ka, kb);
                assert!(
                    (va - vb).abs() <= 1e-9 * va.abs().max(1.0),
                    "{} {ka}: {va} vs {vb}",
                    w.name()
                );
            }
        }
    }

    #[test]
    fn serial_backend_matches_parallel_on_all_eight_fields() {
        // The serial sweep is the accounting oracle: a job run on one
        // lane must agree with the pooled backend field for field (the
        // full map × workload sweep lives in tests/workload_matrix.rs).
        let sched = Scheduler::new(4, None);
        for (w, nb, map) in [
            (WorkloadKind::Edm, 8u64, "lambda2"),
            (WorkloadKind::Triple, 4, "bb"),
            (WorkloadKind::GasketCA, 8, "lambda-gasket"),
        ] {
            let mut serial = job(w, nb, map);
            serial.backend = BackendKind::Serial;
            let a = sched.run(&serial).unwrap();
            let b = sched.run(&job(w, nb, map)).unwrap();
            assert_eq!(a.accounting(), b.accounting(), "{}", w.name());
            for ((ka, va), (kb, vb)) in a.outputs.iter().zip(&b.outputs) {
                assert_eq!(ka, kb);
                assert!(
                    (va - vb).abs() <= 1e-9 * va.abs().max(1.0),
                    "{} {ka}: {va} vs {vb}",
                    w.name()
                );
            }
        }
    }

    #[test]
    fn lambda2_launches_half_the_blocks_of_bb() {
        let sched = Scheduler::new(2, None);
        let bb = sched.run(&job(WorkloadKind::Edm, 16, "bb")).unwrap();
        let l2 = sched.run(&job(WorkloadKind::Edm, 16, "lambda2")).unwrap();
        assert_eq!(bb.blocks_mapped, l2.blocks_mapped);
        assert!(bb.blocks_launched > l2.blocks_launched * 18 / 10);
        assert_eq!(l2.block_efficiency(), 1.0);
    }

    #[test]
    fn unknown_map_and_unsupported_size_error() {
        let sched = Scheduler::new(1, None);
        assert!(matches!(
            sched.run(&job(WorkloadKind::Edm, 8, "nope")),
            Err(ScheduleError::UnknownMap(_, _))
        ));
        assert!(matches!(
            sched.run(&job(WorkloadKind::Edm, 17, "lambda2")),
            Err(ScheduleError::Unsupported(_, _))
        ));
    }

    #[test]
    fn pjrt_without_executor_errors() {
        let sched = Scheduler::new(1, None);
        let mut j = job(WorkloadKind::Edm, 8, "lambda2");
        j.backend = BackendKind::Pjrt;
        assert!(matches!(
            sched.run(&j),
            Err(ScheduleError::NoExecutor(_))
        ));
    }

    #[test]
    fn metrics_accumulate_across_jobs() {
        let sched = Scheduler::new(2, None);
        sched.run(&job(WorkloadKind::Edm, 8, "lambda2")).unwrap();
        sched.run(&job(WorkloadKind::Edm, 8, "bb")).unwrap();
        let snap = sched.metrics.snapshot();
        assert_eq!(snap.get("jobs_completed").unwrap().as_u64(), Some(2));
        assert!(snap.get("blocks_mapped").unwrap().as_u64().unwrap() > 0);
        // Streaming mode records fused-phase samples, not map/exec.
        assert_eq!(
            snap.get("fused_phase").unwrap().get("count").unwrap().as_u64(),
            Some(2)
        );
    }

    #[test]
    fn profiled_jobs_surface_lane_stats_and_series() {
        let mut sched = Scheduler::new(3, None);
        sched.profile_lanes = true;
        let r = sched.run(&job(WorkloadKind::Edm, 8, "lambda2")).unwrap();
        assert!(!r.lane_profile.is_empty());
        let covered: u64 = r.lane_profile.iter().map(|p| p.blocks_processed).sum();
        assert_eq!(covered, r.blocks_launched, "lanes cover the launch");
        assert!(r.lane_imbalance.unwrap() >= 1.0);
        let snap = sched.metrics.snapshot();
        let imb = snap.get("lane_imbalance").unwrap();
        assert_eq!(imb.get("count").unwrap().as_u64(), Some(1));
        assert!(imb.get("mean").unwrap().as_f64().unwrap() >= 1.0);
        let series = snap.get("series").unwrap();
        let s = series.get("edm/lambda2/parallel").unwrap();
        assert_eq!(s.get("count").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn unprofiled_jobs_carry_no_lane_stats() {
        let sched = Scheduler::new(2, None);
        assert!(!sched.profile_lanes, "profiling is opt-in");
        let r = sched.run(&job(WorkloadKind::Edm, 8, "lambda2")).unwrap();
        assert!(r.lane_profile.is_empty());
        assert!(r.lane_imbalance.is_none());
        // The labeled series records regardless — it is a metrics
        // surface, not a profiling one.
        let snap = sched.metrics.snapshot();
        let series = snap.get("series").unwrap();
        assert!(series.get("edm/lambda2/parallel").is_some());
    }

    #[test]
    fn map_cache_hits_across_repeated_jobs() {
        let sched = Scheduler::new(2, None);
        sched.run(&job(WorkloadKind::Edm, 8, "lambda2")).unwrap();
        sched.run(&job(WorkloadKind::Edm, 16, "lambda2")).unwrap();
        sched.run(&job(WorkloadKind::Edm, 8, "bb")).unwrap();
        let hits = sched.metrics.map_cache_hits.load(Ordering::Relaxed);
        let misses = sched.metrics.map_cache_misses.load(Ordering::Relaxed);
        assert_eq!(misses, 2, "lambda2 and bb resolved once each");
        assert_eq!(hits, 1, "second lambda2 job reuses the layout");
    }

    #[test]
    fn unsupported_size_still_counts_a_cache_entry() {
        // Resolution happens before the size check, so the map object
        // is reusable even after a bad-size job.
        let sched = Scheduler::new(1, None);
        assert!(sched.run(&job(WorkloadKind::Edm, 17, "lambda2")).is_err());
        sched.run(&job(WorkloadKind::Edm, 16, "lambda2")).unwrap();
        assert_eq!(sched.metrics.map_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(sched.metrics.map_cache_hits.load(Ordering::Relaxed), 1);
    }
}
