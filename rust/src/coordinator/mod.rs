//! L3 coordinator — the serving layer around the maps.
//!
//! The paper's contribution is the *launch geometry*; this module is
//! the system that exploits it end-to-end, shaped like a (small)
//! serving runtime:
//!
//! - [`job`] — the job model: a workload + problem size + map choice +
//!   execution backend, and its structured result.
//! - [`scheduler`] — the unified execution engine: one pipeline for
//!   every workload at every m, fused map+execute by default (opt-in
//!   collect mode), a single ρ policy, and a map-layout cache.
//! - [`queue`] — a bounded job queue with a worker pool: concurrent
//!   clients execute in parallel, overload answers with backpressure.
//! - [`batcher`] — gathers the tile operands of λ-mapped blocks into
//!   fixed-size batches and executes them on the PJRT runtime (the
//!   AOT-compiled Pallas kernels), padding the final partial batch.
//! - [`metrics`] — process-wide counters, phase timings (Welford +
//!   log-bucketed histograms), labeled per-scenario series, queue
//!   gauges, Prometheus exposition.
//! - [`span`] — lightweight lifecycle spans in a bounded ring buffer,
//!   exportable as Chrome trace-event JSON.
//! - [`server`] — a JSON-lines-over-TCP leader: accepts jobs from
//!   clients and runs them through the queue (examples/serve_client).
//!   One blocking thread per connection — the measurable baseline.
//! - [`reactor`] — the non-blocking poll multiplexer: thousands of
//!   connections on one thread, capped-frame reads, backpressured
//!   writes, and the streaming `sweep`/`results` fan-out commands.
//! - [`results_store`] — the connection-independent sweep results
//!   store: bounded, TTL-evicted, keyed by durable token so clients
//!   reconnect and resume pagination instead of losing work.

pub mod batcher;
pub mod job;
pub mod metrics;
pub mod queue;
pub mod reactor;
pub mod results_store;
pub mod scheduler;
pub mod server;
pub mod span;
pub mod trace;

pub use batcher::TileBatcher;
pub use job::{Backend, BackendKind, Job, JobResult, WorkloadKind};
pub use metrics::Metrics;
pub use queue::{JobQueue, Priority, QueueConfig};
pub use reactor::{Reactor, ReactorConfig};
pub use results_store::{PutOutcome, ResultsStore, StoreConfig, StoreError};
pub use scheduler::{ExecMode, RhoPolicy, ScheduleError, Scheduler};
pub use span::{Span, SpanRecorder};
