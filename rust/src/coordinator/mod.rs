//! L3 coordinator — the serving layer around the maps.
//!
//! The paper's contribution is the *launch geometry*; this module is
//! the system that exploits it end-to-end, shaped like a (small)
//! serving runtime:
//!
//! - [`job`] — the job model: a workload + problem size + map choice +
//!   execution backend, and its structured result.
//! - [`batcher`] — gathers the tile operands of λ-mapped blocks into
//!   fixed-size batches and executes them on the PJRT runtime (the
//!   AOT-compiled Pallas kernels), padding the final partial batch.
//! - [`scheduler`] — runs jobs: grid launch (map hot path) → tile
//!   execution (pure-Rust or PJRT backend) → aggregation; owns the
//!   worker pool and the metrics.
//! - [`metrics`] — process-wide counters and latency summaries.
//! - [`server`] — a JSON-lines-over-TCP leader: accepts jobs from
//!   clients, schedules them, streams results (examples/serve_client).

pub mod batcher;
pub mod job;
pub mod metrics;
pub mod scheduler;
pub mod server;
pub mod trace;

pub use batcher::TileBatcher;
pub use job::{Backend, Job, JobResult, WorkloadKind};
pub use metrics::Metrics;
pub use scheduler::Scheduler;
