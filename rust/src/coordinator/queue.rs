//! Bounded job queue + worker pool in front of the unified engine.
//!
//! The server used to run every job inline on its connection thread;
//! the queue decouples admission from execution: connections enqueue,
//! a fixed pool of queue workers executes jobs in parallel on the
//! shared scheduler, and the bounded capacity gives backpressure
//! ([`ScheduleError::QueueFull`]) instead of unbounded memory growth
//! under overload. Queue depth and enqueue→dequeue wait times are
//! exported through the scheduler's [`Metrics`](crate::coordinator::Metrics).
//!
//! Shutdown drains: workers finish every job already enqueued (their
//! clients are still waiting on replies) before exiting.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::job::{Job, JobResult};
use crate::coordinator::scheduler::{ScheduleError, Scheduler};
use crate::coordinator::span::{self, ActiveSpan};

/// Queue sizing knobs.
#[derive(Clone, Copy, Debug)]
pub struct QueueConfig {
    /// Concurrent job executions (queue workers).
    pub workers: usize,
    /// Maximum enqueued-but-not-started jobs before backpressure.
    pub capacity: usize,
}

impl Default for QueueConfig {
    fn default() -> QueueConfig {
        QueueConfig {
            workers: 4,
            capacity: 64,
        }
    }
}

/// The result channel a submitted job resolves through.
pub type JobReceiver = mpsc::Receiver<Result<JobResult, ScheduleError>>;

struct Queued {
    job: Job,
    enqueued: Instant,
    /// Span covering enqueue→dequeue; finished by the worker that pops
    /// the item (rejected submissions never construct a `Queued`, so
    /// their spans never start).
    wait_span: ActiveSpan,
    reply: mpsc::Sender<Result<JobResult, ScheduleError>>,
}

struct Inner {
    queue: Mutex<VecDeque<Queued>>,
    available: Condvar,
    shutdown: AtomicBool,
    capacity: usize,
    scheduler: Arc<Scheduler>,
}

/// A running queue: workers live until shutdown/drop.
pub struct JobQueue {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl JobQueue {
    pub fn start(scheduler: Arc<Scheduler>, cfg: QueueConfig) -> JobQueue {
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            capacity: cfg.capacity.max(1),
            scheduler,
        });
        let workers = (0..cfg.workers.max(1))
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("smx-jobq-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn queue worker")
            })
            .collect();
        JobQueue { inner, workers }
    }

    /// Enqueue a job; the receiver yields its result once a worker
    /// finishes. Fails fast when the queue is full (backpressure) or
    /// the coordinator is shutting down.
    pub fn submit(&self, job: Job) -> Result<JobReceiver, ScheduleError> {
        let metrics = &self.inner.scheduler.metrics;
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.inner.queue.lock().unwrap();
            // Shutdown must be re-checked under the queue lock: workers
            // take the same lock before their final empty+shutdown
            // check, so a job enqueued here is guaranteed to be seen
            // by the drain (no stranded reply channels).
            if self.inner.shutdown.load(Ordering::SeqCst) {
                return Err(ScheduleError::Shutdown);
            }
            if q.len() >= self.inner.capacity {
                metrics.queue_rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ScheduleError::QueueFull(self.inner.capacity));
            }
            q.push_back(Queued {
                wait_span: span::global().start("queue", "queue_wait", 0),
                job,
                enqueued: Instant::now(),
                reply: tx,
            });
            // Gauge updates stay under the lock so a worker cannot pop
            // (and decrement) before the increment lands.
            metrics.jobs_queued.fetch_add(1, Ordering::Relaxed);
            metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        }
        self.inner.available.notify_one();
        Ok(rx)
    }

    /// Submit and block for the result (what a connection thread does).
    pub fn run(&self, job: Job) -> Result<JobResult, ScheduleError> {
        let rx = self.submit(job)?;
        rx.recv().unwrap_or(Err(ScheduleError::Shutdown))
    }

    /// Live queue depth (enqueued, not yet picked up).
    pub fn depth(&self) -> u64 {
        self.inner
            .scheduler
            .metrics
            .queue_depth
            .load(Ordering::Relaxed)
    }

    /// Stop accepting new jobs; workers drain what is already queued.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.available.notify_all();
    }
}

impl Drop for JobQueue {
    fn drop(&mut self) {
        self.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        let item = {
            let mut q = inner.queue.lock().unwrap();
            loop {
                if let Some(item) = q.pop_front() {
                    // Decrement under the same lock as the pop so the
                    // gauge always equals the pending-set size — the
                    // bound `queue_depth ≤ capacity` is exact at every
                    // instant (the queue property tests sample it
                    // mid-burst).
                    inner
                        .scheduler
                        .metrics
                        .queue_depth
                        .fetch_sub(1, Ordering::Relaxed);
                    break Some(item);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = inner.available.wait(q).unwrap();
            }
        };
        let Some(item) = item else { return };
        let metrics = &inner.scheduler.metrics;
        metrics.record_queue_wait(item.enqueued.elapsed().as_secs_f64());
        span::global().finish_with(
            item.wait_span,
            vec![
                ("workload", item.job.workload.name().to_string()),
                ("map", item.job.map.clone()),
            ],
        );
        let result = inner.scheduler.run(&item.job);
        // The client may have disconnected; dropping the result is fine.
        let _ = item.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{Backend, WorkloadKind};

    fn job(nb: u64, seed: u64) -> Job {
        Job {
            workload: WorkloadKind::Edm,
            nb,
            map: "lambda2".into(),
            backend: Backend::Parallel,
            seed,
        }
    }

    #[test]
    fn jobs_submitted_concurrently_all_complete() {
        let sched = Arc::new(Scheduler::new(2, None));
        let q = JobQueue::start(
            Arc::clone(&sched),
            QueueConfig {
                workers: 3,
                capacity: 32,
            },
        );
        let receivers: Vec<_> = (0..9).map(|i| q.submit(job(8, i)).unwrap()).collect();
        for rx in receivers {
            let r = rx.recv().unwrap().expect("job result");
            assert_eq!(r.outputs[0].0, "neighbour_count");
        }
        assert_eq!(
            sched
                .metrics
                .jobs_queued
                .load(std::sync::atomic::Ordering::Relaxed),
            9
        );
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn full_queue_rejects_with_backpressure() {
        // No workers draining: saturate a capacity-2 queue.
        let sched = Arc::new(Scheduler::new(1, None));
        let q = JobQueue::start(
            Arc::clone(&sched),
            QueueConfig {
                workers: 1,
                capacity: 2,
            },
        );
        // Stop the worker first so the queue cannot drain mid-test:
        // enqueue a job, then shut down? No — shutdown rejects. Instead
        // rely on capacity bounding the *pending* set: submit many
        // fast and expect at least one rejection OR all completions.
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..64 {
            match q.submit(job(8, i)) {
                Ok(rx) => receivers.push(rx),
                Err(ScheduleError::QueueFull(cap)) => {
                    assert_eq!(cap, 2);
                    rejected += 1;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        for rx in receivers {
            rx.recv().unwrap().expect("accepted jobs complete");
        }
        assert!(
            rejected > 0,
            "64 instant submissions against capacity 2 must trip backpressure"
        );
        assert_eq!(
            sched
                .metrics
                .queue_rejected
                .load(std::sync::atomic::Ordering::Relaxed),
            rejected
        );
    }

    #[test]
    fn shutdown_rejects_new_jobs_but_drains_queued_ones() {
        let sched = Arc::new(Scheduler::new(1, None));
        let q = JobQueue::start(
            Arc::clone(&sched),
            QueueConfig {
                workers: 1,
                capacity: 8,
            },
        );
        let rx = q.submit(job(8, 1)).unwrap();
        q.shutdown();
        assert!(matches!(q.submit(job(8, 2)), Err(ScheduleError::Shutdown)));
        // The already-enqueued job still resolves.
        let r = rx.recv().unwrap();
        assert!(r.is_ok(), "drained job must complete: {:?}", r.err().map(|e| e.to_string()));
    }

    #[test]
    fn queue_wait_metric_accumulates() {
        let sched = Arc::new(Scheduler::new(1, None));
        let q = JobQueue::start(Arc::clone(&sched), QueueConfig::default());
        q.run(job(8, 3)).unwrap();
        let snap = sched.metrics.snapshot();
        assert_eq!(
            snap.get("queue_wait").unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
        assert_eq!(snap.get("jobs_queued").unwrap().as_u64(), Some(1));
    }
}
