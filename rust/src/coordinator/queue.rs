//! Bounded job queue + worker pool in front of the unified engine,
//! with job priorities and per-client fairness lanes.
//!
//! The server used to run every job inline on its connection thread;
//! the queue decouples admission from execution: connections enqueue,
//! a fixed pool of queue workers executes jobs in parallel on the
//! shared scheduler, and the bounded capacity gives backpressure
//! ([`ScheduleError::QueueFull`]) instead of unbounded memory growth
//! under overload. Queue depth and enqueue→dequeue wait times are
//! exported through the scheduler's [`Metrics`](crate::coordinator::Metrics).
//!
//! ## Priorities and fairness
//!
//! Jobs carry a [`Priority`] (strict: a high job is always dequeued
//! before any normal job, normal before low) and a *lane* — an opaque
//! client token (the reactor uses the connection id). Within one
//! priority level, lanes are served round-robin, one job per turn, so
//! a client that fans a 4096-row sweep into the queue cannot starve a
//! client submitting single jobs: the single job waits behind at most
//! one job per other active lane, not behind the whole sweep. The
//! capacity bound stays global — `queue_depth ≤ capacity` holds
//! exactly at every instant regardless of how jobs spread over lanes.
//!
//! ## Sync and async admission
//!
//! [`submit`](JobQueue::submit)/[`run`](JobQueue::run) keep the
//! blocking channel shape the threaded server uses.
//! [`submit_async`](JobQueue::submit_async) hands the result to a
//! callback on the worker thread instead — the poll-reactor submits
//! hundreds of sweep jobs this way without parking a thread per job.
//!
//! Shutdown drains: workers finish every job already enqueued (their
//! clients are still waiting on replies) before exiting.
//!
//! Memory-ordering policy: the only atomic is the `shutdown` flag, and
//! every access (the two worker checks, the enqueue check, the store
//! in [`JobQueue::shutdown`]) happens while holding the queue mutex —
//! the mutex provides all the ordering, so the flag itself is Relaxed.
// lint: atomics(Relaxed)

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::job::{Job, JobResult};
use crate::coordinator::scheduler::{ScheduleError, Scheduler};
use crate::coordinator::span::{self, ActiveSpan};
use crate::util::sync::{lock_unpoisoned, wait_unpoisoned};

/// Queue sizing knobs.
#[derive(Clone, Copy, Debug)]
pub struct QueueConfig {
    /// Concurrent job executions (queue workers).
    pub workers: usize,
    /// Maximum enqueued-but-not-started jobs before backpressure.
    pub capacity: usize,
}

impl Default for QueueConfig {
    fn default() -> QueueConfig {
        QueueConfig {
            workers: 4,
            capacity: 64,
        }
    }
}

/// Strict job priority: every queued High job dequeues before any
/// Normal job, every Normal before any Low. Fairness applies *within*
/// a level, not across levels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    High = 0,
    Normal = 1,
    Low = 2,
}

impl Priority {
    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Low];

    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" | "" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    #[inline]
    fn index(self) -> usize {
        self as usize
    }
}

/// The result channel a submitted job resolves through.
pub type JobReceiver = mpsc::Receiver<Result<JobResult, ScheduleError>>;

/// How a finished job reaches its submitter.
enum Reply {
    /// Blocking shape: the submitter parks on the receiver.
    Channel(mpsc::Sender<Result<JobResult, ScheduleError>>),
    /// Reactor shape: invoked on the worker thread; must not block.
    Callback(Box<dyn FnOnce(Result<JobResult, ScheduleError>) + Send>),
}

impl Reply {
    fn deliver(self, result: Result<JobResult, ScheduleError>) {
        match self {
            // The client may have disconnected; dropping is fine.
            Reply::Channel(tx) => drop(tx.send(result)),
            Reply::Callback(cb) => cb(result),
        }
    }
}

struct Queued {
    job: Job,
    enqueued: Instant,
    /// Latest instant the job may still *start*; a worker popping it
    /// later delivers [`ScheduleError::Expired`] instead of running it
    /// (a stuck queue fails jobs loudly instead of arbitrarily late).
    deadline: Option<Instant>,
    /// Span covering enqueue→dequeue; finished by the worker that pops
    /// the item (rejected submissions never construct a `Queued`, so
    /// their spans never start).
    wait_span: ActiveSpan,
    reply: Reply,
}

/// Priority levels × per-client FIFO lanes with a round-robin cursor
/// per level. Lanes materialize on first push and evaporate when
/// drained, so the footprint is bounded by the jobs themselves.
#[derive(Default)]
struct Lanes {
    levels: [BTreeMap<u64, VecDeque<Queued>>; 3],
    /// Last lane served per level; the next pop starts strictly after
    /// it (wrapping), which is exactly round-robin.
    cursor: [u64; 3],
    len: usize,
}

impl Lanes {
    fn push(&mut self, priority: Priority, lane: u64, item: Queued) {
        // lint: allow(panic, priority index is 0..=2 by construction over a 3-lane array)
        self.levels[priority.index()]
            .entry(lane)
            .or_default()
            .push_back(item);
        self.len += 1;
    }

    fn pop(&mut self) -> Option<Queued> {
        for (level, cursor) in self.levels.iter_mut().zip(self.cursor.iter_mut()) {
            if level.is_empty() {
                continue;
            }
            // First lane strictly after the cursor, wrapping to the
            // smallest lane id.
            let lane = level
                .range(cursor.wrapping_add(1)..)
                .next()
                .map(|(k, _)| *k)
                .or_else(|| level.keys().next().copied())?;
            let fifo = level.get_mut(&lane)?;
            let item = fifo.pop_front()?;
            if fifo.is_empty() {
                level.remove(&lane);
            }
            *cursor = lane;
            self.len -= 1;
            return Some(item);
        }
        None
    }

    fn len(&self) -> usize {
        self.len
    }
}

struct Inner {
    queue: Mutex<Lanes>,
    available: Condvar,
    shutdown: AtomicBool,
    capacity: usize,
    scheduler: Arc<Scheduler>,
}

/// A running queue: workers live until shutdown/drop.
pub struct JobQueue {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl JobQueue {
    pub fn start(scheduler: Arc<Scheduler>, cfg: QueueConfig) -> JobQueue {
        let inner = Arc::new(Inner {
            queue: Mutex::new(Lanes::default()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            capacity: cfg.capacity.max(1),
            scheduler,
        });
        let workers = (0..cfg.workers.max(1))
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("smx-jobq-{i}"))
                    .spawn(move || worker_loop(&inner))
                    // lint: allow(panic, startup precedes serving; no threads means no server)
                    .expect("spawn queue worker")
            })
            .collect();
        JobQueue { inner, workers }
    }

    /// The shared admission path: everything under one lock so the
    /// capacity bound and the gauges stay exact.
    fn enqueue(
        &self,
        job: Job,
        priority: Priority,
        lane: u64,
        deadline: Option<Instant>,
        reply: Reply,
    ) -> Result<(), ScheduleError> {
        let metrics = &self.inner.scheduler.metrics;
        {
            let mut q = lock_unpoisoned(&self.inner.queue);
            // Shutdown must be re-checked under the queue lock: workers
            // take the same lock before their final empty+shutdown
            // check, so a job enqueued here is guaranteed to be seen
            // by the drain (no stranded replies).
            if self.inner.shutdown.load(Ordering::Relaxed) {
                return Err(ScheduleError::Shutdown);
            }
            if q.len() >= self.inner.capacity {
                metrics.queue_rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ScheduleError::QueueFull(self.inner.capacity));
            }
            q.push(
                priority,
                lane,
                Queued {
                    wait_span: span::global().start("queue", "queue_wait", 0),
                    job,
                    enqueued: Instant::now(),
                    deadline,
                    reply,
                },
            );
            // Gauge updates stay under the lock so a worker cannot pop
            // (and decrement) before the increment lands.
            metrics.jobs_queued.fetch_add(1, Ordering::Relaxed);
            metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        }
        self.inner.available.notify_one();
        Ok(())
    }

    /// Enqueue a job; the receiver yields its result once a worker
    /// finishes. Fails fast when the queue is full (backpressure) or
    /// the coordinator is shutting down.
    pub fn submit(&self, job: Job) -> Result<JobReceiver, ScheduleError> {
        self.submit_with(job, Priority::Normal, 0)
    }

    /// [`submit`](JobQueue::submit) with an explicit priority and
    /// fairness lane.
    pub fn submit_with(
        &self,
        job: Job,
        priority: Priority,
        lane: u64,
    ) -> Result<JobReceiver, ScheduleError> {
        let (tx, rx) = mpsc::channel();
        self.enqueue(job, priority, lane, None, Reply::Channel(tx))?;
        Ok(rx)
    }

    /// Non-blocking admission: the callback runs on the worker thread
    /// that finishes the job (it must not block — hand off and return).
    /// On rejection the callback is *not* invoked; the error comes
    /// back synchronously so the reactor can answer backpressure
    /// inline.
    pub fn submit_async(
        &self,
        job: Job,
        priority: Priority,
        lane: u64,
        on_done: impl FnOnce(Result<JobResult, ScheduleError>) + Send + 'static,
    ) -> Result<(), ScheduleError> {
        self.enqueue(job, priority, lane, None, Reply::Callback(Box::new(on_done)))
    }

    /// [`submit_async`](JobQueue::submit_async) with a start deadline:
    /// if no worker picks the job up by `deadline`, it resolves to
    /// [`ScheduleError::Expired`] (and counts in `jobs_expired`)
    /// instead of running arbitrarily late. The reactor uses this for
    /// sweep rows so one stuck sweep cannot silently hold a client's
    /// results forever — expired rows go through the bounded retry
    /// path instead.
    pub fn submit_async_with_deadline(
        &self,
        job: Job,
        priority: Priority,
        lane: u64,
        deadline: Option<Instant>,
        on_done: impl FnOnce(Result<JobResult, ScheduleError>) + Send + 'static,
    ) -> Result<(), ScheduleError> {
        self.enqueue(job, priority, lane, deadline, Reply::Callback(Box::new(on_done)))
    }

    /// Submit and block for the result (what a connection thread does).
    pub fn run(&self, job: Job) -> Result<JobResult, ScheduleError> {
        let rx = self.submit(job)?;
        rx.recv().unwrap_or(Err(ScheduleError::Shutdown))
    }

    /// Live queue depth (enqueued, not yet picked up).
    pub fn depth(&self) -> u64 {
        self.inner
            .scheduler
            .metrics
            .queue_depth
            .load(Ordering::Relaxed)
    }

    /// The backpressure bound.
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Stop accepting new jobs; workers drain what is already queued.
    pub fn shutdown(&self) {
        // The store must happen under the queue lock. A worker checks
        // the flag *between* its empty-check and its condvar wait,
        // holding this same lock; a bare store-then-notify could land
        // exactly in that window — the notify would precede the wait
        // and the worker would sleep forever on an empty queue (lost
        // wakeup; `Drop` would then hang on `join`). Storing *inside*
        // the critical section serializes against the check-then-wait
        // sequence and the mutex release publishes the flag to every
        // later lock holder, which is why Relaxed suffices.
        let q = lock_unpoisoned(&self.inner.queue);
        self.inner.shutdown.store(true, Ordering::Relaxed);
        drop(q);
        self.inner.available.notify_all();
    }
}

impl Drop for JobQueue {
    fn drop(&mut self) {
        self.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        let item = {
            let mut q = lock_unpoisoned(&inner.queue);
            loop {
                if let Some(item) = q.pop() {
                    // Decrement under the same lock as the pop so the
                    // gauge always equals the pending-set size — the
                    // bound `queue_depth ≤ capacity` is exact at every
                    // instant (the queue property tests sample it
                    // mid-burst).
                    inner
                        .scheduler
                        .metrics
                        .queue_depth
                        .fetch_sub(1, Ordering::Relaxed);
                    break Some(item);
                }
                if inner.shutdown.load(Ordering::Relaxed) {
                    break None;
                }
                q = wait_unpoisoned(&inner.available, q);
            }
        };
        let Some(item) = item else { return };
        let metrics = &inner.scheduler.metrics;
        let waited = item.enqueued.elapsed();
        metrics.record_queue_wait(waited.as_secs_f64());
        span::global().finish_with(
            item.wait_span,
            vec![
                ("workload", item.job.workload.name().to_string()),
                ("map", item.job.map.clone()),
            ],
        );
        // Deadline check happens at pop, not mid-run: a running job
        // cannot be cancelled, so "expired" means expired-in-queue.
        let expired = item.deadline.is_some_and(|d| Instant::now() > d);
        let result = if expired {
            metrics.jobs_expired.fetch_add(1, Ordering::Relaxed);
            Err(ScheduleError::Expired(waited.as_millis() as u64))
        } else {
            inner.scheduler.run(&item.job)
        };
        item.reply.deliver(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{Backend, WorkloadKind};

    fn job(nb: u64, seed: u64) -> Job {
        Job {
            workload: WorkloadKind::Edm,
            nb,
            map: "lambda2".into(),
            backend: Backend::Parallel,
            seed,
        }
    }

    fn queued(seed: u64) -> Queued {
        let (tx, _rx) = mpsc::channel();
        Queued {
            job: job(8, seed),
            enqueued: Instant::now(),
            deadline: None,
            wait_span: span::global().start("queue", "queue_wait", 0),
            reply: Reply::Channel(tx),
        }
    }

    #[test]
    fn lanes_round_robin_within_a_level() {
        // Lane 1 floods five jobs, lane 2 and 3 one each: the pops must
        // interleave lanes, not drain lane 1 first.
        let mut lanes = Lanes::default();
        for seed in 0..5 {
            lanes.push(Priority::Normal, 1, queued(seed));
        }
        lanes.push(Priority::Normal, 2, queued(10));
        lanes.push(Priority::Normal, 3, queued(11));
        let order: Vec<u64> = std::iter::from_fn(|| lanes.pop())
            .map(|q| q.job.seed)
            .collect();
        assert_eq!(order, vec![0, 10, 11, 1, 2, 3, 4]);
        assert_eq!(lanes.len(), 0);
    }

    #[test]
    fn lanes_strict_priority_across_levels() {
        let mut lanes = Lanes::default();
        lanes.push(Priority::Low, 1, queued(30));
        lanes.push(Priority::Normal, 1, queued(20));
        lanes.push(Priority::High, 2, queued(10));
        lanes.push(Priority::High, 1, queued(11));
        let order: Vec<u64> = std::iter::from_fn(|| lanes.pop())
            .map(|q| q.job.seed)
            .collect();
        // Both high jobs (round-robin over lanes 2 then 1 — cursor
        // starts at 0 so lane 1 is "next"), then normal, then low.
        assert_eq!(order, vec![11, 10, 20, 30]);
    }

    #[test]
    fn lanes_cursor_resumes_after_served_lane() {
        let mut lanes = Lanes::default();
        for lane in [5u64, 9, 14] {
            lanes.push(Priority::Normal, lane, queued(lane));
            lanes.push(Priority::Normal, lane, queued(lane + 100));
        }
        let order: Vec<u64> = std::iter::from_fn(|| lanes.pop())
            .map(|q| q.job.seed)
            .collect();
        assert_eq!(order, vec![5, 9, 14, 105, 109, 114]);
    }

    #[test]
    fn priority_parse_and_names_roundtrip() {
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.name()), Some(p));
        }
        assert_eq!(Priority::parse(""), Some(Priority::Normal));
        assert_eq!(Priority::parse("urgent"), None);
        assert!(Priority::High < Priority::Normal);
    }

    #[test]
    fn jobs_submitted_concurrently_all_complete() {
        let sched = Arc::new(Scheduler::new(2, None));
        let q = JobQueue::start(
            Arc::clone(&sched),
            QueueConfig {
                workers: 3,
                capacity: 32,
            },
        );
        let receivers: Vec<_> = (0..9).map(|i| q.submit(job(8, i)).unwrap()).collect();
        for rx in receivers {
            let r = rx.recv().unwrap().expect("job result");
            assert_eq!(r.outputs[0].0, "neighbour_count");
        }
        assert_eq!(
            sched
                .metrics
                .jobs_queued
                .load(std::sync::atomic::Ordering::Relaxed),
            9
        );
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn submit_async_delivers_via_callback() {
        let sched = Arc::new(Scheduler::new(2, None));
        let q = JobQueue::start(
            Arc::clone(&sched),
            QueueConfig {
                workers: 2,
                capacity: 16,
            },
        );
        let (tx, rx) = mpsc::channel();
        for i in 0..6u64 {
            let tx = tx.clone();
            q.submit_async(job(8, i), Priority::Normal, i % 2, move |r| {
                tx.send((i, r.map(|jr| jr.job.nb))).unwrap();
            })
            .unwrap();
        }
        let mut seen: Vec<u64> = (0..6)
            .map(|_| rx.recv().unwrap())
            .map(|(i, r)| {
                assert_eq!(r.expect("job ok"), 8);
                i
            })
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn full_queue_rejects_with_backpressure() {
        // No workers draining: saturate a capacity-2 queue.
        let sched = Arc::new(Scheduler::new(1, None));
        let q = JobQueue::start(
            Arc::clone(&sched),
            QueueConfig {
                workers: 1,
                capacity: 2,
            },
        );
        // Stop the worker first so the queue cannot drain mid-test:
        // enqueue a job, then shut down? No — shutdown rejects. Instead
        // rely on capacity bounding the *pending* set: submit many
        // fast and expect at least one rejection OR all completions.
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..64 {
            match q.submit(job(8, i)) {
                Ok(rx) => receivers.push(rx),
                Err(ScheduleError::QueueFull(cap)) => {
                    assert_eq!(cap, 2);
                    rejected += 1;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        for rx in receivers {
            rx.recv().unwrap().expect("accepted jobs complete");
        }
        assert!(
            rejected > 0,
            "64 instant submissions against capacity 2 must trip backpressure"
        );
        assert_eq!(
            sched
                .metrics
                .queue_rejected
                .load(std::sync::atomic::Ordering::Relaxed),
            rejected
        );
    }

    #[test]
    fn submit_async_rejection_is_synchronous_and_skips_callback() {
        let sched = Arc::new(Scheduler::new(1, None));
        let q = JobQueue::start(
            Arc::clone(&sched),
            QueueConfig {
                workers: 1,
                capacity: 2,
            },
        );
        let fired = Arc::new(AtomicBool::new(false));
        let mut rejections = 0;
        for i in 0..64u64 {
            let fired = Arc::clone(&fired);
            match q.submit_async(job(8, i), Priority::Low, 7, move |r| {
                if r.is_err() {
                    fired.store(true, Ordering::SeqCst);
                }
            }) {
                Ok(()) => {}
                Err(ScheduleError::QueueFull(_)) => rejections += 1,
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(rejections > 0);
        drop(q); // drain
        assert!(
            !fired.load(Ordering::SeqCst),
            "rejected submissions must never reach the callback with an error"
        );
    }

    #[test]
    fn shutdown_rejects_new_jobs_but_drains_queued_ones() {
        let sched = Arc::new(Scheduler::new(1, None));
        let q = JobQueue::start(
            Arc::clone(&sched),
            QueueConfig {
                workers: 1,
                capacity: 8,
            },
        );
        let rx = q.submit(job(8, 1)).unwrap();
        q.shutdown();
        assert!(matches!(q.submit(job(8, 2)), Err(ScheduleError::Shutdown)));
        // The already-enqueued job still resolves.
        let r = rx.recv().unwrap();
        assert!(r.is_ok(), "drained job must complete: {:?}", r.err().map(|e| e.to_string()));
    }

    #[test]
    fn expired_deadline_fails_at_pop_and_counts() {
        let sched = Arc::new(Scheduler::new(1, None));
        let q = JobQueue::start(
            Arc::clone(&sched),
            QueueConfig {
                workers: 2,
                capacity: 16,
            },
        );
        let (tx, rx) = mpsc::channel();
        // A deadline already in the past: the popping worker must
        // deliver Expired without running the job.
        let past = Instant::now() - std::time::Duration::from_millis(10);
        let tx2 = tx.clone();
        q.submit_async_with_deadline(job(8, 1), Priority::Normal, 0, Some(past), move |r| {
            tx2.send(r).unwrap();
        })
        .unwrap();
        let r = rx.recv().unwrap();
        assert!(matches!(r, Err(ScheduleError::Expired(_))), "{r:?}");
        assert_eq!(
            sched.metrics.jobs_expired.load(Ordering::Relaxed),
            1,
            "expiry must count"
        );
        // A generous deadline runs normally.
        let future = Instant::now() + std::time::Duration::from_secs(60);
        q.submit_async_with_deadline(job(8, 2), Priority::Normal, 0, Some(future), move |r| {
            tx.send(r).unwrap();
        })
        .unwrap();
        assert!(rx.recv().unwrap().is_ok());
        assert_eq!(sched.metrics.jobs_expired.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn queue_wait_metric_accumulates() {
        let sched = Arc::new(Scheduler::new(1, None));
        let q = JobQueue::start(Arc::clone(&sched), QueueConfig::default());
        q.run(job(8, 3)).unwrap();
        let snap = sched.metrics.snapshot();
        assert_eq!(
            snap.get("queue_wait").unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
        assert_eq!(snap.get("jobs_queued").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn lanes_interleaved_priorities_and_lanes_drain_exactly_once() {
        // A mixed burst: every pushed job must come back exactly once,
        // never reordered within its (priority, lane) FIFO.
        let mut lanes = Lanes::default();
        let mut pushed = Vec::new();
        for (i, (p, lane)) in [
            (Priority::Low, 3u64),
            (Priority::Normal, 1),
            (Priority::High, 1),
            (Priority::Normal, 1),
            (Priority::Normal, 2),
            (Priority::High, 9),
            (Priority::Low, 3),
        ]
        .into_iter()
        .enumerate()
        {
            lanes.push(p, lane, queued(i as u64));
            pushed.push((p, lane, i as u64));
        }
        let mut popped = Vec::new();
        while let Some(q) = lanes.pop() {
            popped.push(q.job.seed);
        }
        assert_eq!(popped.len(), pushed.len(), "no loss, no duplication");
        let mut sorted = popped.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4, 5, 6]);
        // Per-(priority, lane) FIFO order is preserved: lane 1 normal
        // saw seeds 1 then 3; lane 3 low saw 0 then 6.
        let pos = |s: u64| popped.iter().position(|&x| x == s).unwrap();
        assert!(pos(1) < pos(3));
        assert!(pos(0) < pos(6));
        // Strict priority: both highs (2, 5) precede every normal and low.
        for high in [2u64, 5] {
            for other in [0u64, 1, 3, 4, 6] {
                assert!(pos(high) < pos(other));
            }
        }
    }
}
