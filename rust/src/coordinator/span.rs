//! Lightweight execution spans with Chrome trace-event export.
//!
//! A [`Span`] is one timed region of the job lifecycle — accept,
//! queue wait, map/fused sweep, per-lane launch work — identified by
//! `(id, parent)` so the regions nest into a tree, stamped with
//! monotonic nanoseconds from a process-wide clock ([`now_ns`]), and
//! tagged with `key=value` attributes. Finished spans land in a
//! bounded ring buffer (oldest evicted first) owned by a
//! [`SpanRecorder`]; the process-wide recorder ([`global`]) is what
//! the scheduler, queue and server instrument.
//!
//! Recording is **off by default**: a disabled recorder's
//! [`SpanRecorder::start`] is a single relaxed atomic load returning a
//! dead [`ActiveSpan`] (id 0) whose finish is a no-op — the
//! instrumentation stays negligible on the hot path (verified by
//! `benches/observability_overhead.rs`). Enable via
//! `SIMPLEXMAP_SPANS=1`, [`SpanRecorder::set_enabled`], or the server
//! `{"cmd":"trace","enable":true}` command.
//!
//! Export ([`chrome_trace`]) is the Chrome trace-event JSON format
//! (load in `chrome://tracing` or Perfetto): one complete event
//! (`"ph":"X"`) per span with `ts`/`dur` in microseconds, `name` from
//! the span name, `cat` from the target, and the span id, parent and
//! attributes under `args`. All strings pass through the
//! [`crate::util::json`] writer, so attribute values containing `"`
//! or `\` stay parseable.
//!
//! Memory-ordering policy: the recording toggle and span-id counter
//! are independent cells — the id only needs uniqueness (`fetch_add`
//! is atomic at any ordering) and the toggle tolerates a stale read
//! by design (spans started just before a toggle flip may record) —
//! so all accesses are Relaxed.
// lint: atomics(Relaxed)

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Monotonic nanoseconds since the first call in this process (shared
/// with nothing else — span timestamps are only comparable to each
/// other, which is all a trace viewer needs).
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// A finished span.
#[derive(Clone, Debug)]
pub struct Span {
    /// Unique per recorder, starting at 1 (0 means "no span").
    pub id: u64,
    /// Parent span id, 0 for roots.
    pub parent: u64,
    /// Subsystem that produced the span (`scheduler`, `queue`, ...).
    pub target: &'static str,
    /// Region name (`job`, `queue_wait`, `fused_sweep`, `lane-3`, ...).
    pub name: String,
    pub start_ns: u64,
    pub end_ns: u64,
    pub attrs: Vec<(&'static str, String)>,
}

/// Handle for an in-flight span. Dead handles (id 0, from a disabled
/// recorder) finish as no-ops. Dropping an unfinished handle simply
/// loses the span — there is no `Drop` bookkeeping on the hot path.
#[derive(Debug)]
pub struct ActiveSpan {
    id: u64,
    parent: u64,
    target: &'static str,
    name: &'static str,
    start_ns: u64,
}

impl ActiveSpan {
    /// The span id to hand to children as `parent` (0 when disabled —
    /// children then record as roots, which degrades gracefully).
    pub fn id(&self) -> u64 {
        self.id
    }
}

/// Bounded ring buffer of finished spans plus the id allocator.
pub struct SpanRecorder {
    enabled: AtomicBool,
    next_id: AtomicU64,
    capacity: usize,
    ring: Mutex<VecDeque<Span>>,
}

impl SpanRecorder {
    pub fn new(capacity: usize) -> SpanRecorder {
        SpanRecorder {
            enabled: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            capacity: capacity.max(1),
            ring: Mutex::new(VecDeque::new()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Begin a span. When disabled this is one atomic load and returns
    /// a dead handle — no clock read, no allocation.
    pub fn start(&self, target: &'static str, name: &'static str, parent: u64) -> ActiveSpan {
        if !self.enabled() {
            return ActiveSpan {
                id: 0,
                parent: 0,
                target,
                name,
                start_ns: 0,
            };
        }
        ActiveSpan {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            parent,
            target,
            name,
            start_ns: now_ns(),
        }
    }

    pub fn finish(&self, span: ActiveSpan) {
        self.finish_with(span, Vec::new());
    }

    /// End a span, attaching attributes. Dead handles are dropped
    /// without touching the ring.
    pub fn finish_with(&self, span: ActiveSpan, attrs: Vec<(&'static str, String)>) {
        if span.id == 0 {
            return;
        }
        self.push(Span {
            id: span.id,
            parent: span.parent,
            target: span.target,
            name: span.name.to_string(),
            start_ns: span.start_ns,
            end_ns: now_ns(),
            attrs,
        });
    }

    /// Record a span whose interval was measured externally (per-lane
    /// busy time comes back through the launcher's join handles, after
    /// the fact). No-op when disabled.
    pub fn record_interval(
        &self,
        target: &'static str,
        name: String,
        parent: u64,
        start_ns: u64,
        end_ns: u64,
        attrs: Vec<(&'static str, String)>,
    ) {
        if !self.enabled() {
            return;
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.push(Span {
            id,
            parent,
            target,
            name,
            start_ns,
            end_ns: end_ns.max(start_ns),
            attrs,
        });
    }

    fn push(&self, span: Span) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.capacity {
            ring.pop_front();
        }
        ring.push_back(span);
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        self.ring.lock().unwrap().clear();
    }

    /// The most recent `n` finished spans, oldest first.
    pub fn snapshot_last(&self, n: usize) -> Vec<Span> {
        let ring = self.ring.lock().unwrap();
        let skip = ring.len().saturating_sub(n);
        ring.iter().skip(skip).cloned().collect()
    }
}

/// The process-wide recorder. Capacity comes from
/// `SIMPLEXMAP_SPAN_CAPACITY` (default 8192 spans ≈ a few MB at the
/// attr sizes the scheduler emits); recording starts enabled only if
/// `SIMPLEXMAP_SPANS` is `1`/`true`.
pub fn global() -> &'static SpanRecorder {
    static GLOBAL: OnceLock<SpanRecorder> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let capacity = std::env::var("SIMPLEXMAP_SPAN_CAPACITY")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(8192);
        let rec = SpanRecorder::new(capacity);
        let on = std::env::var("SIMPLEXMAP_SPANS")
            .map(|s| s == "1" || s.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
        rec.set_enabled(on);
        rec
    })
}

/// Render spans as a Chrome trace-event document:
/// `{"traceEvents":[{"ph":"X","name","cat","ts","dur","pid","tid","args"}]}`
/// with `ts`/`dur` in microseconds (the viewer's unit). Span id,
/// parent and attributes ride in `args`.
pub fn chrome_trace(spans: &[Span]) -> Json {
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            let mut args = vec![
                ("span_id", Json::from(s.id)),
                ("parent", Json::from(s.parent)),
            ];
            for (k, v) in &s.attrs {
                args.push((*k, Json::from(v.as_str())));
            }
            Json::obj(vec![
                ("ph", "X".into()),
                ("name", s.name.as_str().into()),
                ("cat", s.target.into()),
                ("ts", (s.start_ns as f64 / 1e3).into()),
                ("dur", ((s.end_ns - s.start_ns) as f64 / 1e3).into()),
                ("pid", 1u64.into()),
                ("tid", 1u64.into()),
                ("args", Json::obj(args)),
            ])
        })
        .collect();
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn disabled_recorder_records_nothing_and_hands_out_dead_ids() {
        let rec = SpanRecorder::new(16);
        assert!(!rec.enabled());
        let s = rec.start("t", "noop", 0);
        assert_eq!(s.id(), 0);
        rec.finish(s);
        rec.record_interval("t", "lane-0".to_string(), 0, 10, 20, Vec::new());
        assert!(rec.is_empty());
    }

    #[test]
    fn spans_nest_and_carry_attrs() {
        let rec = SpanRecorder::new(16);
        rec.set_enabled(true);
        let root = rec.start("scheduler", "job", 0);
        let root_id = root.id();
        assert!(root_id > 0);
        let child = rec.start("engine", "fused_sweep", root_id);
        rec.finish_with(child, vec![("blocks", "42".to_string())]);
        rec.finish_with(root, vec![("workload", "edm".to_string())]);
        let spans = rec.snapshot_last(16);
        assert_eq!(spans.len(), 2);
        // Ring order is finish order: the child landed first.
        assert_eq!(spans[0].name, "fused_sweep");
        assert_eq!(spans[0].parent, root_id);
        assert_eq!(spans[1].name, "job");
        assert_eq!(spans[1].parent, 0);
        assert!(spans[1].end_ns >= spans[1].start_ns);
        assert!(spans[0].attrs.iter().any(|(k, v)| *k == "blocks" && v == "42"));
    }

    #[test]
    fn ring_is_bounded_and_keeps_the_newest() {
        let rec = SpanRecorder::new(4);
        rec.set_enabled(true);
        for i in 0..10u64 {
            rec.record_interval("t", format!("s{i}"), 0, i, i + 1, Vec::new());
        }
        assert_eq!(rec.len(), 4);
        let names: Vec<String> = rec.snapshot_last(99).into_iter().map(|s| s.name).collect();
        assert_eq!(names, ["s6", "s7", "s8", "s9"]);
        let last_two: Vec<String> = rec.snapshot_last(2).into_iter().map(|s| s.name).collect();
        assert_eq!(last_two, ["s8", "s9"]);
        rec.clear();
        assert!(rec.is_empty());
    }

    #[test]
    fn record_interval_clamps_reversed_intervals() {
        let rec = SpanRecorder::new(4);
        rec.set_enabled(true);
        rec.record_interval("t", "rev".to_string(), 0, 100, 50, Vec::new());
        let spans = rec.snapshot_last(1);
        assert_eq!(spans[0].start_ns, 100);
        assert_eq!(spans[0].end_ns, 100);
    }

    #[test]
    fn chrome_trace_roundtrips_with_hostile_attr_values() {
        let rec = SpanRecorder::new(8);
        rec.set_enabled(true);
        let s = rec.start("scheduler", "job", 0);
        rec.finish_with(s, vec![("map", r#"lam"bda\2"#.to_string())]);
        let doc = chrome_trace(&rec.snapshot_last(8));
        let text = doc.to_string_compact();
        let back = parse(&text).expect("chrome trace must be valid JSON");
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        let e = &events[0];
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(e.get("name").and_then(Json::as_str), Some("job"));
        assert_eq!(e.get("cat").and_then(Json::as_str), Some("scheduler"));
        assert!(e.get("ts").and_then(Json::as_f64).is_some());
        assert!(e.get("dur").and_then(Json::as_f64).unwrap() >= 0.0);
        let args = e.get("args").unwrap();
        assert_eq!(args.get("map").and_then(Json::as_str), Some(r#"lam"bda\2"#));
        assert!(args.get("span_id").and_then(Json::as_u64).unwrap() > 0);
    }

    #[test]
    fn now_ns_is_monotone() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }
}
