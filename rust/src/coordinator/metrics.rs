//! Process-wide coordinator metrics: job counters, per-phase latency
//! accumulators, tile/batch counters, job-queue gauges, and the
//! scheduler's map-layout-cache hit rate. Snapshots serialize to JSON
//! for the server's `metrics` command.
//!
//! Phases: streaming jobs run map+execute fused (one `fused_phase`
//! sample per job); collect-mode and PJRT jobs keep the split
//! `map_phase`/`exec_phase` timings. Queue metrics: `queue_depth` is a
//! live gauge, `queue_wait` the enqueue→dequeue latency.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats::Welford;

#[derive(Default)]
pub struct Metrics {
    pub jobs_accepted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub blocks_mapped: AtomicU64,
    pub tile_batches: AtomicU64,
    pub tiles_padded: AtomicU64,
    /// Jobs that entered the bounded job queue.
    pub jobs_queued: AtomicU64,
    /// Jobs rejected because the queue was full (backpressure).
    pub queue_rejected: AtomicU64,
    /// Live queue depth (enqueued, not yet picked up by a worker).
    pub queue_depth: AtomicU64,
    pub map_cache_hits: AtomicU64,
    pub map_cache_misses: AtomicU64,
    map_phase: Mutex<Welford>,
    exec_phase: Mutex<Welford>,
    fused_phase: Mutex<Welford>,
    queue_wait: Mutex<Welford>,
    job_wall: Mutex<Welford>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_map_phase(&self, secs: f64) {
        self.map_phase.lock().unwrap().push(secs);
    }

    pub fn record_exec_phase(&self, secs: f64) {
        self.exec_phase.lock().unwrap().push(secs);
    }

    /// One fused map+execute sweep (the streaming engine's hot path).
    pub fn record_fused_phase(&self, secs: f64) {
        self.fused_phase.lock().unwrap().push(secs);
    }

    /// Time a job spent waiting in the bounded queue.
    pub fn record_queue_wait(&self, secs: f64) {
        self.queue_wait.lock().unwrap().push(secs);
    }

    pub fn record_job(&self, secs: f64) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.job_wall.lock().unwrap().push(secs);
    }

    pub fn snapshot(&self) -> Json {
        let phase = |w: &Mutex<Welford>| {
            let w = w.lock().unwrap();
            Json::obj(vec![
                ("count", w.count().into()),
                ("mean_secs", w.mean().into()),
                ("stddev_secs", w.stddev().into()),
                ("max_secs", if w.count() > 0 { w.max() } else { 0.0 }.into()),
            ])
        };
        let counter = |a: &AtomicU64| Json::from(a.load(Ordering::Relaxed));
        Json::obj(vec![
            ("jobs_accepted", counter(&self.jobs_accepted)),
            ("jobs_completed", counter(&self.jobs_completed)),
            ("jobs_failed", counter(&self.jobs_failed)),
            ("blocks_mapped", counter(&self.blocks_mapped)),
            ("tile_batches", counter(&self.tile_batches)),
            ("tiles_padded", counter(&self.tiles_padded)),
            ("jobs_queued", counter(&self.jobs_queued)),
            ("queue_rejected", counter(&self.queue_rejected)),
            ("queue_depth", counter(&self.queue_depth)),
            ("map_cache_hits", counter(&self.map_cache_hits)),
            ("map_cache_misses", counter(&self.map_cache_misses)),
            ("map_phase", phase(&self.map_phase)),
            ("exec_phase", phase(&self.exec_phase)),
            ("fused_phase", phase(&self.fused_phase)),
            ("queue_wait", phase(&self.queue_wait)),
            ("job_wall", phase(&self.job_wall)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::new();
        m.jobs_accepted.fetch_add(3, Ordering::Relaxed);
        m.record_job(0.5);
        m.record_job(1.5);
        m.record_map_phase(0.1);
        m.record_fused_phase(0.2);
        m.record_queue_wait(0.01);
        let s = m.snapshot();
        assert_eq!(s.get("jobs_accepted").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("jobs_completed").unwrap().as_u64(), Some(2));
        let wall = s.get("job_wall").unwrap();
        assert_eq!(wall.get("count").unwrap().as_u64(), Some(2));
        assert!((wall.get("mean_secs").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-12);
        assert_eq!(
            s.get("fused_phase").unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
        assert_eq!(
            s.get("queue_wait").unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
        assert_eq!(s.get("queue_depth").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn empty_metrics_snapshot_is_valid_json() {
        let s = Metrics::new().snapshot();
        let text = s.to_string_compact();
        assert!(crate::util::json::parse(&text).is_ok());
    }
}
