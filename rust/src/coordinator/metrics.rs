//! Process-wide coordinator metrics: job counters, per-phase latency
//! accumulators, tile/batch counters, job-queue gauges, and the
//! scheduler's map-layout-cache hit rate. Snapshots serialize to JSON
//! for the server's `metrics` command; [`Metrics::prometheus`] renders
//! the same state as Prometheus text exposition.
//!
//! Phases: streaming jobs run map+execute fused (one `fused_phase`
//! sample per job); collect-mode and PJRT jobs keep the split
//! `map_phase`/`exec_phase` timings. Queue metrics: `queue_depth` is a
//! live gauge, `queue_wait` the enqueue→dequeue latency.
//!
//! Every phase is backed by two accumulators: a Welford mean/stddev
//! (exact moments) and a lock-free log-bucketed
//! [`Histogram`](crate::util::histogram::Histogram) for
//! p50/p90/p99/p99.9 (≤ 6.25% relative quantile error). Labeled
//! series key job wall time by `(workload, map, backend)` so
//! per-scenario latency stays queryable after the fact.
//!
//! Memory-ordering policy: every atomic is a monotonic counter or a
//! last-write-wins gauge; readers only ever see a slightly stale
//! snapshot, which is the contract of a metrics endpoint — Relaxed.
// lint: atomics(Relaxed)

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::histogram::Histogram;
use crate::util::json::{escape, Json};
use crate::util::stats::Welford;

/// One phase's latency accumulators: Welford for exact mean/stddev
/// plus a histogram for quantiles.
struct PhaseMetric {
    welford: Mutex<Welford>,
    hist: Histogram,
}

impl Default for PhaseMetric {
    fn default() -> Self {
        PhaseMetric {
            welford: Mutex::new(Welford::new()),
            hist: Histogram::new(),
        }
    }
}

impl PhaseMetric {
    fn record(&self, secs: f64) {
        self.welford.lock().unwrap().push(secs);
        self.hist.record_secs(secs);
    }

    fn to_json(&self) -> Json {
        let w = self.welford.lock().unwrap();
        let qs = self.hist.summary_quantiles_secs();
        let q = |i: usize| qs.map(|a| Json::from(a[i])).unwrap_or(Json::Null);
        Json::obj(vec![
            ("count", w.count().into()),
            ("mean_secs", w.mean().into()),
            ("stddev_secs", w.stddev().into()),
            ("max_secs", if w.count() > 0 { w.max() } else { 0.0 }.into()),
            ("p50_secs", q(0)),
            ("p90_secs", q(1)),
            ("p99_secs", q(2)),
            ("p999_secs", q(3)),
        ])
    }
}

type SeriesKey = (String, String, String);

#[derive(Default)]
pub struct Metrics {
    pub jobs_accepted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub blocks_mapped: AtomicU64,
    pub tile_batches: AtomicU64,
    pub tiles_padded: AtomicU64,
    /// Jobs that entered the bounded job queue.
    pub jobs_queued: AtomicU64,
    /// Jobs rejected because the queue was full (backpressure).
    pub queue_rejected: AtomicU64,
    /// Live queue depth (enqueued, not yet picked up by a worker).
    pub queue_depth: AtomicU64,
    pub map_cache_hits: AtomicU64,
    pub map_cache_misses: AtomicU64,
    /// TCP connections the serving tier accepted / closed (both
    /// server modes).
    pub conns_accepted: AtomicU64,
    pub conns_closed: AtomicU64,
    /// Connections dropped because their write backlog crossed the
    /// reactor's hard cap (slow-client protection).
    pub slow_client_drops: AtomicU64,
    /// Frames rejected by the capped reader before parsing.
    pub frames_oversized: AtomicU64,
    /// Sweep fan-outs started / fully resolved, and individual sweep
    /// jobs that completed (ok or failed).
    pub sweeps_started: AtomicU64,
    pub sweeps_completed: AtomicU64,
    pub sweep_jobs_completed: AtomicU64,
    /// Completed-job accounting across the serving tier. Every `Ok`
    /// job result is exactly one of: delivered to a live connection,
    /// stored in the results store, or orphaned (store refused it) —
    /// `jobs_completed == results_delivered + results_stored +
    /// orphaned_results` is test-asserted end to end.
    pub results_delivered: AtomicU64,
    pub results_stored: AtomicU64,
    pub orphaned_results: AtomicU64,
    /// Results-store entries evicted (TTL age-out or LRU admission).
    pub store_evictions: AtomicU64,
    /// Jobs re-enqueued after a retryable failure (bounded per job).
    pub jobs_retried: AtomicU64,
    /// Jobs that outlived their deadline waiting in the queue.
    pub jobs_expired: AtomicU64,
    /// Results-store occupancy gauges (rows held / sweeps addressable).
    pub store_rows: AtomicU64,
    pub store_sweeps: AtomicU64,
    map_phase: PhaseMetric,
    exec_phase: PhaseMetric,
    fused_phase: PhaseMetric,
    queue_wait: PhaseMetric,
    job_wall: PhaseMetric,
    /// First-job-submitted → last-job-resolved wall time per sweep.
    sweep_wall: PhaseMetric,
    /// max/mean lane-busy ratio per profiled launch (dimensionless).
    lane_imbalance: Mutex<Welford>,
    /// Job wall-time histograms keyed by `(workload, map, backend)`.
    /// The map is touched once per job (get-or-insert an `Arc`); the
    /// recording itself is lock-free on the shared histogram.
    series: Mutex<BTreeMap<SeriesKey, Arc<Histogram>>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_map_phase(&self, secs: f64) {
        self.map_phase.record(secs);
    }

    pub fn record_exec_phase(&self, secs: f64) {
        self.exec_phase.record(secs);
    }

    /// One fused map+execute sweep (the streaming engine's hot path).
    pub fn record_fused_phase(&self, secs: f64) {
        self.fused_phase.record(secs);
    }

    /// Time a job spent waiting in the bounded queue.
    pub fn record_queue_wait(&self, secs: f64) {
        self.queue_wait.record(secs);
    }

    pub fn record_job(&self, secs: f64) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.job_wall.record(secs);
    }

    /// Wall time of one whole sweep fan-out (submit → last result).
    pub fn record_sweep_wall(&self, secs: f64) {
        self.sweep_wall.record(secs);
    }

    /// Lane-imbalance ratio of a profiled launch (≥ 1.0).
    pub fn record_lane_imbalance(&self, ratio: f64) {
        self.lane_imbalance.lock().unwrap().push(ratio);
    }

    /// Record one job's wall time under its `(workload, map, backend)`
    /// series.
    pub fn record_series(&self, workload: &str, map: &str, backend: &str, secs: f64) {
        let hist = {
            let mut series = self.series.lock().unwrap();
            let key = (workload.to_string(), map.to_string(), backend.to_string());
            Arc::clone(series.entry(key).or_default())
        };
        hist.record_secs(secs);
    }

    pub fn snapshot(&self) -> Json {
        let counter = |a: &AtomicU64| Json::from(a.load(Ordering::Relaxed));
        let imbalance = {
            let w = self.lane_imbalance.lock().unwrap();
            Json::obj(vec![
                ("count", w.count().into()),
                ("mean", w.mean().into()),
                ("max", if w.count() > 0 { w.max() } else { 0.0 }.into()),
            ])
        };
        let series = {
            let series = self.series.lock().unwrap();
            let mut obj = BTreeMap::new();
            for ((w, m, b), h) in series.iter() {
                obj.insert(format!("{w}/{m}/{b}"), h.to_json());
            }
            Json::Obj(obj)
        };
        Json::obj(vec![
            ("jobs_accepted", counter(&self.jobs_accepted)),
            ("jobs_completed", counter(&self.jobs_completed)),
            ("jobs_failed", counter(&self.jobs_failed)),
            ("blocks_mapped", counter(&self.blocks_mapped)),
            ("tile_batches", counter(&self.tile_batches)),
            ("tiles_padded", counter(&self.tiles_padded)),
            ("jobs_queued", counter(&self.jobs_queued)),
            ("queue_rejected", counter(&self.queue_rejected)),
            ("queue_depth", counter(&self.queue_depth)),
            ("map_cache_hits", counter(&self.map_cache_hits)),
            ("map_cache_misses", counter(&self.map_cache_misses)),
            ("conns_accepted", counter(&self.conns_accepted)),
            ("conns_closed", counter(&self.conns_closed)),
            ("slow_client_drops", counter(&self.slow_client_drops)),
            ("frames_oversized", counter(&self.frames_oversized)),
            ("sweeps_started", counter(&self.sweeps_started)),
            ("sweeps_completed", counter(&self.sweeps_completed)),
            ("sweep_jobs_completed", counter(&self.sweep_jobs_completed)),
            ("results_delivered", counter(&self.results_delivered)),
            ("results_stored", counter(&self.results_stored)),
            ("orphaned_results", counter(&self.orphaned_results)),
            ("store_evictions", counter(&self.store_evictions)),
            ("jobs_retried", counter(&self.jobs_retried)),
            ("jobs_expired", counter(&self.jobs_expired)),
            ("store_rows", counter(&self.store_rows)),
            ("store_sweeps", counter(&self.store_sweeps)),
            ("map_phase", self.map_phase.to_json()),
            ("exec_phase", self.exec_phase.to_json()),
            ("fused_phase", self.fused_phase.to_json()),
            ("queue_wait", self.queue_wait.to_json()),
            ("job_wall", self.job_wall.to_json()),
            ("sweep_wall", self.sweep_wall.to_json()),
            ("lane_imbalance", imbalance),
            ("series", series),
        ])
    }

    /// Prometheus text exposition (format 0.0.4). Counters end in
    /// `_total`, gauges keep their name, phase latencies render as
    /// summaries in seconds with `quantile` labels, and the labeled
    /// series add `workload`/`map`/`backend` labels to
    /// `simplexmap_job_seconds`. Label values are escaped through
    /// [`crate::util::json::escape`] — the Prometheus label escapes
    /// (`\\`, `\"`, `\n`) are a subset of JSON's string escapes, so
    /// the shared routine covers them.
    pub fn prometheus(&self) -> String {
        fn scalar(out: &mut String, name: &str, kind: &str, v: u64) {
            out.push_str(&format!("# TYPE simplexmap_{name} {kind}\n"));
            out.push_str(&format!("simplexmap_{name} {v}\n"));
        }
        fn summary_body(out: &mut String, name: &str, labels: &str, hist: &Histogram) {
            if let Some(qs) = hist.summary_quantiles_secs() {
                let pairs = [("0.5", qs[0]), ("0.9", qs[1]), ("0.99", qs[2]), ("0.999", qs[3])];
                for (q, v) in pairs {
                    if labels.is_empty() {
                        out.push_str(&format!("simplexmap_{name}{{quantile=\"{q}\"}} {v}\n"));
                    } else {
                        out.push_str(&format!(
                            "simplexmap_{name}{{{labels},quantile=\"{q}\"}} {v}\n"
                        ));
                    }
                }
            }
            let suffix = if labels.is_empty() {
                String::new()
            } else {
                format!("{{{labels}}}")
            };
            out.push_str(&format!(
                "simplexmap_{name}_sum{suffix} {}\n",
                hist.sum_secs()
            ));
            out.push_str(&format!("simplexmap_{name}_count{suffix} {}\n", hist.count()));
        }

        let mut out = String::new();
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed);
        scalar(&mut out, "jobs_accepted_total", "counter", load(&self.jobs_accepted));
        scalar(&mut out, "jobs_completed_total", "counter", load(&self.jobs_completed));
        scalar(&mut out, "jobs_failed_total", "counter", load(&self.jobs_failed));
        scalar(&mut out, "blocks_mapped_total", "counter", load(&self.blocks_mapped));
        scalar(&mut out, "tile_batches_total", "counter", load(&self.tile_batches));
        scalar(&mut out, "tiles_padded_total", "counter", load(&self.tiles_padded));
        scalar(&mut out, "jobs_queued_total", "counter", load(&self.jobs_queued));
        scalar(&mut out, "queue_rejected_total", "counter", load(&self.queue_rejected));
        scalar(&mut out, "queue_depth", "gauge", load(&self.queue_depth));
        scalar(&mut out, "map_cache_hits_total", "counter", load(&self.map_cache_hits));
        scalar(&mut out, "map_cache_misses_total", "counter", load(&self.map_cache_misses));
        scalar(&mut out, "conns_accepted_total", "counter", load(&self.conns_accepted));
        scalar(&mut out, "conns_closed_total", "counter", load(&self.conns_closed));
        scalar(&mut out, "slow_client_drops_total", "counter", load(&self.slow_client_drops));
        scalar(&mut out, "frames_oversized_total", "counter", load(&self.frames_oversized));
        scalar(&mut out, "sweeps_started_total", "counter", load(&self.sweeps_started));
        scalar(&mut out, "sweeps_completed_total", "counter", load(&self.sweeps_completed));
        scalar(
            &mut out,
            "sweep_jobs_completed_total",
            "counter",
            load(&self.sweep_jobs_completed),
        );
        scalar(&mut out, "results_delivered_total", "counter", load(&self.results_delivered));
        scalar(&mut out, "results_stored_total", "counter", load(&self.results_stored));
        scalar(&mut out, "orphaned_results_total", "counter", load(&self.orphaned_results));
        scalar(&mut out, "store_evictions_total", "counter", load(&self.store_evictions));
        scalar(&mut out, "jobs_retried_total", "counter", load(&self.jobs_retried));
        scalar(&mut out, "jobs_expired_total", "counter", load(&self.jobs_expired));
        scalar(&mut out, "store_rows", "gauge", load(&self.store_rows));
        scalar(&mut out, "store_sweeps", "gauge", load(&self.store_sweeps));

        for (name, phase) in [
            ("map_phase_seconds", &self.map_phase),
            ("exec_phase_seconds", &self.exec_phase),
            ("fused_phase_seconds", &self.fused_phase),
            ("queue_wait_seconds", &self.queue_wait),
            ("job_wall_seconds", &self.job_wall),
            ("sweep_wall_seconds", &self.sweep_wall),
        ] {
            out.push_str(&format!("# TYPE simplexmap_{name} summary\n"));
            summary_body(&mut out, name, "", &phase.hist);
        }

        {
            let w = self.lane_imbalance.lock().unwrap();
            scalar(&mut out, "lane_imbalance_samples_total", "counter", w.count());
            if w.count() > 0 {
                out.push_str("# TYPE simplexmap_lane_imbalance gauge\n");
                out.push_str(&format!(
                    "simplexmap_lane_imbalance{{stat=\"mean\"}} {}\n",
                    w.mean()
                ));
                out.push_str(&format!(
                    "simplexmap_lane_imbalance{{stat=\"max\"}} {}\n",
                    w.max()
                ));
            }
        }

        let series = self.series.lock().unwrap();
        if !series.is_empty() {
            out.push_str("# TYPE simplexmap_job_seconds summary\n");
            for ((w, m, b), h) in series.iter() {
                let labels = format!(
                    "workload=\"{}\",map=\"{}\",backend=\"{}\"",
                    escape(w),
                    escape(m),
                    escape(b)
                );
                summary_body(&mut out, "job_seconds", &labels, h);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::new();
        m.jobs_accepted.fetch_add(3, Ordering::Relaxed);
        m.record_job(0.5);
        m.record_job(1.5);
        m.record_map_phase(0.1);
        m.record_fused_phase(0.2);
        m.record_queue_wait(0.01);
        let s = m.snapshot();
        assert_eq!(s.get("jobs_accepted").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("jobs_completed").unwrap().as_u64(), Some(2));
        let wall = s.get("job_wall").unwrap();
        assert_eq!(wall.get("count").unwrap().as_u64(), Some(2));
        assert!((wall.get("mean_secs").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-12);
        assert_eq!(
            s.get("fused_phase").unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
        assert_eq!(
            s.get("queue_wait").unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
        assert_eq!(s.get("queue_depth").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn empty_metrics_snapshot_is_valid_json() {
        let s = Metrics::new().snapshot();
        let text = s.to_string_compact();
        assert!(crate::util::json::parse(&text).is_ok());
        // Empty phases expose null quantiles, honestly.
        assert_eq!(s.get("job_wall").unwrap().get("p50_secs"), Some(&Json::Null));
        assert_eq!(s.get("series").unwrap(), &Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn phase_quantiles_are_present_and_monotone() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_job(i as f64 * 1e-3);
        }
        let wall = m.snapshot();
        let wall = wall.get("job_wall").unwrap();
        let p = |k: &str| wall.get(k).unwrap().as_f64().unwrap();
        assert_eq!(wall.get("count").unwrap().as_u64(), Some(100));
        assert!(p("p50_secs") <= p("p90_secs"));
        assert!(p("p90_secs") <= p("p99_secs"));
        assert!(p("p99_secs") <= p("p999_secs"));
        // p50 of 1..100 ms is ~50 ms, within the 6.25% bucket error.
        assert!((p("p50_secs") - 0.0505).abs() / 0.0505 < 0.07);
    }

    #[test]
    fn labeled_series_key_by_scenario() {
        let m = Metrics::new();
        m.record_series("edm", "lambda2", "parallel", 0.010);
        m.record_series("edm", "lambda2", "parallel", 0.020);
        m.record_series("collision", "bb", "serial", 0.005);
        let s = m.snapshot();
        let series = s.get("series").unwrap();
        let edm = series.get("edm/lambda2/parallel").unwrap();
        assert_eq!(edm.get("count").unwrap().as_u64(), Some(2));
        let col = series.get("collision/bb/serial").unwrap();
        assert_eq!(col.get("count").unwrap().as_u64(), Some(1));
        assert!(col.get("p50_secs").unwrap().as_f64().is_some());
    }

    #[test]
    fn hostile_map_names_survive_snapshot_and_prometheus() {
        // Satellite regression: a map name containing `"` and `\` must
        // escape cleanly in both expositions.
        let hostile = r#"lam"bda\2"#;
        let m = Metrics::new();
        m.record_series("edm", hostile, "parallel", 0.003);
        let text = m.snapshot().to_string_compact();
        let back = crate::util::json::parse(&text).expect("snapshot must stay valid JSON");
        let series = back.get("series").unwrap();
        let key = format!("edm/{hostile}/parallel");
        assert_eq!(
            series.get(&key).unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
        let prom = m.prometheus();
        assert!(
            prom.contains(r#"map="lam\"bda\\2""#),
            "escaped label missing in:\n{prom}"
        );
    }

    #[test]
    fn prometheus_exposition_has_counters_and_summaries() {
        let m = Metrics::new();
        m.jobs_accepted.fetch_add(2, Ordering::Relaxed);
        m.record_job(0.25);
        m.record_queue_wait(0.001);
        m.record_lane_imbalance(1.5);
        m.record_series("edm", "lambda2", "parallel", 0.25);
        let prom = m.prometheus();
        assert!(prom.contains("# TYPE simplexmap_jobs_accepted_total counter"));
        assert!(prom.contains("simplexmap_jobs_accepted_total 2"));
        assert!(prom.contains("# TYPE simplexmap_queue_depth gauge"));
        assert!(prom.contains("# TYPE simplexmap_job_wall_seconds summary"));
        assert!(prom.contains("simplexmap_job_wall_seconds{quantile=\"0.5\"}"));
        assert!(prom.contains("simplexmap_job_wall_seconds_count 1"));
        assert!(prom.contains("simplexmap_lane_imbalance{stat=\"mean\"} 1.5"));
        let labeled = concat!(
            "simplexmap_job_seconds",
            "{workload=\"edm\",map=\"lambda2\",backend=\"parallel\",quantile=\"0.5\"}"
        );
        assert!(prom.contains(labeled), "missing labeled series in:\n{prom}");
        assert!(prom.ends_with('\n'));
    }

    #[test]
    fn serving_counters_and_sweep_wall_export() {
        let m = Metrics::new();
        m.conns_accepted.fetch_add(5, Ordering::Relaxed);
        m.conns_closed.fetch_add(4, Ordering::Relaxed);
        m.slow_client_drops.fetch_add(1, Ordering::Relaxed);
        m.frames_oversized.fetch_add(2, Ordering::Relaxed);
        m.sweeps_started.fetch_add(3, Ordering::Relaxed);
        m.sweeps_completed.fetch_add(3, Ordering::Relaxed);
        m.sweep_jobs_completed.fetch_add(12, Ordering::Relaxed);
        m.record_sweep_wall(0.125);
        let s = m.snapshot();
        assert_eq!(s.get("conns_accepted").unwrap().as_u64(), Some(5));
        assert_eq!(s.get("slow_client_drops").unwrap().as_u64(), Some(1));
        assert_eq!(s.get("frames_oversized").unwrap().as_u64(), Some(2));
        assert_eq!(s.get("sweeps_completed").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("sweep_jobs_completed").unwrap().as_u64(), Some(12));
        let sweep = s.get("sweep_wall").unwrap();
        assert_eq!(sweep.get("count").unwrap().as_u64(), Some(1));
        assert!(sweep.get("p50_secs").unwrap().as_f64().is_some());
        let prom = m.prometheus();
        assert!(prom.contains("simplexmap_conns_accepted_total 5"));
        assert!(prom.contains("simplexmap_sweeps_started_total 3"));
        assert!(prom.contains("# TYPE simplexmap_sweep_wall_seconds summary"));
        assert!(prom.contains("simplexmap_sweep_wall_seconds_count 1"));
    }

    #[test]
    fn results_store_counters_and_gauges_export() {
        let m = Metrics::new();
        m.results_delivered.fetch_add(7, Ordering::Relaxed);
        m.results_stored.fetch_add(4, Ordering::Relaxed);
        m.orphaned_results.fetch_add(1, Ordering::Relaxed);
        m.store_evictions.fetch_add(2, Ordering::Relaxed);
        m.jobs_retried.fetch_add(3, Ordering::Relaxed);
        m.jobs_expired.fetch_add(5, Ordering::Relaxed);
        m.store_rows.store(64, Ordering::Relaxed);
        m.store_sweeps.store(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.get("results_delivered").unwrap().as_u64(), Some(7));
        assert_eq!(s.get("results_stored").unwrap().as_u64(), Some(4));
        assert_eq!(s.get("orphaned_results").unwrap().as_u64(), Some(1));
        assert_eq!(s.get("store_evictions").unwrap().as_u64(), Some(2));
        assert_eq!(s.get("jobs_retried").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("jobs_expired").unwrap().as_u64(), Some(5));
        assert_eq!(s.get("store_rows").unwrap().as_u64(), Some(64));
        assert_eq!(s.get("store_sweeps").unwrap().as_u64(), Some(2));
        let prom = m.prometheus();
        assert!(prom.contains("# TYPE simplexmap_results_stored_total counter"));
        assert!(prom.contains("simplexmap_orphaned_results_total 1"));
        assert!(prom.contains("simplexmap_jobs_retried_total 3"));
        assert!(prom.contains("# TYPE simplexmap_store_rows gauge"));
        assert!(prom.contains("simplexmap_store_rows 64"));
        assert!(prom.contains("simplexmap_store_sweeps 2"));
    }

    #[test]
    fn empty_prometheus_has_no_quantile_lines() {
        let prom = Metrics::new().prometheus();
        assert!(!prom.contains("quantile="));
        assert!(prom.contains("simplexmap_job_wall_seconds_count 0"));
    }
}
