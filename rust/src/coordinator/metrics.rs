//! Process-wide coordinator metrics: job counters, per-phase latency
//! accumulators, tile/batch counters. Snapshots serialize to JSON for
//! the server's `metrics` command.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats::Welford;

#[derive(Default)]
pub struct Metrics {
    pub jobs_accepted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub blocks_mapped: AtomicU64,
    pub tile_batches: AtomicU64,
    pub tiles_padded: AtomicU64,
    map_phase: Mutex<Welford>,
    exec_phase: Mutex<Welford>,
    job_wall: Mutex<Welford>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_map_phase(&self, secs: f64) {
        self.map_phase.lock().unwrap().push(secs);
    }

    pub fn record_exec_phase(&self, secs: f64) {
        self.exec_phase.lock().unwrap().push(secs);
    }

    pub fn record_job(&self, secs: f64) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.job_wall.lock().unwrap().push(secs);
    }

    pub fn snapshot(&self) -> Json {
        let phase = |w: &Mutex<Welford>| {
            let w = w.lock().unwrap();
            Json::obj(vec![
                ("count", w.count().into()),
                ("mean_secs", w.mean().into()),
                ("stddev_secs", w.stddev().into()),
                ("max_secs", if w.count() > 0 { w.max() } else { 0.0 }.into()),
            ])
        };
        Json::obj(vec![
            (
                "jobs_accepted",
                self.jobs_accepted.load(Ordering::Relaxed).into(),
            ),
            (
                "jobs_completed",
                self.jobs_completed.load(Ordering::Relaxed).into(),
            ),
            (
                "jobs_failed",
                self.jobs_failed.load(Ordering::Relaxed).into(),
            ),
            (
                "blocks_mapped",
                self.blocks_mapped.load(Ordering::Relaxed).into(),
            ),
            (
                "tile_batches",
                self.tile_batches.load(Ordering::Relaxed).into(),
            ),
            (
                "tiles_padded",
                self.tiles_padded.load(Ordering::Relaxed).into(),
            ),
            ("map_phase", phase(&self.map_phase)),
            ("exec_phase", phase(&self.exec_phase)),
            ("job_wall", phase(&self.job_wall)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::new();
        m.jobs_accepted.fetch_add(3, Ordering::Relaxed);
        m.record_job(0.5);
        m.record_job(1.5);
        m.record_map_phase(0.1);
        let s = m.snapshot();
        assert_eq!(s.get("jobs_accepted").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("jobs_completed").unwrap().as_u64(), Some(2));
        let wall = s.get("job_wall").unwrap();
        assert_eq!(wall.get("count").unwrap().as_u64(), Some(2));
        assert!((wall.get("mean_secs").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_snapshot_is_valid_json() {
        let s = Metrics::new().snapshot();
        let text = s.to_string_compact();
        assert!(crate::util::json::parse(&text).is_ok());
    }
}
