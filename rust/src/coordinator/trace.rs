//! Workload traces: synthetic job streams with Poisson arrivals, and a
//! replay engine that measures serving latency under a live scheduler.
//! This is the serving-system flavour of E10: the coordinator as a
//! long-running leader absorbing a mixed job mix — the deployment the
//! paper's intro imagines for interaction/simulation services.

use std::time::{Duration, Instant};

use crate::coordinator::job::{Backend, Job, WorkloadKind};
use crate::coordinator::scheduler::Scheduler;
use crate::util::prng::Xoshiro256;
use crate::util::stats::Summary;

/// One trace entry: a job plus its scheduled arrival offset.
#[derive(Clone, Debug)]
pub struct TraceEntry {
    pub at: Duration,
    pub job: Job,
}

/// Trace generator parameters.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub jobs: usize,
    /// Mean arrival rate (jobs/sec) for the Poisson process.
    pub rate_hz: f64,
    /// Candidate workloads (uniform mix).
    pub workloads: Vec<WorkloadKind>,
    /// Candidate maps (uniform mix).
    pub maps: Vec<String>,
    /// Candidate problem sizes.
    pub sizes: Vec<u64>,
    pub backend: Backend,
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            jobs: 50,
            rate_hz: 50.0,
            workloads: vec![
                WorkloadKind::Edm,
                WorkloadKind::Collision,
                WorkloadKind::NBody,
                WorkloadKind::Cellular,
                WorkloadKind::TriMatVec,
            ],
            maps: vec!["lambda2".into(), "bb".into(), "rb".into(), "enum2".into()],
            sizes: vec![16, 32, 64],
            backend: Backend::Parallel,
            seed: 7,
        }
    }
}

/// Generate a reproducible trace: exponential inter-arrival gaps,
/// uniform mixes.
pub fn generate(spec: &TraceSpec) -> Vec<TraceEntry> {
    let mut rng = Xoshiro256::seed_from_u64(spec.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.jobs);
    for i in 0..spec.jobs {
        // Exponential gap: -ln(U)/rate.
        let u = rng.gen_f64().max(f64::MIN_POSITIVE);
        t += -u.ln() / spec.rate_hz;
        let workload = spec.workloads[rng.gen_range(0, spec.workloads.len())];
        // Higher-m workloads need a map of their dimension; fall back
        // to the canonical recursive map for m ≥ 3.
        let map = match workload.m() {
            2 => spec.maps[rng.gen_range(0, spec.maps.len())].clone(),
            3 => "lambda3".to_string(),
            _ => "lambda-m".to_string(),
        };
        let nb = spec.sizes[rng.gen_range(0, spec.sizes.len())];
        out.push(TraceEntry {
            at: Duration::from_secs_f64(t),
            job: Job {
                workload,
                nb,
                map,
                backend: spec.backend,
                seed: i as u64,
            },
        });
    }
    out
}

/// Replay result.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub completed: usize,
    pub failed: usize,
    /// End-to-end latency per job (queueing + service).
    pub latency: Summary,
    /// Pure service time per job.
    pub service: Summary,
    pub wall: Duration,
}

/// Replay a trace against a scheduler: jobs are released at their
/// arrival times (sleeping as needed) and run synchronously in arrival
/// order — a single-queue, in-order leader (the simplest serving
/// discipline; latency includes queueing behind earlier jobs).
pub fn replay(sched: &Scheduler, trace: &[TraceEntry]) -> ReplayReport {
    let start = Instant::now();
    let mut latencies = Vec::with_capacity(trace.len());
    let mut services = Vec::with_capacity(trace.len());
    let mut failed = 0usize;
    for entry in trace {
        if let Some(wait) = entry.at.checked_sub(start.elapsed()) {
            std::thread::sleep(wait);
        }
        let t0 = Instant::now();
        match sched.run(&entry.job) {
            Ok(_) => {
                services.push(t0.elapsed().as_secs_f64());
                latencies.push((start.elapsed() - entry.at).as_secs_f64());
            }
            Err(_) => failed += 1,
        }
    }
    ReplayReport {
        completed: latencies.len(),
        failed,
        // An all-failed replay reports the honest empty summary
        // (count 0, NaN moments → null JSON), not fabricated zeros.
        latency: Summary::from_samples(&latencies).unwrap_or_else(Summary::empty),
        service: Summary::from_samples(&services).unwrap_or_else(Summary::empty),
        wall: start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let spec = TraceSpec {
            jobs: 20,
            ..Default::default()
        };
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.job.nb, y.job.nb);
            assert_eq!(x.job.map, y.job.map);
        }
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at, "arrivals sorted");
        }
    }

    #[test]
    fn trace_respects_m3_map_constraint() {
        let spec = TraceSpec {
            jobs: 60,
            workloads: vec![WorkloadKind::Triple],
            ..Default::default()
        };
        for e in generate(&spec) {
            assert_eq!(e.job.map, "lambda3");
        }
    }

    #[test]
    fn mean_gap_tracks_rate() {
        let spec = TraceSpec {
            jobs: 4000,
            rate_hz: 100.0,
            ..Default::default()
        };
        let trace = generate(&spec);
        let total = trace.last().unwrap().at.as_secs_f64();
        let mean_gap = total / trace.len() as f64;
        assert!((mean_gap - 0.01).abs() < 0.002, "mean gap {mean_gap}");
    }

    #[test]
    fn replay_runs_a_small_trace() {
        let sched = Scheduler::new(2, None);
        let spec = TraceSpec {
            jobs: 6,
            rate_hz: 1000.0, // effectively back-to-back
            sizes: vec![8],
            ..Default::default()
        };
        let trace = generate(&spec);
        let report = replay(&sched, &trace);
        assert_eq!(report.completed, 6);
        assert_eq!(report.failed, 0);
        assert!(report.latency.p50 >= 0.0);
        assert!(report.service.mean > 0.0);
    }

    #[test]
    fn replay_counts_failures_without_aborting() {
        let sched = Scheduler::new(1, None);
        let mut trace = generate(&TraceSpec {
            jobs: 2,
            rate_hz: 1000.0,
            sizes: vec![8],
            ..Default::default()
        });
        trace[0].job.nb = 17; // unsupported by lambda2/bb? bb supports 17…
        trace[0].job.map = "lambda2".into(); // λ2 rejects non-pow2
        let report = replay(&sched, &trace);
        assert_eq!(report.failed, 1);
        assert_eq!(report.completed, 1);
    }

    #[test]
    fn replay_with_every_job_failing_reports_empty_summaries() {
        let sched = Scheduler::new(1, None);
        let mut trace = generate(&TraceSpec {
            jobs: 2,
            rate_hz: 1000.0,
            sizes: vec![8],
            ..Default::default()
        });
        for e in &mut trace {
            e.job.nb = 17;
            e.job.map = "lambda2".into(); // λ2 rejects non-pow2 sizes
        }
        let report = replay(&sched, &trace);
        assert_eq!(report.completed, 0);
        assert_eq!(report.failed, 2);
        assert_eq!(report.latency.count, 0);
        assert!(report.latency.p50.is_nan(), "no fabricated zero quantiles");
        assert_eq!(report.service.count, 0);
    }
}
