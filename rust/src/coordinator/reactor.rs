//! Non-blocking connection multiplexer: one thread, a `poll(2)`
//! readiness loop over `std::net`, thousands of concurrent clients.
//!
//! The threaded server ([`crate::coordinator::server`]) spends a stack
//! and a parked thread per connection and serializes each client's
//! jobs behind a blocking `queue.run`. This reactor keeps every
//! connection in one readiness loop (mio-style, zero dependencies):
//! reads go through the capped incremental framer
//! ([`FrameBuffer`]) so a hostile or confused client can neither buffer
//! unbounded garbage nor wedge the loop with a frame that never ends;
//! writes go through per-connection buffers with a soft watermark that
//! pauses both reads and result transfer for that client (backpressure)
//! and a hard cap that drops the connection (slow-client protection,
//! counted in `slow_client_drops`).
//!
//! Job execution never blocks the loop: `run` and `sweep` submit
//! through [`JobQueue::submit_async`](crate::coordinator::queue::JobQueue)
//! and the queue workers hand results back through a completion list
//! plus a loopback UDP wake datagram — the reactor sleeps in `poll`
//! until either a socket or a completion needs it.
//!
//! ## Sweep fan-out
//!
//! `{"cmd":"sweep","workloads":["edm"],"nbs":[8,16],…}` expands a
//! workloads × maps × nbs grid (row-major; `maps` defaults to each
//! workload's [`WorkloadKind::sweep_maps`] roster, so a wire sweep is
//! row-for-row the CLI `sweep`) and fans the rows through the queue
//! under the connection's fairness lane and the request's priority.
//! At most `window` rows are in flight per sweep at a time, so a
//! 4096-row sweep cannot monopolize the bounded queue: the global
//! invariant `queue_depth ≤ capacity` holds at every instant and
//! `QueueFull` during fan-out is retried on the next completion
//! instead of surfacing to the client.
//!
//! Replies stream per connection in *request order* (slots): the ack
//! frame `{"ok":true,"sweep":S,"jobs":N,"streaming":…}` first, then —
//! when streaming — one frame per row *in completion order*
//! (`{"sweep":S,"job":i,…}`), then `{"sweep":S,"done":true,…}`.
//! Results are also reassembled *in row order* into a per-sweep store
//! served by `{"cmd":"results","sweep":S,"cursor":0,"limit":64}` with
//! cursor pagination — the non-streaming path for very large sweeps.
//! The store is bounded (sweeps per connection × rows per sweep) and
//! freed on disconnect.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream, UdpSocket};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::job::{Job, JobResult, WorkloadKind};
use crate::coordinator::queue::{Priority, QueueConfig};
use crate::coordinator::scheduler::{ScheduleError, Scheduler};
use crate::coordinator::server::{dispatch_control, err_reply, ServerCtx};
use crate::coordinator::span::{self, ActiveSpan};
use crate::util::json::{self, Frame, FrameBuffer, Json, DEFAULT_MAX_FRAME};
use crate::{log_info, log_warn};

/// Hand-rolled `poll(2)` binding — the only system call the reactor
/// needs beyond `std::net`, so no crate dependency is worth it.
#[cfg(unix)]
mod sys {
    use std::io::ErrorKind;
    use std::os::raw::{c_int, c_short};

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const POLLERR: c_short = 0x008;
    pub const POLLHUP: c_short = 0x010;

    #[cfg(target_os = "linux")]
    type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type NfdsT = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
    }

    /// `poll` with EINTR retry. Returns the number of ready entries.
    pub fn poll_wait(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        loop {
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let e = std::io::Error::last_os_error();
            if e.kind() != ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }
}

/// Portability fallback: no readiness facility — sleep briefly and
/// report every registered interest as ready (the sockets are all
/// non-blocking, so spurious readiness only costs a `WouldBlock`).
#[cfg(not(unix))]
mod sys {
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    pub fn poll_wait(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        std::thread::sleep(std::time::Duration::from_millis(timeout_ms.clamp(1, 5) as u64));
        let mut ready = 0;
        for f in fds.iter_mut() {
            f.revents = f.events;
            if f.revents != 0 {
                ready += 1;
            }
        }
        Ok(ready)
    }
}

#[cfg(unix)]
fn raw_fd<T: std::os::fd::AsRawFd>(t: &T) -> i32 {
    t.as_raw_fd()
}
#[cfg(not(unix))]
fn raw_fd<T>(_t: &T) -> i32 {
    0
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Reactor sizing knobs. Environment overrides (`from_env`):
/// `SIMPLEXMAP_MAX_FRAME`, `SIMPLEXMAP_MAX_CONNS`,
/// `SIMPLEXMAP_SWEEP_WINDOW`, `SIMPLEXMAP_SWEEP_JOBS_MAX`.
#[derive(Clone, Copy, Debug)]
pub struct ReactorConfig {
    pub queue: QueueConfig,
    /// Largest accepted request frame in bytes (capped reader).
    pub max_frame: usize,
    /// Accepted-connection ceiling; excess connections are refused.
    pub max_conns: usize,
    /// Default per-sweep in-flight window (overridable per request).
    pub sweep_window: usize,
    /// Row ceiling for one sweep expansion.
    pub max_sweep_jobs: usize,
    /// Active (unfinished) sweeps allowed per connection; up to twice
    /// this many total sweeps stay addressable for pagination before
    /// the oldest finished one is evicted.
    pub max_sweeps_per_conn: usize,
    /// Write-backlog level that pauses reads + result transfer.
    pub soft_watermark: usize,
    /// Write-backlog level that drops the connection.
    pub hard_cap: usize,
}

impl Default for ReactorConfig {
    fn default() -> ReactorConfig {
        ReactorConfig {
            queue: QueueConfig::default(),
            max_frame: DEFAULT_MAX_FRAME,
            max_conns: 4096,
            sweep_window: 16,
            max_sweep_jobs: 4096,
            max_sweeps_per_conn: 8,
            soft_watermark: 256 * 1024,
            hard_cap: 8 * 1024 * 1024,
        }
    }
}

impl ReactorConfig {
    pub fn from_env() -> ReactorConfig {
        let d = ReactorConfig::default();
        ReactorConfig {
            max_frame: env_usize("SIMPLEXMAP_MAX_FRAME", d.max_frame).max(64),
            max_conns: env_usize("SIMPLEXMAP_MAX_CONNS", d.max_conns).max(1),
            sweep_window: env_usize("SIMPLEXMAP_SWEEP_WINDOW", d.sweep_window).max(1),
            max_sweep_jobs: env_usize("SIMPLEXMAP_SWEEP_JOBS_MAX", d.max_sweep_jobs).max(1),
            ..d
        }
    }
}

/// Per-request sweep options.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SweepOpts {
    pub stream: bool,
    pub window: usize,
    pub priority: Priority,
}

/// Expand a `sweep` request into its job rows (row-major:
/// workloads → maps → nbs) plus options. Pure — unit-tested without
/// sockets, and the contract the wire-vs-CLI differential test pins.
pub fn expand_sweep(
    req: &Json,
    default_window: usize,
    max_jobs: usize,
) -> Result<(Vec<Job>, SweepOpts), String> {
    let str_list = |key: &str| -> Result<Option<Vec<String>>, String> {
        match req.get(key) {
            None => Ok(None),
            Some(Json::Arr(items)) => {
                let mut out = Vec::with_capacity(items.len());
                for it in items {
                    out.push(
                        it.as_str()
                            .ok_or(format!("{key} must be an array of strings"))?
                            .to_string(),
                    );
                }
                Ok(Some(out))
            }
            Some(_) => Err(format!("{key} must be an array of strings")),
        }
    };
    let workload_names = str_list("workloads")?.ok_or("sweep needs workloads: [\"edm\", …]")?;
    if workload_names.is_empty() {
        return Err("sweep needs at least one workload".into());
    }
    let mut workloads = Vec::with_capacity(workload_names.len());
    for name in &workload_names {
        workloads.push(WorkloadKind::parse(name).ok_or(format!("unknown workload {name}"))?);
    }
    let nbs: Vec<u64> = match req.get("nbs") {
        Some(Json::Arr(items)) if !items.is_empty() => {
            let mut out = Vec::with_capacity(items.len());
            for it in items {
                out.push(it.as_u64().ok_or("nbs must be an array of integers")?);
            }
            out
        }
        _ => return Err("sweep needs nbs: [8, 16, …]".into()),
    };
    let maps = str_list("maps")?;
    let backend = match req.get("backend").and_then(Json::as_str) {
        None => crate::coordinator::job::BackendKind::Parallel,
        Some(s) => crate::coordinator::job::BackendKind::parse(s)
            .ok_or(format!("unknown backend {s}"))?,
    };
    let seed = req.get("seed").and_then(Json::as_u64).unwrap_or(42);
    let stream = req.get("stream").and_then(Json::as_bool).unwrap_or(true);
    let window = req
        .get("window")
        .and_then(Json::as_u64)
        .map(|w| (w as usize).clamp(1, 1024))
        .unwrap_or(default_window);
    let priority = match req.get("priority").and_then(Json::as_str) {
        None => Priority::Normal,
        Some(s) => Priority::parse(s).ok_or(format!("unknown priority {s} (high|normal|low)"))?,
    };

    let mut jobs = Vec::new();
    for w in &workloads {
        let maps_for_w = match &maps {
            Some(m) => m.clone(),
            None => w.sweep_maps(),
        };
        for map in &maps_for_w {
            for &nb in &nbs {
                jobs.push(Job {
                    workload: *w,
                    nb,
                    map: map.clone(),
                    backend,
                    seed,
                });
            }
        }
    }
    if jobs.is_empty() {
        return Err("sweep expanded to zero jobs".into());
    }
    if jobs.len() > max_jobs {
        return Err(format!(
            "sweep expands to {} jobs, over the {max_jobs} limit — split it",
            jobs.len()
        ));
    }
    Ok((
        jobs,
        SweepOpts {
            stream,
            window,
            priority,
        },
    ))
}

/// A finished job travelling from a queue worker back to the loop.
struct Done {
    token: u64,
    /// Reply slot (plain `run` only; sweeps reply through their own slot).
    req: u64,
    /// `(sweep id, row index)` when the job belongs to a sweep.
    sweep: Option<(u64, usize)>,
    result: Result<JobResult, ScheduleError>,
}

/// Completion mailbox + self-wake: queue workers push here and nudge
/// the sleeping `poll` with a loopback datagram.
struct Mailbox {
    done: Mutex<Vec<Done>>,
    wake: UdpSocket,
}

impl Mailbox {
    fn push(&self, d: Done) {
        self.done.lock().unwrap().push(d);
        // A full socket buffer means wake datagrams are already
        // pending, which is all a wake needs to guarantee.
        let _ = self.wake.send(&[1]);
    }
}

/// One in-order reply slot: responses leave the connection in request
/// order, so a pipelined client can match frames to requests.
struct Slot {
    req: u64,
    frames: VecDeque<String>,
    done: bool,
}

struct SweepState {
    /// The slot the ack/stream/done frames flow through.
    req: u64,
    jobs: Vec<Job>,
    /// Reassembled in row order as completions arrive (out-of-order
    /// workers land in the right cell).
    results: Vec<Option<Json>>,
    next_submit: usize,
    in_flight: usize,
    completed: u64,
    failed: u64,
    stream: bool,
    window: usize,
    priority: Priority,
    started: Instant,
    finished: bool,
    span: Option<ActiveSpan>,
}

struct Conn {
    stream: TcpStream,
    fd: i32,
    frames: FrameBuffer,
    out: Vec<u8>,
    slots: VecDeque<Slot>,
    /// Bytes sitting in not-yet-transferred slot frames (`out` bytes
    /// are counted separately); the two together are the write backlog
    /// the watermark/hard-cap act on.
    pending_bytes: usize,
    next_req: u64,
    next_sweep: u64,
    sweeps: BTreeMap<u64, SweepState>,
    inflight_runs: usize,
    read_closed: bool,
    dead: bool,
    span: Option<ActiveSpan>,
}

impl Conn {
    fn new(stream: TcpStream, max_frame: usize) -> Conn {
        let fd = raw_fd(&stream);
        Conn {
            stream,
            fd,
            frames: FrameBuffer::new(max_frame),
            out: Vec::new(),
            slots: VecDeque::new(),
            pending_bytes: 0,
            next_req: 0,
            next_sweep: 0,
            sweeps: BTreeMap::new(),
            inflight_runs: 0,
            read_closed: false,
            dead: false,
            span: Some(span::global().start("server", "conn", 0)),
        }
    }

    fn backlog(&self) -> usize {
        self.out.len() + self.pending_bytes
    }

    fn paused(&self, cfg: &ReactorConfig) -> bool {
        self.backlog() > cfg.soft_watermark
    }

    fn new_slot(&mut self) -> u64 {
        let req = self.next_req;
        self.next_req += 1;
        self.slots.push_back(Slot {
            req,
            frames: VecDeque::new(),
            done: false,
        });
        req
    }

    fn push_frame_text(&mut self, req: u64, text: String) {
        if let Some(slot) = self.slots.iter_mut().find(|s| s.req == req) {
            self.pending_bytes += text.len() + 1;
            slot.frames.push_back(text);
        }
    }

    fn push_frame(&mut self, req: u64, j: Json) {
        self.push_frame_text(req, j.to_string_compact());
    }

    fn finish_slot(&mut self, req: u64) {
        if let Some(slot) = self.slots.iter_mut().find(|s| s.req == req) {
            slot.done = true;
        }
    }

    /// One-frame reply: push and close the slot.
    fn reply(&mut self, req: u64, j: Json) {
        self.push_frame(req, j);
        self.finish_slot(req);
    }

    /// Everything delivered, nothing running: safe to forget once the
    /// client side has stopped talking (or shutdown wants us gone).
    fn idle(&self) -> bool {
        self.out.is_empty()
            && self.slots.is_empty()
            && self.inflight_runs == 0
            && self.sweeps.values().all(|s| s.finished)
    }

    /// Transfer frames from the front slot(s) into the write buffer,
    /// strictly in request order, up to the soft watermark.
    fn fill_out(&mut self, cfg: &ReactorConfig) {
        while self.out.len() < cfg.soft_watermark {
            let Some(front) = self.slots.front_mut() else {
                break;
            };
            if let Some(f) = front.frames.pop_front() {
                self.pending_bytes -= f.len() + 1;
                self.out.extend_from_slice(f.as_bytes());
                self.out.push(b'\n');
            } else if front.done {
                self.slots.pop_front();
            } else {
                break;
            }
        }
    }

    fn write_out(&mut self) {
        while !self.out.is_empty() {
            match self.stream.write(&self.out) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.out.drain(..n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
    }

    fn read_in(&mut self) {
        let mut buf = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                Ok(n) => self.frames.push(&buf[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
    }
}

/// The poll-reactor server. Same wire protocol as the threaded
/// [`Server`](crate::coordinator::server::Server) (shared
/// [`dispatch_control`]) plus the streaming `sweep`/`results` pair.
pub struct Reactor {
    ctx: Arc<ServerCtx>,
    cfg: ReactorConfig,
}

impl Reactor {
    pub fn new(scheduler: Arc<Scheduler>) -> Reactor {
        Reactor::with_config(scheduler, ReactorConfig::default())
    }

    pub fn with_config(scheduler: Arc<Scheduler>, cfg: ReactorConfig) -> Reactor {
        Reactor {
            ctx: Arc::new(ServerCtx::new(scheduler, cfg.queue)),
            cfg,
        }
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.ctx.shutdown)
    }

    /// Bind and multiplex until a shutdown command arrives. Reports the
    /// bound address through `on_bound` (lets tests/examples use port 0).
    pub fn serve(
        &self,
        addr: &str,
        on_bound: impl FnOnce(std::net::SocketAddr),
    ) -> std::io::Result<()> {
        let cfg = self.cfg;
        let ctx = &self.ctx;
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        log_info!("reactor", "listening on {local}");
        on_bound(local);

        // Loopback self-wake pair: workers signal completions through
        // `mailbox.wake` → `wake_rx` becomes readable → poll returns.
        let wake_rx = UdpSocket::bind("127.0.0.1:0")?;
        wake_rx.set_nonblocking(true)?;
        let wake_tx = UdpSocket::bind("127.0.0.1:0")?;
        wake_tx.set_nonblocking(true)?;
        wake_tx.connect(wake_rx.local_addr()?)?;
        let mailbox = Arc::new(Mailbox {
            done: Mutex::new(Vec::new()),
            wake: wake_tx,
        });

        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut next_token: u64 = 1;
        let mut fds: Vec<sys::PollFd> = Vec::new();
        let mut order: Vec<u64> = Vec::new();
        let mut grace_rounds_left: Option<u32> = None;

        loop {
            let shutting_down = ctx.shutdown.load(Ordering::SeqCst);
            if shutting_down && grace_rounds_left.is_none() {
                grace_rounds_left = Some(50); // ≈5 s at the 100 ms tick
            }

            fds.clear();
            order.clear();
            let accepting = !shutting_down && conns.len() < cfg.max_conns;
            fds.push(sys::PollFd {
                fd: raw_fd(&listener),
                events: if accepting { sys::POLLIN } else { 0 },
                revents: 0,
            });
            fds.push(sys::PollFd {
                fd: raw_fd(&wake_rx),
                events: sys::POLLIN,
                revents: 0,
            });
            for (tok, c) in conns.iter() {
                let mut ev = 0;
                if !c.read_closed && !c.paused(&cfg) {
                    ev |= sys::POLLIN;
                }
                if !c.out.is_empty() {
                    ev |= sys::POLLOUT;
                }
                fds.push(sys::PollFd {
                    fd: c.fd,
                    events: ev,
                    revents: 0,
                });
                order.push(*tok);
            }

            sys::poll_wait(&mut fds, 100)?;

            // Drain wake datagrams (their only content is "look at the
            // mailbox").
            if fds[1].revents & (sys::POLLIN | sys::POLLERR) != 0 {
                let mut sink = [0u8; 64];
                while wake_rx.recv(&mut sink).is_ok() {}
            }

            // Completions from the queue workers.
            let batch = std::mem::take(&mut *mailbox.done.lock().unwrap());
            for d in batch {
                let Some(c) = conns.get_mut(&d.token) else {
                    continue; // client vanished mid-job; result dropped
                };
                match d.sweep {
                    Some((sid, idx)) => {
                        apply_sweep_result(c, ctx, sid, idx, d.result, true);
                    }
                    None => {
                        c.inflight_runs = c.inflight_runs.saturating_sub(1);
                        let reply = match d.result {
                            Ok(r) => Json::obj(vec![
                                ("ok", true.into()),
                                ("result", r.to_json()),
                            ]),
                            Err(e) => {
                                ctx.scheduler
                                    .metrics
                                    .jobs_failed
                                    .fetch_add(1, Ordering::Relaxed);
                                err_reply(e.to_string())
                            }
                        };
                        c.reply(d.req, reply);
                    }
                }
            }

            // New connections.
            if accepting && fds[0].revents & sys::POLLIN != 0 {
                loop {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            if conns.len() >= cfg.max_conns {
                                drop(stream);
                                log_warn!("reactor", "refusing {peer}: connection limit");
                                continue;
                            }
                            if stream.set_nonblocking(true).is_err() {
                                continue;
                            }
                            let _ = stream.set_nodelay(true);
                            ctx.scheduler
                                .metrics
                                .conns_accepted
                                .fetch_add(1, Ordering::Relaxed);
                            let tok = next_token;
                            next_token += 1;
                            conns.insert(tok, Conn::new(stream, cfg.max_frame));
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(e) => return Err(e),
                    }
                }
            }

            // Socket readiness per connection.
            for (i, tok) in order.iter().enumerate() {
                let revents = fds[i + 2].revents;
                let Some(c) = conns.get_mut(tok) else { continue };
                if revents & sys::POLLERR != 0 {
                    c.dead = true;
                    continue;
                }
                if revents & (sys::POLLIN | sys::POLLHUP) != 0 && !c.read_closed {
                    c.read_in();
                }
            }

            // Frame processing, sweep pumping, reply transfer, writes.
            for (tok, c) in conns.iter_mut() {
                if c.dead {
                    continue;
                }
                while !c.paused(&cfg) {
                    match c.frames.next_frame() {
                        Some(Frame::Line(line)) => {
                            if line.trim().is_empty() {
                                continue;
                            }
                            handle_request(c, *tok, &line, ctx, &mailbox, &cfg);
                        }
                        Some(Frame::Oversized { limit }) => {
                            ctx.scheduler
                                .metrics
                                .frames_oversized
                                .fetch_add(1, Ordering::Relaxed);
                            let req = c.new_slot();
                            c.reply(
                                req,
                                err_reply(format!("frame exceeds {limit} byte limit")),
                            );
                        }
                        None => break,
                    }
                }
                pump_sweeps(c, *tok, ctx, &mailbox, &cfg);
                c.fill_out(&cfg);
                c.write_out();
                if c.backlog() > cfg.hard_cap {
                    ctx.scheduler
                        .metrics
                        .slow_client_drops
                        .fetch_add(1, Ordering::Relaxed);
                    log_warn!("reactor", "dropping slow client ({} bytes backlog)", c.backlog());
                    c.dead = true;
                }
            }

            // Reap: broken connections, and quiet ones whose client
            // already said goodbye.
            let force_close = grace_rounds_left == Some(0);
            conns.retain(|_, c| {
                let quiet = c.idle() && (c.read_closed || shutting_down);
                let gone = c.dead || quiet || force_close;
                if gone {
                    if let Some(sp) = c.span.take() {
                        span::global().finish(sp);
                    }
                    ctx.scheduler
                        .metrics
                        .conns_closed
                        .fetch_add(1, Ordering::Relaxed);
                }
                !gone
            });

            if let Some(g) = grace_rounds_left.as_mut() {
                if conns.is_empty() {
                    break;
                }
                if *g == 0 {
                    break;
                }
                *g -= 1;
            }
        }

        ctx.queue.shutdown();
        log_info!("reactor", "shut down");
        Ok(())
    }
}

fn handle_request(
    c: &mut Conn,
    token: u64,
    line: &str,
    ctx: &Arc<ServerCtx>,
    mailbox: &Arc<Mailbox>,
    cfg: &ReactorConfig,
) {
    let req_id = c.new_slot();
    let req = match json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            c.reply(req_id, err_reply(format!("bad json: {e}")));
            return;
        }
    };
    if let Some(reply) = dispatch_control(&req, ctx) {
        c.reply(req_id, reply);
        return;
    }
    match req.get("cmd").and_then(Json::as_str) {
        Some("run") => handle_run(c, token, req_id, &req, ctx, mailbox),
        Some("sweep") => handle_sweep(c, req_id, &req, ctx, cfg),
        Some("results") => handle_results(c, req_id, &req),
        _ => c.reply(
            req_id,
            err_reply("unknown cmd (ping|run|sweep|results|maps|metrics|trace|shutdown)".into()),
        ),
    }
}

fn handle_run(
    c: &mut Conn,
    token: u64,
    req_id: u64,
    req: &Json,
    ctx: &Arc<ServerCtx>,
    mailbox: &Arc<Mailbox>,
) {
    let metrics = &ctx.scheduler.metrics;
    metrics.jobs_accepted.fetch_add(1, Ordering::Relaxed);
    let Some(job) = Job::from_json(req) else {
        c.reply(req_id, err_reply("invalid job (need workload, nb, map)".into()));
        return;
    };
    let priority = match req.get("priority").and_then(Json::as_str) {
        None => Priority::Normal,
        Some(s) => match Priority::parse(s) {
            Some(p) => p,
            None => {
                c.reply(req_id, err_reply(format!("unknown priority {s}")));
                return;
            }
        },
    };
    // Accept span: admission → completion (the reply transfer happens
    // on the loop right after, so this is the client-visible latency
    // minus socket time).
    let accept = span::global().start("server", "accept", 0);
    let attrs = vec![
        ("workload", job.workload.name().to_string()),
        ("map", job.map.clone()),
    ];
    let mb = Arc::clone(mailbox);
    match ctx.queue.submit_async(job, priority, token, move |result| {
        span::global().finish_with(accept, attrs);
        mb.push(Done {
            token,
            req: req_id,
            sweep: None,
            result,
        });
    }) {
        Ok(()) => c.inflight_runs += 1,
        Err(e) => {
            metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
            c.reply(req_id, err_reply(e.to_string()));
        }
    }
}

fn handle_sweep(
    c: &mut Conn,
    req_id: u64,
    req: &Json,
    ctx: &Arc<ServerCtx>,
    cfg: &ReactorConfig,
) {
    let (jobs, opts) = match expand_sweep(req, cfg.sweep_window, cfg.max_sweep_jobs) {
        Ok(x) => x,
        Err(msg) => {
            c.reply(req_id, err_reply(msg));
            return;
        }
    };
    let active = c.sweeps.values().filter(|s| !s.finished).count();
    if active >= cfg.max_sweeps_per_conn {
        c.reply(
            req_id,
            err_reply(format!(
                "too many active sweeps ({active}); wait for one to finish"
            )),
        );
        return;
    }
    // Evict the oldest finished sweep once the pagination store is at
    // capacity — bounded memory per connection.
    while c.sweeps.len() >= cfg.max_sweeps_per_conn * 2 {
        let oldest_done = c
            .sweeps
            .iter()
            .find(|(_, s)| s.finished)
            .map(|(id, _)| *id);
        match oldest_done {
            Some(id) => {
                c.sweeps.remove(&id);
            }
            None => break,
        }
    }
    let sid = c.next_sweep;
    c.next_sweep += 1;
    let metrics = &ctx.scheduler.metrics;
    metrics.sweeps_started.fetch_add(1, Ordering::Relaxed);
    metrics
        .jobs_accepted
        .fetch_add(jobs.len() as u64, Ordering::Relaxed);
    let n = jobs.len();
    let ack = Json::obj(vec![
        ("ok", true.into()),
        ("sweep", sid.into()),
        ("jobs", (n as u64).into()),
        ("streaming", opts.stream.into()),
    ]);
    c.push_frame(req_id, ack);
    if !opts.stream {
        // Non-streaming sweeps answer just the ack; rows arrive via
        // `results` pagination. The slot closes so later requests
        // (e.g. the polls) are not blocked behind the fan-out.
        c.finish_slot(req_id);
    }
    c.sweeps.insert(
        sid,
        SweepState {
            req: req_id,
            results: vec![None; n],
            jobs,
            next_submit: 0,
            in_flight: 0,
            completed: 0,
            failed: 0,
            stream: opts.stream,
            window: opts.window,
            priority: opts.priority,
            started: Instant::now(),
            finished: false,
            span: Some(span::global().start("server", "sweep", 0)),
        },
    );
    // Rows are submitted by `pump_sweeps` on this same loop iteration.
}

fn handle_results(c: &mut Conn, req_id: u64, req: &Json) {
    let Some(sid) = req.get("sweep").and_then(Json::as_u64) else {
        c.reply(req_id, err_reply("results needs a sweep id".into()));
        return;
    };
    let Some(st) = c.sweeps.get(&sid) else {
        c.reply(
            req_id,
            err_reply(format!(
                "unknown sweep {sid} (results are per-connection and bounded)"
            )),
        );
        return;
    };
    let cursor = req.get("cursor").and_then(Json::as_u64).unwrap_or(0) as usize;
    let limit = req
        .get("limit")
        .and_then(Json::as_u64)
        .unwrap_or(64)
        .clamp(1, 256) as usize;
    let total = st.results.len();
    let end = cursor.saturating_add(limit).min(total);
    let page: Vec<Json> = st
        .results
        .get(cursor.min(total)..end)
        .unwrap_or(&[])
        .iter()
        .map(|r| r.clone().unwrap_or(Json::Null))
        .collect();
    let next = if end < total {
        Json::from(end as u64)
    } else {
        Json::Null
    };
    let reply = Json::obj(vec![
        ("ok", true.into()),
        ("sweep", sid.into()),
        ("jobs", (total as u64).into()),
        ("cursor", (cursor as u64).into()),
        ("done", st.finished.into()),
        ("results", Json::Arr(page)),
        ("next_cursor", next),
    ]);
    c.reply(req_id, reply);
}

/// Submit sweep rows up to each sweep's in-flight window. `QueueFull`
/// stops the pump without failing the row — the next completion frees
/// queue space and wakes the loop, which retries here. This is what
/// keeps `queue_depth ≤ capacity` while a 4096-row sweep drains.
fn pump_sweeps(
    c: &mut Conn,
    token: u64,
    ctx: &Arc<ServerCtx>,
    mailbox: &Arc<Mailbox>,
    cfg: &ReactorConfig,
) {
    // A backlogged client stops receiving new rows: in-flight ones
    // finish (bounded by the window), then the fan-out idles until the
    // client drains — memory stays bounded without dropping results.
    if c.paused(cfg) {
        return;
    }
    let mut hard_failures: Vec<(u64, usize, ScheduleError)> = Vec::new();
    for (&sid, st) in c.sweeps.iter_mut() {
        while !st.finished && st.next_submit < st.jobs.len() && st.in_flight < st.window {
            let idx = st.next_submit;
            let job = st.jobs[idx].clone();
            let mb = Arc::clone(mailbox);
            match ctx.queue.submit_async(job, st.priority, token, move |result| {
                mb.push(Done {
                    token,
                    req: 0,
                    sweep: Some((sid, idx)),
                    result,
                });
            }) {
                Ok(()) => {
                    st.in_flight += 1;
                    st.next_submit += 1;
                }
                Err(ScheduleError::QueueFull(_)) => return,
                Err(e) => {
                    // Shutdown and friends: fail the row, move on.
                    st.next_submit += 1;
                    hard_failures.push((sid, idx, e));
                }
            }
        }
    }
    for (sid, idx, e) in hard_failures {
        apply_sweep_result(c, ctx, sid, idx, Err(e), false);
    }
}

/// Land one sweep row: reassemble into the row-order store, stream the
/// frame if requested, close out the sweep when the last row lands.
fn apply_sweep_result(
    c: &mut Conn,
    ctx: &Arc<ServerCtx>,
    sid: u64,
    idx: usize,
    result: Result<JobResult, ScheduleError>,
    from_queue: bool,
) {
    let metrics = &ctx.scheduler.metrics;
    let Some(st) = c.sweeps.get_mut(&sid) else {
        return;
    };
    if from_queue {
        st.in_flight = st.in_flight.saturating_sub(1);
    }
    if idx >= st.results.len() || st.results[idx].is_some() {
        return; // structurally impossible duplicate; never double-count
    }
    let ok = result.is_ok();
    let frame = match result {
        Ok(r) => Json::obj(vec![
            ("sweep", sid.into()),
            ("job", (idx as u64).into()),
            ("ok", true.into()),
            ("result", r.to_json()),
        ]),
        Err(e) => Json::obj(vec![
            ("sweep", sid.into()),
            ("job", (idx as u64).into()),
            ("ok", false.into()),
            ("error", e.to_string().into()),
        ]),
    };
    if ok {
        st.completed += 1;
    } else {
        st.failed += 1;
        metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
    }
    metrics.sweep_jobs_completed.fetch_add(1, Ordering::Relaxed);
    let mut texts: Vec<String> = Vec::new();
    if st.stream {
        texts.push(frame.to_string_compact());
    }
    st.results[idx] = Some(frame);
    let req = st.req;
    let stream = st.stream;
    let finished_now = st.completed + st.failed == st.results.len() as u64;
    if finished_now {
        st.finished = true;
        metrics.sweeps_completed.fetch_add(1, Ordering::Relaxed);
        metrics.record_sweep_wall(st.started.elapsed().as_secs_f64());
        let (jobs, completed, failed) =
            (st.results.len() as u64, st.completed, st.failed);
        if let Some(sp) = st.span.take() {
            span::global().finish_with(sp, vec![("jobs", jobs.to_string())]);
        }
        if stream {
            texts.push(
                Json::obj(vec![
                    ("sweep", sid.into()),
                    ("done", true.into()),
                    ("jobs", jobs.into()),
                    ("completed", completed.into()),
                    ("failed", failed.into()),
                ])
                .to_string_compact(),
            );
        }
    }
    for t in texts {
        c.push_frame_text(req, t);
    }
    if finished_now && stream {
        c.finish_slot(req);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expand_sweep_defaults_match_cli_sweep_roster() {
        let req = json::parse(r#"{"cmd":"sweep","workloads":["edm"],"nbs":[8]}"#).unwrap();
        let (jobs, opts) = expand_sweep(&req, 16, 4096).expect("valid sweep");
        let maps: Vec<&str> = jobs.iter().map(|j| j.map.as_str()).collect();
        assert_eq!(maps, vec!["bb", "lambda2", "enum2", "rb", "ries", "lambda-s"]);
        assert!(jobs.iter().all(|j| j.nb == 8 && j.seed == 42));
        assert_eq!(
            opts,
            SweepOpts {
                stream: true,
                window: 16,
                priority: Priority::Normal
            }
        );
    }

    #[test]
    fn expand_sweep_is_row_major_over_workloads_maps_nbs() {
        let req = json::parse(
            r#"{"cmd":"sweep","workloads":["edm","nbody"],"maps":["bb","lambda2"],
                "nbs":[4,8],"seed":7,"stream":false,"window":3,"priority":"low"}"#,
        )
        .unwrap();
        let (jobs, opts) = expand_sweep(&req, 16, 4096).unwrap();
        let rows: Vec<(String, String, u64)> = jobs
            .iter()
            .map(|j| (j.workload.name().to_string(), j.map.clone(), j.nb))
            .collect();
        let expect = [
            ("edm", "bb", 4),
            ("edm", "bb", 8),
            ("edm", "lambda2", 4),
            ("edm", "lambda2", 8),
            ("nbody", "bb", 4),
            ("nbody", "bb", 8),
            ("nbody", "lambda2", 4),
            ("nbody", "lambda2", 8),
        ];
        let expect: Vec<(String, String, u64)> = expect
            .iter()
            .map(|(w, m, n)| (w.to_string(), m.to_string(), *n))
            .collect();
        assert_eq!(rows, expect);
        assert_eq!(
            opts,
            SweepOpts {
                stream: false,
                window: 3,
                priority: Priority::Low
            }
        );
        assert!(jobs.iter().all(|j| j.seed == 7));
    }

    #[test]
    fn expand_sweep_rejects_malformed_requests() {
        let bad = [
            r#"{"cmd":"sweep"}"#,
            r#"{"cmd":"sweep","workloads":[],"nbs":[8]}"#,
            r#"{"cmd":"sweep","workloads":["edm"]}"#,
            r#"{"cmd":"sweep","workloads":["edm"],"nbs":[]}"#,
            r#"{"cmd":"sweep","workloads":["dance"],"nbs":[8]}"#,
            r#"{"cmd":"sweep","workloads":["edm"],"nbs":[8],"priority":"urgent"}"#,
            r#"{"cmd":"sweep","workloads":["edm"],"nbs":[8],"backend":"tpu"}"#,
            r#"{"cmd":"sweep","workloads":"edm","nbs":[8]}"#,
        ];
        for b in bad {
            let req = json::parse(b).unwrap();
            assert!(expand_sweep(&req, 16, 4096).is_err(), "{b}");
        }
    }

    #[test]
    fn expand_sweep_enforces_row_ceiling() {
        let req = json::parse(
            r#"{"cmd":"sweep","workloads":["edm"],"maps":["bb"],"nbs":[4,8,16,32]}"#,
        )
        .unwrap();
        assert!(expand_sweep(&req, 16, 4).is_ok());
        let err = expand_sweep(&req, 16, 3).unwrap_err();
        assert!(err.contains("over the 3"), "{err}");
    }

    #[test]
    fn poll_wait_times_out_with_no_fds() {
        let mut fds: Vec<sys::PollFd> = Vec::new();
        let t = Instant::now();
        let n = sys::poll_wait(&mut fds, 10).unwrap();
        assert_eq!(n, 0);
        assert!(t.elapsed().as_millis() >= 5, "timeout must actually wait");
    }

    #[test]
    fn reactor_config_env_floors() {
        let d = ReactorConfig::default();
        assert!(d.soft_watermark < d.hard_cap);
        assert!(d.max_sweep_jobs >= d.sweep_window);
        let e = ReactorConfig::from_env();
        assert!(e.max_frame >= 64);
        assert!(e.sweep_window >= 1);
    }
}
