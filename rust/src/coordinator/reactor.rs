//! Non-blocking connection multiplexer: one thread, a `poll(2)`
//! readiness loop over `std::net`, thousands of concurrent clients.
//!
//! The threaded server ([`crate::coordinator::server`]) spends a stack
//! and a parked thread per connection and serializes each client's
//! jobs behind a blocking `queue.run`. This reactor keeps every
//! connection in one readiness loop (mio-style, zero dependencies):
//! reads go through the capped incremental framer
//! ([`FrameBuffer`]) so a hostile or confused client can neither buffer
//! unbounded garbage nor wedge the loop with a frame that never ends;
//! writes go through per-connection buffers with a soft watermark that
//! pauses both reads and result transfer for that client (backpressure)
//! and a hard cap that drops the connection (slow-client protection,
//! counted in `slow_client_drops`).
//!
//! Job execution never blocks the loop: `run` and `sweep` submit
//! through [`JobQueue::submit_async`](crate::coordinator::queue::JobQueue)
//! and the queue workers hand results back through a completion list
//! plus a loopback UDP wake datagram — the reactor sleeps in `poll`
//! until either a socket or a completion needs it.
//!
//! ## Sweep fan-out and the durable results store
//!
//! `{"cmd":"sweep","workloads":["edm"],"nbs":[8,16],…}` expands a
//! workloads × maps × nbs grid (row-major; `maps` defaults to each
//! workload's [`WorkloadKind::sweep_maps`] roster, so a wire sweep is
//! row-for-row the CLI `sweep`) and fans the rows through the queue
//! under the connection's fairness lane and the request's priority.
//! At most `window` rows are in flight per sweep at a time, so a
//! 4096-row sweep cannot monopolize the bounded queue: the global
//! invariant `queue_depth ≤ capacity` holds at every instant and
//! `QueueFull` during fan-out is retried on the next completion
//! instead of surfacing to the client.
//!
//! Replies stream per connection in *request order* (slots): the ack
//! frame `{"ok":true,"sweep":S,"token":"swp-…","jobs":N,…}` first,
//! then — when streaming — one frame per row *in completion order*
//! (`{"sweep":S,"job":i,…}`), then `{"sweep":S,"done":true,…}`.
//!
//! Results do **not** live in the connection. Every row lands in the
//! process-wide [`ResultsStore`], keyed by the durable `token` from
//! the ack — so a client that loses its TCP connection mid-sweep
//! reconnects, presents the token to `{"cmd":"results","token":…}`,
//! and resumes cursor pagination exactly where the rows are, while
//! the sweep itself keeps running detached (its owner is cleared, the
//! fan-out continues into the store). The store is bounded
//! (`SIMPLEXMAP_STORE_CAP` rows, pre-reserved per sweep at admission
//! so mid-sweep overflow is impossible) and TTL-evicted
//! (`SIMPLEXMAP_STORE_TTL_SECS`, finished entries only); admission
//! refusal is a typed wire error, never silent loss.
//!
//! ## Job timeout and bounded retry
//!
//! Every submitted row carries a start deadline
//! (`SIMPLEXMAP_JOB_TIMEOUT_MS`). A row the queue could not start in
//! time resolves to [`ScheduleError::Expired`] and is re-enqueued
//! through the same priority/fairness lane at most
//! `SIMPLEXMAP_JOB_RETRY_MAX` times (counted in `jobs_retried`)
//! before it fails for real. Completed-job accounting is closed:
//! `jobs_completed == results_delivered + results_stored +
//! orphaned_results` — a finished job is delivered to a live
//! connection, stored under a token, or (only if the store refuses an
//! orphan) counted, never silently dropped.
//!
//! Memory-ordering policy: every atomic the reactor touches is either
//! a monotonic metrics counter/gauge or the polled `shutdown` flag.
//! Nothing synchronizes *through* them — the 100 ms poll tick is the
//! only freshness bound the flag needs — so all accesses are Relaxed.
// lint: atomics(Relaxed)

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream, UdpSocket};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::job::{Job, JobResult, WorkloadKind};
use crate::coordinator::queue::{Priority, QueueConfig};
use crate::coordinator::results_store::{PutOutcome, ResultsStore, StoreConfig};
use crate::coordinator::scheduler::{ScheduleError, Scheduler};
use crate::coordinator::server::{dispatch_control, err_reply, ServerCtx};
use crate::coordinator::span::{self, ActiveSpan};
use crate::util::json::{self, Frame, FrameBuffer, Json, DEFAULT_MAX_FRAME};
use crate::util::prng::SplitMix64;
use crate::util::sync::lock_unpoisoned;
use crate::{log_info, log_warn};

/// Hand-rolled `poll(2)` binding — the only system call the reactor
/// needs beyond `std::net`, so no crate dependency is worth it.
#[cfg(unix)]
mod sys {
    use std::io::ErrorKind;
    use std::os::raw::{c_int, c_short};

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const POLLERR: c_short = 0x008;
    pub const POLLHUP: c_short = 0x010;

    #[cfg(target_os = "linux")]
    type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type NfdsT = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
    }

    /// `poll` with EINTR retry. Returns the number of ready entries.
    pub fn poll_wait(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        loop {
            // SAFETY: `fds` is a live exclusively-borrowed slice; the
            // pointer/length pair describes exactly its allocation and
            // the kernel writes only the `revents` fields within it.
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let e = std::io::Error::last_os_error();
            if e.kind() != ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }
}

/// Portability fallback mirror of the pollfd shape (no real `poll`).
#[cfg(not(unix))]
mod sys {
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
}

/// Readiness probing for platforms without `poll(2)`.
///
/// The old fallback set `revents = events` unconditionally after a
/// 1–5 ms nap — every fd looked ready on every call, which both
/// busy-spun the loop and reported *phantom readiness* (a `POLLIN`
/// with nothing to read, over and over). This probe sleeps in ~1 ms
/// ticks up to the full poll timeout and wakes early **only** when a
/// socket shows real pending input via a non-blocking peek. Write
/// interest and unpeekable fds ([`Probe::Assume`], e.g. listeners)
/// are reported only at exit — they never cut the sleep short, so
/// they cannot spin the loop.
///
/// Compiled on unix too (under `cfg(test)`) so the regression tests
/// run on the primary platform.
#[cfg(any(test, not(unix)))]
mod probe {
    use std::io::ErrorKind;
    use std::net::{TcpStream, UdpSocket};
    use std::time::{Duration, Instant};

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    /// How one registered fd can be probed for input readiness.
    pub enum Probe<'a> {
        /// No way to peek (a listener): readiness is only reported at
        /// exit, and the caller discovers the truth by attempting the
        /// (non-blocking) operation.
        Assume,
        Tcp(&'a TcpStream),
        Udp(&'a UdpSocket),
    }

    /// Real, observable input readiness right now — or 0.
    fn input_ready(p: &Probe<'_>) -> i16 {
        let mut b = [0u8; 1];
        match p {
            Probe::Assume => 0,
            Probe::Tcp(s) => match s.peek(&mut b) {
                Ok(0) => POLLIN | POLLHUP, // orderly EOF: a read will see it
                Ok(_) => POLLIN,
                Err(e) if e.kind() == ErrorKind::WouldBlock => 0,
                Err(e) if e.kind() == ErrorKind::Interrupted => 0,
                Err(_) => POLLERR,
            },
            Probe::Udp(s) => match s.peek(&mut b) {
                Ok(_) => POLLIN,
                Err(e) if e.kind() == ErrorKind::WouldBlock => 0,
                Err(e) if e.kind() == ErrorKind::Interrupted => 0,
                Err(_) => POLLERR,
            },
        }
    }

    /// `poll` replacement: returns one `revents` per interest. Wakes
    /// early only on real pending input; `POLLOUT` and [`Probe::Assume`]
    /// interests are folded in at exit.
    pub fn poll_probed(interests: &[(i16, Probe<'_>)], timeout_ms: i32) -> Vec<i16> {
        let deadline = Instant::now() + Duration::from_millis(timeout_ms.max(0) as u64);
        loop {
            let mut revents: Vec<i16> = Vec::with_capacity(interests.len());
            let mut ready = false;
            for (events, p) in interests {
                let r = if events & POLLIN != 0 { input_ready(p) } else { 0 };
                if r != 0 {
                    ready = true;
                }
                revents.push(r);
            }
            if ready || Instant::now() >= deadline {
                for (i, (events, p)) in interests.iter().enumerate() {
                    match p {
                        // Unpeekable: report the registered interest;
                        // the non-blocking attempt sorts out the truth.
                        Probe::Assume => revents[i] |= events,
                        _ => revents[i] |= events & POLLOUT,
                    }
                }
                return revents;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

#[cfg(unix)]
fn raw_fd<T: std::os::fd::AsRawFd>(t: &T) -> i32 {
    t.as_raw_fd()
}
#[cfg(not(unix))]
fn raw_fd<T>(_t: &T) -> i32 {
    0
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Reactor sizing knobs. Environment overrides (`from_env`):
/// `SIMPLEXMAP_MAX_FRAME`, `SIMPLEXMAP_MAX_CONNS`,
/// `SIMPLEXMAP_SWEEP_WINDOW`, `SIMPLEXMAP_SWEEP_JOBS_MAX`,
/// `SIMPLEXMAP_STORE_CAP`, `SIMPLEXMAP_STORE_TTL_SECS`,
/// `SIMPLEXMAP_JOB_TIMEOUT_MS`, `SIMPLEXMAP_JOB_RETRY_MAX`.
#[derive(Clone, Copy, Debug)]
pub struct ReactorConfig {
    pub queue: QueueConfig,
    /// Largest accepted request frame in bytes (capped reader).
    pub max_frame: usize,
    /// Accepted-connection ceiling; excess connections are refused.
    pub max_conns: usize,
    /// Default per-sweep in-flight window (overridable per request).
    pub sweep_window: usize,
    /// Row ceiling for one sweep expansion.
    pub max_sweep_jobs: usize,
    /// Active (unfinished) sweeps allowed per connection; up to twice
    /// this many sweep-id aliases stay addressable per connection
    /// (tokens are never bounded per connection — the store is the
    /// global bound).
    pub max_sweeps_per_conn: usize,
    /// Write-backlog level that pauses reads + result transfer.
    pub soft_watermark: usize,
    /// Write-backlog level that drops the connection.
    pub hard_cap: usize,
    /// Results-store row capacity (pre-reserved per sweep at admission).
    pub store_rows_cap: usize,
    /// Finished store entries idle longer than this age out.
    pub store_ttl_secs: u64,
    /// Start deadline per submitted job (expired-in-queue ⇒ retry/fail).
    pub job_timeout_ms: u64,
    /// Re-enqueues allowed per sweep row after a retryable failure.
    pub job_retry_max: u32,
}

impl Default for ReactorConfig {
    fn default() -> ReactorConfig {
        ReactorConfig {
            queue: QueueConfig::default(),
            max_frame: DEFAULT_MAX_FRAME,
            max_conns: 4096,
            sweep_window: 16,
            max_sweep_jobs: 4096,
            max_sweeps_per_conn: 8,
            soft_watermark: 256 * 1024,
            hard_cap: 8 * 1024 * 1024,
            store_rows_cap: 65_536,
            store_ttl_secs: 600,
            job_timeout_ms: 300_000,
            job_retry_max: 1,
        }
    }
}

impl ReactorConfig {
    pub fn from_env() -> ReactorConfig {
        let d = ReactorConfig::default();
        ReactorConfig {
            max_frame: env_usize("SIMPLEXMAP_MAX_FRAME", d.max_frame).max(64),
            max_conns: env_usize("SIMPLEXMAP_MAX_CONNS", d.max_conns).max(1),
            sweep_window: env_usize("SIMPLEXMAP_SWEEP_WINDOW", d.sweep_window).max(1),
            max_sweep_jobs: env_usize("SIMPLEXMAP_SWEEP_JOBS_MAX", d.max_sweep_jobs).max(1),
            store_rows_cap: env_usize("SIMPLEXMAP_STORE_CAP", d.store_rows_cap).max(1),
            store_ttl_secs: env_u64("SIMPLEXMAP_STORE_TTL_SECS", d.store_ttl_secs),
            job_timeout_ms: env_u64("SIMPLEXMAP_JOB_TIMEOUT_MS", d.job_timeout_ms),
            job_retry_max: env_u64("SIMPLEXMAP_JOB_RETRY_MAX", d.job_retry_max as u64) as u32,
            ..d
        }
    }

    fn store_config(&self) -> StoreConfig {
        StoreConfig {
            max_rows: self.store_rows_cap,
            ttl: Duration::from_secs(self.store_ttl_secs),
        }
    }
}

/// Per-request sweep options.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SweepOpts {
    pub stream: bool,
    pub window: usize,
    pub priority: Priority,
}

/// Expand a `sweep` request into its job rows (row-major:
/// workloads → maps → nbs) plus options. Pure — unit-tested without
/// sockets, and the contract the wire-vs-CLI differential test pins.
pub fn expand_sweep(
    req: &Json,
    default_window: usize,
    max_jobs: usize,
) -> Result<(Vec<Job>, SweepOpts), String> {
    let str_list = |key: &str| -> Result<Option<Vec<String>>, String> {
        match req.get(key) {
            None => Ok(None),
            Some(Json::Arr(items)) => {
                let mut out = Vec::with_capacity(items.len());
                for it in items {
                    out.push(
                        it.as_str()
                            .ok_or(format!("{key} must be an array of strings"))?
                            .to_string(),
                    );
                }
                Ok(Some(out))
            }
            Some(_) => Err(format!("{key} must be an array of strings")),
        }
    };
    let workload_names = str_list("workloads")?.ok_or("sweep needs workloads: [\"edm\", …]")?;
    if workload_names.is_empty() {
        return Err("sweep needs at least one workload".into());
    }
    let mut workloads = Vec::with_capacity(workload_names.len());
    for name in &workload_names {
        workloads.push(WorkloadKind::parse(name).ok_or(format!("unknown workload {name}"))?);
    }
    let nbs: Vec<u64> = match req.get("nbs") {
        Some(Json::Arr(items)) if !items.is_empty() => {
            let mut out = Vec::with_capacity(items.len());
            for it in items {
                out.push(it.as_u64().ok_or("nbs must be an array of integers")?);
            }
            out
        }
        _ => return Err("sweep needs nbs: [8, 16, …]".into()),
    };
    let maps = str_list("maps")?;
    let backend = match req.get("backend").and_then(Json::as_str) {
        None => crate::coordinator::job::BackendKind::Parallel,
        Some(s) => crate::coordinator::job::BackendKind::parse(s)
            .ok_or(format!("unknown backend {s}"))?,
    };
    let seed = req.get("seed").and_then(Json::as_u64).unwrap_or(42);
    let stream = req.get("stream").and_then(Json::as_bool).unwrap_or(true);
    let window = req
        .get("window")
        .and_then(Json::as_u64)
        .map(|w| (w as usize).clamp(1, 1024))
        .unwrap_or(default_window);
    let priority = match req.get("priority").and_then(Json::as_str) {
        None => Priority::Normal,
        Some(s) => Priority::parse(s).ok_or(format!("unknown priority {s} (high|normal|low)"))?,
    };

    let mut jobs = Vec::new();
    for w in &workloads {
        let maps_for_w = match &maps {
            Some(m) => m.clone(),
            None => w.sweep_maps(),
        };
        for map in &maps_for_w {
            for &nb in &nbs {
                jobs.push(Job {
                    workload: *w,
                    nb,
                    map: map.clone(),
                    backend,
                    seed,
                });
            }
        }
    }
    if jobs.is_empty() {
        return Err("sweep expanded to zero jobs".into());
    }
    if jobs.len() > max_jobs {
        return Err(format!(
            "sweep expands to {} jobs, over the {max_jobs} limit — split it",
            jobs.len()
        ));
    }
    Ok((
        jobs,
        SweepOpts {
            stream,
            window,
            priority,
        },
    ))
}

/// A finished job travelling from a queue worker back to the loop.
struct Done {
    /// Connection the job belongs to (plain `run` routing; sweep rows
    /// route by sweep id — their sweep outlives any connection).
    token: u64,
    /// Reply slot (plain `run` only; sweeps reply through their own slot).
    req: u64,
    /// `(sweep id, row index)` when the job belongs to a sweep.
    sweep: Option<(u64, usize)>,
    result: Result<JobResult, ScheduleError>,
}

/// Completion mailbox + self-wake: queue workers push here and nudge
/// the sleeping `poll` with a loopback datagram.
struct Mailbox {
    done: Mutex<Vec<Done>>,
    wake: UdpSocket,
}

impl Mailbox {
    fn push(&self, d: Done) {
        lock_unpoisoned(&self.done).push(d);
        // A full socket buffer means wake datagrams are already
        // pending, which is all a wake needs to guarantee.
        let _ = self.wake.send(&[1]);
    }
}

/// One in-order reply slot: responses leave the connection in request
/// order, so a pipelined client can match frames to requests.
struct Slot {
    req: u64,
    frames: VecDeque<String>,
    done: bool,
}

/// One live sweep fan-out. Process-global (keyed by a global sweep
/// id), not per-connection: rows land in the [`ResultsStore`] under
/// `token`, and `owner` is merely the connection currently receiving
/// stream/done frames — cleared when that connection dies, at which
/// point the fan-out continues detached and the results stay
/// retrievable by token.
struct SweepRun {
    token: String,
    /// Connection receiving stream frames (`None` once it vanished).
    owner: Option<u64>,
    /// The owner's slot the ack/stream/done frames flow through.
    req: u64,
    jobs: Vec<Job>,
    next_submit: usize,
    in_flight: usize,
    /// Row indices awaiting re-submission after a retryable failure.
    retry: VecDeque<usize>,
    /// Retries consumed per row (bounded by `job_retry_max`).
    retries_used: Vec<u8>,
    completed: u64,
    failed: u64,
    stream: bool,
    window: usize,
    priority: Priority,
    /// Fairness lane (the originating connection's token — kept after
    /// detach so a big orphaned sweep still cannot starve other lanes).
    lane: u64,
    started: Instant,
    span: Option<ActiveSpan>,
}

struct Conn {
    stream: TcpStream,
    fd: i32,
    frames: FrameBuffer,
    out: Vec<u8>,
    slots: VecDeque<Slot>,
    /// Bytes sitting in not-yet-transferred slot frames (`out` bytes
    /// are counted separately); the two together are the write backlog
    /// the watermark/hard-cap act on.
    pending_bytes: usize,
    next_req: u64,
    /// sweep-id → token aliases this connection may page by bare id
    /// (`{"cmd":"results","sweep":S}`). Sweep ids are global, so this
    /// doubles as the authorization check: only the starting
    /// connection can address a sweep by id — everyone else needs the
    /// token capability. Bounded; the oldest alias drops first (the
    /// token always keeps working).
    sweep_tokens: BTreeMap<u64, String>,
    inflight_runs: usize,
    read_closed: bool,
    dead: bool,
    span: Option<ActiveSpan>,
}

impl Conn {
    fn new(stream: TcpStream, max_frame: usize) -> Conn {
        let fd = raw_fd(&stream);
        Conn {
            stream,
            fd,
            frames: FrameBuffer::new(max_frame),
            out: Vec::new(),
            slots: VecDeque::new(),
            pending_bytes: 0,
            next_req: 0,
            sweep_tokens: BTreeMap::new(),
            inflight_runs: 0,
            read_closed: false,
            dead: false,
            span: Some(span::global().start("server", "conn", 0)),
        }
    }

    fn backlog(&self) -> usize {
        self.out.len() + self.pending_bytes
    }

    fn paused(&self, cfg: &ReactorConfig) -> bool {
        self.backlog() > cfg.soft_watermark
    }

    fn new_slot(&mut self) -> u64 {
        let req = self.next_req;
        self.next_req += 1;
        self.slots.push_back(Slot {
            req,
            frames: VecDeque::new(),
            done: false,
        });
        req
    }

    fn push_frame_text(&mut self, req: u64, text: String) {
        if let Some(slot) = self.slots.iter_mut().find(|s| s.req == req) {
            self.pending_bytes += text.len() + 1;
            slot.frames.push_back(text);
        }
    }

    fn push_frame(&mut self, req: u64, j: Json) {
        self.push_frame_text(req, j.to_string_compact());
    }

    fn finish_slot(&mut self, req: u64) {
        if let Some(slot) = self.slots.iter_mut().find(|s| s.req == req) {
            slot.done = true;
        }
    }

    /// One-frame reply: push and close the slot.
    fn reply(&mut self, req: u64, j: Json) {
        self.push_frame(req, j);
        self.finish_slot(req);
    }

    /// Everything delivered, nothing running: safe to forget once the
    /// client side has stopped talking (or shutdown wants us gone).
    /// A streaming sweep holds its slot open until the done frame, so
    /// such a connection is never idle mid-sweep; non-streaming sweeps
    /// deliberately survive their connection (they detach into the
    /// store), so they don't pin the connection here.
    fn idle(&self) -> bool {
        self.out.is_empty() && self.slots.is_empty() && self.inflight_runs == 0
    }

    /// Transfer frames from the front slot(s) into the write buffer,
    /// strictly in request order, up to the soft watermark.
    fn fill_out(&mut self, cfg: &ReactorConfig) {
        while self.out.len() < cfg.soft_watermark {
            let Some(front) = self.slots.front_mut() else {
                break;
            };
            if let Some(f) = front.frames.pop_front() {
                self.pending_bytes -= f.len() + 1;
                self.out.extend_from_slice(f.as_bytes());
                self.out.push(b'\n');
            } else if front.done {
                self.slots.pop_front();
            } else {
                break;
            }
        }
    }

    fn write_out(&mut self) {
        while !self.out.is_empty() {
            match self.stream.write(&self.out) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.out.drain(..n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
    }

    fn read_in(&mut self) {
        let mut buf = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                // lint: allow(panic, the Read contract guarantees n is at most the buffer length)
                Ok(n) => self.frames.push(&buf[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
    }
}

/// Durable sweep token: `swp-{sid}-{nonce}`. The nonce mixes a
/// per-server salt so tokens are not guessable from the (sequential)
/// sweep id alone — a token is a capability, the id is not.
fn fresh_token(sid: u64, salt: u64) -> String {
    let mut mix = SplitMix64::new(salt ^ sid.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    format!("swp-{sid}-{:08x}", mix.next_u64() as u32)
}

/// Whether a failed row deserves a trip through the bounded retry
/// path: queue expiry and runtime faults are environmental (another
/// attempt can land differently); everything else — unknown map,
/// unsupported size, shutdown — fails identically every time.
fn retryable(e: &ScheduleError) -> bool {
    matches!(e, ScheduleError::Expired(_) | ScheduleError::Runtime(_))
}

/// The poll-reactor server. Same wire protocol as the threaded
/// [`Server`](crate::coordinator::server::Server) (shared
/// [`dispatch_control`]) plus the streaming `sweep`/`results` pair.
pub struct Reactor {
    ctx: Arc<ServerCtx>,
    cfg: ReactorConfig,
}

impl Reactor {
    pub fn new(scheduler: Arc<Scheduler>) -> Reactor {
        Reactor::with_config(scheduler, ReactorConfig::default())
    }

    pub fn with_config(scheduler: Arc<Scheduler>, cfg: ReactorConfig) -> Reactor {
        Reactor {
            ctx: Arc::new(ServerCtx::new(scheduler, cfg.queue)),
            cfg,
        }
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.ctx.shutdown)
    }

    /// Bind and multiplex until a shutdown command arrives. Reports the
    /// bound address through `on_bound` (lets tests/examples use port 0).
    pub fn serve(
        &self,
        addr: &str,
        on_bound: impl FnOnce(std::net::SocketAddr),
    ) -> std::io::Result<()> {
        let cfg = self.cfg;
        let ctx = &self.ctx;
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        log_info!("reactor", "listening on {local}");
        on_bound(local);

        // Loopback self-wake pair: workers signal completions through
        // `mailbox.wake` → `wake_rx` becomes readable → poll returns.
        let wake_rx = UdpSocket::bind("127.0.0.1:0")?;
        wake_rx.set_nonblocking(true)?;
        let wake_tx = UdpSocket::bind("127.0.0.1:0")?;
        wake_tx.set_nonblocking(true)?;
        wake_tx.connect(wake_rx.local_addr()?)?;
        let mailbox = Arc::new(Mailbox {
            done: Mutex::new(Vec::new()),
            wake: wake_tx,
        });

        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut sweeps: HashMap<u64, SweepRun> = HashMap::new();
        let mut store = ResultsStore::new(cfg.store_config());
        let mut next_token: u64 = 1;
        let mut next_sid: u64 = 1;
        // Per-server token salt: wall clock ⊕ pid, so two servers (or
        // two runs) never mint the same token for the same sid.
        let salt = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5EED)
            ^ (std::process::id() as u64).rotate_left(32);
        let mut fds: Vec<sys::PollFd> = Vec::new();
        let mut order: Vec<u64> = Vec::new();
        let mut grace_rounds_left: Option<u32> = None;

        loop {
            let shutting_down = ctx.shutdown.load(Ordering::Relaxed);
            if shutting_down && grace_rounds_left.is_none() {
                grace_rounds_left = Some(50); // ≈5 s at the 100 ms tick
            }

            fds.clear();
            order.clear();
            let accepting = !shutting_down && conns.len() < cfg.max_conns;
            fds.push(sys::PollFd {
                fd: raw_fd(&listener),
                events: if accepting { sys::POLLIN } else { 0 },
                revents: 0,
            });
            fds.push(sys::PollFd {
                fd: raw_fd(&wake_rx),
                events: sys::POLLIN,
                revents: 0,
            });
            for (tok, c) in conns.iter() {
                let mut ev = 0;
                if !c.read_closed && !c.paused(&cfg) {
                    ev |= sys::POLLIN;
                }
                if !c.out.is_empty() {
                    ev |= sys::POLLOUT;
                }
                fds.push(sys::PollFd {
                    fd: c.fd,
                    events: ev,
                    revents: 0,
                });
                order.push(*tok);
            }

            #[cfg(unix)]
            sys::poll_wait(&mut fds, 100)?;
            #[cfg(not(unix))]
            {
                let revents = {
                    let mut interests: Vec<(i16, probe::Probe<'_>)> =
                        Vec::with_capacity(fds.len());
                    for (i, f) in fds.iter().enumerate() {
                        let p = match i {
                            0 => probe::Probe::Assume,
                            1 => probe::Probe::Udp(&wake_rx),
                            _ => match order.get(i - 2).and_then(|tok| conns.get(tok)) {
                                Some(c) => probe::Probe::Tcp(&c.stream),
                                None => probe::Probe::Assume,
                            },
                        };
                        interests.push((f.events, p));
                    }
                    probe::poll_probed(&interests, 100)
                };
                for (f, r) in fds.iter_mut().zip(revents) {
                    f.revents = r;
                }
            }

            let now = Instant::now();

            // Drain wake datagrams (their only content is "look at the
            // mailbox").
            if fds.get(1).is_some_and(|f| f.revents & (sys::POLLIN | sys::POLLERR) != 0) {
                let mut sink = [0u8; 64];
                while wake_rx.recv(&mut sink).is_ok() {}
            }

            // Completions from the queue workers.
            let batch = std::mem::take(&mut *lock_unpoisoned(&mailbox.done));
            for d in batch {
                match d.sweep {
                    Some((sid, idx)) => {
                        // Sweep rows route by global sweep id — the
                        // sweep (and its store entry) outlive any
                        // individual connection.
                        apply_sweep_result(
                            &mut conns, &mut sweeps, &mut store, ctx, &cfg, sid, idx, d.result,
                            true,
                        );
                    }
                    None => match conns.get_mut(&d.token) {
                        Some(c) => {
                            c.inflight_runs = c.inflight_runs.saturating_sub(1);
                            let reply = match d.result {
                                Ok(r) => {
                                    ctx.scheduler
                                        .metrics
                                        .results_delivered
                                        .fetch_add(1, Ordering::Relaxed);
                                    Json::obj(vec![
                                        ("ok", true.into()),
                                        ("result", r.to_json()),
                                    ])
                                }
                                Err(e) => {
                                    ctx.scheduler
                                        .metrics
                                        .jobs_failed
                                        .fetch_add(1, Ordering::Relaxed);
                                    err_reply(e.to_string())
                                }
                            };
                            c.reply(d.req, reply);
                        }
                        None => {
                            // Client vanished mid-job. The old reactor
                            // dropped the result on the floor here;
                            // now an Ok result is stashed under a
                            // derived token so a reconnecting client
                            // (or operator) can still fetch it, and
                            // every outcome is accounted.
                            let metrics = &ctx.scheduler.metrics;
                            match d.result {
                                Ok(r) => {
                                    let frame = Json::obj(vec![
                                        ("ok", true.into()),
                                        ("result", r.to_json()),
                                    ]);
                                    let run_token = format!("run-{}-{}", d.token, d.req);
                                    match store.stash(&run_token, frame, true, now) {
                                        Ok(evicted) => {
                                            metrics
                                                .results_stored
                                                .fetch_add(1, Ordering::Relaxed);
                                            metrics
                                                .store_evictions
                                                .fetch_add(evicted as u64, Ordering::Relaxed);
                                            log_info!(
                                                "reactor",
                                                "stashed orphaned run result as {run_token}"
                                            );
                                        }
                                        Err(_) => {
                                            metrics
                                                .orphaned_results
                                                .fetch_add(1, Ordering::Relaxed);
                                            log_warn!(
                                                "reactor",
                                                "store full; orphaned run result dropped \
                                                 (counted in orphaned_results)"
                                            );
                                        }
                                    }
                                }
                                Err(_) => {
                                    metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    },
                }
            }

            // New connections.
            if accepting && fds.first().is_some_and(|f| f.revents & sys::POLLIN != 0) {
                loop {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            if conns.len() >= cfg.max_conns {
                                drop(stream);
                                log_warn!("reactor", "refusing {peer}: connection limit");
                                continue;
                            }
                            if stream.set_nonblocking(true).is_err() {
                                continue;
                            }
                            let _ = stream.set_nodelay(true);
                            ctx.scheduler
                                .metrics
                                .conns_accepted
                                .fetch_add(1, Ordering::Relaxed);
                            let tok = next_token;
                            next_token += 1;
                            conns.insert(tok, Conn::new(stream, cfg.max_frame));
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(e) => return Err(e),
                    }
                }
            }

            // Socket readiness per connection.
            for (i, tok) in order.iter().enumerate() {
                let Some(revents) = fds.get(i + 2).map(|f| f.revents) else {
                    continue;
                };
                let Some(c) = conns.get_mut(tok) else { continue };
                if revents & sys::POLLERR != 0 {
                    c.dead = true;
                    continue;
                }
                if revents & (sys::POLLIN | sys::POLLHUP) != 0 && !c.read_closed {
                    c.read_in();
                }
            }

            // Frame processing per connection.
            for (tok, c) in conns.iter_mut() {
                if c.dead {
                    continue;
                }
                while !c.paused(&cfg) {
                    match c.frames.next_frame() {
                        Some(Frame::Line(line)) => {
                            if line.trim().is_empty() {
                                continue;
                            }
                            handle_request(
                                c, *tok, &line, ctx, &mailbox, &cfg, &mut sweeps, &mut store,
                                &mut next_sid, salt,
                            );
                        }
                        Some(Frame::Oversized { limit }) => {
                            ctx.scheduler
                                .metrics
                                .frames_oversized
                                .fetch_add(1, Ordering::Relaxed);
                            let req = c.new_slot();
                            c.reply(
                                req,
                                err_reply(format!("frame exceeds {limit} byte limit")),
                            );
                        }
                        None => break,
                    }
                }
            }

            // Pump every live sweep (owned or detached) up to its
            // window, then land any submit-time hard failures.
            let failures = pump_sweeps(&conns, &mut sweeps, ctx, &mailbox, &cfg);
            for (sid, idx, e) in failures {
                apply_sweep_result(
                    &mut conns, &mut sweeps, &mut store, ctx, &cfg, sid, idx, Err(e), false,
                );
            }

            // Reply transfer and writes.
            for c in conns.values_mut() {
                if c.dead {
                    continue;
                }
                c.fill_out(&cfg);
                c.write_out();
                if c.backlog() > cfg.hard_cap {
                    ctx.scheduler
                        .metrics
                        .slow_client_drops
                        .fetch_add(1, Ordering::Relaxed);
                    log_warn!("reactor", "dropping slow client ({} bytes backlog)", c.backlog());
                    c.dead = true;
                }
            }

            // Reap: broken connections, and quiet ones whose client
            // already said goodbye. Sweeps they own detach (owner
            // cleared) and keep fanning out into the store.
            let force_close = grace_rounds_left == Some(0);
            let mut reaped: Vec<u64> = Vec::new();
            conns.retain(|tok, c| {
                let quiet = c.idle() && (c.read_closed || shutting_down);
                let gone = c.dead || quiet || force_close;
                if gone {
                    if let Some(sp) = c.span.take() {
                        span::global().finish(sp);
                    }
                    ctx.scheduler
                        .metrics
                        .conns_closed
                        .fetch_add(1, Ordering::Relaxed);
                    reaped.push(*tok);
                }
                !gone
            });
            if !reaped.is_empty() {
                for run in sweeps.values_mut() {
                    if run.owner.is_some_and(|t| reaped.contains(&t)) {
                        run.owner = None;
                        log_info!(
                            "reactor",
                            "sweep {} detached (client gone); results stay under its token",
                            run.token
                        );
                    }
                }
            }

            // Store housekeeping: age out abandoned finished sweeps and
            // publish the occupancy gauges.
            let aged = store.evict_expired(now);
            if aged > 0 {
                ctx.scheduler
                    .metrics
                    .store_evictions
                    .fetch_add(aged as u64, Ordering::Relaxed);
            }
            ctx.scheduler
                .metrics
                .store_rows
                .store(store.rows_used() as u64, Ordering::Relaxed);
            ctx.scheduler
                .metrics
                .store_sweeps
                .store(store.sweeps() as u64, Ordering::Relaxed);

            if let Some(g) = grace_rounds_left.as_mut() {
                // Exit once every connection is gone *and* every sweep
                // has drained into the store — or the grace runs out.
                if conns.is_empty() && sweeps.is_empty() {
                    break;
                }
                if *g == 0 {
                    break;
                }
                *g -= 1;
            }
        }

        ctx.queue.shutdown();
        log_info!("reactor", "shut down");
        Ok(())
    }
}

/// One framed request → reply frames into the request's slot. Control
/// commands share [`dispatch_control`] with the threaded server;
/// `run`/`sweep`/`results` are the reactor's own non-blocking paths.
#[allow(clippy::too_many_arguments)]
fn handle_request(
    c: &mut Conn,
    conn_tok: u64,
    line: &str,
    ctx: &ServerCtx,
    mailbox: &Arc<Mailbox>,
    cfg: &ReactorConfig,
    sweeps: &mut HashMap<u64, SweepRun>,
    store: &mut ResultsStore,
    next_sid: &mut u64,
    salt: u64,
) {
    let req_id = c.new_slot();
    let req = match json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            c.reply(req_id, err_reply(format!("bad json: {e}")));
            return;
        }
    };
    if let Some(reply) = dispatch_control(&req, ctx) {
        c.reply(req_id, reply);
        return;
    }
    match req.get("cmd").and_then(Json::as_str) {
        Some("run") => handle_run(c, conn_tok, req_id, &req, ctx, mailbox, cfg),
        Some("sweep") => {
            handle_sweep(c, conn_tok, req_id, &req, ctx, cfg, sweeps, store, next_sid, salt)
        }
        Some("results") => handle_results(c, req_id, &req, store),
        _ => c.reply(
            req_id,
            err_reply("unknown cmd (ping|run|sweep|results|maps|metrics|trace|shutdown)".into()),
        ),
    }
}

/// Non-blocking `run`: submit through the queue (with the start
/// deadline), let the completion route back through the mailbox.
fn handle_run(
    c: &mut Conn,
    conn_tok: u64,
    req_id: u64,
    req: &Json,
    ctx: &ServerCtx,
    mailbox: &Arc<Mailbox>,
    cfg: &ReactorConfig,
) {
    ctx.scheduler
        .metrics
        .jobs_accepted
        .fetch_add(1, Ordering::Relaxed);
    let Some(job) = Job::from_json(req) else {
        c.reply(req_id, err_reply("invalid job (need workload, nb, map)".into()));
        return;
    };
    let priority = match req.get("priority").and_then(Json::as_str) {
        None => Priority::Normal,
        Some(s) => match Priority::parse(s) {
            Some(p) => p,
            None => {
                c.reply(
                    req_id,
                    err_reply(format!("unknown priority {s} (high|normal|low)")),
                );
                return;
            }
        },
    };
    let mb = Arc::clone(mailbox);
    let deadline = Some(Instant::now() + Duration::from_millis(cfg.job_timeout_ms));
    let outcome = ctx.queue.submit_async_with_deadline(
        job,
        priority,
        conn_tok,
        deadline,
        move |result| {
            mb.push(Done {
                token: conn_tok,
                req: req_id,
                sweep: None,
                result,
            });
        },
    );
    match outcome {
        Ok(()) => c.inflight_runs += 1,
        Err(e) => {
            ctx.scheduler
                .metrics
                .jobs_failed
                .fetch_add(1, Ordering::Relaxed);
            c.reply(req_id, err_reply(e.to_string()));
        }
    }
}

/// Start a sweep: expand, reserve store rows under a fresh token (the
/// bounded-store pushback happens *here*, before any work is queued),
/// ack with the token, and register the global run for the pump.
#[allow(clippy::too_many_arguments)]
fn handle_sweep(
    c: &mut Conn,
    conn_tok: u64,
    req_id: u64,
    req: &Json,
    ctx: &ServerCtx,
    cfg: &ReactorConfig,
    sweeps: &mut HashMap<u64, SweepRun>,
    store: &mut ResultsStore,
    next_sid: &mut u64,
    salt: u64,
) {
    let (jobs, opts) = match expand_sweep(req, cfg.sweep_window, cfg.max_sweep_jobs) {
        Ok(x) => x,
        Err(e) => {
            c.reply(req_id, err_reply(e));
            return;
        }
    };
    let active = sweeps
        .values()
        .filter(|r| r.owner == Some(conn_tok))
        .count();
    if active >= cfg.max_sweeps_per_conn {
        c.reply(
            req_id,
            err_reply(format!(
                "too many active sweeps ({active}); wait for one to finish"
            )),
        );
        return;
    }
    let n = jobs.len();
    let sid = *next_sid;
    let token = fresh_token(sid, salt);
    match store.admit(&token, n, Instant::now()) {
        Ok(evicted) => {
            if evicted > 0 {
                ctx.scheduler
                    .metrics
                    .store_evictions
                    .fetch_add(evicted as u64, Ordering::Relaxed);
            }
        }
        Err(e) => {
            // Typed admission pushback: the sweep was never started, so
            // nothing is counted as accepted and nothing can be lost.
            c.reply(req_id, err_reply(e.to_string()));
            return;
        }
    }
    *next_sid += 1;
    ctx.scheduler
        .metrics
        .sweeps_started
        .fetch_add(1, Ordering::Relaxed);
    ctx.scheduler
        .metrics
        .jobs_accepted
        .fetch_add(n as u64, Ordering::Relaxed);
    // Bounded id→token alias table; dropping an old alias never loses
    // results — the token itself keeps paging.
    while c.sweep_tokens.len() >= cfg.max_sweeps_per_conn * 2 {
        let Some(oldest) = c.sweep_tokens.keys().next().copied() else {
            break;
        };
        c.sweep_tokens.remove(&oldest);
    }
    c.sweep_tokens.insert(sid, token.clone());
    c.push_frame(
        req_id,
        Json::obj(vec![
            ("ok", true.into()),
            ("sweep", sid.into()),
            ("token", token.clone().into()),
            ("jobs", n.into()),
            ("streaming", opts.stream.into()),
        ]),
    );
    if !opts.stream {
        // Non-streaming: the ack is the whole reply; rows are paged
        // later via `results` (by id on this connection, by token on
        // any connection).
        c.finish_slot(req_id);
    }
    sweeps.insert(
        sid,
        SweepRun {
            token,
            owner: Some(conn_tok),
            req: req_id,
            jobs,
            next_submit: 0,
            in_flight: 0,
            retry: VecDeque::new(),
            retries_used: vec![0; n],
            completed: 0,
            failed: 0,
            stream: opts.stream,
            window: opts.window,
            priority: opts.priority,
            lane: conn_tok,
            started: Instant::now(),
            span: Some(span::global().start("server", "sweep", 0)),
        },
    );
}

/// Page stored results by durable token (any connection — this is the
/// reconnect path) or by bare sweep id (only the connection that
/// started it).
fn handle_results(c: &mut Conn, req_id: u64, req: &Json, store: &mut ResultsStore) {
    let explicit = req.get("token").and_then(Json::as_str).map(str::to_string);
    let sid = req.get("sweep").and_then(Json::as_u64);
    let (token, sid_for_reply) = match (explicit, sid) {
        (Some(t), s) => (t, s),
        (None, Some(s)) => match c.sweep_tokens.get(&s) {
            Some(t) => (t.clone(), Some(s)),
            None => {
                c.reply(
                    req_id,
                    err_reply(format!(
                        "unknown sweep {s} (ids are per-connection — reconnecting \
                         clients page by token)"
                    )),
                );
                return;
            }
        },
        (None, None) => {
            c.reply(req_id, err_reply("results needs a sweep id or token".into()));
            return;
        }
    };
    let cursor = req.get("cursor").and_then(Json::as_u64).unwrap_or(0) as usize;
    let limit = req
        .get("limit")
        .and_then(Json::as_u64)
        .unwrap_or(64)
        .clamp(1, 256) as usize;
    let Some(page) = store.page(&token, cursor, limit, Instant::now()) else {
        c.reply(
            req_id,
            err_reply(format!("unknown token {token} (expired or evicted)")),
        );
        return;
    };
    let mut fields: Vec<(&str, Json)> = vec![("ok", true.into())];
    if let Some(s) = sid_for_reply {
        fields.push(("sweep", s.into()));
    }
    fields.push(("token", token.into()));
    fields.push(("jobs", page.jobs.into()));
    fields.push(("cursor", page.cursor.into()));
    fields.push(("done", page.done.into()));
    fields.push(("completed", page.completed.into()));
    fields.push(("failed", page.failed.into()));
    fields.push(("results", Json::Arr(page.results)));
    fields.push((
        "next_cursor",
        match page.next_cursor {
            Some(nc) => nc.into(),
            None => Json::Null,
        },
    ));
    c.reply(req_id, Json::obj(fields));
}

/// Keep every live sweep (owned or detached) at its in-flight window.
/// Retried rows resubmit ahead of fresh ones through the same
/// priority/fairness lane. `QueueFull` stops pumping for this tick
/// (state untouched — the row is only peeked); hard submit failures
/// are returned for the caller to land as row results.
fn pump_sweeps(
    conns: &HashMap<u64, Conn>,
    sweeps: &mut HashMap<u64, SweepRun>,
    ctx: &ServerCtx,
    mailbox: &Arc<Mailbox>,
    cfg: &ReactorConfig,
) -> Vec<(u64, usize, ScheduleError)> {
    let mut failures = Vec::new();
    'runs: for (&sid, run) in sweeps.iter_mut() {
        if run.stream {
            // Streaming sweeps throttle on their owner's backpressure;
            // once detached they drain into the store unthrottled.
            if let Some(owner) = run.owner {
                if conns.get(&owner).is_some_and(|c| c.paused(cfg)) {
                    continue;
                }
            }
        }
        while run.in_flight < run.window {
            let from_retry = run.retry.front().is_some();
            let idx = match run.retry.front().copied() {
                Some(i) => i,
                None if run.next_submit < run.jobs.len() => run.next_submit,
                None => break,
            };
            let Some(job) = run.jobs.get(idx).cloned() else {
                // An out-of-range index can only be a bookkeeping bug;
                // discard the slot rather than wedge the pump.
                if from_retry {
                    run.retry.pop_front();
                } else {
                    run.next_submit += 1;
                }
                continue;
            };
            let mb = Arc::clone(mailbox);
            let deadline = Some(Instant::now() + Duration::from_millis(cfg.job_timeout_ms));
            let outcome = ctx.queue.submit_async_with_deadline(
                job,
                run.priority,
                run.lane,
                deadline,
                move |result| {
                    mb.push(Done {
                        token: 0,
                        req: 0,
                        sweep: Some((sid, idx)),
                        result,
                    });
                },
            );
            match outcome {
                Ok(()) => {
                    run.in_flight += 1;
                    if from_retry {
                        run.retry.pop_front();
                    } else {
                        run.next_submit += 1;
                    }
                }
                Err(ScheduleError::QueueFull(_)) => break 'runs,
                Err(e) => {
                    if from_retry {
                        run.retry.pop_front();
                    } else {
                        run.next_submit += 1;
                    }
                    failures.push((sid, idx, e));
                }
            }
        }
    }
    failures
}

/// Land one sweep-row outcome: maybe re-enqueue (bounded retry), store
/// the row under the sweep's token, stream it to a live owner, and —
/// on the last row — finish the sweep (done frame, wall record, span).
#[allow(clippy::too_many_arguments)]
fn apply_sweep_result(
    conns: &mut HashMap<u64, Conn>,
    sweeps: &mut HashMap<u64, SweepRun>,
    store: &mut ResultsStore,
    ctx: &ServerCtx,
    cfg: &ReactorConfig,
    sid: u64,
    idx: usize,
    result: Result<JobResult, ScheduleError>,
    from_queue: bool,
) {
    let Some(run) = sweeps.get_mut(&sid) else {
        return;
    };
    let metrics = &ctx.scheduler.metrics;
    if from_queue {
        run.in_flight = run.in_flight.saturating_sub(1);
        if let Err(e) = &result {
            // An out-of-range idx (impossible by construction) reads
            // as retries-exhausted, so the row fails instead of
            // panicking the loop.
            let used = run.retries_used.get(idx).copied().unwrap_or(u8::MAX);
            if retryable(e) && u32::from(used) < cfg.job_retry_max {
                if let Some(u) = run.retries_used.get_mut(idx) {
                    *u = u.saturating_add(1);
                }
                metrics.jobs_retried.fetch_add(1, Ordering::Relaxed);
                run.retry.push_back(idx);
                return;
            }
        }
    }
    let ok = result.is_ok();
    let frame = match result {
        Ok(r) => Json::obj(vec![
            ("sweep", sid.into()),
            ("job", idx.into()),
            ("ok", true.into()),
            ("result", r.to_json()),
        ]),
        Err(e) => Json::obj(vec![
            ("sweep", sid.into()),
            ("job", idx.into()),
            ("ok", false.into()),
            ("error", e.to_string().into()),
        ]),
    };
    let text = (run.stream && run.owner.is_some()).then(|| frame.to_string_compact());
    match store.put(&run.token, idx, frame, ok, Instant::now()) {
        // A duplicate landing means this row is already fully
        // accounted — nothing further to apply.
        PutOutcome::Duplicate => return,
        PutOutcome::Unknown => {
            // The entry aged out (or was LRU-evicted) mid-sweep; the
            // result has nowhere durable to go.
            if ok {
                metrics.orphaned_results.fetch_add(1, Ordering::Relaxed);
            }
        }
        PutOutcome::Stored => {
            if ok {
                metrics.results_stored.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    if ok {
        run.completed += 1;
    } else {
        run.failed += 1;
        metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
    }
    metrics.sweep_jobs_completed.fetch_add(1, Ordering::Relaxed);
    let finished = run.completed + run.failed == run.jobs.len() as u64;
    if finished {
        metrics.sweeps_completed.fetch_add(1, Ordering::Relaxed);
        metrics.record_sweep_wall(run.started.elapsed().as_secs_f64());
        if let Some(sp) = run.span.take() {
            span::global().finish_with(sp, vec![("jobs", run.jobs.len().to_string())]);
        }
    }
    let owner = run.owner;
    let req = run.req;
    let stream = run.stream;
    let token = run.token.clone();
    let jobs_n = run.jobs.len();
    let (completed, failed) = (run.completed, run.failed);
    if finished {
        // The run's job is done; the *results* live on in the store
        // until paged + TTL-evicted.
        sweeps.remove(&sid);
    }
    if let Some(c) = owner.and_then(|t| conns.get_mut(&t)) {
        if let Some(t) = text {
            c.push_frame_text(req, t);
        }
        if finished && stream {
            c.push_frame(
                req,
                Json::obj(vec![
                    ("sweep", sid.into()),
                    ("done", true.into()),
                    ("jobs", jobs_n.into()),
                    ("completed", completed.into()),
                    ("failed", failed.into()),
                    ("token", token.into()),
                ]),
            );
            c.finish_slot(req);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expand_sweep_defaults_match_cli_sweep_roster() {
        let req = json::parse(r#"{"cmd":"sweep","workloads":["edm"],"nbs":[8]}"#).unwrap();
        let (jobs, opts) = expand_sweep(&req, 16, 4096).expect("valid sweep");
        let maps: Vec<&str> = jobs.iter().map(|j| j.map.as_str()).collect();
        assert_eq!(maps, vec!["bb", "lambda2", "enum2", "rb", "ries", "lambda-s"]);
        assert!(jobs.iter().all(|j| j.nb == 8 && j.seed == 42));
        assert_eq!(
            opts,
            SweepOpts {
                stream: true,
                window: 16,
                priority: Priority::Normal
            }
        );
    }

    #[test]
    fn expand_sweep_is_row_major_over_workloads_maps_nbs() {
        let req = json::parse(
            r#"{"cmd":"sweep","workloads":["edm","nbody"],"maps":["bb","lambda2"],
                "nbs":[4,8],"seed":7,"stream":false,"window":3,"priority":"low"}"#,
        )
        .unwrap();
        let (jobs, opts) = expand_sweep(&req, 16, 4096).unwrap();
        let rows: Vec<(String, String, u64)> = jobs
            .iter()
            .map(|j| (j.workload.name().to_string(), j.map.clone(), j.nb))
            .collect();
        let expect = [
            ("edm", "bb", 4),
            ("edm", "bb", 8),
            ("edm", "lambda2", 4),
            ("edm", "lambda2", 8),
            ("nbody", "bb", 4),
            ("nbody", "bb", 8),
            ("nbody", "lambda2", 4),
            ("nbody", "lambda2", 8),
        ];
        let expect: Vec<(String, String, u64)> = expect
            .iter()
            .map(|(w, m, n)| (w.to_string(), m.to_string(), *n))
            .collect();
        assert_eq!(rows, expect);
        assert_eq!(
            opts,
            SweepOpts {
                stream: false,
                window: 3,
                priority: Priority::Low
            }
        );
        assert!(jobs.iter().all(|j| j.seed == 7));
    }

    #[test]
    fn expand_sweep_rejects_malformed_requests() {
        let bad = [
            r#"{"cmd":"sweep"}"#,
            r#"{"cmd":"sweep","workloads":[],"nbs":[8]}"#,
            r#"{"cmd":"sweep","workloads":["edm"]}"#,
            r#"{"cmd":"sweep","workloads":["edm"],"nbs":[]}"#,
            r#"{"cmd":"sweep","workloads":["dance"],"nbs":[8]}"#,
            r#"{"cmd":"sweep","workloads":["edm"],"nbs":[8],"priority":"urgent"}"#,
            r#"{"cmd":"sweep","workloads":["edm"],"nbs":[8],"backend":"tpu"}"#,
            r#"{"cmd":"sweep","workloads":"edm","nbs":[8]}"#,
        ];
        for b in bad {
            let req = json::parse(b).unwrap();
            assert!(expand_sweep(&req, 16, 4096).is_err(), "{b}");
        }
    }

    #[test]
    fn expand_sweep_enforces_row_ceiling() {
        let req = json::parse(
            r#"{"cmd":"sweep","workloads":["edm"],"maps":["bb"],"nbs":[4,8,16,32]}"#,
        )
        .unwrap();
        assert!(expand_sweep(&req, 16, 4).is_ok());
        let err = expand_sweep(&req, 16, 3).unwrap_err();
        assert!(err.contains("over the 3"), "{err}");
    }

    #[cfg(unix)]
    #[test]
    fn poll_wait_times_out_with_no_fds() {
        let mut fds: Vec<sys::PollFd> = Vec::new();
        let t = Instant::now();
        let n = sys::poll_wait(&mut fds, 10).unwrap();
        assert_eq!(n, 0);
        assert!(t.elapsed().as_millis() >= 5, "timeout must actually wait");
    }

    #[test]
    fn reactor_config_env_floors() {
        let d = ReactorConfig::default();
        assert!(d.soft_watermark < d.hard_cap);
        assert!(d.max_sweep_jobs >= d.sweep_window);
        assert_eq!(d.store_ttl_secs, 600);
        assert_eq!(d.job_timeout_ms, 300_000);
        assert_eq!(d.job_retry_max, 1);
        let e = ReactorConfig::from_env();
        assert!(e.max_frame >= 64);
        assert!(e.sweep_window >= 1);
        assert!(e.store_rows_cap >= 1);
    }

    #[test]
    fn fresh_tokens_are_distinct_and_carry_the_sweep_id() {
        let a = fresh_token(1, 0xDEAD);
        let b = fresh_token(2, 0xDEAD);
        let c = fresh_token(1, 0xBEEF);
        assert!(a.starts_with("swp-1-"), "{a}");
        assert!(b.starts_with("swp-2-"), "{b}");
        assert_ne!(a, b);
        assert_ne!(a, c, "the salt must reach the nonce");
    }

    #[test]
    fn retryable_covers_expiry_and_runtime_only() {
        assert!(retryable(&ScheduleError::Expired(5)));
        assert!(!retryable(&ScheduleError::QueueFull(8)));
        assert!(!retryable(&ScheduleError::Shutdown));
        assert!(!retryable(&ScheduleError::UnknownMap("x".into(), 2)));
    }

    // ---- probe (the non-unix poll fallback) --------------------------
    //
    // The old fallback reported `revents = events` for every fd on
    // every call: phantom POLLIN with nothing to read, i.e. a busy
    // loop. These tests pin the fix on the primary platform.

    use std::net::{TcpListener as TL, TcpStream as TS, UdpSocket as US};

    fn tcp_pair() -> (TS, TS) {
        let l = TL::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TS::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        a.set_nonblocking(true).unwrap();
        b.set_nonblocking(true).unwrap();
        (a, b)
    }

    #[test]
    fn probe_reports_no_readiness_without_data_and_waits_out_the_timeout() {
        let (a, _b) = tcp_pair();
        let interests = vec![(probe::POLLIN, probe::Probe::Tcp(&a))];
        let t = Instant::now();
        let revents = probe::poll_probed(&interests, 30);
        assert!(
            t.elapsed().as_millis() >= 25,
            "must sleep, not busy-return: {:?}",
            t.elapsed()
        );
        assert_eq!(revents, vec![0], "no data ⇒ no phantom POLLIN");
    }

    #[test]
    fn probe_wakes_early_on_pending_tcp_data() {
        let (a, mut b) = tcp_pair();
        b.write_all(b"hi").unwrap();
        let interests = vec![(probe::POLLIN, probe::Probe::Tcp(&a))];
        let t = Instant::now();
        let revents = probe::poll_probed(&interests, 5_000);
        assert!(t.elapsed().as_millis() < 1_000, "pending data must cut the wait");
        assert_eq!(revents[0] & probe::POLLIN, probe::POLLIN);
    }

    #[test]
    fn probe_flags_hangup_on_peer_close() {
        let (a, b) = tcp_pair();
        drop(b);
        let revents = probe::poll_probed(&[(probe::POLLIN, probe::Probe::Tcp(&a))], 5_000);
        assert_eq!(revents[0] & probe::POLLIN, probe::POLLIN);
        assert_eq!(revents[0] & probe::POLLHUP, probe::POLLHUP);
    }

    #[test]
    fn probe_sees_udp_datagrams_and_folds_interests_only_at_exit() {
        let rx = US::bind("127.0.0.1:0").unwrap();
        rx.set_nonblocking(true).unwrap();
        let tx = US::bind("127.0.0.1:0").unwrap();
        tx.connect(rx.local_addr().unwrap()).unwrap();
        tx.send(&[1]).unwrap();
        let (a, _b) = tcp_pair();
        let interests = vec![
            (probe::POLLIN, probe::Probe::Udp(&rx)),
            // Write interest never wakes the loop early; it is folded
            // in at exit so the caller still attempts the write.
            (probe::POLLOUT, probe::Probe::Tcp(&a)),
            (probe::POLLIN, probe::Probe::Assume),
        ];
        let revents = probe::poll_probed(&interests, 5_000);
        assert_eq!(revents[0] & probe::POLLIN, probe::POLLIN);
        assert_eq!(revents[1], probe::POLLOUT);
        assert_eq!(revents[2], probe::POLLIN, "Assume reports its registered interest");
    }
}
