//! JSON-lines-over-TCP leader: accepts jobs from clients and runs them
//! through the bounded job queue on the unified engine. One line in →
//! one line out; concurrent clients execute in parallel on the queue's
//! worker pool instead of serializing behind each other.
//!
//! Protocol (request → response):
//! - `{"cmd":"ping"}` → `{"ok":true,"pong":true}`
//! - `{"cmd":"run","workload":"edm","nb":64,"map":"lambda2",
//!    "backend":"parallel","seed":7}` → `{"ok":true,"result":{…}}` —
//!    the job goes through the queue; a full queue answers
//!    `{"ok":false,"error":"job queue full …"}` (backpressure).
//!    `backend` is the execution axis `serial|parallel|pjrt` (the
//!    legacy name `rust` still parses as `parallel`); omitting it
//!    defaults to `parallel`. Results carry all eight launch-accounting
//!    fields (passes, launch_waves, blocks launched/filler/mapped,
//!    threads launched/mapped/predicated-off).
//! - `{"cmd":"maps"}` → `{"ok":true,"maps":{"2":[…],…,"8":[…],
//!   "gasket":[…]}}` — the registered map names per dimension (the
//!   unified registry), plus the non-simplex gasket domain under its
//!   own key.
//! - `{"cmd":"metrics"}` → `{"ok":true,"metrics":{…}}` — includes
//!   queue depth/wait and per-phase timings with p50/p90/p99/p99.9
//!   quantiles plus the labeled `(workload, map, backend)` series.
//!   `{"cmd":"metrics","format":"prometheus"}` answers
//!   `{"ok":true,"format":"prometheus","text":"…"}` with the same
//!   state as Prometheus text exposition.
//! - `{"cmd":"trace","n":256}` → `{"ok":true,"spans":N,"trace":{…}}` —
//!   the most recent `n` finished spans (default 256) as a Chrome
//!   trace-event document. An optional `"enable":true|false` toggles
//!   span recording first (so a client can switch tracing on, run
//!   jobs, and pull the trace without restarting the server).
//! - `{"cmd":"shutdown"}` → `{"ok":true}` and the server stops.
//!
//! Errors come back as `{"ok":false,"error":"…"}` — the connection
//! stays usable (a malformed job must not kill the leader).
//!
//! This module is the *threaded* mode (one blocking thread per
//! connection) — the measurable baseline. The poll-based multiplexer
//! in [`crate::coordinator::reactor`] serves the same protocol plus
//! the streaming `sweep`/`results` commands on a single thread; both
//! share [`ServerCtx`] and [`dispatch_control`].
//!
//! Memory-ordering policy: the atomics here are monotonic metrics
//! counters and the `shutdown` flag. The flag is polled by the accept
//! loop (bounded by the accept timeout) and checked per request — no
//! data is published through it — so every access is Relaxed.
// lint: atomics(Relaxed)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::coordinator::job::Job;
use crate::coordinator::queue::{JobQueue, QueueConfig};
use crate::coordinator::scheduler::Scheduler;
use crate::util::json::{self, Json};
use crate::{log_info, log_warn};

/// Everything a request needs: the scheduler (for metrics/maps), the
/// job queue (for runs), and the shutdown flag.
pub struct ServerCtx {
    pub scheduler: Arc<Scheduler>,
    pub queue: JobQueue,
    pub shutdown: Arc<AtomicBool>,
}

impl ServerCtx {
    pub fn new(scheduler: Arc<Scheduler>, queue_cfg: QueueConfig) -> ServerCtx {
        let queue = JobQueue::start(Arc::clone(&scheduler), queue_cfg);
        ServerCtx {
            scheduler,
            queue,
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }
}

pub struct Server {
    ctx: Arc<ServerCtx>,
}

impl Server {
    pub fn new(scheduler: Arc<Scheduler>) -> Server {
        Server::with_queue(scheduler, QueueConfig::default())
    }

    pub fn with_queue(scheduler: Arc<Scheduler>, cfg: QueueConfig) -> Server {
        Server {
            ctx: Arc::new(ServerCtx::new(scheduler, cfg)),
        }
    }

    /// Bind and serve until a shutdown command arrives. Returns the
    /// bound address through `on_bound` (lets tests use port 0).
    pub fn serve(
        &self,
        addr: &str,
        on_bound: impl FnOnce(std::net::SocketAddr),
    ) -> std::io::Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        log_info!("server", "listening on {local}");
        on_bound(local);
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.ctx.shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, peer)) => {
                    log_info!("server", "connection from {peer}");
                    self.ctx
                        .scheduler
                        .metrics
                        .conns_accepted
                        .fetch_add(1, Ordering::Relaxed);
                    let ctx = Arc::clone(&self.ctx);
                    conns.push(std::thread::spawn(move || {
                        if let Err(e) = handle_conn(stream, &ctx) {
                            log_warn!("server", "connection error: {e}");
                        }
                        ctx.scheduler
                            .metrics
                            .conns_closed
                            .fetch_add(1, Ordering::Relaxed);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => return Err(e),
            }
        }
        for c in conns {
            let _ = c.join();
        }
        self.ctx.queue.shutdown();
        log_info!("server", "shut down");
        Ok(())
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.ctx.shutdown)
    }
}

fn handle_conn(stream: TcpStream, ctx: &ServerCtx) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = dispatch(&line, ctx);
        writer.write_all(response.to_string_compact().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if ctx.shutdown.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(())
}

/// Standard error-reply shape shared by both server modes.
pub fn err_reply(msg: String) -> Json {
    Json::obj(vec![("ok", false.into()), ("error", msg.into())])
}

/// The synchronous control commands every server mode answers the same
/// way: `ping`, `maps`, `metrics`, `trace`, `shutdown`. Returns `None`
/// for anything else (`run`, `sweep`, …) — those are execution
/// commands whose blocking behaviour differs per mode, so each server
/// routes them itself.
pub fn dispatch_control(req: &Json, ctx: &ServerCtx) -> Option<Json> {
    match req.get("cmd").and_then(Json::as_str) {
        Some("ping") => Some(Json::obj(vec![
            ("ok", true.into()),
            ("pong", true.into()),
        ])),
        Some("maps") => {
            let mut per_m: Vec<(String, Json)> = (2..=crate::simplex::block_m::M_MAX as u32)
                .map(|m| {
                    let names = crate::maps::map_names(m)
                        .into_iter()
                        .map(Json::Str)
                        .collect();
                    (m.to_string(), Json::Arr(names))
                })
                .collect();
            // Non-simplex domains list under their own key.
            per_m.push((
                "gasket".to_string(),
                Json::Arr(
                    crate::maps::map_names_for(2, crate::maps::DomainKind::Gasket)
                        .into_iter()
                        .map(Json::Str)
                        .collect(),
                ),
            ));
            Some(Json::obj(vec![
                ("ok", true.into()),
                ("maps", Json::Obj(per_m.into_iter().collect())),
            ]))
        }
        Some("metrics") => {
            if req.get("format").and_then(Json::as_str) == Some("prometheus") {
                Some(Json::obj(vec![
                    ("ok", true.into()),
                    ("format", "prometheus".into()),
                    ("text", ctx.scheduler.metrics.prometheus().into()),
                ]))
            } else {
                Some(Json::obj(vec![
                    ("ok", true.into()),
                    ("metrics", ctx.scheduler.metrics.snapshot()),
                ]))
            }
        }
        Some("trace") => {
            let recorder = crate::coordinator::span::global();
            if let Some(on) = req.get("enable").and_then(Json::as_bool) {
                recorder.set_enabled(on);
            }
            let n = req.get("n").and_then(Json::as_u64).unwrap_or(256) as usize;
            let spans = recorder.snapshot_last(n);
            Some(Json::obj(vec![
                ("ok", true.into()),
                ("enabled", recorder.enabled().into()),
                ("spans", spans.len().into()),
                ("trace", crate::coordinator::span::chrome_trace(&spans)),
            ]))
        }
        Some("shutdown") => {
            ctx.shutdown.store(true, Ordering::Relaxed);
            Some(Json::obj(vec![("ok", true.into())]))
        }
        _ => None,
    }
}

/// Pure request → response mapping for the threaded server
/// (unit-testable without sockets).
pub fn dispatch(line: &str, ctx: &ServerCtx) -> Json {
    let err = err_reply;
    let req = match json::parse(line) {
        Ok(j) => j,
        Err(e) => return err(format!("bad json: {e}")),
    };
    if let Some(reply) = dispatch_control(&req, ctx) {
        return reply;
    }
    match req.get("cmd").and_then(Json::as_str) {
        Some("run") => {
            ctx.scheduler
                .metrics
                .jobs_accepted
                .fetch_add(1, Ordering::Relaxed);
            match Job::from_json(&req) {
                None => err("invalid job (need workload, nb, map)".into()),
                Some(job) => {
                    // Accept span: admission through reply, covering the
                    // queue wait and the job execution beneath it.
                    let recorder = crate::coordinator::span::global();
                    let accept = recorder.start("server", "accept", 0);
                    let attrs = vec![
                        ("workload", job.workload.name().to_string()),
                        ("map", job.map.clone()),
                    ];
                    let outcome = ctx.queue.run(job);
                    recorder.finish_with(accept, attrs);
                    match outcome {
                        Ok(result) => {
                            // A threaded-mode reply goes straight down the
                            // connection — the delivered leg of the
                            // completed-job accounting identity.
                            ctx.scheduler
                                .metrics
                                .results_delivered
                                .fetch_add(1, Ordering::Relaxed);
                            Json::obj(vec![
                                ("ok", true.into()),
                                ("result", result.to_json()),
                            ])
                        }
                        Err(e) => {
                            ctx.scheduler
                                .metrics
                                .jobs_failed
                                .fetch_add(1, Ordering::Relaxed);
                            err(e.to_string())
                        }
                    }
                }
            }
        }
        Some("sweep") | Some("results") => err(
            "sweep streaming needs the reactor server (restart with --mode reactor)".into(),
        ),
        _ => err("unknown cmd (ping|run|maps|metrics|trace|shutdown)".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ServerCtx {
        ServerCtx::new(Arc::new(Scheduler::new(2, None)), QueueConfig::default())
    }

    #[test]
    fn dispatch_ping() {
        let c = ctx();
        let r = dispatch(r#"{"cmd":"ping"}"#, &c);
        assert_eq!(r.get("pong").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn dispatch_run_job_through_queue() {
        let c = ctx();
        let r = dispatch(
            r#"{"cmd":"run","workload":"edm","nb":8,"map":"lambda2"}"#,
            &c,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        let result = r.get("result").unwrap();
        assert_eq!(result.get("block_efficiency").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            c.scheduler
                .metrics
                .jobs_queued
                .load(std::sync::atomic::Ordering::Relaxed),
            1,
            "run must pass through the job queue"
        );
    }

    #[test]
    fn dispatch_maps_lists_names_per_dimension() {
        let c = ctx();
        let r = dispatch(r#"{"cmd":"maps"}"#, &c);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        let maps = r.get("maps").unwrap();
        let names = |m: &str| -> Vec<String> {
            maps.get(m)
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|j| j.as_str().unwrap().to_string())
                .collect()
        };
        assert!(names("2").contains(&"lambda2".to_string()));
        assert!(names("3").contains(&"lambda3".to_string()));
        for m in ["4", "5", "6", "7", "8"] {
            assert!(names(m).contains(&"lambda-m".to_string()), "m={m}");
            assert!(names(m).contains(&"bb".to_string()), "m={m}");
        }
        // The gasket domain advertises its maps under its own key, and
        // they stay out of the numeric (simplex) lists.
        assert_eq!(
            names("gasket"),
            vec!["bb-gasket".to_string(), "lambda-gasket".to_string()]
        );
        assert!(!names("2").contains(&"lambda-gasket".to_string()));
        // Every advertised name must resolve in the unified registry
        // (gasket maps register at m = 2).
        for m in 2..=8u32 {
            for name in names(&m.to_string()) {
                assert!(
                    crate::maps::map_by_name(m, &name).is_some(),
                    "m={m} {name}"
                );
            }
        }
        for name in names("gasket") {
            assert!(crate::maps::map_by_name(2, &name).is_some(), "{name}");
        }
    }

    #[test]
    fn dispatch_runs_gasket_jobs_and_reports_domain_mismatch() {
        let c = ctx();
        let r = dispatch(
            r#"{"cmd":"run","workload":"gasket","nb":8,"map":"lambda-gasket"}"#,
            &c,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        let result = r.get("result").unwrap();
        assert_eq!(result.get("block_efficiency").unwrap().as_f64(), Some(1.0));
        assert!(result.get("outputs").unwrap().get("checksum_after").is_some());
        // Simplex workload on a gasket-only map → clean client error.
        let r = dispatch(
            r#"{"cmd":"run","workload":"edm","nb":8,"map":"lambda-gasket"}"#,
            &c,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        assert!(
            r.get("error").unwrap().as_str().unwrap().contains("gasket"),
            "{r}"
        );
    }

    #[test]
    fn dispatch_metrics_prometheus_format() {
        let c = ctx();
        dispatch(
            r#"{"cmd":"run","workload":"edm","nb":8,"map":"lambda2"}"#,
            &c,
        );
        let r = dispatch(r#"{"cmd":"metrics","format":"prometheus"}"#, &c);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("format").unwrap().as_str(), Some("prometheus"));
        let text = r.get("text").unwrap().as_str().unwrap();
        assert!(text.contains("simplexmap_jobs_completed_total 1"), "{text}");
        assert!(text.contains("simplexmap_job_wall_seconds{quantile=\"0.5\"}"));
        // The default format is untouched by the new axis.
        let r = dispatch(r#"{"cmd":"metrics"}"#, &c);
        assert!(r.get("metrics").unwrap().get("job_wall").is_some());
    }

    #[test]
    fn dispatch_trace_answers_a_chrome_document() {
        // Recording stays disabled here (toggling the global recorder
        // belongs to tests/observability.rs — lib tests share a
        // process); the shape of the reply is what this covers.
        let c = ctx();
        let r = dispatch(r#"{"cmd":"trace","n":16}"#, &c);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert!(r.get("spans").unwrap().as_u64().is_some());
        let trace = r.get("trace").unwrap();
        assert!(trace.get("traceEvents").unwrap().as_arr().is_some());
    }

    #[test]
    fn dispatch_bad_json_and_unknown_cmd() {
        let c = ctx();
        assert_eq!(
            dispatch("{oops", &c).get("ok").unwrap().as_bool(),
            Some(false)
        );
        assert_eq!(
            dispatch(r#"{"cmd":"dance"}"#, &c)
                .get("ok")
                .unwrap()
                .as_bool(),
            Some(false)
        );
    }

    #[test]
    fn dispatch_invalid_job_counts_failure() {
        let c = ctx();
        let r = dispatch(
            r#"{"cmd":"run","workload":"edm","nb":17,"map":"lambda2"}"#,
            &c,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(
            c.scheduler
                .metrics
                .jobs_failed
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn dispatch_control_splits_sync_from_execution_cmds() {
        let c = ctx();
        let ping = json::parse(r#"{"cmd":"ping"}"#).unwrap();
        assert!(dispatch_control(&ping, &c).is_some());
        let run = json::parse(r#"{"cmd":"run","workload":"edm","nb":8,"map":"bb"}"#).unwrap();
        assert!(
            dispatch_control(&run, &c).is_none(),
            "execution commands are each mode's own business"
        );
        // The threaded server points sweep clients at the reactor
        // instead of silently running the fan-out serially.
        let r = dispatch(r#"{"cmd":"sweep","workloads":["edm"]}"#, &c);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        assert!(
            r.get("error").unwrap().as_str().unwrap().contains("reactor"),
            "{r}"
        );
    }

    #[test]
    fn dispatch_shutdown_sets_flag() {
        let c = ctx();
        dispatch(r#"{"cmd":"shutdown"}"#, &c);
        assert!(c.shutdown.load(Ordering::SeqCst));
    }

    #[test]
    fn server_end_to_end_over_tcp() {
        use std::io::{BufRead, BufReader, Write};
        let server = Server::new(Arc::new(Scheduler::new(2, None)));
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = {
            let srv = server;
            std::thread::spawn(move || {
                srv.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
                    .unwrap();
            })
        };
        let addr = rx.recv().unwrap();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        conn.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("pong"));

        line.clear();
        conn.write_all(
            b"{\"cmd\":\"run\",\"workload\":\"collision\",\"nb\":8,\"map\":\"rb\"}\n",
        )
        .unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("overlap_count"), "{line}");

        line.clear();
        conn.write_all(b"{\"cmd\":\"maps\"}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("lambda-m"), "{line}");
        assert!(line.contains("\"4\""), "{line}");

        line.clear();
        conn.write_all(
            b"{\"cmd\":\"run\",\"workload\":\"ktuple4\",\"nb\":3,\"map\":\"bb\"}\n",
        )
        .unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("ktuple_energy"), "{line}");

        line.clear();
        conn.write_all(b"{\"cmd\":\"metrics\"}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("jobs_completed"));
        assert!(line.contains("queue_depth"), "{line}");

        conn.write_all(b"{\"cmd\":\"shutdown\"}\n").unwrap();
        handle.join().unwrap();
    }
}
