//! Connection-independent sweep results store: the container that
//! outlives the consumer.
//!
//! The reactor used to keep sweep rows inside the `Conn` that started
//! the sweep, so a dropped TCP connection destroyed every completed
//! result of an in-flight sweep. This store severs that tie the same
//! way the paper's block-space maps sever parallel space from domain
//! space: rows are keyed by a durable *token* handed out in the sweep
//! ack, and any connection that presents the token can page through
//! the rows — mid-sweep or after completion, across reconnects.
//!
//! ## Invariants
//!
//! - **Bounded.** Total stored rows never exceed `max_rows`. Admission
//!   pre-reserves *all* of a sweep's rows up front, so a sweep that is
//!   admitted can never hit store-full mid-flight — degradation happens
//!   at the edge (a typed [`StoreError::Full`] refusal the caller turns
//!   into a wire error), never as silent row loss in the middle.
//! - **Only finished entries are evicted.** Unfinished entries are
//!   always driven to completion by a live `SweepRun` in the reactor
//!   (even after the owning client vanishes), so TTL/LRU eviction
//!   considers finished entries only; an admitted sweep keeps its
//!   reservation until it finishes and ages out.
//! - **Duplicate-delivery guard.** `put` reports [`PutOutcome::Duplicate`]
//!   for a row that already landed, so the caller's exactly-once
//!   accounting survives reconnects and retries.
//!
//! The store is owned by the single-threaded reactor loop and takes
//! `&mut self` — no interior locking — and it counts nothing itself:
//! the reactor translates return values ([`PutOutcome`], eviction
//! counts) into metrics, which keeps these unit tests standalone.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Store sizing knobs (reactor copies these out of `ReactorConfig`,
/// which reads `SIMPLEXMAP_STORE_CAP` / `SIMPLEXMAP_STORE_TTL_SECS`).
#[derive(Clone, Copy, Debug)]
pub struct StoreConfig {
    /// Ceiling on total rows held across all sweeps.
    pub max_rows: usize,
    /// Finished entries older than this (since last access) age out.
    pub ttl: Duration,
}

impl Default for StoreConfig {
    fn default() -> StoreConfig {
        StoreConfig {
            max_rows: 65_536,
            ttl: Duration::from_secs(600),
        }
    }
}

/// Typed admission refusal: the caller reports `need`/`cap`/`used` to
/// the client instead of silently dropping rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreError {
    Full {
        need: usize,
        cap: usize,
        used: usize,
    },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Full { need, cap, used } => write!(
                f,
                "results store full: sweep needs {need} rows, {used}/{cap} in use \
                 (finish or expire older sweeps, or raise SIMPLEXMAP_STORE_CAP)"
            ),
        }
    }
}

impl std::error::Error for StoreError {}

/// What happened to a row handed to [`ResultsStore::put`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PutOutcome {
    /// Landed in its cell.
    Stored,
    /// The cell was already filled — exactly-once guard tripped.
    Duplicate,
    /// No entry under that token (evicted or never admitted).
    Unknown,
}

/// One `results` page, reassembled row-major.
#[derive(Clone, Debug)]
pub struct Page {
    pub jobs: usize,
    pub cursor: usize,
    pub results: Vec<Json>,
    pub next_cursor: Option<usize>,
    pub done: bool,
    pub completed: u64,
    pub failed: u64,
}

struct Entry {
    /// Row-major cells; `None` until the row's completion lands.
    rows: Vec<Option<Json>>,
    finished: bool,
    completed: u64,
    failed: u64,
    /// Admission, put, finish and page all refresh this — TTL measures
    /// abandonment, not age.
    last_access: Instant,
}

/// Bounded, TTL-evicted map from sweep token to its result rows.
pub struct ResultsStore {
    cfg: StoreConfig,
    entries: HashMap<String, Entry>,
    rows_used: usize,
}

impl ResultsStore {
    pub fn new(cfg: StoreConfig) -> ResultsStore {
        ResultsStore {
            cfg,
            entries: HashMap::new(),
            rows_used: 0,
        }
    }

    /// Reserve `jobs` row cells under `token`. Evicts finished entries
    /// oldest-access-first to make room; refuses (typed, no partial
    /// state) when even that cannot fit the sweep. Returns how many
    /// entries were evicted so the caller can count them.
    pub fn admit(&mut self, token: &str, jobs: usize, now: Instant) -> Result<usize, StoreError> {
        if let Some(old) = self.entries.remove(token) {
            // A token collision can only be a caller bug, but leaking
            // the old reservation would corrupt the occupancy gauge.
            self.rows_used -= old.rows.len();
        }
        let mut evicted = 0;
        while self.rows_used + jobs > self.cfg.max_rows {
            let oldest = self
                .entries
                .iter()
                .filter(|(_, e)| e.finished)
                .min_by_key(|(_, e)| e.last_access)
                .map(|(t, _)| t.clone());
            match oldest {
                Some(t) => {
                    if let Some(e) = self.entries.remove(&t) {
                        self.rows_used -= e.rows.len();
                        evicted += 1;
                    }
                }
                None => {
                    return Err(StoreError::Full {
                        need: jobs,
                        cap: self.cfg.max_rows,
                        used: self.rows_used,
                    });
                }
            }
        }
        self.rows_used += jobs;
        self.entries.insert(
            token.to_string(),
            Entry {
                rows: vec![None; jobs],
                finished: false,
                completed: 0,
                failed: 0,
                last_access: now,
            },
        );
        Ok(evicted)
    }

    /// Land one row in its cell; `ok` feeds the completed/failed tally.
    pub fn put(
        &mut self,
        token: &str,
        idx: usize,
        row: Json,
        ok: bool,
        now: Instant,
    ) -> PutOutcome {
        let Some(e) = self.entries.get_mut(token) else {
            return PutOutcome::Unknown;
        };
        e.last_access = now;
        let Some(cell) = e.rows.get_mut(idx) else {
            return PutOutcome::Duplicate;
        };
        if cell.is_some() {
            return PutOutcome::Duplicate;
        }
        *cell = Some(row);
        if ok {
            e.completed += 1;
        } else {
            e.failed += 1;
        }
        if e.completed + e.failed == e.rows.len() as u64 {
            e.finished = true;
        }
        PutOutcome::Stored
    }

    /// One-shot stash for an orphaned single-job result (a plain `run`
    /// whose client vanished before the reply could be written): admit
    /// a 1-row entry, fill it, and mark it finished in one step.
    pub fn stash(
        &mut self,
        token: &str,
        row: Json,
        ok: bool,
        now: Instant,
    ) -> Result<usize, StoreError> {
        let evicted = self.admit(token, 1, now)?;
        let outcome = self.put(token, 0, row, ok, now);
        debug_assert_eq!(outcome, PutOutcome::Stored);
        Ok(evicted)
    }

    /// Cursor-paginated read. `None` means unknown token. Missing rows
    /// page as `Json::Null` exactly like the old per-conn store, so a
    /// reconnecting client can poll mid-sweep.
    pub fn page(&mut self, token: &str, cursor: usize, limit: usize, now: Instant) -> Option<Page> {
        let e = self.entries.get_mut(token)?;
        e.last_access = now;
        let total = e.rows.len();
        let start = cursor.min(total);
        let end = cursor.saturating_add(limit).min(total);
        let results: Vec<Json> = e
            .rows
            .iter()
            .skip(start)
            .take(end - start)
            .map(|r| r.clone().unwrap_or(Json::Null))
            .collect();
        Some(Page {
            jobs: total,
            cursor,
            results,
            next_cursor: if end < total { Some(end) } else { None },
            done: e.finished,
            completed: e.completed,
            failed: e.failed,
        })
    }

    /// Drop finished entries not touched within the TTL. Returns the
    /// eviction count for the caller's `store_evictions` counter.
    pub fn evict_expired(&mut self, now: Instant) -> usize {
        let ttl = self.cfg.ttl;
        let expired: Vec<String> = self
            .entries
            .iter()
            .filter(|(_, e)| e.finished && now.duration_since(e.last_access) >= ttl)
            .map(|(t, _)| t.clone())
            .collect();
        for t in &expired {
            if let Some(e) = self.entries.remove(t) {
                self.rows_used -= e.rows.len();
            }
        }
        expired.len()
    }

    /// Occupancy gauges for `{"cmd":"metrics"}`.
    pub fn rows_used(&self) -> usize {
        self.rows_used
    }

    pub fn sweeps(&self) -> usize {
        self.entries.len()
    }

    pub fn contains(&self, token: &str) -> bool {
        self.entries.contains_key(token)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(i: usize) -> Json {
        Json::obj(vec![("job", (i as u64).into()), ("ok", true.into())])
    }

    fn store(max_rows: usize, ttl_secs: u64) -> ResultsStore {
        ResultsStore::new(StoreConfig {
            max_rows,
            ttl: Duration::from_secs(ttl_secs),
        })
    }

    #[test]
    fn admit_put_page_round_trip_row_major() {
        let now = Instant::now();
        let mut s = store(16, 600);
        s.admit("swp-1", 3, now).unwrap();
        assert_eq!(s.rows_used(), 3);
        assert_eq!(s.sweeps(), 1);
        // Out-of-order completions land in row-major cells.
        assert_eq!(s.put("swp-1", 2, row(2), true, now), PutOutcome::Stored);
        assert_eq!(s.put("swp-1", 0, row(0), true, now), PutOutcome::Stored);
        let p = s.page("swp-1", 0, 2, now).unwrap();
        assert_eq!(p.jobs, 3);
        assert_eq!(p.results.len(), 2);
        assert_eq!(p.results[0].get("job").and_then(Json::as_u64), Some(0));
        assert!(matches!(p.results[1], Json::Null), "missing row pages as null");
        assert_eq!(p.next_cursor, Some(2));
        assert!(!p.done);
        let p2 = s.page("swp-1", 2, 10, now).unwrap();
        assert_eq!(p2.next_cursor, None);
        assert_eq!(s.put("swp-1", 1, row(1), false, now), PutOutcome::Stored);
        let p3 = s.page("swp-1", 0, 10, now).unwrap();
        assert!(p3.done, "all rows landed ⇒ finished");
        assert_eq!((p3.completed, p3.failed), (2, 1));
    }

    #[test]
    fn duplicate_and_unknown_puts_are_reported_not_stored() {
        let now = Instant::now();
        let mut s = store(8, 600);
        s.admit("t", 2, now).unwrap();
        assert_eq!(s.put("t", 0, row(0), true, now), PutOutcome::Stored);
        assert_eq!(s.put("t", 0, row(0), true, now), PutOutcome::Duplicate);
        assert_eq!(s.put("t", 9, row(9), true, now), PutOutcome::Duplicate);
        assert_eq!(s.put("nope", 0, row(0), true, now), PutOutcome::Unknown);
        let p = s.page("t", 0, 10, now).unwrap();
        assert_eq!((p.completed, p.failed), (1, 0), "duplicates never double-count");
    }

    #[test]
    fn admission_evicts_finished_lru_and_refuses_past_unfinished() {
        let t0 = Instant::now();
        let mut s = store(4, 600);
        s.admit("old", 2, t0).unwrap();
        s.put("old", 0, row(0), true, t0);
        s.put("old", 1, row(1), true, t0); // finished
        s.admit("live", 2, t0 + Duration::from_secs(1)).unwrap();
        assert_eq!(s.rows_used(), 4);
        // Needs 2, store full: evicts the finished "old", keeps "live".
        let evicted = s.admit("new", 2, t0 + Duration::from_secs(2)).unwrap();
        assert_eq!(evicted, 1);
        assert!(!s.contains("old"));
        assert!(s.contains("live"));
        assert_eq!(s.rows_used(), 4);
        // Only unfinished entries remain — typed refusal, no state change.
        let err = s.admit("more", 1, t0 + Duration::from_secs(3)).unwrap_err();
        assert_eq!(
            err,
            StoreError::Full {
                need: 1,
                cap: 4,
                used: 4
            }
        );
        assert!(err.to_string().contains("SIMPLEXMAP_STORE_CAP"));
        assert_eq!(s.sweeps(), 2);
    }

    #[test]
    fn oversized_sweep_is_refused_outright() {
        let now = Instant::now();
        let mut s = store(4, 600);
        assert!(matches!(
            s.admit("big", 5, now),
            Err(StoreError::Full { need: 5, cap: 4, used: 0 })
        ));
        assert_eq!(s.rows_used(), 0);
    }

    #[test]
    fn ttl_evicts_only_finished_entries_and_access_refreshes() {
        let t0 = Instant::now();
        let mut s = store(16, 10);
        s.admit("done", 1, t0).unwrap();
        s.put("done", 0, row(0), true, t0);
        s.admit("touched", 1, t0).unwrap();
        s.put("touched", 0, row(0), true, t0);
        s.admit("pending", 1, t0).unwrap();
        // Page refreshes last_access on "touched" just before the sweep.
        s.page("touched", 0, 1, t0 + Duration::from_secs(9)).unwrap();
        let evicted = s.evict_expired(t0 + Duration::from_secs(12));
        assert_eq!(evicted, 1, "only the stale finished entry ages out");
        assert!(!s.contains("done"));
        assert!(s.contains("touched"));
        assert!(s.contains("pending"), "unfinished entries never TTL out");
        assert_eq!(s.rows_used(), 2);
    }

    #[test]
    fn stash_is_a_one_shot_finished_entry() {
        let now = Instant::now();
        let mut s = store(4, 600);
        s.stash("run-7", row(0), true, now).unwrap();
        let p = s.page("run-7", 0, 10, now).unwrap();
        assert!(p.done);
        assert_eq!((p.jobs, p.completed, p.failed), (1, 1, 0));
        s.stash("run-8", row(1), false, now).unwrap();
        let p8 = s.page("run-8", 0, 10, now).unwrap();
        assert_eq!((p8.completed, p8.failed), (0, 1));
        // Stashes are finished, so they are evictable for new admissions.
        let evicted = s.admit("swp", 4, now).unwrap();
        assert_eq!(evicted, 2);
        assert_eq!(s.rows_used(), 4);
    }

    #[test]
    fn readmitting_a_token_replaces_without_leaking_occupancy() {
        let now = Instant::now();
        let mut s = store(8, 600);
        s.admit("t", 3, now).unwrap();
        s.put("t", 0, row(0), true, now);
        s.admit("t", 2, now).unwrap();
        assert_eq!(s.rows_used(), 2);
        let p = s.page("t", 0, 10, now).unwrap();
        assert_eq!(p.jobs, 2);
        assert!(matches!(p.results[0], Json::Null), "fresh reservation");
    }
}
