//! ASCII visualization of the maps — the executable version of the
//! paper's Figures 4, 6 and 7: render where every parallel block lands
//! in data space, labelled by recursion level, so the recursive
//! structure is visible at a glance.
//!
//! `simplexmap show --map lambda2 --nb 16` prints e.g.
//!
//! ```text
//! 0
//! 1 0
//! 2 2 1
//! 2 2 1 0
//! ...
//! ```
//!
//! where the digit is the λ2 recursion level that produced the block
//! (`.` = never covered — must not appear for the bijective maps).

use crate::maps::ThreadMap;

/// Character for a block produced by parallel block `w` of pass `pass`.
fn label(map_name: &str, w: [u64; 3], pass: u64) -> char {
    let level = match map_name {
        // λ2: level = ⌊log2 y⌋ of the parallel row (diagonal rows get 'D').
        "lambda2" => {
            if w[1] == 0 {
                return 'D';
            }
            63 - w[1].leading_zeros() as u64
        }
        // Ries: the pass is the level.
        "ries" => pass,
        // Everything else: label by pass (multi-pass) or a dot-free '#'.
        _ => pass,
    };
    char::from_digit((level % 36) as u32, 36).unwrap_or('#')
}

/// Render the m=2 data triangle with per-block labels.
pub fn render_m2(map: &dyn ThreadMap, nb: u64) -> String {
    assert_eq!(map.m(), 2);
    let mut cells = vec![vec!['.'; nb as usize]; nb as usize];
    for pass in 0..map.passes(nb) {
        for w in map.grid(nb, pass).iter() {
            if let Some(d) = map.map_block(nb, pass, w) {
                let (c, r) = (d[0] as usize, d[1] as usize);
                if r < nb as usize && c <= r {
                    cells[r][c] = if map.name() == "lambda2" && w[1] == nb {
                        'D'
                    } else {
                        label(map.name(), w, pass)
                    };
                }
            }
        }
    }
    let mut out = String::new();
    for (r, row) in cells.iter().enumerate() {
        for c in 0..=r {
            out.push(row[c]);
            out.push(' ');
        }
        out.push('\n');
    }
    out
}

/// Render z-slices of the m=3 tetrahedron; label = recursion level
/// ('0' main cube identity part, 'f' folded, digits for deeper levels,
/// 'P' diagonal plane).
pub fn render_m3(map: &dyn ThreadMap, nb: u64) -> String {
    assert_eq!(map.m(), 3);
    let n = nb as usize;
    let mut cells = vec![vec![vec!['.'; n]; n]; n];
    for pass in 0..map.passes(nb) {
        for w in map.grid(nb, pass).iter() {
            if let Some(d) = map.map_block(nb, pass, w) {
                let (x, y, z) = (d[0] as usize, d[1] as usize, d[2] as usize);
                if x + y + z < n {
                    cells[z][y][x] = classify_m3(map.name(), nb, w, d);
                }
            }
        }
    }
    let mut out = String::new();
    for (z, plane) in cells.iter().enumerate() {
        out.push_str(&format!("z = {z}\n"));
        for (y, row) in plane.iter().enumerate() {
            if row.iter().take(n - z - y.min(n - z)).all(|&c| c == '.') && y + z >= n {
                continue;
            }
            let width = n - z - y;
            if width == 0 {
                continue;
            }
            out.push_str("  ");
            for c in row.iter().take(width) {
                out.push(*c);
                out.push(' ');
            }
            out.push('\n');
        }
    }
    out
}

fn classify_m3(name: &str, nb: u64, w: [u64; 3], d: [u64; 3]) -> char {
    if name != "lambda3" {
        return '#';
    }
    if w[2] >= 3 * nb / 4 {
        return 'P'; // diagonal plane layers
    }
    if w[2] < nb / 2 {
        // Main cube: identity or folded?
        return if d == w { '0' } else { 'f' };
    }
    // Deeper levels: level from the y coordinate.
    let u = nb / 2 - 1 - w[1];
    let level_log = 63 - u.leading_zeros() as u64;
    char::from_digit(((level_log + 1) % 36) as u32, 36).unwrap_or('#')
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{Lambda2Map, Lambda3Map, RiesMap};

    #[test]
    fn lambda2_rendering_has_no_holes() {
        let s = render_m2(&Lambda2Map, 16);
        assert!(!s.contains('.'), "bijective map leaves no holes:\n{s}");
        // Levels 0..3 and the diagonal all appear.
        for c in ['0', '1', '2', '3', 'D'] {
            assert!(s.contains(c), "missing label {c}:\n{s}");
        }
    }

    #[test]
    fn lambda2_levels_form_squares() {
        // Level 2 of nb=16 consists of 4×4 squares just below the
        // diagonal; check one known cell.
        let s = render_m2(&Lambda2Map, 16);
        let rows: Vec<&str> = s.lines().collect();
        // Row 4 (0-indexed), col 0 belongs to the level-2 square
        // (cols [0,4) × rows [4,8)).
        assert_eq!(rows[4].chars().next(), Some('2'));
    }

    #[test]
    fn ries_rendering_matches_lambda2_geometry() {
        // Same squares, labelled by pass instead of row-level.
        let l = render_m2(&Lambda2Map, 8);
        let r = render_m2(&RiesMap, 8);
        assert!(!r.contains('.'));
        assert_eq!(l.len(), r.len());
    }

    #[test]
    fn lambda3_rendering_covers_tetra() {
        let s = render_m3(&Lambda3Map, 8);
        assert!(!s.contains('.'), "no holes:\n{s}");
        for c in ['0', 'f', '1', 'P'] {
            assert!(s.contains(c), "missing label {c}:\n{s}");
        }
    }
}
