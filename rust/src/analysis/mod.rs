//! Report generation — the executable versions of the paper's tables
//! and figures (experiment index in DESIGN.md). Every function returns
//! the formatted table as a `String` so the CLI prints it and the
//! tests assert on its contents.

pub mod viz;

use crate::gensearch;
use crate::maps::{
    alpha, domain_volume, map2_by_name, map3_by_name, space_efficiency, ThreadMap,
};
use crate::simplex::recursive_set::{alpha_half, recursive_volume_half};
use crate::simplex::volume::{bb_alpha, bb_alpha_limit, simplex_volume};

/// E1 (eq. 2-4, Figs. 2-3): simplex vs bounding-box volumes and the
/// waste ratio α for m = 1..=m_max at a reference n.
pub fn report_volumes(n: u64, m_max: u32) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "E1: bounding-box waste (eq. 4), n = {n}\n\
         {:>3} {:>22} {:>22} {:>12} {:>12}\n",
        "m", "V(simplex)", "V(bounding-box)", "alpha(n)", "lim m!-1"
    ));
    for m in 1..=m_max {
        out.push_str(&format!(
            "{:>3} {:>22} {:>22} {:>12.4} {:>12.1}\n",
            m,
            simplex_volume(n, m),
            (n as u128).pow(m),
            bb_alpha(n, m),
            bb_alpha_limit(m),
        ));
    }
    out
}

/// E2/E6 summary: per-map parallel volume, efficiency and α at size nb.
pub fn report_maps(nb: u64) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Map space efficiency at nb = {nb} (V(domain) m=2: {}, m=3: {})\n\
         {:<14} {:>3} {:>14} {:>10} {:>10} {:>8}\n",
        domain_volume(nb, 2),
        domain_volume(nb, 3),
        "map",
        "m",
        "V(parallel)",
        "eff",
        "alpha",
        "passes"
    ));
    let mut rows: Vec<Box<dyn ThreadMap>> = Vec::new();
    for name in crate::maps::MAP2_NAMES {
        rows.push(map2_by_name(name).unwrap());
    }
    for name in crate::maps::MAP3_NAMES {
        rows.push(map3_by_name(name).unwrap());
    }
    for map in &rows {
        if !map.supports(nb) {
            continue;
        }
        out.push_str(&format!(
            "{:<14} {:>3} {:>14} {:>10.4} {:>10.4} {:>8}\n",
            map.name(),
            map.m(),
            map.parallel_volume(nb),
            space_efficiency(map.as_ref(), nb),
            alpha(map.as_ref(), nb),
            map.passes(nb),
        ));
    }
    out
}

/// E4 (eq. 17-19, Fig. 5): the arity-3 recursive set's extra volume
/// converging to 1/5.
pub fn report_arity3(k_max: u32) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "E4: arity-3 recursive set vs tetrahedron (eq. 19: lim = 1/5)\n\
         {:>10} {:>18} {:>18} {:>10}\n",
        "n", "V(S_n^3) beta=3", "V(tet_n)", "alpha"
    ));
    for k in 2..=k_max {
        let n = 1u64 << k;
        let v_s = recursive_volume_half(n, 3, 3);
        let v_d = simplex_volume(n, 3);
        out.push_str(&format!(
            "{:>10} {:>18} {:>18} {:>10.5}\n",
            n,
            v_s,
            v_d,
            v_s as f64 / v_d as f64 - 1.0
        ));
    }
    out
}

/// E5 (eq. 20): launch counts of the §III.B recursive map vs the
/// 32-concurrent-kernel budget and λ3's single pass.
pub fn report_launches(k_max: u32) -> String {
    use crate::maps::lambda3_recursive::launch_count;
    let mut out = String::new();
    out.push_str(&format!(
        "E5: kernel launches (eq. 20) — lambda3-rec vs lambda3, cap 32\n\
         {:>8} {:>14} {:>12} {:>10}\n",
        "nb", "rec launches", "waves(cap32)", "lambda3"
    ));
    for k in 1..=k_max {
        let nb = 1u64 << k;
        let lc = launch_count(nb) + 1;
        out.push_str(&format!(
            "{:>8} {:>14} {:>12} {:>10}\n",
            nb,
            lc,
            lc.div_ceil(32),
            1
        ));
    }
    out
}

/// E8 (eq. 28-29): r=1/2, β=2 waste blow-up table.
pub fn report_general(m_max: u32) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "E8: r=1/2, beta=2 general-m waste (eq. 29: lim = m!/(2^m-2) - 1)\n\
         {:>3} {:>14} {:>14}\n",
        "m", "alpha(n=2^14)", "alpha limit"
    ));
    for m in 2..=m_max {
        out.push_str(&format!(
            "{:>3} {:>14.4} {:>14.4}\n",
            m,
            alpha_half(1 << 14, m, 2),
            crate::simplex::recursive_set::alpha_limit_half_beta2(m),
        ));
    }
    out
}

/// E9 (§III.D): the (m, β) search table.
pub fn report_search(m_lo: u32, m_hi: u32, betas: &[f64], horizon: u64) -> String {
    let rows = gensearch::search((m_lo, m_hi), betas, horizon);
    let mut out = String::new();
    out.push_str(&format!(
        "E9: §III.D parameter search, r = m!^(-1/m), horizon = {horizon}\n\
         {:>3} {:>8} {:>10} {:>12} {:>10} {:>12} {:>14}\n",
        "m", "beta", "r", "n0", "n0 exec", "waste lim", "eff vs BB"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:>3} {:>8} {:>10.5} {:>12} {:>10} {:>12.4} {:>14.1}\n",
            r.m,
            r.beta,
            r.r,
            r.n0.map(|v| v.to_string()).unwrap_or_else(|| "-".into()),
            r.n0_exec
                .map(|v| v.to_string())
                .unwrap_or_else(|| "-".into()),
            r.waste_limit,
            r.efficiency_vs_bb,
        ));
    }
    out
}

/// §III.A non-power-of-two approaches: waste (approach 1) vs launch
/// count (approach 2), for sizes around a power of two.
pub fn report_nonpow2() -> String {
    use crate::maps::{CoverFromAbove, CoverFromBelow2, Lambda2Map};
    let mut out = String::new();
    out.push_str(
        "§III.A non-pow2 handling: approach 1 (round up + filter) vs approach 2 (binary segments)
",
    );
    out.push_str(&format!(
        "{:>6} {:>16} {:>14} {:>16} {:>14}
",
        "nb", "above: V(par)/V", "above passes", "below: V(par)/V", "below passes"
    ));
    let above = CoverFromAbove::new(Lambda2Map);
    let below = CoverFromBelow2;
    for nb in [9u64, 12, 17, 21, 33, 63, 65, 100, 127, 129] {
        let dv = domain_volume(nb, 2) as f64;
        out.push_str(&format!(
            "{:>6} {:>16.4} {:>14} {:>16.4} {:>14}
",
            nb,
            above.parallel_volume(nb) as f64 / dv,
            above.passes(nb),
            below.parallel_volume(nb) as f64 / dv,
            below.passes(nb),
        ));
    }
    out
}

/// E11: the Avril f32 accuracy cliff.
pub fn report_avril() -> String {
    use crate::maps::avril::f32_error_rate;
    let mut out = String::new();
    out.push_str(
        "E11: Avril thread-map f32 error rate (paper: accurate n in [0, 3000])\n",
    );
    out.push_str(&format!("{:>10} {:>14}\n", "n", "err rate"));
    for n in [512u64, 1000, 2000, 3000, 5000, 10_000, 20_000, 50_000] {
        let stride = (n * (n - 1) / 2 / 20_000).max(1);
        out.push_str(&format!(
            "{:>10} {:>14.6}\n",
            n,
            f32_error_rate(n, stride)
        ));
    }
    out
}

/// E12: Ries multi-pass vs λ2 single-pass.
pub fn report_ries(k_max: u32) -> String {
    use crate::maps::{Lambda2Map, RiesMap};
    let mut out = String::new();
    out.push_str(&format!(
        "E12: launch passes — Ries recursive partition vs lambda2\n\
         {:>8} {:>10} {:>10}\n",
        "nb", "ries", "lambda2"
    ));
    for k in 1..=k_max {
        let nb = 1u64 << k;
        out.push_str(&format!(
            "{:>8} {:>10} {:>10}\n",
            nb,
            RiesMap.passes(nb),
            Lambda2Map.passes(nb)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volumes_report_shows_factorial_limits() {
        let r = report_volumes(4096, 5);
        assert!(r.contains("119.0"), "5!-1 = 119:\n{r}");
        assert!(r.contains("E1"));
    }

    #[test]
    fn maps_report_lists_all_supported_maps() {
        let r = report_maps(64);
        for name in ["bb2", "lambda2", "enum2", "rb", "ries", "bb3", "lambda3", "enum3"] {
            assert!(r.contains(name), "missing {name}:\n{r}");
        }
    }

    #[test]
    fn arity3_report_converges_to_one_fifth() {
        let r = report_arity3(12);
        assert!(r.contains("0.2000") || r.contains("0.19"), "{r}");
    }

    #[test]
    fn launches_report_shows_explosion() {
        let r = report_launches(8);
        assert!(r.contains("3281")); // (3^8-1)/2 + 1 at nb=256
    }

    #[test]
    fn general_report_matches_eq29_values() {
        let r = report_general(7);
        assert!(r.contains("3.0000"), "m=5 → 3x:\n{r}");
        assert!(r.contains("39.0000"), "m=7 → 39x:\n{r}");
    }

    #[test]
    fn search_report_has_n0_column() {
        let r = report_search(4, 5, &[2.0, 8.0], 1 << 40);
        assert!(r.contains("512"), "n0(5,2)=512:\n{r}");
    }

    #[test]
    fn nonpow2_report_shows_tradeoff() {
        let r = report_nonpow2();
        // Approach 2 always shows ratio 1.0000 (zero waste).
        assert!(r.contains("1.0000"), "{r}");
        // Approach 1 always shows a single pass.
        assert!(r.contains("§III.A"));
    }

    #[test]
    fn avril_report_runs() {
        let r = report_avril();
        assert!(r.contains("20000") || r.contains("20_000") || r.contains(" 20000"));
    }

    #[test]
    fn ries_report_passes() {
        let r = report_ries(10);
        assert!(r.contains("11")); // log2(1024)+1
    }
}
