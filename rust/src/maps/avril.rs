//! Avril, Gouranton & Arnaldi's GPU mapping function for collision
//! detection [1] — a *thread-space* map `u(x) → (a, b)` from the linear
//! thread index to a unique pair `a < b` of the upper-triangular
//! interaction matrix.
//!
//! The related-work section highlights its limitation: computed in
//! floating point over thread indices (k up to n²/2), it is "accurate
//! only in the range n ∈ [0, 3000]" when evaluated in f32. We implement
//! both precisions and reproduce that accuracy cliff as experiment E11.

use crate::maps::ThreadMap;
use crate::simplex::Orthotope;

/// Start offset of row `a` when strict upper pairs `(a, b)`, `a < b`,
/// are enumerated row-major: row a holds `n-1-a` pairs, so
/// `row_start(a) = Σ_{i<a} (n-1-i) = a·n - a - a(a-1)/2`.
#[inline(always)]
fn row_start(a: u64, n: u64) -> u64 {
    a * n - a - a * a.saturating_sub(1) / 2
}

/// The closed form, f64: thread k ∈ [0, n(n-1)/2) → (a, b), a < b < n.
///
/// Inverting `row_start(a) ≤ k` gives
/// `a = ⌊(2n-1 - √((2n-1)² - 8k)) / 2⌋` — one sqrt per thread
/// (equivalent to Avril's published map with index shifts folded in).
#[inline(always)]
pub fn avril_map_f64(k: u64, n: u64) -> (u64, u64) {
    let kf = k as f64;
    let nf = n as f64;
    let disc = (2.0 * nf - 1.0) * (2.0 * nf - 1.0) - 8.0 * kf;
    let a = ((2.0 * nf - 1.0 - disc.sqrt()) * 0.5) as u64;
    let b = a + 1 + (k - row_start(a, n));
    (a, b)
}

/// Same formula evaluated in f32 — the precision the GPU fast-sqrt
/// path of [1] relied on; exhibits the paper's n ≈ 3000 accuracy cliff
/// (the discriminant needs more than 24 mantissa bits past it).
#[inline(always)]
pub fn avril_map_f32(k: u64, n: u64) -> (u64, u64) {
    let kf = k as f32;
    let nf = n as f32;
    let disc = (2.0 * nf - 1.0) * (2.0 * nf - 1.0) - 8.0 * kf;
    let a = ((2.0 * nf - 1.0 - disc.sqrt()) * 0.5) as u64;
    let rs = a
        .wrapping_mul(n)
        .wrapping_sub(a)
        .wrapping_sub(a.wrapping_mul(a.wrapping_sub(1)) / 2);
    let b = a.wrapping_add(1).wrapping_add(k.wrapping_sub(rs));
    (a, b)
}

/// Exact integer reference (binary search) for accuracy scoring.
pub fn avril_map_exact(k: u64, n: u64) -> (u64, u64) {
    // Find the largest a with row_start(a) ≤ k.
    let (mut lo, mut hi) = (0u64, n - 1);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if row_start(mid, n) <= k {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    (lo, lo + 1 + (k - row_start(lo, n)))
}

/// Fraction of thread indices the f32 map gets wrong at size n
/// (sampled; exact for small n). Experiment E11.
pub fn f32_error_rate(n: u64, sample_stride: u64) -> f64 {
    let total = n * (n - 1) / 2;
    let mut wrong = 0u64;
    let mut checked = 0u64;
    let mut k = 0u64;
    while k < total {
        if avril_map_f32(k, n) != avril_map_exact(k, n) {
            wrong += 1;
        }
        checked += 1;
        k += sample_stride;
    }
    wrong as f64 / checked as f64
}

/// Presented through the block-map interface for throughput benches:
/// each "block" is one thread index of an n-thread-per-side problem
/// (the map is genuinely thread-space, per the paper's related work).
pub struct AvrilMap;

impl ThreadMap for AvrilMap {
    fn name(&self) -> &'static str {
        "avril"
    }

    fn m(&self) -> u32 {
        2
    }

    fn supports(&self, nb: u64) -> bool {
        nb >= 2
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        // Strict upper pairs, linearized into a near-square 2-D grid
        // (the GPU constraint: grids are orthotopes).
        let total = nb * (nb - 1) / 2;
        let w = (total as f64).sqrt().ceil() as u64;
        Orthotope::d2(w, total.div_ceil(w.max(1)))
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        let grid_w = self.grid(nb, 0).dims[0];
        let k = w[1] * grid_w + w[0];
        if k >= nb * (nb - 1) / 2 {
            return None;
        }
        let (a, b) = avril_map_f64(k, nb);
        // Convert upper pair (a < b) to the canonical lower-tri block
        // domain (col ≤ row): col = a, row = b.
        Some([a, b, 0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{domain_volume, in_domain};
    use std::collections::HashSet;

    #[test]
    fn f64_matches_exact_for_moderate_n() {
        for n in [4u64, 37, 256, 1000, 3000] {
            let total = n * (n - 1) / 2;
            let stride = (total / 4096).max(1);
            let mut k = 0;
            while k < total {
                assert_eq!(
                    avril_map_f64(k, n),
                    avril_map_exact(k, n),
                    "n={n}, k={k}"
                );
                k += stride;
            }
        }
    }

    #[test]
    fn exact_map_is_bijection() {
        let n = 64u64;
        let mut seen = HashSet::new();
        for k in 0..n * (n - 1) / 2 {
            let (a, b) = avril_map_exact(k, n);
            assert!(a < b && b < n, "k={k} → ({a},{b})");
            assert!(seen.insert((a, b)));
        }
        assert_eq!(seen.len() as u64, n * (n - 1) / 2);
    }

    #[test]
    fn f32_cliff_reproduced() {
        // E11: f32 map is exact for small n, degrades past n ≈ 3000.
        assert_eq!(f32_error_rate(512, 7), 0.0, "exact at n=512");
        assert_eq!(f32_error_rate(2000, 97), 0.0, "exact at n=2000");
        let big = f32_error_rate(20_000, 9973);
        assert!(big > 0.0, "errors must appear by n=20000: rate={big}");
    }

    #[test]
    fn block_interface_covers_strict_pairs() {
        let nb = 32u64;
        let map = AvrilMap;
        let mut seen = HashSet::new();
        for w in map.grid(nb, 0).iter() {
            if let Some(d) = map.map_block(nb, 0, w) {
                assert!(in_domain(nb, 2, d));
                assert!(d[0] < d[1], "strict pairs only");
                assert!(seen.insert((d[0], d[1])));
            }
        }
        // Strict pairs = inclusive domain minus the diagonal.
        assert_eq!(seen.len() as u128, domain_volume(nb, 2) - nb as u128);
    }
}
