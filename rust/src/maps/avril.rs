//! Avril, Gouranton & Arnaldi's GPU mapping function for collision
//! detection [1] — a *thread-space* map `u(x) → (a, b)` from the linear
//! thread index to a unique pair `a < b` of the upper-triangular
//! interaction matrix.
//!
//! The related-work section highlights its limitation: computed in
//! floating point over thread indices (k up to n²/2), it is "accurate
//! only in the range n ∈ [0, 3000]" when evaluated in f32. We implement
//! both precisions and reproduce that accuracy cliff as experiment E11.
//!
//! The f64 evaluation has its own cliff: the discriminant
//! `(2n−1)² − 8k` is a difference of two ~2^2·log2(n)-bit quantities, so
//! for k near the top of the range (a near n) catastrophic cancellation
//! eats the mantissa — python-verified first misassignments at
//! n = 2^28 (k = 36028796884746239) and n = 2^31. Since PR 5 the block
//! path ([`AvrilMap::map_block`]) therefore uses [`avril_map_isqrt`] —
//! the same inversion on the exact integer Newton root
//! ([`crate::util::isqrt`]) — and the float variants remain only as the
//! measured E11 subjects.

use crate::maps::ThreadMap;
use crate::simplex::Orthotope;
use crate::util::isqrt::{isqrt_u64, triangular_root};

/// Start offset of row `a` when strict upper pairs `(a, b)`, `a < b`,
/// are enumerated row-major: row a holds `n-1-a` pairs, so
/// `row_start(a) = Σ_{i<a} (n-1-i) = a·n - a - a(a-1)/2`.
#[inline(always)]
fn row_start(a: u64, n: u64) -> u64 {
    a * n - a - a * a.saturating_sub(1) / 2
}

/// Just the row of the f64 map — split out so the precision-cliff
/// regression tests can probe the row at k values where the full map's
/// `k - row_start(a)` would underflow on the misassigned row.
#[inline(always)]
pub fn avril_row_f64(k: u64, n: u64) -> u64 {
    let kf = k as f64;
    let nf = n as f64;
    let disc = (2.0 * nf - 1.0) * (2.0 * nf - 1.0) - 8.0 * kf;
    // lint: allow(cast, the f64 Avril baseline measures exactly this float truncation, E11)
    ((2.0 * nf - 1.0 - disc.sqrt()) * 0.5) as u64
}

/// The closed form, f64: thread k ∈ [0, n(n-1)/2) → (a, b), a < b < n.
///
/// Inverting `row_start(a) ≤ k` gives
/// `a = ⌊(2n-1 - √((2n-1)² - 8k)) / 2⌋` — one sqrt per thread
/// (equivalent to Avril's published map with index shifts folded in).
#[inline(always)]
pub fn avril_map_f64(k: u64, n: u64) -> (u64, u64) {
    let a = avril_row_f64(k, n);
    let b = a + 1 + (k - row_start(a, n));
    (a, b)
}

/// Exact integer inversion, O(1): count pairs from the *end* of the
/// enumeration, where the reversed rows have triangular starts —
/// reversed index `k' = total−1−k` lies in reversed row
/// `j = triangular_root(k')`, i.e. row `a = n−2−j`. One integer
/// Newton isqrt, no cancellation, exact at every n a u64 can index.
#[inline(always)]
pub fn avril_map_isqrt(k: u64, n: u64) -> (u64, u64) {
    let total = n * (n - 1) / 2;
    debug_assert!(k < total);
    let a = n - 2 - triangular_root(total - 1 - k);
    (a, a + 1 + (k - row_start(a, n)))
}

/// Same formula evaluated in f32 — the precision the GPU fast-sqrt
/// path of [1] relied on; exhibits the paper's n ≈ 3000 accuracy cliff
/// (the discriminant needs more than 24 mantissa bits past it).
#[inline(always)]
pub fn avril_map_f32(k: u64, n: u64) -> (u64, u64) {
    let kf = k as f32;
    let nf = n as f32;
    let disc = (2.0 * nf - 1.0) * (2.0 * nf - 1.0) - 8.0 * kf;
    // lint: allow(cast, the f32 variant exists to measure this exact truncation error, E11)
    let a = ((2.0 * nf - 1.0 - disc.sqrt()) * 0.5) as u64;
    let rs = a
        .wrapping_mul(n)
        .wrapping_sub(a)
        .wrapping_sub(a.wrapping_mul(a.wrapping_sub(1)) / 2);
    let b = a.wrapping_add(1).wrapping_add(k.wrapping_sub(rs));
    (a, b)
}

/// Exact integer reference (binary search) for accuracy scoring.
pub fn avril_map_exact(k: u64, n: u64) -> (u64, u64) {
    // Find the largest a with row_start(a) ≤ k.
    let (mut lo, mut hi) = (0u64, n - 1);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if row_start(mid, n) <= k {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    (lo, lo + 1 + (k - row_start(lo, n)))
}

/// Fraction of thread indices the f32 map gets wrong at size n
/// (sampled; exact for small n). Experiment E11.
pub fn f32_error_rate(n: u64, sample_stride: u64) -> f64 {
    let total = n * (n - 1) / 2;
    let mut wrong = 0u64;
    let mut checked = 0u64;
    let mut k = 0u64;
    while k < total {
        if avril_map_f32(k, n) != avril_map_exact(k, n) {
            wrong += 1;
        }
        checked += 1;
        k += sample_stride;
    }
    wrong as f64 / checked as f64
}

/// Presented through the block-map interface for throughput benches:
/// each "block" is one thread index of an n-thread-per-side problem
/// (the map is genuinely thread-space, per the paper's related work).
pub struct AvrilMap;

impl ThreadMap for AvrilMap {
    fn name(&self) -> &'static str {
        "avril"
    }

    fn m(&self) -> u32 {
        2
    }

    fn supports(&self, nb: u64) -> bool {
        // row_start's a·n term must fit u64 (so must the pair index).
        nb >= 2 && (nb as u128) * (nb as u128 - 1) <= u64::MAX as u128
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        // Strict upper pairs, linearized into a near-square 2-D grid
        // (the GPU constraint: grids are orthotopes). Integer ceil-sqrt
        // width — the grid shape must not wobble with f64 either.
        let total = nb * (nb - 1) / 2;
        let s = isqrt_u64(total);
        let w = if s * s == total { s } else { s + 1 };
        Orthotope::d2(w.max(1), total.div_ceil(w.max(1)))
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        let grid_w = self.grid(nb, 0).dims[0];
        let k = w[1] * grid_w + w[0];
        if k >= nb * (nb - 1) / 2 {
            return None;
        }
        // Exact integer inversion — the f64 form misassigns rows from
        // n ≈ 2^28 (see module doc); the floats stay E11-only.
        let (a, b) = avril_map_isqrt(k, nb);
        // Convert upper pair (a < b) to the canonical lower-tri block
        // domain (col ≤ row): col = a, row = b.
        Some([a, b, 0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{domain_volume, in_domain};
    use std::collections::HashSet;

    #[test]
    fn f64_matches_exact_for_moderate_n() {
        for n in [4u64, 37, 256, 1000, 3000] {
            let total = n * (n - 1) / 2;
            let stride = (total / 4096).max(1);
            let mut k = 0;
            while k < total {
                assert_eq!(
                    avril_map_f64(k, n),
                    avril_map_exact(k, n),
                    "n={n}, k={k}"
                );
                k += stride;
            }
        }
    }

    #[test]
    fn exact_map_is_bijection() {
        let n = 64u64;
        let mut seen = HashSet::new();
        for k in 0..n * (n - 1) / 2 {
            let (a, b) = avril_map_exact(k, n);
            assert!(a < b && b < n, "k={k} → ({a},{b})");
            assert!(seen.insert((a, b)));
        }
        assert_eq!(seen.len() as u64, n * (n - 1) / 2);
    }

    #[test]
    fn isqrt_map_matches_exact_everywhere_small() {
        for n in [2u64, 3, 5, 17, 64, 301, 1000] {
            for k in 0..n * (n - 1) / 2 {
                assert_eq!(avril_map_isqrt(k, n), avril_map_exact(k, n), "n={n} k={k}");
            }
        }
    }

    #[test]
    fn f64_cliff_at_2p28_and_isqrt_exact_there() {
        // The f64 discriminant cancellation flips the row assignment at
        // nb = 2^28 (python-verified golden): one block lands on the
        // degenerate "pair" (n−1, n−1). The integer-Newton inversion
        // used by the block path stays exact at the same index — the
        // regression the old float inverse could not pass.
        let n = 1u64 << 28;
        let k = 36_028_796_884_746_239u64; // near total−1: max cancellation
        assert!(k < n * (n - 1) / 2);
        let exact = avril_map_exact(k, n);
        assert_eq!(exact, (n - 2, n - 1));
        assert_eq!(avril_row_f64(k, n), n - 1, "f64 misassigns the row");
        assert_ne!(avril_row_f64(k, n), exact.0);
        assert_eq!(avril_map_isqrt(k, n), exact);

        // Same shape at nb = 2^31.
        let n = 1u64 << 31;
        let k = 2_305_843_008_139_952_127u64;
        assert!(k < n * (n - 1) / 2);
        let exact = avril_map_exact(k, n);
        assert_eq!(exact, (n - 2, n - 1));
        assert_eq!(avril_row_f64(k, n), n - 1, "f64 misassigns the row");
        assert_eq!(avril_map_isqrt(k, n), exact);
    }

    #[test]
    fn isqrt_map_exact_at_sampled_large_sizes() {
        // Sampled agreement with the binary-search oracle across the
        // nb ∈ 2^24..2^32 range the ISSUE names, including the
        // cancellation-critical top of each range.
        for n in [1u64 << 24, (1 << 26) + 3, 1 << 28, 1 << 31, 1 << 32] {
            let total = n * (n - 1) / 2;
            let stride = total / 64 + 1;
            let mut k = 0u64;
            while k < total {
                assert_eq!(avril_map_isqrt(k, n), avril_map_exact(k, n), "n={n} k={k}");
                k += stride;
            }
            for k in [total - 1, total - 2, total - n / 2, total / 2] {
                assert_eq!(avril_map_isqrt(k, n), avril_map_exact(k, n), "n={n} k={k}");
            }
        }
    }

    #[test]
    fn block_path_survives_the_f64_cliff() {
        // map_block at nb = 2^28 must place the cliff block correctly
        // (it uses the isqrt inversion, not the f64 one).
        let nb = 1u64 << 28;
        let map = AvrilMap;
        assert!(map.supports(nb));
        let grid_w = map.grid(nb, 0).dims[0];
        let k = 36_028_796_884_746_239u64;
        let w = [k % grid_w, k / grid_w, 0];
        let d = map.map_block(nb, 0, w).expect("in range");
        assert_eq!((d[0], d[1]), (nb - 2, nb - 1));
    }

    #[test]
    fn f32_cliff_reproduced() {
        // E11: f32 map is exact for small n, degrades past n ≈ 3000.
        assert_eq!(f32_error_rate(512, 7), 0.0, "exact at n=512");
        assert_eq!(f32_error_rate(2000, 97), 0.0, "exact at n=2000");
        let big = f32_error_rate(20_000, 9973);
        assert!(big > 0.0, "errors must appear by n=20000: rate={big}");
    }

    #[test]
    fn block_interface_covers_strict_pairs() {
        let nb = 32u64;
        let map = AvrilMap;
        let mut seen = HashSet::new();
        for w in map.grid(nb, 0).iter() {
            if let Some(d) = map.map_block(nb, 0, w) {
                assert!(in_domain(nb, 2, d));
                assert!(d[0] < d[1], "strict pairs only");
                assert!(seen.insert((d[0], d[1])));
            }
        }
        // Strict pairs = inclusive domain minus the diagonal.
        assert_eq!(seen.len() as u128, domain_volume(nb, 2) - nb as u128);
    }
}
