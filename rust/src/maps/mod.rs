//! Block-space thread maps `λ: Z^m → Z^m` — the paper's subject.
//!
//! A [`ThreadMap`] takes a *parallel-space* block coordinate (a cell of
//! a grid orthotope, §I) and produces a *data-space* block coordinate
//! inside a discrete orthogonal m-simplex, or `None` when the parallel
//! block is structural filler that must be discarded. Maps may need
//! several launch *passes* (Ries-style recursive partition, the arity-3
//! λ of §III.B); single-pass maps use `passes() == 1`.
//!
//! ## Block-level domain conventions
//!
//! With ρ threads per block side and `n = N·ρ` the thread-level problem
//! size, the *block-level* domains are:
//!
//! - **m=2** — `B2(N) = { (bc, br) : bc ≤ br < N }` (lower-triangular
//!   block pairs *including* the diagonal): these are exactly the blocks
//!   that intersect the thread-level triangle, whether the workload
//!   wants `col < row` or `col ≤ row` (diagonal blocks predicate
//!   per-thread). `|B2| = N(N+1)/2 = V(Δ_N^2)`.
//! - **m=3** — `B3(N) = { (x, y, z) ∈ Z³₊ : x+y+z ≤ N-1 }` (simplex
//!   coordinates). `|B3| = V(Δ_N^3)`. Workloads over unique triples
//!   `k < j < i` convert with [`crate::simplex::point::simplex_to_tet_triple`].
//!
//! Every map here is validated by exhaustive coverage tests: the images
//! of all valid parallel blocks partition the block domain exactly
//! (λ2, λ3, RB, ENUM) or cover it with the predicted waste (BB).
//!
//! Dimensions above 3 live in [`mdim`] (the dynamic-coordinate
//! [`MThreadMap`] trait, into which these fixed maps adapt unchanged)
//! and [`lambda_m`] (the executable §III.D recursive map); the
//! all-dimensions registry is [`map_by_name`].

pub mod avril;
pub mod bounding_box;
pub mod enumeration;
pub mod lambda2;
pub mod lambda3;
pub mod lambda3_recursive;
pub mod lambda_gasket;
pub mod lambda_m;
pub mod lambda_scalable;
pub mod mdim;
pub mod nonpow2;
pub mod rectangular_box;
pub mod ries;

use crate::simplex::Orthotope;

pub use avril::{avril_map_f32, avril_map_f64, AvrilMap};
pub use bounding_box::{BoundingBox2, BoundingBox3};
pub use enumeration::{Enum2Map, Enum3Map};
pub use lambda2::Lambda2Map;
pub use lambda3::Lambda3Map;
pub use lambda3_recursive::Lambda3RecMap;
pub use lambda_gasket::{GasketBoundingBoxMap, GasketLambdaMap};
pub use lambda_m::LambdaMMap;
pub use lambda_scalable::{searched_width, LambdaScalable2, LambdaScalable3, LambdaScalableRho3};
pub use mdim::{
    adapt, alpha_m, in_domain_m, map_by_name, map_names, map_names_for, space_efficiency_m,
    BoundingBoxM, FixedAdapter, MThreadMap,
};

pub use crate::simplex::gasket::DomainKind;
pub use nonpow2::{CoverFromAbove, CoverFromBelow2};
pub use rectangular_box::RectangularBoxMap;
pub use ries::RiesMap;

/// A block-space thread map for an m-simplex domain.
pub trait ThreadMap: Send + Sync {
    /// Short name used in CLIs, benches and reports.
    fn name(&self) -> &'static str;

    /// Dimensionality of the data space (2 or 3 here).
    fn m(&self) -> u32;

    /// Whether the map accepts a problem of `nb` blocks per side
    /// (e.g. λ2/λ3 require `nb = 2^k` — §III.A's discussion).
    fn supports(&self, nb: u64) -> bool;

    /// Number of kernel launches required for one full mapping.
    fn passes(&self, _nb: u64) -> u64 {
        1
    }

    /// Grid (parallel orthotope, in blocks) of launch pass `pass`.
    fn grid(&self, nb: u64, pass: u64) -> Orthotope;

    /// Map parallel block `w` of pass `pass` to a data block, or `None`
    /// for filler blocks. Must be O(1) for the single-pass maps — this
    /// is the measured hot path.
    fn map_block(&self, nb: u64, pass: u64, w: [u64; 3]) -> Option<[u64; 3]>;

    /// Total parallel-space volume in blocks (all passes) — the paper's
    /// `V(Π)` that eq. 4/24 compare against `V(Δ)`.
    fn parallel_volume(&self, nb: u64) -> u128 {
        (0..self.passes(nb))
            .map(|p| self.grid(nb, p).volume())
            .sum()
    }
}

/// Number of *useful* data blocks for dimension m at block size nb.
pub fn domain_volume(nb: u64, m: u32) -> u128 {
    crate::simplex::volume::simplex_volume(nb, m)
}

/// Parallel-space efficiency `V(Δ) / V(Π)` ∈ (0, 1] — the figure of
/// merit of the whole paper (1.0 = zero wasted blocks).
pub fn space_efficiency(map: &dyn ThreadMap, nb: u64) -> f64 {
    domain_volume(nb, map.m()) as f64 / map.parallel_volume(nb) as f64
}

/// `V(Π)/V(Δ) - 1` — the paper's α waste ratio (eq. 4 / 24).
pub fn alpha(map: &dyn ThreadMap, nb: u64) -> f64 {
    map.parallel_volume(nb) as f64 / domain_volume(nb, map.m()) as f64 - 1.0
}

/// Whether a data block lies in the block-level domain (see module doc).
#[inline]
pub fn in_domain(nb: u64, m: u32, d: [u64; 3]) -> bool {
    match m {
        2 => d[0] <= d[1] && d[1] < nb,
        3 => d[0] + d[1] + d[2] <= nb - 1,
        _ => unreachable!("block domains defined for m ∈ {{2,3}}"),
    }
}

/// The single fixed-m registry table (m ∈ {2, 3}); the general-m entry
/// point is [`map_by_name`], which adapts these rows unchanged and adds
/// the m ≥ 4 natives (λ_m, BB_m).
pub fn fixed_map_by_name(m: u32, name: &str) -> Option<Box<dyn ThreadMap>> {
    match (m, name) {
        (2, "bb" | "bounding-box") => Some(Box::new(BoundingBox2)),
        (2, "lambda2" | "lambda") => Some(Box::new(Lambda2Map)),
        (2, "enum2" | "enum") => Some(Box::new(Enum2Map)),
        (2, "rb" | "rectangular-box") => Some(Box::new(RectangularBoxMap)),
        (2, "ries" | "rec") => Some(Box::new(RiesMap)),
        (2, "avril") => Some(Box::new(AvrilMap)),
        // λ_S (arXiv 2208.11617): exact at arbitrary nb, integer roots.
        (2, "lambda-s" | "scalable") => Some(Box::new(LambdaScalable2)),
        (3, "lambda-s" | "scalable") => Some(Box::new(LambdaScalable3)),
        // λ_S with the ρ-aware searched container width (per-nb W).
        (3, "lambda-sw" | "scalable-rho") => Some(Box::new(LambdaScalableRho3)),
        // §III.A non-power-of-two approaches (1: from above, 2: below).
        (2, "above2" | "from-above") => Some(Box::new(CoverFromAbove::new(Lambda2Map))),
        (2, "below2" | "from-below") => Some(Box::new(CoverFromBelow2)),
        (3, "bb" | "bounding-box") => Some(Box::new(BoundingBox3)),
        (3, "lambda3" | "lambda") => Some(Box::new(Lambda3Map)),
        (3, "enum3" | "enum") => Some(Box::new(Enum3Map)),
        (3, "lambda3-rec" | "rec3") => Some(Box::new(Lambda3RecMap)),
        _ => None,
    }
}

/// Registry: construct a 2-simplex map by name (thin wrapper).
pub fn map2_by_name(name: &str) -> Option<Box<dyn ThreadMap>> {
    fixed_map_by_name(2, name)
}

/// Registry: construct a 3-simplex map by name (thin wrapper).
pub fn map3_by_name(name: &str) -> Option<Box<dyn ThreadMap>> {
    fixed_map_by_name(3, name)
}

/// All registered 2-simplex map names (for CLIs and sweeps).
pub const MAP2_NAMES: &[&str] =
    &["bb", "lambda2", "enum2", "rb", "ries", "avril", "above2", "below2", "lambda-s"];
/// All registered 3-simplex map names.
pub const MAP3_NAMES: &[&str] = &["bb", "lambda3", "enum3", "lambda3-rec", "lambda-s", "lambda-sw"];
/// The gasket-domain map names (m = 2, [`DomainKind::Gasket`]) — listed
/// separately from [`MAP2_NAMES`] because they cover a different data
/// domain (the simplex conformance sweeps must not pick them up).
pub const GASKET_MAP_NAMES: &[&str] = &["bb-gasket", "lambda-gasket"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for name in MAP2_NAMES {
            let m = map2_by_name(name).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(m.m(), 2);
        }
        for name in MAP3_NAMES {
            let m = map3_by_name(name).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(m.m(), 3);
        }
        assert!(map2_by_name("nope").is_none());
    }

    #[test]
    fn fixed_registry_is_dimension_scoped() {
        assert!(fixed_map_by_name(2, "lambda2").is_some());
        assert!(fixed_map_by_name(3, "lambda2").is_none());
        assert!(fixed_map_by_name(2, "lambda3").is_none());
        assert!(fixed_map_by_name(4, "bb").is_none(), "m ≥ 4 is mdim's job");
    }

    #[test]
    fn domain_volume_matches_simplex_numbers() {
        assert_eq!(domain_volume(8, 2), 36); // 8·9/2
        assert_eq!(domain_volume(8, 3), 120); // 8·9·10/6
    }

    #[test]
    fn in_domain_m2_is_inclusive_lower_triangle() {
        assert!(in_domain(4, 2, [0, 0, 0]));
        assert!(in_domain(4, 2, [3, 3, 0]));
        assert!(in_domain(4, 2, [1, 3, 0]));
        assert!(!in_domain(4, 2, [3, 1, 0]));
        assert!(!in_domain(4, 2, [0, 4, 0]));
    }

    #[test]
    fn in_domain_m3_is_simplex() {
        assert!(in_domain(4, 3, [0, 0, 0]));
        assert!(in_domain(4, 3, [1, 1, 1]));
        assert!(!in_domain(4, 3, [2, 1, 1]));
        assert!(!in_domain(4, 3, [4, 0, 0]));
    }
}
