//! λ_m — the executable general-m recursive map (§III.D).
//!
//! The paper proves a recursive parallel space `S_n^m` with volume
//! `V(S_n^m) = (rn)^m + β·V(S_{rn}^m)` (eq. 25) covers the m-simplex
//! for `n ≥ n₀` when `r = m!^{-1/m}` and `2 ≤ β < m!`, at asymptotic
//! waste `β/(m!-β)` — ≈ m! better than a bounding box — but leaves the
//! packing (which parallel cell computes which simplex cell) open. This
//! module supplies one:
//!
//! - **Geometry** comes straight from the gensearch parametrization:
//!   [`GeneralSetParams::level_plan`] discretizes the recursion into
//!   integer levels (`β^i` orthotopes of side `round(r^{i+1} n)`), and
//!   a size is *covered* when the plan's volume reaches
//!   `V(Δ_n^m) = C(n+m-1, m)`. Each level launches as one pass with its
//!   `β^i` sub-orthotopes concatenated along the last grid axis.
//! - **Assignment** is the combinatorial number system: parallel cell
//!   ranks (pass-major, axis-0-minor) map to simplex cells in colex
//!   order through the prefix-sum bijection
//!   `x ↦ { c_i = x_1+…+x_i + (i-1) }` between `Bm(N)` and m-subsets of
//!   `{0, …, N+m-2}`. Unranking is O(m² log n) integer arithmetic per
//!   block — no floating-point roots, exact at every size. Ranks past
//!   `V(Δ)` are the structural filler (the measured waste, which equals
//!   the plan's closed form exactly and approaches eq. 27's β/(m!-β)).
//! - **Below the first covered size** the map falls back to §III.A's
//!   cover-from-above: run at the smallest covered `n' ≥ nb` and filter
//!   images to `Bm(nb)` — the same trade CoverFromAbove makes for λ2/λ3
//!   at non-power-of-two sizes.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::maps::mdim::{in_domain_m, MThreadMap};
use crate::simplex::block_m::{BlockM, OrthotopeM, M_MAX};
use crate::simplex::recursive_set::{GeneralSetParams, LevelPlan};
use crate::simplex::volume::{binomial, factorial, simplex_volume};

/// Default scan bound for covered sizes: far above every practical
/// grid, low enough that u128 simplex volumes cannot overflow at m ≤ 8.
pub const DEFAULT_HORIZON: u64 = 4096;

/// Per-native-size layout, cached because `map_block` is the hot path.
struct Layout {
    plan: LevelPlan,
    /// Rank base of each pass: Σ volumes of earlier levels.
    bases: Vec<u128>,
    /// `V(Δ_n^m)` — ranks at or above this are filler.
    domain: u128,
}

pub struct LambdaMMap {
    m: u32,
    beta: u32,
    params: GeneralSetParams,
    horizon: u64,
    layouts: RwLock<HashMap<u64, Arc<Layout>>>,
    /// nb → native size (the cover-from-above scan is O(horizon)).
    natives: RwLock<HashMap<u64, Option<u64>>>,
}

impl LambdaMMap {
    /// The paper parametrization: `r = m!^{-1/m}`, explicit arity β.
    pub fn for_paper(m: u32, beta: u32) -> LambdaMMap {
        Self::try_for_paper(m, beta)
            .unwrap_or_else(|| panic!("λ_m needs 2 ≤ m ≤ {M_MAX} and 2 ≤ β < m!"))
    }

    /// Non-panicking constructor (registry path for user-typed names).
    pub fn try_for_paper(m: u32, beta: u32) -> Option<LambdaMMap> {
        // lint: allow(cast, u32 to usize widens on every supported target)
        if m < 2 || m as usize > M_MAX || beta < 2 || (beta as u128) >= factorial(m) {
            return None;
        }
        Some(LambdaMMap {
            m,
            beta,
            params: GeneralSetParams::for_paper(m, beta as f64),
            horizon: DEFAULT_HORIZON,
            layouts: RwLock::new(HashMap::new()),
            natives: RwLock::new(HashMap::new()),
        })
    }

    /// Pick β automatically from the gensearch trade-off: the smallest
    /// power-of-two arity whose first covered size is ≤ 32 (waste grows
    /// with β, n₀ shrinks — §III.D), else the β minimizing the first
    /// covered size. None when no arity covers within the horizon.
    pub fn auto(m: u32) -> Option<LambdaMMap> {
        let mut candidates = Vec::new();
        let mut beta = 2u32;
        while (beta as u128) < factorial(m) {
            let p = GeneralSetParams::for_paper(m, beta as f64);
            if let Some(fc) = p.first_covered(2, 512) {
                candidates.push((beta, fc));
            }
            beta = beta.checked_mul(2)?;
        }
        let pick = candidates
            .iter()
            .find(|(_, fc)| *fc <= 32)
            .or_else(|| candidates.iter().min_by_key(|(_, fc)| *fc))?;
        Self::try_for_paper(m, pick.0)
    }

    pub fn beta(&self) -> u32 {
        self.beta
    }

    pub fn r(&self) -> f64 {
        self.params.r
    }

    /// Whether the discretized recursion covers `Bm(nb)` natively.
    pub fn covered(&self, nb: u64) -> bool {
        nb >= 2 && self.params.discrete_covers(nb)
    }

    /// The size the map actually runs at: `nb` when covered, else the
    /// smallest covered size above it (cover-from-above fallback).
    /// Cached: `map_block` resolves this per call, and re-evaluating
    /// the level plan (allocations + float math) per block would
    /// dominate the hot path the benches measure.
    pub fn native_size(&self, nb: u64) -> Option<u64> {
        if let Some(n) = self.natives.read().unwrap().get(&nb) {
            return *n;
        }
        let native = if self.covered(nb) {
            Some(nb)
        } else {
            self.params.first_covered(nb.max(2), self.horizon)
        };
        self.natives.write().unwrap().insert(nb, native);
        native
    }

    fn layout(&self, native: u64) -> Arc<Layout> {
        if let Some(l) = self.layouts.read().unwrap().get(&native) {
            return Arc::clone(l);
        }
        let plan = self
            .params
            .level_plan(native)
            .expect("supports() guards plan overflow");
        let mut bases = Vec::with_capacity(plan.levels());
        let mut acc = 0u128;
        for i in 0..plan.levels() {
            bases.push(acc);
            acc += plan.level_volume(i).expect("supports() guards volume");
        }
        let layout = Arc::new(Layout {
            plan,
            bases,
            domain: simplex_volume(native, self.m),
        });
        self.layouts
            .write()
            .unwrap()
            .entry(native)
            .or_insert(layout)
            .clone()
    }

    fn pass_grid(&self, layout: &Layout, pass: u64) -> OrthotopeM {
        // lint: allow(cast, pass < plan.levels <= M_MAX)
        let i = pass as usize;
        let side = layout.plan.sides[i];
        // lint: allow(cast, u64 grid-dims contract: count * side <= u64::MAX)
        let count = layout.plan.counts[i] as u64;
        let mut dims = [side; M_MAX];
        // lint: allow(cast, u32 to usize widens)
        dims[self.m as usize - 1] = count * side;
        // lint: allow(cast, u32 to usize widens)
        OrthotopeM::new(&dims[..self.m as usize])
    }

    /// Colex unranking through the combinatorial number system:
    /// rank `t` → the m-subset `c_m > … > c_1` with `Σ C(c_i, i) = t`
    /// (greedy, binary-searched), then prefix-sum differences give the
    /// simplex cell.
    fn unrank(&self, mut t: u128, native: u64) -> BlockM {
        // lint: allow(cast, u32 to usize widens)
        let m = self.m as usize;
        let mut cs = [0u64; M_MAX];
        // lint: allow(cast, u32 to u64 widens)
        let mut ub = native + self.m as u64 - 2;
        for i in (1..=m).rev() {
            let k = i as u128;
            // lint: allow(cast, i is at most m <= M_MAX)
            let (mut lo, mut hi) = (i as u64 - 1, ub);
            while lo < hi {
                let mid = lo + (hi - lo + 1) / 2;
                if binomial(mid as u128, k) <= t {
                    lo = mid;
                } else {
                    hi = mid - 1;
                }
            }
            cs[i - 1] = lo;
            t -= binomial(lo as u128, k);
            ub = lo.saturating_sub(1);
        }
        debug_assert_eq!(t, 0);
        let mut x = BlockM::zeros(self.m);
        x[0] = cs[0];
        for i in 1..m {
            x[i] = cs[i] - cs[i - 1] - 1;
        }
        x
    }
}

impl MThreadMap for LambdaMMap {
    fn name(&self) -> String {
        format!("lambda-m-b{}", self.beta)
    }

    fn m(&self) -> u32 {
        self.m
    }

    fn supports(&self, nb: u64) -> bool {
        if nb < 2 {
            return false;
        }
        let Some(native) = self.native_size(nb) else {
            return false;
        };
        // Ranks and per-pass linear indices must fit u64.
        match self.params.discrete_volume(native) {
            Some(v) => v <= u64::MAX as u128,
            None => false,
        }
    }

    fn passes(&self, nb: u64) -> u64 {
        let native = self.native_size(nb).expect("unsupported nb");
        // lint: allow(cast, usize to u64 widens here)
        self.layout(native).plan.levels() as u64
    }

    fn grid(&self, nb: u64, pass: u64) -> OrthotopeM {
        let native = self.native_size(nb).expect("unsupported nb");
        self.pass_grid(&self.layout(native), pass)
    }

    #[inline]
    fn map_block(&self, nb: u64, pass: u64, w: &BlockM) -> Option<BlockM> {
        let native = self.native_size(nb).expect("unsupported nb");
        let layout = self.layout(native);
        let grid = self.pass_grid(&layout, pass);
        // lint: allow(cast, pass < plan.levels <= M_MAX)
        let t = layout.bases[pass as usize] + grid.linear_of(w) as u128;
        if t >= layout.domain {
            return None; // structural filler past V(Δ)
        }
        let x = self.unrank(t, native);
        if native == nb || in_domain_m(nb, self.m, &x) {
            Some(x)
        } else {
            None // cover-from-above: outside the true (smaller) domain
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::domain_volume;
    use crate::maps::mdim::space_efficiency_m;
    use std::collections::HashSet;

    fn sweep(map: &LambdaMMap, nb: u64) -> (u128, u128, HashSet<BlockM>) {
        let mut seen = HashSet::new();
        let mut filler = 0u128;
        let mut parallel = 0u128;
        for pass in 0..map.passes(nb) {
            for w in map.grid(nb, pass).iter() {
                parallel += 1;
                match map.map_block(nb, pass, &w) {
                    None => filler += 1,
                    Some(d) => {
                        assert!(in_domain_m(nb, map.m(), &d), "{w:?} → {d:?}");
                        assert!(seen.insert(d), "dup image {d:?} from {w:?}");
                    }
                }
            }
        }
        (parallel, filler, seen)
    }

    #[test]
    fn unrank_is_a_bijection() {
        for (m, n) in [(4u32, 6u64), (5, 5), (3, 8), (6, 4)] {
            let map = LambdaMMap::for_paper(m, 2);
            let vol = domain_volume(n, m);
            let mut seen = HashSet::new();
            for t in 0..vol {
                let x = map.unrank(t, n);
                assert!(x.sum() <= n - 1, "t={t} → {x:?}");
                assert!(seen.insert(x), "t={t} duplicates {x:?}");
            }
            assert_eq!(seen.len() as u128, vol, "m={m} n={n}");
        }
    }

    #[test]
    fn native_partition_m4() {
        // Python cross-check: n=28 → parallel 31501, filler 36.
        let map = LambdaMMap::for_paper(4, 2);
        assert!(map.covered(28));
        let (parallel, filler, seen) = sweep(&map, 28);
        assert_eq!(parallel, 31501);
        assert_eq!(filler, 36);
        assert_eq!(seen.len() as u128, domain_volume(28, 4));
        assert_eq!(parallel, map.parallel_volume(28));
    }

    #[test]
    fn native_partition_m5() {
        // Python cross-check: n=4 → 64/8; n=9 → 1299/12.
        let map = LambdaMMap::for_paper(5, 32);
        let (parallel, filler, seen) = sweep(&map, 4);
        assert_eq!((parallel, filler), (64, 8));
        assert_eq!(seen.len() as u128, domain_volume(4, 5));
        let (parallel, filler, _) = sweep(&map, 9);
        assert_eq!((parallel, filler), (1299, 12));
    }

    #[test]
    fn fallback_covers_uncovered_sizes_from_above() {
        // nb=5 is uncovered for (m=5, β=32); runs at n'=9, filters.
        let map = LambdaMMap::for_paper(5, 32);
        assert!(!map.covered(5));
        assert_eq!(map.native_size(5), Some(9));
        let (parallel, filler, seen) = sweep(&map, 5);
        assert_eq!(parallel, 1299);
        assert_eq!(seen.len() as u128, domain_volume(5, 5));
        assert_eq!(filler, parallel - domain_volume(5, 5));
    }

    #[test]
    fn auto_picks_cross_checked_arities() {
        // Python: m=4 → β=2 (fc 28), m=5 → β=16 (fc 17), m=6 → β=128.
        assert_eq!(LambdaMMap::auto(4).unwrap().beta(), 2);
        assert_eq!(LambdaMMap::auto(5).unwrap().beta(), 16);
        assert_eq!(LambdaMMap::auto(6).unwrap().beta(), 128);
    }

    #[test]
    fn efficiency_beats_bounding_box_at_first_covered_size() {
        // Acceptance: ≥ 3× over BB at the first covered size for m=4
        // (measured: 19.5×).
        let map = LambdaMMap::for_paper(4, 2);
        let bb = crate::maps::mdim::BoundingBoxM::new(4);
        let nb = 28;
        let ratio = space_efficiency_m(&map, nb) / space_efficiency_m(&bb, nb);
        assert!(ratio >= 3.0, "λ_m/BB = {ratio}");
        assert!(ratio > 15.0, "cross-check says ≈19.5, got {ratio}");
    }

    #[test]
    fn name_round_trips_through_registry() {
        let map = LambdaMMap::for_paper(5, 32);
        let again = crate::maps::map_by_name(5, &map.name()).unwrap();
        assert_eq!(again.name(), map.name());
        assert_eq!(again.m(), 5);
    }

    #[test]
    fn invalid_parameters_rejected() {
        assert!(LambdaMMap::try_for_paper(4, 24).is_none(), "β = m!");
        assert!(LambdaMMap::try_for_paper(4, 1).is_none());
        assert!(LambdaMMap::try_for_paper(9, 2).is_none(), "m > M_MAX");
        assert!(!LambdaMMap::for_paper(4, 2).supports(1));
    }
}
