//! The bounding-box (BB) baseline the paper argues against (§I, Figs.
//! 2-3): an orthotope large enough to cover the simplex with the
//! identity map `f(x) = x`, discarding out-of-domain blocks by
//! predicate. Waste approaches `m! - 1` (eq. 4).

use crate::maps::{in_domain, ThreadMap};
use crate::simplex::Orthotope;

/// BB for the 2-simplex: an N×N grid, keep blocks with `bc ≤ br`.
pub struct BoundingBox2;

impl ThreadMap for BoundingBox2 {
    fn name(&self) -> &'static str {
        "bb2"
    }

    fn m(&self) -> u32 {
        2
    }

    fn supports(&self, nb: u64) -> bool {
        nb >= 1
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        Orthotope::d2(nb, nb)
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        // Identity map + predicate — the whole point of the paper is
        // that `nb(nb-1)/2` blocks die on this branch.
        if in_domain(nb, 2, w) {
            Some(w)
        } else {
            None
        }
    }
}

/// BB for the 3-simplex: an N×N×N grid, keep `x+y+z ≤ N-1`.
pub struct BoundingBox3;

impl ThreadMap for BoundingBox3 {
    fn name(&self) -> &'static str {
        "bb3"
    }

    fn m(&self) -> u32 {
        3
    }

    fn supports(&self, nb: u64) -> bool {
        nb >= 1
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        Orthotope::d3(nb, nb, nb)
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        if in_domain(nb, 3, w) {
            Some(w)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{alpha, domain_volume};

    #[test]
    fn bb2_keeps_exactly_the_domain() {
        let map = BoundingBox2;
        let nb = 16;
        let kept: Vec<_> = map
            .grid(nb, 0)
            .iter()
            .filter_map(|w| map.map_block(nb, 0, w))
            .collect();
        assert_eq!(kept.len() as u128, domain_volume(nb, 2));
        // Identity: every kept block maps to itself.
        for k in &kept {
            assert!(k[0] <= k[1] && k[1] < nb);
        }
    }

    #[test]
    fn bb3_keeps_exactly_the_domain() {
        let map = BoundingBox3;
        let nb = 10;
        let kept = map
            .grid(nb, 0)
            .iter()
            .filter_map(|w| map.map_block(nb, 0, w))
            .count();
        assert_eq!(kept as u128, domain_volume(nb, 3));
    }

    #[test]
    fn bb2_alpha_approaches_1() {
        // Fig. 2: parallel space ≈ 2× data space → α → 1.
        let a = alpha(&BoundingBox2, 1 << 12);
        assert!((a - 1.0).abs() < 1e-3, "α={a}");
    }

    #[test]
    fn bb3_alpha_approaches_5() {
        // Fig. 3: BB ≈ 6× the tetrahedron → α → 5.
        let a = alpha(&BoundingBox3, 1 << 10);
        assert!((a - 5.0).abs() < 2e-2, "α={a}");
    }

    #[test]
    fn bb_single_pass_any_size() {
        assert_eq!(BoundingBox2.passes(17), 1);
        assert!(BoundingBox2.supports(17));
        assert!(BoundingBox3.supports(1000));
    }
}
