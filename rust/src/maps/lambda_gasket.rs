//! Block-space maps for the embedded Sierpiński gasket (arXiv:1706.04552
//! brought into the unified [`MThreadMap`] engine) — the first maps
//! whose data domain is *not* an orthogonal simplex.
//!
//! - [`GasketLambdaMap`] (`lambda-gasket`) — the recursive block-space
//!   map λ_Δ: a compact parallel orthotope of exactly `3^k` blocks,
//!   each sent to one gasket block by an O(k) base-3 digit descent
//!   (0 = top, 1 = bottom-left, 2 = bottom-right sub-triangle). Zero
//!   filler, space efficiency 1.0.
//! - [`GasketBoundingBoxMap`] (`bb-gasket`) — the baseline: launch the
//!   gasket's tight `nb × nb` bounding box and predicate-discard every
//!   non-gasket block. `4^k − 3^k` filler blocks, so λ_Δ improves the
//!   parallel space by exactly `(4/3)^k` — ≈5.6× at k = 6 and
//!   unbounded in k, the fractal counterpart of eq. 4's `m! − 1`.
//!
//! Both report [`DomainKind::Gasket`] and override
//! [`MThreadMap::domain_volume`] to `3^k`, so the engine's
//! waste/efficiency accounting compares them on the *gasket* cell
//! count, not the simplex closed form.

use crate::maps::MThreadMap;
use crate::simplex::block_m::{BlockM, OrthotopeM};
use crate::simplex::gasket::{gasket_cell, gasket_order, gasket_volume, in_gasket, DomainKind};

/// λ_Δ — the recursive gasket map. Stateless: the whole layout is the
/// digit arithmetic (O(log nb) per block, like the source paper's
/// recursive descent).
pub struct GasketLambdaMap;

impl GasketLambdaMap {
    /// Parallel grid for order k: a balanced two-axis factorization of
    /// `3^k` (`3^⌈k/2⌉ × 3^⌊k/2⌋`), keeping both grid dimensions small
    /// the way a real CUDA launch would.
    fn grid_for(k: u32) -> OrthotopeM {
        OrthotopeM::new(&[3u64.pow(k.div_ceil(2)), 3u64.pow(k / 2)])
    }
}

impl MThreadMap for GasketLambdaMap {
    fn name(&self) -> String {
        "lambda-gasket".into()
    }

    fn m(&self) -> u32 {
        2
    }

    fn domain(&self) -> DomainKind {
        DomainKind::Gasket
    }

    fn domain_volume(&self, nb: u64) -> u128 {
        gasket_order(nb).map_or(0, gasket_volume)
    }

    fn supports(&self, nb: u64) -> bool {
        gasket_order(nb).is_some()
    }

    fn grid(&self, nb: u64, _pass: u64) -> OrthotopeM {
        Self::grid_for(gasket_order(nb).expect("supports() gates nb"))
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: &BlockM) -> Option<BlockM> {
        let k = gasket_order(nb)?;
        // Linear rank in grid order (axis 0 fastest, matching
        // OrthotopeM::linear_of).
        let t = w[1] * 3u64.pow(k.div_ceil(2)) + w[0];
        let (col, row) = gasket_cell(k, t);
        Some(BlockM::from_slice(&[col, row]))
    }
}

/// BB_Δ — the gasket bounding-box baseline: identity over the full
/// `nb × nb` grid plus the membership predicate.
pub struct GasketBoundingBoxMap;

impl MThreadMap for GasketBoundingBoxMap {
    fn name(&self) -> String {
        "bb-gasket".into()
    }

    fn m(&self) -> u32 {
        2
    }

    fn domain(&self) -> DomainKind {
        DomainKind::Gasket
    }

    fn domain_volume(&self, nb: u64) -> u128 {
        gasket_order(nb).map_or(0, gasket_volume)
    }

    fn supports(&self, nb: u64) -> bool {
        gasket_order(nb).is_some()
    }

    fn grid(&self, nb: u64, _pass: u64) -> OrthotopeM {
        OrthotopeM::new(&[nb, nb])
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: &BlockM) -> Option<BlockM> {
        if in_gasket(nb, w[0], w[1]) {
            Some(*w)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{alpha_m, space_efficiency_m};
    use crate::simplex::gasket::enumerate_gasket;
    use std::collections::HashSet;

    fn images(map: &dyn MThreadMap, nb: u64) -> (HashSet<(u64, u64)>, u64) {
        let mut seen = HashSet::new();
        let mut filler = 0u64;
        for pass in 0..map.passes(nb) {
            for w in map.grid(nb, pass).iter() {
                match map.map_block(nb, pass, &w) {
                    None => filler += 1,
                    Some(d) => {
                        assert!(seen.insert((d[0], d[1])), "dup {:?}", d.as_slice());
                    }
                }
            }
        }
        (seen, filler)
    }

    #[test]
    fn lambda_gasket_partitions_with_zero_filler() {
        for k in 0..=5u32 {
            let nb = 1u64 << k;
            let (seen, filler) = images(&GasketLambdaMap, nb);
            assert_eq!(filler, 0, "k={k}");
            let scan: HashSet<_> = enumerate_gasket(nb).into_iter().collect();
            assert_eq!(seen, scan, "k={k}");
        }
    }

    #[test]
    fn bb_gasket_covers_with_4k_minus_3k_filler() {
        for k in 0..=5u32 {
            let nb = 1u64 << k;
            let (seen, filler) = images(&GasketBoundingBoxMap, nb);
            let scan: HashSet<_> = enumerate_gasket(nb).into_iter().collect();
            assert_eq!(seen, scan, "k={k}");
            assert_eq!(filler as u128, 4u128.pow(k) - 3u128.pow(k), "k={k}");
        }
    }

    #[test]
    fn gasket_grid_is_balanced() {
        let g = GasketLambdaMap.grid(64, 0); // k=6 → 27 × 27
        assert_eq!(g.dims.as_slice(), &[27, 27]);
        let g = GasketLambdaMap.grid(32, 0); // k=5 → 27 × 9
        assert_eq!(g.dims.as_slice(), &[27, 9]);
        assert_eq!(GasketLambdaMap.parallel_volume(32), 243);
    }

    #[test]
    fn efficiency_uses_the_gasket_domain_volume() {
        // space_efficiency_m divides by the map's own domain volume —
        // 3^k here, not the simplex nb(nb+1)/2.
        let nb = 64u64;
        assert_eq!(GasketLambdaMap.domain_volume(nb), 729);
        assert!((space_efficiency_m(&GasketLambdaMap, nb) - 1.0).abs() < 1e-12);
        assert!(
            (space_efficiency_m(&GasketBoundingBoxMap, nb) - 0.75f64.powi(6)).abs() < 1e-12
        );
        assert!((alpha_m(&GasketLambdaMap, nb)).abs() < 1e-12);
    }

    #[test]
    fn improvement_matches_4_thirds_pow_k() {
        // The acceptance golden: parallel-space improvement over the
        // bounding box is (4/3)^k, within 1% at k = 6 (it is exact).
        let nb = 64u64;
        let ratio = GasketBoundingBoxMap.parallel_volume(nb) as f64
            / GasketLambdaMap.parallel_volume(nb) as f64;
        let closed = (4f64 / 3f64).powi(6);
        assert!(
            (ratio - closed).abs() / closed < 0.01,
            "{ratio} vs {closed}"
        );
        assert_eq!(GasketLambdaMap.parallel_volume(nb), 729);
        assert_eq!(GasketBoundingBoxMap.parallel_volume(nb), 4096);
    }

    #[test]
    fn unsupported_sizes_rejected() {
        assert!(!GasketLambdaMap.supports(12));
        assert!(!GasketBoundingBoxMap.supports(0));
        assert!(GasketLambdaMap.supports(1), "k=0 is one block");
        let (seen, filler) = images(&GasketLambdaMap, 1);
        assert_eq!((seen.len(), filler), (1, 0));
    }
}
