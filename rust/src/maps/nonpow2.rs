//! Non-power-of-two problem sizes (§III.A's two approaches).
//!
//! Approach 1 ("from above"): run the recursive map at
//! `N' = 2^⌈log2 N⌉` and filter the blocks that land outside the real
//! domain — simple, costs extra blocks (bounded by the test below).
//!
//! Approach 2 ("from below") is a set of shrinking power-of-two
//! sub-maps; it adds no waste but needs one launch per sub-orthotope.
//! We implement approach 1 as a generic wrapper (what the paper deems
//! practical — "in many cases it is possible to adapt the problem size
//! to n = 2^k") and account approach 2's launch count analytically in
//! the E1 report.

use crate::maps::{in_domain, ThreadMap};
use crate::simplex::volume::next_pow2;
use crate::simplex::Orthotope;

/// Wrap a power-of-two-only map so it accepts any `nb ≥ 2` by rounding
/// the parallel structure up and filtering.
pub struct CoverFromAbove<M: ThreadMap> {
    pub inner: M,
}

impl<M: ThreadMap> CoverFromAbove<M> {
    pub fn new(inner: M) -> Self {
        CoverFromAbove { inner }
    }
}

impl<M: ThreadMap> ThreadMap for CoverFromAbove<M> {
    fn name(&self) -> &'static str {
        "cover-from-above"
    }

    fn m(&self) -> u32 {
        self.inner.m()
    }

    fn supports(&self, nb: u64) -> bool {
        nb >= 2 && self.inner.supports(next_pow2(nb))
    }

    fn passes(&self, nb: u64) -> u64 {
        self.inner.passes(next_pow2(nb))
    }

    fn grid(&self, nb: u64, pass: u64) -> Orthotope {
        self.inner.grid(next_pow2(nb), pass)
    }

    #[inline]
    fn map_block(&self, nb: u64, pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        let d = self.inner.map_block(next_pow2(nb), pass, w)?;
        // Keep only blocks inside the true (smaller) domain.
        if in_domain(nb, self.m(), d) {
            Some(d)
        } else {
            None
        }
    }
}

/// Approach 2 ("from below") for m=2: decompose `nb` into its binary
/// segments `nb = Σ 2^{k_i}` laid along the diagonal. Segment i
/// (size s_i, starting at row offset `o_i = Σ_{j<i} s_j`) contributes
///
/// - one λ2 pass over its own inclusive sub-triangle (rows/cols
///   `[o_i, o_i+s_i)`), and
/// - one plain rectangular pass `s_i × o_i` for the block rectangle
///   `rows [o_i, o_i+s_i) × cols [0, o_i)` (fully inside the domain).
///
/// Zero filler blocks for *any* nb — the paper's trade: no waste, but
/// `2·popcount(nb) - 1` launches instead of 1.
pub struct CoverFromBelow2;

impl CoverFromBelow2 {
    /// (segment size, row offset) per binary digit, largest first.
    fn segments(nb: u64) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut offset = 0u64;
        for bit in (0..64).rev() {
            let s = 1u64 << bit;
            if nb & s != 0 {
                out.push((s, offset));
                offset += s;
            }
        }
        out
    }
}

impl ThreadMap for CoverFromBelow2 {
    fn name(&self) -> &'static str {
        "from-below2"
    }

    fn m(&self) -> u32 {
        2
    }

    fn supports(&self, nb: u64) -> bool {
        nb >= 1
    }

    /// One triangle pass per segment + one rectangle pass per segment
    /// after the first.
    fn passes(&self, nb: u64) -> u64 {
        // lint: allow(cast, count_ones is u32, widening)
        2 * nb.count_ones() as u64 - 1
    }

    fn grid(&self, nb: u64, pass: u64) -> Orthotope {
        let segs = Self::segments(nb);
        // lint: allow(cast, pass < passes = 2*popcount-1 <= 127)
        let i = (pass as usize + 1) / 2;
        let (s, o) = segs[i];
        if pass % 2 == 1 {
            // Rectangle pass for segment i ≥ 1.
            Orthotope::d2(o, s)
        } else if s == 1 {
            // A size-1 triangle is a single diagonal block.
            Orthotope::d2(1, 1)
        } else {
            // λ2-inclusive grid for the segment's sub-triangle.
            Orthotope::d2(s / 2, s + 1)
        }
    }

    #[inline]
    fn map_block(&self, nb: u64, pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        let segs = Self::segments(nb);
        // lint: allow(cast, pass < passes = 2*popcount-1 <= 127)
        let i = (pass as usize + 1) / 2;
        let (s, o) = segs[i];
        if pass % 2 == 1 {
            // Rectangle: cols [0, o) × rows [o, o+s).
            Some([w[0], o + w[1], 0])
        } else if s == 1 {
            Some([o, o, 0])
        } else {
            let (c, r) = crate::maps::lambda2::lambda2_inclusive(s, w[0], w[1]);
            Some([o + c, o + r, 0])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{domain_volume, Lambda2Map, Lambda3Map};
    use std::collections::HashSet;

    #[test]
    fn from_below_exact_for_arbitrary_sizes() {
        // Approach 2 (§III.A): zero waste at every size.
        for nb in [1u64, 2, 3, 5, 7, 11, 12, 21, 31, 33, 63, 64, 100] {
            let map = CoverFromBelow2;
            let mut seen = HashSet::new();
            for pass in 0..map.passes(nb) {
                for w in map.grid(nb, pass).iter() {
                    let d = map.map_block(nb, pass, w).expect("no filler");
                    assert!(in_domain(nb, 2, d), "nb={nb} pass={pass} {w:?}→{d:?}");
                    assert!(seen.insert((d[0], d[1])), "nb={nb} dup {d:?}");
                }
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, 2), "nb={nb}");
            // Zero waste: parallel volume == domain volume.
            assert_eq!(map.parallel_volume(nb), domain_volume(nb, 2), "nb={nb}");
        }
    }

    #[test]
    fn from_below_pass_count_is_popcount_based() {
        assert_eq!(CoverFromBelow2.passes(64), 1); // one power of two
        assert_eq!(CoverFromBelow2.passes(63), 11); // six bits → 2·6−1
        assert_eq!(CoverFromBelow2.passes(5), 3); // 101 → 2·2−1
    }

    #[test]
    fn approaches_trade_waste_for_launches() {
        // The §III.A trade-off, quantified: from-above wastes blocks
        // but launches once; from-below wastes nothing but launches
        // O(popcount) times.
        let nb = 21u64; // 10101: worst-ish case
        let above = CoverFromAbove::new(Lambda2Map);
        let below = CoverFromBelow2;
        assert!(above.parallel_volume(nb) > domain_volume(nb, 2));
        assert_eq!(below.parallel_volume(nb), domain_volume(nb, 2));
        assert_eq!(above.passes(nb), 1);
        assert_eq!(below.passes(nb), 5);
    }

    #[test]
    fn covers_arbitrary_sizes_m2() {
        for nb in [3u64, 5, 7, 12, 25, 63, 100] {
            let map = CoverFromAbove::new(Lambda2Map);
            assert!(map.supports(nb));
            let mut seen = HashSet::new();
            for pass in 0..map.passes(nb) {
                for w in map.grid(nb, pass).iter() {
                    if let Some(d) = map.map_block(nb, pass, w) {
                        assert!(in_domain(nb, 2, d));
                        assert!(seen.insert((d[0], d[1])), "dup {d:?} nb={nb}");
                    }
                }
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, 2), "nb={nb}");
        }
    }

    #[test]
    fn covers_arbitrary_sizes_m3() {
        for nb in [5u64, 9, 13, 27] {
            let map = CoverFromAbove::new(Lambda3Map);
            let mut seen = HashSet::new();
            for pass in 0..map.passes(nb) {
                for w in map.grid(nb, pass).iter() {
                    if let Some(d) = map.map_block(nb, pass, w) {
                        assert!(in_domain(nb, 3, d));
                        assert!(seen.insert((d[0], d[1], d[2])), "dup {d:?} nb={nb}");
                    }
                }
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, 3), "nb={nb}");
        }
    }

    #[test]
    fn waste_bounded_by_four_x() {
        // Rounding N up to 2^⌈log2⌉ at worst ~quadruples the m=2
        // parallel volume (just under a power of two it's ~1×).
        let map = CoverFromAbove::new(Lambda2Map);
        for nb in [9u64, 100, 1000] {
            let waste =
                map.parallel_volume(nb) as f64 / domain_volume(nb, 2) as f64;
            assert!(waste < 4.0 + 0.5, "nb={nb}: {waste}");
        }
        // Just below a power of two the overhead is tiny.
        let w = map.parallel_volume(63) as f64 / domain_volume(63, 2) as f64;
        assert!(w < 1.1, "{w}");
    }

    #[test]
    fn pow2_sizes_add_no_waste() {
        let map = CoverFromAbove::new(Lambda2Map);
        assert_eq!(map.parallel_volume(64), domain_volume(64, 2));
    }
}
