//! λ_S — the *scalable* block-rearrangement map of the follow-up paper
//! ("A Scalable and Energy Efficient GPU Thread Map for m-Simplex
//! Domains", arXiv 2208.11617): rearrange the simplex's blocks onto a
//! compact orthotopal grid by inverting the simplex enumeration *at
//! block granularity* with exact integer Newton roots
//! ([`crate::util::isqrt`]) — no float inverse anywhere, so the map
//! stays exact at arbitrary `nb`, not just the powers of two λ2/λ3
//! require and not just the f64-safe sizes the thread-space inverses
//! survive.
//!
//! ## m = 2 — half-width grid, zero waste at *every* size
//!
//! The grid is the half-width orthotope `w × h` with `w = ⌈nb/2⌉` and
//! `h = T(nb)/w` — an *exact* division for every nb, because
//! `T(nb) = nb(nb+1)/2` always factors through `⌈nb/2⌉`:
//!
//! ```text
//! nb even:  T = (nb/2)·(nb+1)        → grid (nb/2) × (nb+1)
//! nb odd:   T = ((nb+1)/2)·nb        → grid ((nb+1)/2) × nb
//! ```
//!
//! Block `(x, y)` takes linear rank `k = y·w + x ∈ [0, T(nb))` and is
//! rearranged to the k-th block of the inclusive lower triangle in
//! row-major order: `row = triangular_root(k)`, `col = k − T(row)`.
//! That is a bijection `[0, T(nb)) ↔ B2(nb)` (standard triangular
//! unranking), so the parallel space *equals* the domain — the paper's
//! 2×-over-BB headline — at every single size. λ2 achieves the same
//! ratio with cheaper per-block arithmetic but only at `nb = 2^k`;
//! λ_S is the production map for everything else.
//!
//! ## m = 3 — the tetrahedral extension
//!
//! Same rearrangement one dimension up: a half-width-based container
//! `W × W × L` with `W = ⌈nb/2⌉` and `L = ⌈Tet(nb)/W²⌉` (just enough
//! layers), linear rank `k`, and the two-stage descent
//! `slab = tetrahedral_root(k)`, then the triangular unranking inside
//! the slab `Σ x_i = slab`. Waste is only the final-layer rounding,
//! `W²·L − Tet(nb) < W²` — strictly tighter than λ3's container slack
//! of 12.5% (at nb = 32: 6144 launched vs λ3's 6912, exactly 1.125×
//! tighter; python-cross-checked) and again available at every nb.
//!
//! Exhaustive conformance (partition, zero double-coverage, closed-form
//! waste) for all nb ≤ 64 at m = 2 and nb ≤ 32 at m = 3 lives in
//! `tests/map_conformance.rs`; E16 in DESIGN.md has the derivation.

use crate::maps::ThreadMap;
use crate::simplex::volume::triangular;
use crate::simplex::Orthotope;
use crate::util::isqrt::{tetrahedral_root, tetrahedron, triangular_root};

/// Half-width grid width shared by both dimensions: `⌈nb/2⌉`.
#[inline(always)]
pub fn scalable_width(nb: u64) -> u64 {
    nb.div_ceil(2)
}

/// The m = 2 rearrangement: linear block rank → inclusive lower-tri
/// pair `(col, row)`, `col ≤ row` (one integer Newton isqrt). Exact
/// for every rank in the `supports()` range, i.e. rows below 2³²,
/// where `row·(row+1)` stays inside u64.
#[inline(always)]
pub fn lambda_s2(k: u64) -> (u64, u64) {
    let row = triangular_root(k);
    (k - row * (row + 1) / 2, row)
}

/// The m = 3 rearrangement: linear block rank → simplex coordinate
/// `(x, y, z)` with `x+y+z = slab` (two integer Newton roots).
#[inline(always)]
pub fn lambda_s3(k: u64) -> (u64, u64, u64) {
    let slab = tetrahedral_root(k);
    // lint: allow(cast, Tet of tetrahedral_root of k is at most k, a u64)
    let rem = k - tetrahedron(slab) as u64;
    let row = triangular_root(rem);
    let col = rem - row * (row + 1) / 2;
    (col, row - col, slab - row)
}

/// λ_S for the 2-simplex: half-width grid, zero filler at every nb.
pub struct LambdaScalable2;

impl LambdaScalable2 {
    /// Grid height `T(nb)/w` — exact division (module doc).
    #[inline]
    fn height(nb: u64) -> u64 {
        // lint: allow(cast, quotient <= T-of-nb which fits u64 for supported nb)
        (triangular(nb) / scalable_width(nb) as u128) as u64
    }
}

impl ThreadMap for LambdaScalable2 {
    fn name(&self) -> &'static str {
        "lambda-s"
    }

    fn m(&self) -> u32 {
        2
    }

    fn supports(&self, nb: u64) -> bool {
        // Any size whose rank arithmetic stays in u64: the unranking
        // computes row·(row+1) for rows up to nb−1, so nb(nb+1) (not
        // just T(nb)) must fit — i.e. every nb ≤ 2³² − 1.
        nb >= 1 && (nb as u128) * (nb as u128 + 1) <= u64::MAX as u128
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        Orthotope::d2(scalable_width(nb), Self::height(nb))
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        let k = w[1] * scalable_width(nb) + w[0];
        let (c, r) = lambda_s2(k);
        Some([c, r, 0])
    }
}

/// ρ granularity the searched container aligns against — the m = 3
/// block side of the default [`RhoPolicy`](crate::coordinator::RhoPolicy).
const SEARCH_RHO: u64 = 8;

/// ρ-aware container search for the m = 3 map (`lambda-sw`): instead
/// of always taking `W = ⌈nb/2⌉`, scan the window
/// `[max(min(W₀, ρ), W₀ − ρ), W₀ + ρ]` around the half-width `W₀` and
/// pick the width minimizing the final-layer waste
/// `W²·⌈Tet(nb)/W²⌉ − Tet(nb)`, tie-breaking toward ρ-aligned widths,
/// then proximity to `W₀`, then the smaller width. The window always
/// contains `W₀`, so the searched container is *never worse* than the
/// fixed one (golden-pinned in the tests below). Cached per `nb` — the
/// scan is ~17 integer divisions, but `map_block` asks for the width
/// on every block.
pub fn searched_width(nb: u64) -> u64 {
    use std::sync::{Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<WidthMemo>> = OnceLock::new();
    CACHE
        .get_or_init(|| Mutex::new(WidthMemo::new(WIDTH_MEMO_CAP)))
        .lock()
        .unwrap()
        .get(nb)
}

/// Entry bound for the [`searched_width`] memo. A long-lived server
/// sweeping adversarial (or merely varied) nb values must not grow an
/// unbounded process-global map; ~1k entries of 16 bytes is plenty for
/// every realistic working set and recomputing a miss is ~17 integer
/// divisions.
const WIDTH_MEMO_CAP: usize = 1024;

/// Bounded FIFO memo for the container search: at capacity the oldest
/// insertion is evicted. The value is a pure function of the key, so
/// eviction can never change an answer — only cost a recompute.
struct WidthMemo {
    cap: usize,
    map: std::collections::HashMap<u64, u64>,
    order: std::collections::VecDeque<u64>,
}

impl WidthMemo {
    fn new(cap: usize) -> WidthMemo {
        WidthMemo {
            cap: cap.max(1),
            map: std::collections::HashMap::new(),
            order: std::collections::VecDeque::new(),
        }
    }

    fn get(&mut self, nb: u64) -> u64 {
        if let Some(&w) = self.map.get(&nb) {
            return w;
        }
        let w = search_width(nb);
        while self.map.len() >= self.cap {
            match self.order.pop_front() {
                Some(old) => {
                    self.map.remove(&old);
                }
                None => break,
            }
        }
        self.map.insert(nb, w);
        self.order.push_back(nb);
        w
    }
}

fn search_width(nb: u64) -> u64 {
    let t = tetrahedron(nb);
    let w0 = scalable_width(nb);
    let lo = w0.min(SEARCH_RHO).max(w0.saturating_sub(SEARCH_RHO)).max(1);
    let hi = w0 + SEARCH_RHO;
    // Lexicographic (waste, ρ-misalignment, |W − W₀|, W): fully ordered,
    // so the winner is deterministic.
    let key = |w: u64| {
        let ww = (w as u128) * (w as u128);
        let waste = ww * t.div_ceil(ww) - t;
        (waste, u64::from(w % SEARCH_RHO != 0), w.abs_diff(w0), w)
    };
    (lo..=hi).min_by_key(|&w| key(w)).unwrap_or(w0)
}

/// λ_S for the 3-simplex: `W × W × L` container, sub-layer waste.
pub struct LambdaScalable3;

impl LambdaScalable3 {
    /// Layer count `⌈Tet(nb)/W²⌉`.
    #[inline]
    fn layers(nb: u64) -> u64 {
        let w = scalable_width(nb) as u128;
        // lint: allow(cast, supports caps Tet-of-nb + w*w at u64::MAX)
        tetrahedron(nb).div_ceil(w * w) as u64
    }
}

impl ThreadMap for LambdaScalable3 {
    fn name(&self) -> &'static str {
        "lambda-s"
    }

    fn m(&self) -> u32 {
        3
    }

    fn supports(&self, nb: u64) -> bool {
        // The padded linear rank tops out below Tet(nb) + W²; keep it
        // (and therefore every k the sweep produces) inside u64. The
        // coarse pre-bound keeps the u128 Tet evaluation itself safe.
        // Tet(5·10⁶) already exceeds u64::MAX, so the cap loses nothing.
        if nb == 0 || nb > 5_000_000 {
            return false;
        }
        let w = scalable_width(nb) as u128;
        tetrahedron(nb) + w * w <= u64::MAX as u128
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        let w = scalable_width(nb);
        Orthotope::d3(w, w, Self::layers(nb))
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        let width = scalable_width(nb);
        let k = (w[2] * width + w[1]) * width + w[0];
        if k as u128 >= tetrahedron(nb) {
            return None; // final-layer rounding past the last element
        }
        let (x, y, z) = lambda_s3(k);
        Some([x, y, z])
    }
}

/// λ_S for the 3-simplex with the ρ-aware searched width
/// ([`searched_width`]): same rearrangement, per-`nb` container choice.
pub struct LambdaScalableRho3;

impl LambdaScalableRho3 {
    /// Layer count `⌈Tet(nb)/W²⌉` for the searched width.
    #[inline]
    fn layers(nb: u64) -> u64 {
        let w = searched_width(nb) as u128;
        // lint: allow(cast, supports caps Tet-of-nb + w*w at u64::MAX)
        tetrahedron(nb).div_ceil(w * w) as u64
    }
}

impl ThreadMap for LambdaScalableRho3 {
    fn name(&self) -> &'static str {
        "lambda-sw"
    }

    fn m(&self) -> u32 {
        3
    }

    fn supports(&self, nb: u64) -> bool {
        // Same shape as LambdaScalable3::supports, but the searched
        // width can sit up to ρ above ⌈nb/2⌉, so the padded-rank bound
        // uses the window ceiling.
        if nb == 0 || nb > 5_000_000 {
            return false;
        }
        let w = (scalable_width(nb) + SEARCH_RHO) as u128;
        tetrahedron(nb) + w * w <= u64::MAX as u128
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        let w = searched_width(nb);
        Orthotope::d3(w, w, Self::layers(nb))
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        let width = searched_width(nb);
        let k = (w[2] * width + w[1]) * width + w[0];
        if k as u128 >= tetrahedron(nb) {
            return None; // final-layer rounding past the last element
        }
        let (x, y, z) = lambda_s3(k);
        Some([x, y, z])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{alpha, domain_volume, in_domain, space_efficiency};
    use std::collections::HashSet;

    #[test]
    fn s2_grid_shapes_divide_exactly() {
        // Even: (nb/2) × (nb+1); odd: ((nb+1)/2) × nb — always T(nb).
        assert_eq!(LambdaScalable2.grid(64, 0).dims, [32, 65, 1]);
        assert_eq!(LambdaScalable2.grid(63, 0).dims, [32, 63, 1]);
        assert_eq!(LambdaScalable2.grid(100, 0).dims, [50, 101, 1]);
        assert_eq!(LambdaScalable2.grid(1, 0).dims, [1, 1, 1]);
        for nb in 1..=300u64 {
            assert_eq!(
                LambdaScalable2.parallel_volume(nb),
                triangular(nb),
                "nb={nb}: the half-width grid must hold exactly T(nb)"
            );
        }
    }

    #[test]
    fn s2_is_exact_bijection_at_awkward_sizes() {
        // The scalability claim: exact partition at non-powers of two
        // (the sizes λ2 rejects). The full nb ≤ 64 sweep is in
        // tests/map_conformance.rs.
        for nb in [1u64, 2, 3, 5, 6, 7, 12, 17, 31, 33, 48, 63, 100] {
            let map = LambdaScalable2;
            assert!(map.supports(nb));
            let mut seen = HashSet::new();
            for w in map.grid(nb, 0).iter() {
                let d = map.map_block(nb, 0, w).expect("λ_S m=2 has no filler");
                assert!(in_domain(nb, 2, d), "nb={nb}: {w:?} → {d:?}");
                assert!(seen.insert((d[0], d[1])), "nb={nb}: dup {d:?}");
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, 2), "nb={nb}");
        }
    }

    #[test]
    fn s2_stays_exact_at_sizes_where_f64_flips() {
        // The precision claim: rank→pair stays an exact inverse at
        // block ranks around T(2^27) − 1 (where the naive f64 root
        // flips — util::isqrt tests) and up to the largest supported
        // rank. Checked via the algebraic roundtrip T(row) + col == k.
        let nb = (1u64 << 32) - 93;
        assert!(LambdaScalable2.supports(nb));
        let w = scalable_width(nb);
        let h = (triangular(nb) / w as u128) as u64;
        for k in [
            0u64,
            w - 1,
            (1u64 << 27) * ((1 << 27) + 1) / 2 - 1,
            (1u64 << 27) * ((1 << 27) + 1) / 2,
            w * h / 2,
            w * h - 1,
        ] {
            let (c, r) = lambda_s2(k);
            assert!(c <= r && r < nb, "k={k} → ({c},{r})");
            assert_eq!(r * (r + 1) / 2 + c, k, "k={k}: rank roundtrip");
        }
    }

    #[test]
    fn s2_zero_waste_and_2x_over_bb_at_every_size() {
        for nb in [4u64, 7, 10, 64, 100, 4096, 4097] {
            assert!(alpha(&LambdaScalable2, nb).abs() < 1e-12, "nb={nb}");
            assert!((space_efficiency(&LambdaScalable2, nb) - 1.0).abs() < 1e-12);
            // Improvement over BB's nb² grid: exactly 2nb/(nb+1) → 2.
            let imp = (nb as f64 * nb as f64) / LambdaScalable2.parallel_volume(nb) as f64;
            let closed = 2.0 * nb as f64 / (nb as f64 + 1.0);
            assert!((imp - closed).abs() < 1e-12, "nb={nb}: {imp} vs {closed}");
        }
    }

    #[test]
    fn s3_container_matches_closed_form() {
        // W = ⌈nb/2⌉, L = ⌈Tet(nb)/W²⌉ — python-cross-checked goldens.
        for (nb, w, l, parallel, filler) in [
            (4u64, 2u64, 5u64, 20u128, 0u128),
            (8, 4, 8, 128, 8),
            (16, 8, 13, 832, 16),
            (32, 16, 24, 6144, 160),
        ] {
            let g = LambdaScalable3.grid(nb, 0);
            assert_eq!(g.dims, [w, w, l], "nb={nb}");
            assert_eq!(LambdaScalable3.parallel_volume(nb), parallel, "nb={nb}");
            assert_eq!(parallel - tetrahedron(nb), filler, "nb={nb}");
        }
    }

    #[test]
    fn s3_covers_domain_exactly_once_at_awkward_sizes() {
        // Full nb ≤ 32 sweep in tests/map_conformance.rs; here the
        // non-pow2 sizes that make the scalability point.
        for nb in [1u64, 2, 3, 5, 6, 7, 9, 12, 15, 17, 21] {
            let map = LambdaScalable3;
            assert!(map.supports(nb));
            let mut seen = HashSet::new();
            let mut filler = 0u128;
            for w in map.grid(nb, 0).iter() {
                match map.map_block(nb, 0, w) {
                    None => filler += 1,
                    Some(d) => {
                        assert!(in_domain(nb, 3, d), "nb={nb}: {w:?} → {d:?}");
                        assert!(seen.insert(d), "nb={nb}: dup {d:?}");
                    }
                }
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, 3), "nb={nb}");
            assert_eq!(
                filler,
                map.parallel_volume(nb) - domain_volume(nb, 3),
                "nb={nb}: filler is exactly the final-layer rounding"
            );
        }
    }

    #[test]
    fn s3_waste_stays_under_one_layer() {
        for nb in 1..=64u64 {
            let w = scalable_width(nb) as u128;
            let waste = LambdaScalable3.parallel_volume(nb) - tetrahedron(nb);
            assert!(waste < w * w, "nb={nb}: waste {waste} ≥ one layer {}", w * w);
        }
    }

    #[test]
    fn s3_beats_lambda3_container_by_exactly_its_slack() {
        // λ3's container is (nb/2)²(3nb/4 + 3); λ_S packs the same
        // domain into ⌈Tet/W²⌉ layers — 1.125× fewer blocks at nb = 32
        // (6912 vs 6144, python-cross-checked), and λ3 does not exist
        // at odd sizes at all.
        use crate::maps::Lambda3Map;
        let nb = 32u64;
        assert_eq!(Lambda3Map.parallel_volume(nb), 6912);
        assert_eq!(LambdaScalable3.parallel_volume(nb), 6144);
        let ratio = Lambda3Map.parallel_volume(nb) as f64
            / LambdaScalable3.parallel_volume(nb) as f64;
        assert!((ratio - 1.125).abs() < 1e-12, "ratio={ratio}");
        assert!(!Lambda3Map.supports(33) && LambdaScalable3.supports(33));
    }

    #[test]
    fn supports_any_size_with_u64_rank() {
        assert!(LambdaScalable2.supports(1));
        assert!(LambdaScalable2.supports(3));
        assert!(LambdaScalable2.supports(1 << 20));
        assert!(LambdaScalable2.supports((1 << 32) - 1));
        assert!(!LambdaScalable2.supports(1 << 32), "row·(row+1) must fit u64");
        assert!(!LambdaScalable2.supports(0));
        assert!(!LambdaScalable2.supports(u64::MAX));
        assert!(LambdaScalable3.supports(1));
        assert!(LambdaScalable3.supports(4_800_000));
        assert!(!LambdaScalable3.supports(0));
        assert!(!LambdaScalable3.supports(u64::MAX));
    }

    #[test]
    fn sw_container_matches_searched_goldens() {
        // (nb, W, L, parallel, waste) — python-cross-checked; the
        // issue's sizes {4, 8, 32, 4096} plus two mid sizes. nb = 16 is
        // a case where the fixed half-width is already waste-optimal.
        for (nb, w, l, parallel, waste) in [
            (4u64, 2u64, 5u64, 20u128, 0u128),
            (8, 11, 1, 121, 1),
            (16, 8, 13, 832, 16),
            (32, 9, 74, 5994, 10),
            (64, 30, 51, 45900, 140),
            (100, 43, 93, 171957, 257),
            (4096, 2042, 2749, 11462681236, 1045140),
        ] {
            assert_eq!(searched_width(nb), w, "nb={nb}");
            let g = LambdaScalableRho3.grid(nb, 0);
            assert_eq!(g.dims, [w, w, l], "nb={nb}");
            assert_eq!(LambdaScalableRho3.parallel_volume(nb), parallel, "nb={nb}");
            assert_eq!(parallel - tetrahedron(nb), waste, "nb={nb}");
        }
    }

    #[test]
    fn sw_never_worse_than_fixed_half_width() {
        // The search window always contains W₀ = ⌈nb/2⌉, so the chosen
        // container can never launch more blocks than the fixed one —
        // and the waste always stays under one searched layer.
        for nb in (1..=400u64).chain([4096]) {
            let fixed = LambdaScalable3.parallel_volume(nb);
            let searched = LambdaScalableRho3.parallel_volume(nb);
            assert!(searched <= fixed, "nb={nb}: searched {searched} > fixed {fixed}");
            let w = searched_width(nb) as u128;
            assert!(searched - tetrahedron(nb) < w * w, "nb={nb}");
        }
    }

    #[test]
    fn sw_covers_domain_exactly_once_at_awkward_sizes() {
        // Registry-level conformance for all nb ≤ 32 rides along in
        // tests/map_conformance.rs via MAP3_NAMES; here the sizes where
        // the searched width differs most from ⌈nb/2⌉.
        for nb in [1u64, 2, 3, 5, 7, 8, 9, 12, 15, 17, 21, 32] {
            let map = LambdaScalableRho3;
            assert!(map.supports(nb));
            let mut seen = HashSet::new();
            let mut filler = 0u128;
            for w in map.grid(nb, 0).iter() {
                match map.map_block(nb, 0, w) {
                    None => filler += 1,
                    Some(d) => {
                        assert!(in_domain(nb, 3, d), "nb={nb}: {w:?} → {d:?}");
                        assert!(seen.insert(d), "nb={nb}: dup {d:?}");
                    }
                }
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, 3), "nb={nb}");
            assert_eq!(filler, map.parallel_volume(nb) - domain_volume(nb, 3), "nb={nb}");
        }
    }

    #[test]
    fn width_memo_holds_its_cap_and_never_changes_answers() {
        let mut memo = WidthMemo::new(64);
        // Overfill by 4×: the map must stay at the cap throughout …
        for nb in 1..=256u64 {
            assert_eq!(memo.get(nb), search_width(nb), "nb={nb}");
            assert!(memo.map.len() <= 64, "nb={nb}: {} entries", memo.map.len());
            assert_eq!(memo.map.len(), memo.order.len(), "nb={nb}");
        }
        assert_eq!(memo.map.len(), 64);
        // … and evicted keys recompute to the identical width (the
        // memo is transparent: same function, just cached).
        for nb in 1..=256u64 {
            assert_eq!(memo.get(nb), search_width(nb), "nb={nb} after eviction");
        }
        // The process-global path answers the same as a direct search.
        for nb in [4u64, 8, 16, 32, 64, 100, 4096] {
            assert_eq!(searched_width(nb), search_width(nb), "nb={nb}");
        }
    }

    #[test]
    fn rank_maps_agree_with_enumeration_order() {
        // λ_S rearranges by the same canonical enumeration ENUM2/ENUM3
        // invert — same rank order, so trace tooling can cross-read.
        for k in 0..10_000u64 {
            let (c, r) = lambda_s2(k);
            assert_eq!(r * (r + 1) / 2 + c, k);
            let (x, y, z) = lambda_s3(k);
            let s = x + y + z;
            let row = x + y;
            assert_eq!(
                tetrahedron(s) as u64 + row * (row + 1) / 2 + x,
                k,
                "m=3 rank roundtrip k={k}"
            );
        }
    }
}
