//! REC — Ries et al.'s recursive partition for triangular matrices
//! [21], as characterized in §II: a divide-and-conquer split of the
//! triangle into the same squares λ2 uses, but dispatched as
//! `O(log2 n)` *separate balanced launches* instead of one flat grid.
//!
//! Pass ℓ ∈ [0, log2 N) launches the 2^ℓ squares of side `N/2^{ℓ+1}` as
//! one `(s) × (s·2^ℓ)` grid; a final pass covers the diagonal blocks.
//! Per-pass blocks map O(1); the cost the paper attributes to this
//! approach is the *pass count* (kernel-launch latency), which the grid
//! simulator charges per launch.

use crate::maps::ThreadMap;
use crate::simplex::volume::{ilog2, is_pow2};
use crate::simplex::Orthotope;

pub struct RiesMap;

impl ThreadMap for RiesMap {
    fn name(&self) -> &'static str {
        "ries"
    }

    fn m(&self) -> u32 {
        2
    }

    fn supports(&self, nb: u64) -> bool {
        is_pow2(nb) && nb >= 2
    }

    /// log2(N) square passes + 1 diagonal pass.
    fn passes(&self, nb: u64) -> u64 {
        // lint: allow(cast, ilog2 is u32, widening)
        ilog2(nb) as u64 + 1
    }

    fn grid(&self, nb: u64, pass: u64) -> Orthotope {
        // lint: allow(cast, ilog2 is u32, widening)
        let square_passes = ilog2(nb) as u64;
        if pass < square_passes {
            // Pass ℓ: 2^ℓ squares of side s = N/2^{ℓ+1}, stacked in y.
            let s = nb >> (pass + 1);
            Orthotope::d2(s, s << pass)
        } else {
            // Diagonal pass: N blocks in a row.
            Orthotope::d2(nb, 1)
        }
    }

    #[inline]
    fn map_block(&self, nb: u64, pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        // lint: allow(cast, ilog2 is u32, widening)
        let square_passes = ilog2(nb) as u64;
        if pass < square_passes {
            let s = nb >> (pass + 1);
            let q = w[1] / s; // which square of this level
            let vy = w[1] - q * s;
            // Level-ℓ square q sits at cols [2qs, 2qs+s), rows [2qs+s, 2qs+2s)
            // — identical geometry to λ2's level ℓ (see lambda2.rs).
            Some([2 * q * s + w[0], 2 * q * s + s + vy, 0])
        } else {
            Some([w[0], w[0], 0])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{domain_volume, in_domain};
    use std::collections::HashSet;

    #[test]
    fn all_passes_together_cover_domain_exactly() {
        for k in 1..9u32 {
            let nb = 1u64 << k;
            let map = RiesMap;
            let mut seen = HashSet::new();
            for pass in 0..map.passes(nb) {
                for w in map.grid(nb, pass).iter() {
                    let d = map.map_block(nb, pass, w).expect("no filler");
                    assert!(in_domain(nb, 2, d), "nb={nb} pass={pass} {w:?}→{d:?}");
                    assert!(seen.insert((d[0], d[1])), "dup {d:?}");
                }
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, 2), "nb={nb}");
        }
    }

    #[test]
    fn pass_count_is_logarithmic() {
        assert_eq!(RiesMap.passes(2), 2);
        assert_eq!(RiesMap.passes(1024), 11);
        // vs λ2's single pass — experiment E12's comparison.
        assert_eq!(crate::maps::Lambda2Map.passes(1024), 1);
    }

    #[test]
    fn total_volume_matches_lambda2() {
        // Same recursive squares → same total block count.
        for k in 1..10u32 {
            let nb = 1u64 << k;
            assert_eq!(
                RiesMap.parallel_volume(nb),
                crate::maps::Lambda2Map.parallel_volume(nb)
            );
        }
    }

    #[test]
    fn per_pass_grids_shrink() {
        let nb = 64;
        let v0 = RiesMap.grid(nb, 0).volume();
        let v1 = RiesMap.grid(nb, 1).volume();
        assert!(v1 < v0);
    }
}
