//! λ3-rec — the §III.B arity-3 recursive map (eq. 20): map the
//! tetrahedron by recursively launching a cube at the orthogonal corner
//! plus three sub-tetrahedra. Each recursion node is its own kernel
//! launch, so the total launch count is Σ 3^ℓ ∈ O(n^{log2 3}) — the
//! paper's argument for abandoning this formulation in favour of §III.C
//! (GPUs of the day ran ≤ 32 concurrent kernels).
//!
//! Cubes at the corner of a (sub)tetrahedron overflow its diagonal
//! face (DESIGN.md §λ3), so each cube launch carries a per-block
//! predicate — this map trades waste *and* launches for simplicity.

use crate::maps::{in_domain, ThreadMap};
use crate::simplex::volume::{ilog2, is_pow2};
use crate::simplex::Orthotope;

pub struct Lambda3RecMap;

/// Number of launches: 3^0 + 3^1 + … + 3^{log2(N)-1} cubes.
pub fn launch_count(nb: u64) -> u64 {
    // lint: allow(cast, u32 to u64 widens)
    let levels = ilog2(nb) as u64;
    (3u64.pow(levels as u32) - 1) / 2
}

/// Offset of launch `idx`: decode the base-3 path. Level ℓ contains
/// launches [ (3^ℓ-1)/2, (3^{ℓ+1}-1)/2 ); digit k of the in-level index
/// picks the x/y/z branch at recursion step k+1.
fn decode(nb: u64, idx: u64) -> (u64, [u64; 3]) {
    let mut level = 0u32;
    let mut base = 0u64;
    while base + 3u64.pow(level) <= idx {
        base += 3u64.pow(level);
        level += 1;
    }
    let mut rem = idx - base;
    let mut offset = [0u64; 3];
    // Digits from least significant = deepest recursion step.
    for step in (1..=level).rev() {
        // lint: allow(cast, rem % 3 is 0..=2)
        let branch = (rem % 3) as usize;
        rem /= 3;
        offset[branch] += nb >> step;
    }
    (nb >> (level + 1), offset) // (cube side, offset)
}

impl ThreadMap for Lambda3RecMap {
    fn name(&self) -> &'static str {
        "lambda3-rec"
    }

    fn m(&self) -> u32 {
        3
    }

    fn supports(&self, nb: u64) -> bool {
        is_pow2(nb) && nb >= 2
    }

    fn passes(&self, nb: u64) -> u64 {
        launch_count(nb) + 1 // + one diagonal-plane pass
    }

    fn grid(&self, nb: u64, pass: u64) -> Orthotope {
        if pass < launch_count(nb) {
            let (side, _) = decode(nb, pass);
            Orthotope::d3(side, side, side)
        } else {
            // Diagonal pass: the plane Σ = N-1 as a 2-D launch.
            Orthotope::d3(nb, nb, 1)
        }
    }

    #[inline]
    fn map_block(&self, nb: u64, pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        if pass < launch_count(nb) {
            let (_side, off) = decode(nb, pass);
            let d = [w[0] + off[0], w[1] + off[1], w[2] + off[2]];
            // Cubes overflow their sub-tetrahedron's diagonal face: the
            // predicate discards the overflow (that is the 1/5 extra
            // volume of eq. 19). The recursion never reaches size-1
            // leaves, whose cells all lie on the plane Σ = N-1; cubes
            // therefore own exactly {Σ ≤ N-2} (disjointly) and the
            // final pass owns the diagonal plane.
            if in_domain(nb, 3, d) && d[0] + d[1] + d[2] <= nb - 2 {
                Some(d)
            } else {
                None
            }
        } else {
            // Diagonal-plane pass: (x, y) → (x, y, N-1-x-y).
            if w[0] + w[1] <= nb - 1 {
                Some([w[0], w[1], nb - 1 - w[0] - w[1]])
            } else {
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::domain_volume;
    use std::collections::HashSet;

    #[test]
    fn launch_count_matches_geometric_sum() {
        assert_eq!(launch_count(2), 1);
        assert_eq!(launch_count(4), 4); // 1 + 3
        assert_eq!(launch_count(8), 13); // 1 + 3 + 9
        assert_eq!(launch_count(1024), (3u64.pow(10) - 1) / 2);
    }

    #[test]
    fn launch_count_exceeds_concurrency_cap_quickly() {
        // §III.B: "an excessive number of parallel calls … up to 32
        // concurrent kernels". Already at n=64 blocks we exceed 32.
        assert!(launch_count(64) > 32, "{}", launch_count(64));
    }

    #[test]
    fn decode_roundtrip_offsets_in_range() {
        let nb = 32;
        for idx in 0..launch_count(nb) {
            let (side, off) = decode(nb, idx);
            assert!(side >= 1);
            for d in off {
                assert!(d < nb);
            }
        }
    }

    /// The union of all passes must cover the simplex (duplicates
    /// allowed only at zero — i.e. none, cubes are disjoint).
    #[test]
    fn covers_domain_completely() {
        for k in 1..6u32 {
            let nb = 1u64 << k;
            let map = Lambda3RecMap;
            let mut seen = HashSet::new();
            let mut dups = 0u64;
            for pass in 0..map.passes(nb) {
                for w in map.grid(nb, pass).iter() {
                    if let Some(d) = map.map_block(nb, pass, w) {
                        assert!(
                            crate::maps::in_domain(nb, 3, d),
                            "nb={nb} pass={pass} {w:?}→{d:?}"
                        );
                        if !seen.insert((d[0], d[1], d[2])) {
                            dups += 1;
                        }
                    }
                }
            }
            assert_eq!(
                seen.len() as u128,
                domain_volume(nb, 3),
                "nb={nb}: incomplete"
            );
            assert_eq!(dups, 0, "nb={nb}: {dups} duplicate mappings");
        }
    }
}
