//! λ3 — the O(1) two-branch fold map for 3-simplices (§III.C).
//!
//! The paper establishes (eq. 21-22) that the two-branch recursive set
//! has volume `(N³-N)/6 = V(Δ_{N-1}^3)` exactly, gives the container
//! `(N/2) × (N/2) × 3(N-1)/4` (eq. 24, 12.5% slack) and a two-case
//! inside/fold formula — but not the packing. We use the derivation in
//! DESIGN.md §λ3:
//!
//! Data space `D(N) = {(x,y,z) ≥ 0 : x+y+z ≤ N-2}`, decomposed as
//! `corner cube [0,N/2)³ (+ fold of its diagonal overflow onto the
//! z-branch) + x-branch D(N/2) + y-branch D(N/2)`. Parallel packing:
//!
//! - `z < N/2` — the level-0 cube, local size `m_loc = N`.
//! - `z ∈ [N/2, 3N/4)` — level ℓ ≥ 1 with cube side `s = N/2^{ℓ+1}`
//!   occupies `y ∈ [N/2-2s, N/2-s)`, `z ∈ [N/2, N/2+s)`; the branch-path
//!   offsets have the closed form `ox = 2sq`, `oy = N-2s-2sq` (bit k of
//!   q picks the x- or y-branch at recursion step k).
//! - fold (both): local `(vx,vy,vz)` with `vx+vy+vz > m_loc-2` reflects
//!   to `(s-1-vx, s-1-vy, m_loc-1-vz)` — the paper's second case, an
//!   O(1) point reflection instead of cube roots.
//!
//! The strict map covers `{x+y+z ≤ N-2}`; the remaining diagonal plane
//! `{x+y+z = N-1}` of the inclusive block domain is a 2-simplex of size
//! N and is covered by three extra z-layers driven by λ2 (§III.A) —
//! keeping the whole map single-pass and O(1).
//!
//! Container: `(N/2) × (N/2) × (3N/4 + 3)`; waste → 2/16 = 12.5%
//! (eq. 24), versus ~500% for BB — the paper's 6× claim.

use crate::maps::lambda2::lambda2_inclusive;
use crate::maps::ThreadMap;
use crate::simplex::volume::{ilog2, is_pow2};
use crate::simplex::Orthotope;

pub struct Lambda3Map;

/// Map the strict part (`z < 3N/4`). Returns `None` for container
/// filler. Exposed for benches.
#[inline(always)]
pub fn lambda3_strict(nb: u64, x: u64, y: u64, z: u64) -> Option<(u64, u64, u64)> {
    let half = nb / 2;
    if z < half {
        // Level-0 corner cube, local size m_loc = N, side s = N/2.
        let sigma = x + y + z;
        if sigma + 2 <= nb {
            Some((x, y, z))
        } else {
            // Fold through the diagonal into the z-branch (point
            // reflection; σ' = 2N-3-σ ≤ N-2 and z' ≥ N/2).
            Some((half - 1 - x, half - 1 - y, nb - 1 - z))
        }
    } else {
        // Deeper levels. Level from y: y ∈ [N/2-2s, N/2-s).
        let u = half - 1 - y; // ∈ [s, 2s) for level with side s
        if u == 0 {
            return None; // y = N/2-1 row is container filler
        }
        let level_log = ilog2(u); // s = 2^level_log
        let s = 1u64 << level_log;
        let vz = z - half;
        if vz >= s {
            return None; // beyond this level's z-slab: filler
        }
        let q = x >> level_log;
        let qs = q << level_log; // q·s
        let vx = x - qs;
        let vy = y - (half - 2 * s);
        debug_assert!(vy < s);
        // Closed-form branch-path offsets (DESIGN.md): bit k of q picks
        // x (1) or y (0) at recursion step k.
        let ox = qs << 1; // 2·s·q
        let oy = nb - 2 * s - ox;
        let m_loc = 2 * s;
        let sigma = vx + vy + vz;
        if sigma + 2 <= m_loc {
            Some((ox + vx, oy + vy, vz))
        } else {
            Some((ox + s - 1 - vx, oy + s - 1 - vy, m_loc - 1 - vz))
        }
    }
}

/// Map the diagonal-plane layers (`z ≥ 3N/4`): three λ2-driven layers
/// covering `{x+y+z = N-1}`.
#[inline(always)]
pub fn lambda3_diagonal(nb: u64, x: u64, y: u64, z: u64) -> Option<(u64, u64, u64)> {
    let t = z - 3 * nb / 4; // layer index 0..3
    let y2 = t * (nb / 2) + y;
    if y2 > nb {
        return None; // last layer is only partially used
    }
    // λ2-inclusive gives (c ≤ r < N); parametrize the plane Σ = N-1 by
    // (c, r) → (c, r-c, N-1-r).
    let (c, r) = lambda2_inclusive(nb, x, y2);
    Some((c, r - c, nb - 1 - r))
}

/// Full single-pass map on the grid `(N/2) × (N/2) × (3N/4 + 3)`.
#[inline(always)]
pub fn lambda3_full(nb: u64, x: u64, y: u64, z: u64) -> Option<(u64, u64, u64)> {
    if z < 3 * nb / 4 {
        lambda3_strict(nb, x, y, z)
    } else {
        lambda3_diagonal(nb, x, y, z)
    }
}

impl ThreadMap for Lambda3Map {
    fn name(&self) -> &'static str {
        "lambda3"
    }

    fn m(&self) -> u32 {
        3
    }

    fn supports(&self, nb: u64) -> bool {
        is_pow2(nb) && nb >= 4
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        Orthotope::d3(nb / 2, nb / 2, 3 * nb / 4 + 3)
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        lambda3_full(nb, w[0], w[1], w[2]).map(|(a, b, c)| [a, b, c])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{alpha, domain_volume, in_domain};
    use std::collections::HashSet;

    /// Exhaustive coverage — experiment E6's correctness core: every
    /// data block covered exactly once, no block outside the simplex.
    #[test]
    fn lambda3_covers_domain_exactly_once() {
        for k in 2..8u32 {
            let nb = 1u64 << k;
            let map = Lambda3Map;
            let mut seen = HashSet::new();
            let mut filler = 0u128;
            for w in map.grid(nb, 0).iter() {
                match map.map_block(nb, 0, w) {
                    None => filler += 1,
                    Some(d) => {
                        assert!(
                            in_domain(nb, 3, d),
                            "nb={nb}: {w:?} escapes domain at {d:?}"
                        );
                        assert!(
                            seen.insert((d[0], d[1], d[2])),
                            "nb={nb}: duplicate image {d:?} from {w:?}"
                        );
                    }
                }
            }
            assert_eq!(
                seen.len() as u128,
                domain_volume(nb, 3),
                "nb={nb}: incomplete coverage"
            );
            // Filler = container minus domain.
            assert_eq!(
                filler,
                map.parallel_volume(nb) - domain_volume(nb, 3),
                "nb={nb}"
            );
        }
    }

    #[test]
    fn strict_part_covers_strict_simplex_exactly() {
        // lambda3_strict alone is a bijection onto {Σ ≤ N-2} (eq. 22:
        // V(S_N^3) = V(Δ_{N-1}^3)).
        for k in 2..8u32 {
            let nb = 1u64 << k;
            let mut seen = HashSet::new();
            for z in 0..3 * nb / 4 {
                for y in 0..nb / 2 {
                    for x in 0..nb / 2 {
                        if let Some(d) = lambda3_strict(nb, x, y, z) {
                            assert!(d.0 + d.1 + d.2 <= nb - 2, "nb={nb} {x},{y},{z} → {d:?}");
                            assert!(seen.insert(d), "nb={nb}: dup {d:?}");
                        }
                    }
                }
            }
            assert_eq!(seen.len() as u128, domain_volume(nb - 1, 3), "nb={nb}");
        }
    }

    #[test]
    fn diagonal_layers_cover_plane_exactly() {
        for k in 2..8u32 {
            let nb = 1u64 << k;
            let mut seen = HashSet::new();
            for z in 3 * nb / 4..3 * nb / 4 + 3 {
                for y in 0..nb / 2 {
                    for x in 0..nb / 2 {
                        if let Some(d) = lambda3_diagonal(nb, x, y, z) {
                            assert_eq!(d.0 + d.1 + d.2, nb - 1, "plane Σ=N-1");
                            assert!(seen.insert(d), "dup {d:?}");
                        }
                    }
                }
            }
            // |{Σ = N-1}| = C(N+1, 2) = N(N+1)/2.
            assert_eq!(seen.len() as u128, (nb as u128) * (nb as u128 + 1) / 2);
        }
    }

    #[test]
    fn container_matches_eq24_dimensions() {
        // (N/2) × (N/2) × ~3N/4 (plus the 3 diagonal layers).
        let nb = 64;
        let g = Lambda3Map.grid(nb, 0);
        assert_eq!(g.dims[0], 32);
        assert_eq!(g.dims[1], 32);
        assert_eq!(g.dims[2], 51); // 48 + 3
    }

    #[test]
    fn alpha_approaches_12_5_percent() {
        // eq. 24: V(Π)/V(Δ) - 1 → 2/16 = 0.125.
        let a = alpha(&Lambda3Map, 1 << 10);
        assert!((a - 0.125).abs() < 0.01, "α={a}");
        // And is ~6× better than BB's α → 5 (the paper's headline).
        let a_bb = alpha(&crate::maps::BoundingBox3, 1 << 10);
        assert!(a_bb / a > 30.0, "λ3 waste {a} vs BB waste {a_bb}");
    }

    #[test]
    fn fold_case_reaches_z_branch() {
        // A level-0 cube block past the diagonal must land at z ≥ N/2.
        let nb = 16;
        let d = lambda3_strict(nb, 7, 7, 7).unwrap();
        assert!(d.2 >= nb / 2, "fold lands in z-branch: {d:?}");
        assert!(d.0 + d.1 + d.2 <= nb - 2);
    }

    #[test]
    fn rejects_small_or_non_pow2() {
        assert!(!Lambda3Map.supports(12));
        assert!(!Lambda3Map.supports(2));
        assert!(Lambda3Map.supports(4));
        assert!(Lambda3Map.supports(256));
    }
}
