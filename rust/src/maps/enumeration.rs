//! Enumeration-principle maps `g: Z¹ → Z^m` (§I and related work [15],
//! [16]): linearize the simplex elements and invert the m-th-order
//! volume polynomial per block. These are the paper's *prior art*
//! baselines — correct and space-tight, but each block pays square /
//! cube roots, which is exactly the overhead λ avoids.

use crate::maps::ThreadMap;
use crate::simplex::volume::simplex_volume;
use crate::simplex::Orthotope;

/// Inverse triangular number: largest `r` with `r(r+1)/2 ≤ k`.
///
/// Until PR 5 this was the quadratic formula in f64 plus a ±1 fix-up —
/// the original implementations' approach, whose raw (unfixed) form
/// provably flips a row at `k = T(2^27) − 1` and whose correctness
/// rested on IEEE rounding arguments. It now delegates to the shared
/// integer-Newton root ([`crate::util::isqrt`]): exact for every u64
/// input by construction, no floating point anywhere. The root is
/// still the measured per-block cost of the enumeration maps — that is
/// exactly the overhead λ avoids.
#[inline(always)]
pub fn triangular_root(k: u64) -> u64 {
    crate::util::isqrt::triangular_root(k)
}

/// Inverse tetrahedral number: largest `c` with `c(c+1)(c+2)/6 ≤ k` —
/// the cubic-equation inverse of [15] that the paper calls out as
/// "several square and cubic roots of overhead"; integer Newton cube
/// root plus a bounded walk (shared helper, exact at every u64 input).
#[inline(always)]
pub fn tetrahedral_root(k: u64) -> u64 {
    crate::util::isqrt::tetrahedral_root(k)
}

/// ENUM2 — HPCC'14-style block map for the 2-simplex: block linear
/// index `k` → inclusive lower-triangular pair. Grid is the same
/// `(N/2) × (N+1)` rectangle λ2 uses, so benches compare pure
/// arithmetic, not launch shape.
pub struct Enum2Map;

impl ThreadMap for Enum2Map {
    fn name(&self) -> &'static str {
        "enum2"
    }

    fn m(&self) -> u32 {
        2
    }

    fn supports(&self, nb: u64) -> bool {
        nb >= 2 && nb % 2 == 0
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        Orthotope::d2(nb / 2, nb + 1)
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        let k = w[1] * (nb / 2) + w[0]; // linear block id
        debug_assert!((k as u128) < simplex_volume(nb, 2));
        let row = triangular_root(k);
        let col = k - row * (row + 1) / 2;
        Some([col, row, 0])
    }
}

/// ENUM3 — CLEI'16-style block map for the 3-simplex: linear index →
/// tetrahedral root (z-slab) → triangular root (row) → column.
/// Grid: a `(N/2) × (N/2)` base rectangle with just enough z-layers.
pub struct Enum3Map;

impl Enum3Map {
    fn layers(nb: u64) -> u64 {
        let need = simplex_volume(nb, 3);
        let base = (nb as u128 / 2) * (nb as u128 / 2);
        // lint: allow(cast, quotient is about 2nb/3, far inside u64)
        need.div_ceil(base) as u64
    }
}

impl ThreadMap for Enum3Map {
    fn name(&self) -> &'static str {
        "enum3"
    }

    fn m(&self) -> u32 {
        3
    }

    fn supports(&self, nb: u64) -> bool {
        nb >= 2 && nb % 2 == 0
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        Orthotope::d3(nb / 2, nb / 2, Self::layers(nb))
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        let base = (nb / 2) * (nb / 2);
        let k = w[2] * base + w[1] * (nb / 2) + w[0];
        if k as u128 >= simplex_volume(nb, 3) {
            return None; // rectangle padding past the last element
        }
        // Enumerate Δ_N^3 by slabs of constant (x+y+z): element k lies
        // in the largest complete tetrahedron tet(s) ≤ k.
        let s = tetrahedral_root(k);
        let rem = k - s * (s + 1) * (s + 2) / 6; // index inside slab Σ = s
        let row = triangular_root(rem);
        let col = rem - row * (row + 1) / 2;
        // Slab Σ = s parametrized by (row, col): x = col, y = row-col,
        // z = s-row (all ≥ 0 since col ≤ row ≤ s).
        Some([col, row - col, s - row])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{domain_volume, in_domain};
    use std::collections::HashSet;

    #[test]
    fn triangular_root_exact_small() {
        for r in 0..200u64 {
            for k in r * (r + 1) / 2..(r + 1) * (r + 2) / 2 {
                assert_eq!(triangular_root(k), r, "k={k}");
            }
        }
    }

    #[test]
    fn triangular_root_exact_near_f64_edge() {
        // Where the naive sqrt goes wrong: huge k.
        for r in [3_000_000_000u64, 4_294_967_295u64] {
            let k = r * (r + 1) / 2;
            assert_eq!(triangular_root(k), r);
            assert_eq!(triangular_root(k - 1), r - 1);
            assert_eq!(triangular_root(k + 1), r);
        }
    }

    #[test]
    fn tetrahedral_root_exact() {
        let tet = |c: u64| c * (c + 1) * (c + 2) / 6;
        for c in 0..120u64 {
            assert_eq!(tetrahedral_root(tet(c)), c);
            if tet(c + 1) > tet(c) + 1 {
                assert_eq!(tetrahedral_root(tet(c + 1) - 1), c);
            }
        }
        // Large value sanity.
        let c = 2_000_000u64;
        assert_eq!(tetrahedral_root(tet(c)), c);
    }

    #[test]
    fn enum2_is_exact_bijection() {
        for nb in [2u64, 4, 8, 16, 32, 64, 100] {
            let map = Enum2Map;
            let mut seen = HashSet::new();
            for w in map.grid(nb, 0).iter() {
                let d = map.map_block(nb, 0, w).expect("enum2 has no filler");
                assert!(in_domain(nb, 2, d), "nb={nb} {w:?}→{d:?}");
                assert!(seen.insert((d[0], d[1])));
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, 2), "nb={nb}");
        }
    }

    #[test]
    fn enum3_covers_domain_exactly_once() {
        for nb in [2u64, 4, 8, 16, 32] {
            let map = Enum3Map;
            let mut seen = HashSet::new();
            for w in map.grid(nb, 0).iter() {
                if let Some(d) = map.map_block(nb, 0, w) {
                    assert!(in_domain(nb, 3, d), "nb={nb} {w:?}→{d:?}");
                    assert!(seen.insert((d[0], d[1], d[2])));
                }
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, 3), "nb={nb}");
        }
    }

    #[test]
    fn enum3_padding_is_small() {
        // The rectangle rounds up to whole z-layers only.
        let nb = 64;
        let pad = Enum3Map.parallel_volume(nb) - domain_volume(nb, 3);
        assert!(pad < (nb as u128 / 2) * (nb as u128 / 2));
    }

    #[test]
    fn enum_maps_accept_even_sizes_only() {
        assert!(Enum2Map.supports(100));
        assert!(!Enum2Map.supports(101));
        assert!(Enum3Map.supports(6));
        assert!(!Enum3Map.supports(7));
    }
}
