//! λ2 — the paper's O(1) recursive block map for 2-simplices (§III.A,
//! eq. 13), extended to cover the diagonal blocks.
//!
//! The strictly-lower-triangular part uses the paper's map verbatim:
//! for parallel block `(x, y)` with `y ∈ [1, N)`,
//!
//! ```text
//! level = ⌊log2 y⌋          (eq. 14 — one clz)
//! b     = 2^level           (eq. 15 — one shift)
//! q     = ⌊x / b⌋
//! λ(ω)  = (x + q·b, y + 2·q·b)        -- (col, row), eq. 13
//! ```
//!
//! which is an *exact bijection* from `[0, N/2) × [1, N)` onto
//! `{(c, r) : c < r < N}`: level ℓ's sub-orthotope q lands on the q-th
//! b×b square of the recursive decomposition of the strict triangle
//! (DESIGN.md §λ2 has the proof).
//!
//! The N diagonal blocks (needed because thread-level domains include
//! diagonal-crossing blocks) are appended as rows `y = 0` and `y = N`
//! of the same grid: total grid `(N/2) × (N+1)` with volume
//! `N(N+1)/2 = V(Δ_N^2)` — zero filler blocks, the 2× improvement over
//! BB promised in the abstract.

use crate::maps::ThreadMap;
use crate::simplex::volume::{ilog2, is_pow2};
use crate::simplex::Orthotope;

pub struct Lambda2Map;

/// The raw eq.-13 map on the strict triangle. Exposed for benches and
/// for reuse inside λ3's diagonal-plane handling.
///
/// §Perf note: a bitmask rewrite (`q·b = x & (!0 << level)`) measured
/// +8% in an isolated micro-benchmark but -2x inside the full grid
/// sweep (it blocks LLVM's vectorization of the shift-mul form), so
/// eq. 13's arithmetic is kept verbatim; the mask form remains below
/// for the equivalence test. See EXPERIMENTS.md §Perf.
#[inline(always)]
pub fn lambda2_strict(x: u64, y: u64) -> (u64, u64) {
    debug_assert!(y >= 1);
    let level = ilog2(y);
    let b = 1u64 << level;
    let q = x >> level; // ⌊x / b⌋ — b is a power of two
    (x + q * b, y + 2 * q * b)
}

/// Bitmask variant (kept for the equivalence test; see §Perf note).
#[inline(always)]
pub fn lambda2_strict_mask(x: u64, y: u64) -> (u64, u64) {
    let level = ilog2(y);
    let qb = x & (u64::MAX << level); // q·b without the multiply
    (x + qb, y + (qb << 1))
}

/// Full inclusive map: grid `(N/2) × (N+1)` → `{(c, r): c ≤ r < N}`.
/// `None` never occurs for valid grid coordinates (zero waste).
#[inline(always)]
pub fn lambda2_inclusive(nb: u64, x: u64, y: u64) -> (u64, u64) {
    if y == 0 {
        // First half of the diagonal.
        (x, x)
    } else if y == nb {
        // Second half of the diagonal.
        (nb / 2 + x, nb / 2 + x)
    } else {
        lambda2_strict(x, y)
    }
}

impl ThreadMap for Lambda2Map {
    fn name(&self) -> &'static str {
        "lambda2"
    }

    fn m(&self) -> u32 {
        2
    }

    fn supports(&self, nb: u64) -> bool {
        // §III.A: the recursive structure needs n = 2^k (the paper's
        // approaches for other n are in maps::nonpow2).
        is_pow2(nb) && nb >= 2
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        Orthotope::d2(nb / 2, nb + 1)
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        let (c, r) = lambda2_inclusive(nb, w[0], w[1]);
        Some([c, r, 0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{alpha, domain_volume, in_domain};
    use std::collections::HashSet;

    /// Exhaustive bijection check — the core of experiment E2.
    #[test]
    fn lambda2_is_exact_bijection() {
        for k in 1..9u32 {
            let nb = 1u64 << k;
            let map = Lambda2Map;
            let mut seen = HashSet::new();
            for w in map.grid(nb, 0).iter() {
                let d = map.map_block(nb, 0, w).expect("λ2 has no filler");
                assert!(
                    in_domain(nb, 2, d),
                    "nb={nb}: block {w:?} escapes domain at {d:?}"
                );
                assert!(seen.insert((d[0], d[1])), "nb={nb}: duplicate image {d:?}");
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, 2), "nb={nb}");
        }
    }

    #[test]
    fn strict_part_matches_paper_formula() {
        // Spot-check eq. 13 arithmetic at specific coordinates.
        // y=1 → level 0, b=1, q=x.
        assert_eq!(lambda2_strict(0, 1), (0, 1));
        assert_eq!(lambda2_strict(1, 1), (2, 3));
        assert_eq!(lambda2_strict(2, 1), (4, 5));
        // y ∈ [2,4) → level 1, b=2.
        assert_eq!(lambda2_strict(0, 2), (0, 2));
        assert_eq!(lambda2_strict(1, 2), (1, 2));
        assert_eq!(lambda2_strict(2, 3), (4, 7));
        // y ∈ [4,8) → level 2, b=4.
        assert_eq!(lambda2_strict(5, 4), (9, 12));
    }

    #[test]
    fn mask_form_equals_eq13_form() {
        // The two arithmetic forms must agree everywhere.
        for y in 1..512u64 {
            for x in 0..256u64 {
                assert_eq!(lambda2_strict(x, y), lambda2_strict_mask(x, y), "({x},{y})");
            }
        }
    }

    #[test]
    fn strict_images_are_strictly_lower() {
        for y in 1..64u64 {
            for x in 0..64u64 {
                let (c, r) = lambda2_strict(x, y);
                assert!(c < r, "({x},{y}) → ({c},{r}) not strictly lower");
            }
        }
    }

    #[test]
    fn parallel_volume_equals_domain_volume() {
        // The 2× improvement: V(Π) = V(Δ) exactly (vs BB's ~2·V(Δ)).
        for k in 1..12u32 {
            let nb = 1u64 << k;
            assert_eq!(Lambda2Map.parallel_volume(nb), domain_volume(nb, 2));
        }
    }

    #[test]
    fn alpha_is_zero() {
        assert!(alpha(&Lambda2Map, 1 << 10).abs() < 1e-12);
    }

    #[test]
    fn rejects_non_pow2() {
        assert!(!Lambda2Map.supports(12));
        assert!(!Lambda2Map.supports(0));
        assert!(!Lambda2Map.supports(1));
        assert!(Lambda2Map.supports(2));
        assert!(Lambda2Map.supports(1 << 20));
    }

    #[test]
    fn diagonal_rows_cover_diagonal_exactly() {
        let nb = 32u64;
        let mut diag = HashSet::new();
        for x in 0..nb / 2 {
            let (c0, r0) = lambda2_inclusive(nb, x, 0);
            let (c1, r1) = lambda2_inclusive(nb, x, nb);
            assert_eq!(c0, r0);
            assert_eq!(c1, r1);
            diag.insert(c0);
            diag.insert(c1);
        }
        assert_eq!(diag.len() as u64, nb);
    }
}
