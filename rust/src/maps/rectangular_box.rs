//! RB — the rectangular-box strategy of Jung & O'Leary [8], applied to
//! the *parallel* space as the paper's related-work section suggests:
//! fold the inclusive lower triangle into an `(N/2) × (N+1)` rectangle
//! by mirroring the wide columns.
//!
//! Map: parallel `(x, y)`, grid `(N/2) × (N+1)`:
//! - `y > x`  → `(col, row) = (x, y-1)`       (left part, col < N/2)
//! - `y ≤ x`  → `(col, row) = (N-1-x, N-1-y)` (mirrored right part)
//!
//! Both parts together cover `{c ≤ r < N}` exactly once (proof in the
//! exhaustive test). O(1), no roots, no recursion — but unlike λ2 it
//! does not generalize to m=3 (no 3-D analog folds a tetrahedron into
//! a box without deformation, cf. §III.B's discussion).

use crate::maps::ThreadMap;
use crate::simplex::Orthotope;

pub struct RectangularBoxMap;

/// Raw RB fold, exposed for benches.
#[inline(always)]
pub fn rb_map(nb: u64, x: u64, y: u64) -> (u64, u64) {
    if y > x {
        (x, y - 1)
    } else {
        (nb - 1 - x, nb - 1 - y)
    }
}

impl ThreadMap for RectangularBoxMap {
    fn name(&self) -> &'static str {
        "rb"
    }

    fn m(&self) -> u32 {
        2
    }

    fn supports(&self, nb: u64) -> bool {
        nb >= 2 && nb % 2 == 0
    }

    fn grid(&self, nb: u64, _pass: u64) -> Orthotope {
        Orthotope::d2(nb / 2, nb + 1)
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: [u64; 3]) -> Option<[u64; 3]> {
        let (c, r) = rb_map(nb, w[0], w[1]);
        Some([c, r, 0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{alpha, domain_volume, in_domain};
    use std::collections::HashSet;

    #[test]
    fn rb_is_exact_bijection() {
        for nb in [2u64, 4, 6, 8, 16, 32, 64, 128] {
            let map = RectangularBoxMap;
            let mut seen = HashSet::new();
            for w in map.grid(nb, 0).iter() {
                let d = map.map_block(nb, 0, w).expect("rb has no filler");
                assert!(in_domain(nb, 2, d), "nb={nb} {w:?}→{d:?}");
                assert!(seen.insert((d[0], d[1])), "nb={nb} dup {d:?}");
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, 2), "nb={nb}");
        }
    }

    #[test]
    fn left_part_keeps_narrow_columns() {
        let nb = 16;
        for y in 0..=nb {
            for x in 0..nb / 2 {
                let (c, r) = rb_map(nb, x, y);
                if y > x {
                    assert!(c < nb / 2);
                } else {
                    assert!(c >= nb / 2);
                }
                assert!(c <= r, "({x},{y}) → ({c},{r})");
            }
        }
    }

    #[test]
    fn alpha_is_zero() {
        assert!(alpha(&RectangularBoxMap, 64).abs() < 1e-12);
    }

    #[test]
    fn even_sizes_only() {
        assert!(RectangularBoxMap.supports(6));
        assert!(!RectangularBoxMap.supports(7));
    }
}
