//! The general-m map layer: [`MThreadMap`] lifts the fixed-`[u64; 3]`
//! [`ThreadMap`] contract to dynamic-dimension block coordinates so the
//! §III.D maps (λ_m, m-dim bounding box) become executable, while every
//! existing m ≤ 3 map registers unchanged through [`FixedAdapter`].
//!
//! Block-level domains extend the module conventions of [`crate::maps`]:
//! m = 2 keeps the inclusive lower-triangle pairs; every m ≥ 3 uses
//! simplex coordinates `Bm(N) = { x ∈ Z₊^m : Σ x_i ≤ N-1 }` with
//! `|Bm(N)| = V(Δ_N^m) = C(N+m-1, m)`.

use crate::maps::ThreadMap;
use crate::simplex::block_m::{BlockM, OrthotopeM, M_MAX};
use crate::simplex::gasket::DomainKind;

/// A block-space thread map for an m-simplex domain, any m ≤ [`M_MAX`].
///
/// Mirrors [`ThreadMap`] with dynamic coordinates; `name` is owned
/// because parameterized maps (λ_m over (r, β)) synthesize theirs.
pub trait MThreadMap: Send + Sync {
    /// Registry name (round-trips through [`map_by_name`]).
    fn name(&self) -> String;

    /// Dimensionality of the data space.
    fn m(&self) -> u32;

    /// Which block-level data domain the map covers. Almost every map
    /// covers the orthogonal m-simplex; the gasket maps override this
    /// (and the scheduler refuses to run a simplex workload on a map
    /// that only covers the gasket).
    fn domain(&self) -> DomainKind {
        DomainKind::Simplex
    }

    /// Number of *useful* data blocks at size `nb` — the denominator of
    /// the waste/efficiency accounting. Defaults to the simplex closed
    /// form; non-simplex domains override (gasket: `3^k`).
    fn domain_volume(&self, nb: u64) -> u128 {
        crate::maps::domain_volume(nb, self.m())
    }

    /// Whether the map accepts a problem of `nb` blocks per side.
    fn supports(&self, nb: u64) -> bool;

    /// Number of kernel launches required for one full mapping.
    fn passes(&self, _nb: u64) -> u64 {
        1
    }

    /// Grid (parallel orthotope, in blocks) of launch pass `pass`.
    fn grid(&self, nb: u64, pass: u64) -> OrthotopeM;

    /// Map parallel block `w` of pass `pass` to a data block, or `None`
    /// for filler blocks.
    fn map_block(&self, nb: u64, pass: u64, w: &BlockM) -> Option<BlockM>;

    /// Total parallel-space volume in blocks (all passes).
    fn parallel_volume(&self, nb: u64) -> u128 {
        (0..self.passes(nb))
            .map(|p| self.grid(nb, p).volume())
            .sum()
    }
}

/// Whether a data block lies in the m-dimensional block-level domain.
#[inline]
pub fn in_domain_m(nb: u64, m: u32, d: &BlockM) -> bool {
    debug_assert_eq!(d.m(), m);
    if m == 2 {
        d[0] <= d[1] && d[1] < nb
    } else {
        d.sum() <= nb - 1
    }
}

/// Parallel-space efficiency `V(D) / V(Π)` for a dynamic-m map, where
/// `V(D)` is the map's *own* domain volume (simplex or gasket).
pub fn space_efficiency_m(map: &dyn MThreadMap, nb: u64) -> f64 {
    map.domain_volume(nb) as f64 / map.parallel_volume(nb) as f64
}

/// `V(Π)/V(D) - 1` — the waste ratio α for a dynamic-m map.
pub fn alpha_m(map: &dyn MThreadMap, nb: u64) -> f64 {
    map.parallel_volume(nb) as f64 / map.domain_volume(nb) as f64 - 1.0
}

/// Adapter: any registered fixed-m [`ThreadMap`] (m ≤ 3) as an
/// [`MThreadMap`], coordinate conversion only — the inner map's grid,
/// passes, and images are untouched.
pub struct FixedAdapter {
    pub inner: Box<dyn ThreadMap>,
}

impl FixedAdapter {
    pub fn new(inner: Box<dyn ThreadMap>) -> FixedAdapter {
        assert!(inner.m() <= 3, "FixedAdapter wraps m ≤ 3 maps");
        FixedAdapter { inner }
    }
}

/// Wrap a fixed m ≤ 3 map for APIs that take the unified
/// [`MThreadMap`] contract (the single launch path).
pub fn adapt<T: ThreadMap + 'static>(inner: T) -> FixedAdapter {
    FixedAdapter::new(Box::new(inner))
}

impl MThreadMap for FixedAdapter {
    fn name(&self) -> String {
        self.inner.name().to_string()
    }

    fn m(&self) -> u32 {
        self.inner.m()
    }

    fn supports(&self, nb: u64) -> bool {
        self.inner.supports(nb)
    }

    fn passes(&self, nb: u64) -> u64 {
        self.inner.passes(nb)
    }

    fn grid(&self, nb: u64, pass: u64) -> OrthotopeM {
        let g = self.inner.grid(nb, pass);
        // lint: allow(cast, u32 to usize widens)
        OrthotopeM::new(&g.dims[..g.m as usize])
    }

    #[inline]
    fn map_block(&self, nb: u64, pass: u64, w: &BlockM) -> Option<BlockM> {
        let d = self.inner.map_block(nb, pass, w.to_fixed3())?;
        Some(BlockM::from_fixed3(d, self.m()))
    }
}

/// The m-dimensional bounding-box baseline: launch the full `nb^m`
/// orthotope and predicate-discard everything outside the simplex —
/// eq. 4's `m! - 1` waste, the number λ_m is measured against.
pub struct BoundingBoxM {
    m: u32,
}

impl BoundingBoxM {
    pub fn new(m: u32) -> BoundingBoxM {
        // lint: allow(cast, u32 to usize widens)
        assert!(m >= 2 && m as usize <= M_MAX);
        BoundingBoxM { m }
    }
}

impl MThreadMap for BoundingBoxM {
    fn name(&self) -> String {
        "bb".into()
    }

    fn m(&self) -> u32 {
        self.m
    }

    fn supports(&self, nb: u64) -> bool {
        // Linear block indices must fit u64.
        nb >= 1 && (nb as u128).checked_pow(self.m).is_some_and(|v| v <= u64::MAX as u128)
    }

    fn grid(&self, nb: u64, _pass: u64) -> OrthotopeM {
        let dims = [nb; M_MAX];
        // lint: allow(cast, u32 to usize widens)
        OrthotopeM::new(&dims[..self.m as usize])
    }

    #[inline]
    fn map_block(&self, nb: u64, _pass: u64, w: &BlockM) -> Option<BlockM> {
        if in_domain_m(nb, self.m, w) {
            Some(*w)
        } else {
            None
        }
    }
}

/// The unified registry: construct a map for any dimension by name.
/// `map2_by_name`/`map3_by_name` are thin wrappers over the same table
/// (m ≤ 3 maps arrive through [`FixedAdapter`]); m ≥ 4 resolves the
/// general-m natives.
pub fn map_by_name(m: u32, name: &str) -> Option<Box<dyn MThreadMap>> {
    match m {
        // m = 2 also hosts the gasket-domain natives (MThreadMap-only:
        // they have no fixed-map ancestor to adapt).
        2 if name == "lambda-gasket" || name == "gasket" => {
            Some(Box::new(crate::maps::lambda_gasket::GasketLambdaMap))
        }
        2 if name == "bb-gasket" || name == "gasket-bb" => {
            Some(Box::new(crate::maps::lambda_gasket::GasketBoundingBoxMap))
        }
        2 | 3 => crate::maps::fixed_map_by_name(m, name)
            .map(|inner| Box::new(FixedAdapter::new(inner)) as Box<dyn MThreadMap>),
        4..=8 => match name {
            "bb" | "bounding-box" => Some(Box::new(BoundingBoxM::new(m))),
            "lambda-m" | "lambda" => crate::maps::lambda_m::LambdaMMap::auto(m)
                .map(|map| Box::new(map) as Box<dyn MThreadMap>),
            _ => {
                let beta: u32 = name.strip_prefix("lambda-m-b")?.parse().ok()?;
                crate::maps::lambda_m::LambdaMMap::try_for_paper(m, beta)
                    .map(|map| Box::new(map) as Box<dyn MThreadMap>)
            }
        },
        _ => None,
    }
}

/// All registered *simplex-domain* map names for dimension m (for CLIs
/// and sweeps). Domain-scoped listing is [`map_names_for`].
pub fn map_names(m: u32) -> Vec<String> {
    map_names_for(m, DomainKind::Simplex)
}

/// Registered map names for a (dimension, domain) pair. The simplex
/// conformance suites sweep `DomainKind::Simplex`; the gasket names
/// live only under `DomainKind::Gasket` so a partition check against
/// the wrong domain can never pick them up by accident.
pub fn map_names_for(m: u32, domain: DomainKind) -> Vec<String> {
    match (domain, m) {
        (DomainKind::Simplex, 2) => {
            crate::maps::MAP2_NAMES.iter().map(|s| s.to_string()).collect()
        }
        (DomainKind::Simplex, 3) => {
            crate::maps::MAP3_NAMES.iter().map(|s| s.to_string()).collect()
        }
        (DomainKind::Simplex, 4..=8) => vec!["bb".into(), "lambda-m".into()],
        (DomainKind::Gasket, 2) => crate::maps::GASKET_MAP_NAMES
            .iter()
            .map(|s| s.to_string())
            .collect(),
        _ => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::domain_volume;
    use std::collections::HashSet;

    #[test]
    fn in_domain_m_matches_fixed_conventions() {
        // m=2 inclusive triangle.
        assert!(in_domain_m(4, 2, &BlockM::from_slice(&[3, 3])));
        assert!(!in_domain_m(4, 2, &BlockM::from_slice(&[3, 1])));
        assert!(!in_domain_m(4, 2, &BlockM::from_slice(&[0, 4])));
        // m=3 simplex, agreeing with maps::in_domain.
        for x in 0..5u64 {
            for y in 0..5u64 {
                for z in 0..5u64 {
                    assert_eq!(
                        in_domain_m(4, 3, &BlockM::from_slice(&[x, y, z])),
                        crate::maps::in_domain(4, 3, [x, y, z])
                    );
                }
            }
        }
        // m=5 simplex.
        assert!(in_domain_m(3, 5, &BlockM::from_slice(&[1, 0, 1, 0, 0])));
        assert!(!in_domain_m(3, 5, &BlockM::from_slice(&[1, 1, 1, 0, 0])));
    }

    #[test]
    fn adapter_preserves_lambda2_partition() {
        let map = map_by_name(2, "lambda2").unwrap();
        assert_eq!(map.m(), 2);
        assert_eq!(map.name(), "lambda2");
        let nb = 16u64;
        assert!(map.supports(nb));
        let mut seen = HashSet::new();
        for pass in 0..map.passes(nb) {
            for w in map.grid(nb, pass).iter() {
                let d = map.map_block(nb, pass, &w).expect("λ2 has no filler");
                assert!(in_domain_m(nb, 2, &d));
                assert!(seen.insert(d));
            }
        }
        assert_eq!(seen.len() as u128, domain_volume(nb, 2));
    }

    #[test]
    fn adapter_preserves_lambda3_images() {
        let fixed = crate::maps::map3_by_name("lambda3").unwrap();
        let dynamic = map_by_name(3, "lambda3").unwrap();
        let nb = 8u64;
        for w in fixed.grid(nb, 0).iter() {
            let a = fixed.map_block(nb, 0, w);
            let b = dynamic.map_block(nb, 0, &BlockM::from_fixed3(w, 3));
            assert_eq!(a.map(|d| BlockM::from_fixed3(d, 3)), b, "{w:?}");
        }
        assert_eq!(fixed.parallel_volume(nb), dynamic.parallel_volume(nb));
    }

    #[test]
    fn bounding_box_m_partitions_with_eq4_waste() {
        for m in [4u32, 5] {
            let map = BoundingBoxM::new(m);
            let nb = 5u64;
            let mut seen = HashSet::new();
            let mut filler = 0u128;
            for w in map.grid(nb, 0).iter() {
                match map.map_block(nb, 0, &w) {
                    None => filler += 1,
                    Some(d) => {
                        assert!(in_domain_m(nb, m, &d));
                        assert!(seen.insert(d));
                    }
                }
            }
            assert_eq!(seen.len() as u128, domain_volume(nb, m), "m={m}");
            assert_eq!(
                filler,
                (nb as u128).pow(m) - domain_volume(nb, m),
                "m={m}"
            );
        }
    }

    #[test]
    fn registry_resolves_gasket_names_at_m2_only() {
        let lam = map_by_name(2, "lambda-gasket").unwrap();
        assert_eq!(lam.domain(), DomainKind::Gasket);
        assert_eq!(lam.domain_volume(8), 27);
        assert!(map_by_name(2, "bb-gasket").is_some());
        assert!(map_by_name(2, "gasket").is_some(), "alias");
        assert!(map_by_name(3, "lambda-gasket").is_none());
        // Simplex maps keep the default domain and simplex volume.
        let l2 = map_by_name(2, "lambda2").unwrap();
        assert_eq!(l2.domain(), DomainKind::Simplex);
        assert_eq!(l2.domain_volume(8), domain_volume(8, 2));
        // Domain-scoped listing: gasket names never leak into the
        // simplex lists the conformance suites sweep.
        let gasket = map_names_for(2, DomainKind::Gasket);
        assert_eq!(gasket, vec!["bb-gasket".to_string(), "lambda-gasket".to_string()]);
        assert!(map_names(2).iter().all(|n| !n.contains("gasket")));
        assert!(map_names_for(3, DomainKind::Gasket).is_empty());
        for name in gasket {
            assert_eq!(map_by_name(2, &name).unwrap().name(), name);
        }
    }

    #[test]
    fn registry_resolves_per_dimension() {
        assert!(map_by_name(2, "ries").is_some());
        assert!(map_by_name(3, "lambda3-rec").is_some());
        assert!(map_by_name(4, "bb").is_some());
        assert!(map_by_name(4, "lambda-m").is_some());
        assert!(map_by_name(5, "lambda-m-b32").is_some());
        assert!(map_by_name(4, "lambda3").is_none());
        assert!(map_by_name(9, "bb").is_none());
        assert!(map_by_name(4, "lambda-m-b999999").is_none());
        for m in 2..=8u32 {
            for name in map_names(m) {
                let map = map_by_name(m, &name).unwrap_or_else(|| panic!("{m} {name}"));
                assert_eq!(map.m(), m, "{name}");
            }
        }
    }
}
