//! GPU grid simulator — the substituted hardware substrate.
//!
//! Models the CUDA constructs of §I faithfully at the level the paper's
//! claims live at: a *grid* is an orthotope of *blocks*; each block is
//! a ρ^m cube of *threads*; a launch applies a thread map to every
//! block, discards filler blocks, and runs a block kernel over the
//! surviving ones on a worker pool (workers ≈ SMs). The launcher
//! accounts launched/filler/useful/predicated-off thread counts — the
//! parallel-space efficiency numbers the paper reasons about — plus a
//! per-launch latency charge so multi-pass maps (Ries, λ3-rec) pay for
//! their launch counts like real kernels do.
//!
//! Since the pipeline unification there is exactly one launch path:
//! every map — fixed m ≤ 3 or general-m — goes through
//! [`Launcher::launch`] over the [`MThreadMap`](crate::maps::MThreadMap)
//! contract, and every mapped block is the dynamic-coordinate
//! [`MappedBlock`].

pub mod launcher;
pub mod occupancy;

use crate::simplex::block_m::{BlockM, M_MAX};

pub use launcher::{BackendKind, LaneProfile, LaunchConfig, LaunchStats, Launcher};
pub use occupancy::OccupancyReport;

/// Threads per block side (ρ in the paper; blocks are ρ^m cubes —
/// m ≤ 3 on real CUDA grids, up to [`M_MAX`] in the general-m
/// subsystem, which linearizes higher dimensions like §I describes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockShape {
    pub rho: u32,
    pub m: u32,
}

impl BlockShape {
    pub fn new(rho: u32, m: u32) -> BlockShape {
        assert!(rho >= 1 && m >= 2 && m as usize <= M_MAX);
        BlockShape { rho, m }
    }

    /// Threads per block (ρ^m).
    pub fn threads(&self) -> u64 {
        (self.rho as u64).pow(self.m)
    }
}

/// A mapped block ready for execution: where it came from in parallel
/// space and where it landed in data space (block coordinates, any
/// dimension 2 ≤ m ≤ [`M_MAX`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MappedBlock {
    pub parallel: BlockM,
    pub data: BlockM,
    pub pass: u64,
}

impl MappedBlock {
    /// Data-space thread origin of this block.
    pub fn thread_origin(&self, shape: BlockShape) -> BlockM {
        let r = shape.rho as u64;
        let mut origin = self.data;
        for i in 0..origin.m() as usize {
            origin[i] *= r;
        }
        origin
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_shape_thread_counts() {
        assert_eq!(BlockShape::new(16, 2).threads(), 256);
        assert_eq!(BlockShape::new(8, 3).threads(), 512);
        assert_eq!(BlockShape::new(1, 2).threads(), 1);
        assert_eq!(BlockShape::new(2, 5).threads(), 32);
    }

    #[test]
    fn mapped_block_thread_origin() {
        let b = MappedBlock {
            parallel: BlockM::zeros(4),
            data: BlockM::from_slice(&[2, 3, 1, 5]),
            pass: 0,
        };
        let origin = b.thread_origin(BlockShape::new(4, 4));
        assert_eq!(origin.as_slice(), &[8, 12, 4, 20]);
    }

    #[test]
    fn thread_origin_scales_by_rho_at_fixed_m() {
        let b = MappedBlock {
            parallel: BlockM::zeros(3),
            data: BlockM::from_slice(&[2, 3, 1]),
            pass: 0,
        };
        let origin = b.thread_origin(BlockShape::new(16, 3));
        assert_eq!(origin.as_slice(), &[32, 48, 16]);
    }

    #[test]
    #[should_panic]
    fn invalid_m_rejected() {
        BlockShape::new(8, M_MAX as u32 + 1);
    }
}
