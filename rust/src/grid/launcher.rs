//! The launch engine: apply a map to a grid, execute surviving blocks.
//!
//! [`Launcher::launch`] is the simulated `kernel<<<grid, block>>>`, and
//! it is the *only* launch path: every map of every dimension goes
//! through the [`MThreadMap`] contract (fixed m ≤ 3 maps arrive via
//! [`FixedAdapter`](crate::maps::FixedAdapter)). It walks every
//! parallel block of every pass, applies the map (the hot path under
//! test), and hands mapped blocks to the block kernel *in place* — the
//! kernel runs inside the map sweep (fused map+execute), so nothing is
//! materialized between the phases. Callers that want the old
//! collect-then-execute flow (trace capture, conformance tests) simply
//! pass a collecting kernel.
//!
//! Thread-level predication is the kernel's job (it knows the
//! workload's domain); the launcher provides exact accounting of all
//! four thread populations:
//!
//! - `launched` — every thread the grid paid for,
//! - `filler`   — threads of blocks the map discarded (`None`),
//! - `mapped`   — threads of blocks that reached the kernel,
//! - `predicated_off` — threads the kernel reported as out-of-domain
//!   (diagonal blocks).
//!
//! A per-pass latency charge models kernel-launch overhead — *modeled
//! only* by default ([`LaunchStats::launch_overhead`]); the actual
//! wall-clock sleep is opt-in via [`LaunchConfig::simulate_latency`] —
//! and a `max_concurrent_launches` cap models the ≤32-kernel limit
//! §III.B invokes against the arity-3 recursive map.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::maps::MThreadMap;

use super::{BlockShape, MappedBlock};

/// Launch-time knobs.
#[derive(Clone, Debug)]
pub struct LaunchConfig {
    pub shape: BlockShape,
    /// Blocks per work chunk handed to a pool worker.
    pub chunk_blocks: usize,
    /// Modeled fixed cost per kernel-launch wave.
    pub launch_latency: Duration,
    /// Hardware cap on concurrent kernel launches (≈32 on the paper's
    /// GPUs): passes beyond the cap serialize into waves.
    pub max_concurrent_launches: u64,
    /// When true, actually sleep for the modeled launch overhead
    /// (latency experiments); when false — the default — the overhead
    /// is accounted in [`LaunchStats::launch_overhead`] only and adds
    /// no wall time.
    pub simulate_latency: bool,
}

impl LaunchConfig {
    pub fn new(shape: BlockShape) -> LaunchConfig {
        LaunchConfig {
            shape,
            chunk_blocks: 4096,
            launch_latency: Duration::from_micros(5),
            max_concurrent_launches: 32,
            simulate_latency: false,
        }
    }
}

/// Exact accounting of one launch (all passes).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LaunchStats {
    pub passes: u64,
    /// Serialized launch waves: ceil(passes / max_concurrent).
    pub launch_waves: u64,
    pub blocks_launched: u64,
    pub blocks_filler: u64,
    pub blocks_mapped: u64,
    pub threads_launched: u64,
    pub threads_mapped: u64,
    pub threads_predicated_off: u64,
    pub wall: Duration,
    /// Modeled launch-latency component (wall time only when
    /// [`LaunchConfig::simulate_latency`] is set).
    pub launch_overhead: Duration,
}

impl LaunchStats {
    /// Fraction of launched threads that did useful work. An empty
    /// launch (zero threads) is vacuously fully efficient — the 0/0
    /// division would otherwise yield NaN (see
    /// [`OccupancyReport::measured_alpha`](super::OccupancyReport::measured_alpha)
    /// for the shared convention).
    pub fn thread_efficiency(&self) -> f64 {
        if self.threads_launched == 0 {
            return 1.0;
        }
        (self.threads_mapped - self.threads_predicated_off) as f64
            / self.threads_launched as f64
    }

    /// Fraction of launched blocks that reached the kernel (1.0 for an
    /// empty launch, 0.0 when everything launched was filler).
    pub fn block_efficiency(&self) -> f64 {
        if self.blocks_launched == 0 {
            return 1.0;
        }
        self.blocks_mapped as f64 / self.blocks_launched as f64
    }

    /// The deterministic accounting fields (everything except the
    /// measured wall time) — what execution-mode equivalence means.
    pub fn accounting(&self) -> [u64; 8] {
        [
            self.passes,
            self.launch_waves,
            self.blocks_launched,
            self.blocks_filler,
            self.blocks_mapped,
            self.threads_launched,
            self.threads_mapped,
            self.threads_predicated_off,
        ]
    }
}

/// The simulated device.
pub struct Launcher {
    workers: usize,
    pub config: LaunchConfig,
}

impl Launcher {
    /// A launcher that fans block ranges out over `workers` lanes
    /// (scoped threads — no pool to spin up per job).
    pub fn with_workers(workers: usize, config: LaunchConfig) -> Launcher {
        Launcher {
            workers: workers.max(1),
            config,
        }
    }

    /// Number of worker lanes.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `map` over the full grid for problem size `nb` (blocks per
    /// side) and invoke `kernel` on every mapped block, fused into the
    /// map sweep. The kernel receives the *lane index* (stable per
    /// worker across passes, `< workers()`) — per-lane accumulators are
    /// how fused workloads aggregate without a blocks vector — and the
    /// mapped block; it returns how many of the block's threads were
    /// predicated off.
    ///
    /// The kernel is called concurrently from different lanes, but any
    /// given lane index is used by at most one thread at a time.
    pub fn launch<K>(&self, map: &dyn MThreadMap, nb: u64, kernel: K) -> LaunchStats
    where
        K: Fn(usize, &MappedBlock) -> u64 + Send + Sync,
    {
        assert!(
            map.supports(nb),
            "map {} does not support nb={nb}",
            map.name()
        );
        assert_eq!(self.config.shape.m, map.m(), "block shape vs map dim");
        let t0 = Instant::now();
        let threads_per_block = self.config.shape.threads();
        let passes = map.passes(nb);

        let blocks_launched = AtomicU64::new(0);
        let blocks_filler = AtomicU64::new(0);
        let blocks_mapped = AtomicU64::new(0);
        let predicated = AtomicU64::new(0);

        for pass in 0..passes {
            let grid = map.grid(nb, pass);
            let total = grid.volume() as usize;
            blocks_launched.fetch_add(total as u64, Ordering::Relaxed);
            let chunks = total.div_ceil(self.config.chunk_blocks.max(1));

            // Share state without 'static bounds: scoped threads, one
            // contiguous block range per lane, results via a mutex.
            let results: Mutex<Vec<(u64, u64, u64)>> = Mutex::new(Vec::new());
            std::thread::scope(|scope| {
                let lanes = self.workers.min(chunks.max(1));
                let chunk_size = total.div_ceil(lanes.max(1));
                for lane in 0..lanes {
                    let lo = lane * chunk_size;
                    if lo >= total {
                        break;
                    }
                    let hi = ((lane + 1) * chunk_size).min(total);
                    let kernel = &kernel;
                    let results = &results;
                    let grid = &grid;
                    scope.spawn(move || {
                        let mut filler = 0u64;
                        let mut mapped = 0u64;
                        let mut pred = 0u64;
                        for idx in lo..hi {
                            let p = grid.of_linear(idx as u64);
                            match map.map_block(nb, pass, &p) {
                                None => filler += 1,
                                Some(data) => {
                                    mapped += 1;
                                    let mb = MappedBlock {
                                        parallel: p,
                                        data,
                                        pass,
                                    };
                                    pred += kernel(lane, &mb);
                                }
                            }
                        }
                        results.lock().unwrap().push((filler, mapped, pred));
                    });
                }
            });
            for (f, m, p) in results.into_inner().unwrap() {
                blocks_filler.fetch_add(f, Ordering::Relaxed);
                blocks_mapped.fetch_add(m, Ordering::Relaxed);
                predicated.fetch_add(p, Ordering::Relaxed);
            }
        }

        // Launch-latency model: passes serialize in waves of
        // max_concurrent_launches. Accounting-only unless the caller
        // opted into simulating the wall time.
        let waves = passes.div_ceil(self.config.max_concurrent_launches.max(1));
        let overhead = self.config.launch_latency * waves as u32;
        if self.config.simulate_latency {
            std::thread::sleep(overhead);
        }

        let bl = blocks_launched.load(Ordering::Relaxed);
        let bm = blocks_mapped.load(Ordering::Relaxed);
        LaunchStats {
            passes,
            launch_waves: waves,
            blocks_launched: bl,
            blocks_filler: blocks_filler.load(Ordering::Relaxed),
            blocks_mapped: bm,
            threads_launched: bl * threads_per_block,
            threads_mapped: bm * threads_per_block,
            threads_predicated_off: predicated.load(Ordering::Relaxed),
            wall: t0.elapsed(),
            launch_overhead: overhead,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{adapt, BoundingBox2, Lambda2Map, Lambda3Map, RiesMap, ThreadMap};

    fn launcher(rho: u32, m: u32) -> Launcher {
        let mut cfg = LaunchConfig::new(BlockShape::new(rho, m));
        cfg.launch_latency = Duration::ZERO;
        Launcher::with_workers(4, cfg)
    }

    #[test]
    fn bb2_accounting_matches_closed_forms() {
        let l = launcher(16, 2);
        let nb = 64u64;
        let stats = l.launch(&adapt(BoundingBox2), nb, |_lane, _b| 0);
        assert_eq!(stats.blocks_launched, nb * nb);
        assert_eq!(stats.blocks_mapped, nb * (nb + 1) / 2);
        assert_eq!(stats.blocks_filler, nb * (nb - 1) / 2);
        assert_eq!(stats.threads_launched, nb * nb * 256);
        assert!((stats.block_efficiency() - 0.5).abs() < 0.01);
    }

    #[test]
    fn lambda2_has_zero_filler() {
        let l = launcher(16, 2);
        let stats = l.launch(&adapt(Lambda2Map), 128, |_lane, _b| 0);
        assert_eq!(stats.blocks_filler, 0);
        assert_eq!(stats.block_efficiency(), 1.0);
    }

    #[test]
    fn lambda3_filler_matches_container_slack() {
        let l = launcher(8, 3);
        let nb = 32u64;
        let stats = l.launch(&adapt(Lambda3Map), nb, |_lane, _b| 0);
        let expect = Lambda3Map.parallel_volume(nb) - crate::maps::domain_volume(nb, 3);
        assert_eq!(stats.blocks_filler as u128, expect);
    }

    #[test]
    fn kernel_sees_every_mapped_block_once() {
        use std::collections::HashSet;
        let l = launcher(4, 2);
        let nb = 32u64;
        let seen = Mutex::new(HashSet::new());
        let stats = l.launch(&adapt(Lambda2Map), nb, |_lane, b| {
            assert!(seen.lock().unwrap().insert(b.data), "dup {:?}", b.data);
            0
        });
        assert_eq!(seen.lock().unwrap().len() as u64, stats.blocks_mapped);
    }

    #[test]
    fn predication_counts_flow_through() {
        let l = launcher(8, 2);
        // Kernel predicates off half of each diagonal block.
        let stats = l.launch(&adapt(Lambda2Map), 16, |_lane, b| {
            if b.data[0] == b.data[1] {
                28 // 8·7/2 threads above the strict diagonal
            } else {
                0
            }
        });
        assert_eq!(stats.threads_predicated_off, 16 * 28);
        assert!(stats.thread_efficiency() < 1.0);
    }

    #[test]
    fn lane_indices_stay_within_workers() {
        let l = launcher(4, 2);
        let max_lane = AtomicU64::new(0);
        l.launch(&adapt(BoundingBox2), 32, |lane, _b| {
            max_lane.fetch_max(lane as u64, Ordering::Relaxed);
            0
        });
        assert!((max_lane.load(Ordering::Relaxed) as usize) < l.workers());
    }

    #[test]
    fn multi_pass_map_counts_waves() {
        let mut cfg = LaunchConfig::new(BlockShape::new(4, 2));
        cfg.launch_latency = Duration::ZERO;
        cfg.max_concurrent_launches = 4;
        let l = Launcher::with_workers(2, cfg);
        let nb = 64u64;
        let stats = l.launch(&adapt(RiesMap), nb, |_lane, _b| 0);
        assert_eq!(stats.passes, 7); // log2(64) + 1
        assert_eq!(stats.launch_waves, 2); // ceil(7/4)
    }

    #[test]
    fn latency_is_modeled_but_not_slept_by_default() {
        let mut cfg = LaunchConfig::new(BlockShape::new(4, 2));
        cfg.launch_latency = Duration::from_millis(250);
        assert!(!cfg.simulate_latency, "accounting-only is the default");
        let l = Launcher::with_workers(2, cfg);
        let stats = l.launch(&adapt(Lambda2Map), 8, |_lane, _b| 0);
        assert_eq!(stats.launch_overhead, Duration::from_millis(250));
        assert!(
            stats.wall < Duration::from_millis(200),
            "no sleep: wall {:?}",
            stats.wall
        );
    }

    #[test]
    #[should_panic(expected = "does not support")]
    fn unsupported_size_panics() {
        launcher(8, 2).launch(&adapt(Lambda2Map), 17, |_lane, _b| 0);
    }

    #[test]
    fn lambda_m_accounting_matches_plan() {
        use crate::maps::{LambdaMMap, MThreadMap as _};
        let l = launcher(2, 4);
        let map = LambdaMMap::for_paper(4, 2);
        let nb = 28u64; // first covered size: parallel 31501, filler 36
        let stats = l.launch(&map, nb, |_lane, _b| 0);
        assert_eq!(stats.blocks_launched, 31501);
        assert_eq!(stats.blocks_filler, 36);
        assert_eq!(stats.blocks_mapped, 31465);
        assert_eq!(stats.passes, map.passes(nb));
        assert_eq!(stats.threads_launched, 31501 * 16);
        assert_eq!(stats.threads_mapped, 31465 * 16);
    }

    #[test]
    fn general_m_sees_each_data_block_once() {
        use crate::maps::BoundingBoxM;
        use std::collections::HashSet;
        let l = launcher(2, 5);
        let map = BoundingBoxM::new(5);
        let nb = 4u64;
        let seen = Mutex::new(HashSet::new());
        let stats = l.launch(&map, nb, |_lane, b| {
            assert!(seen.lock().unwrap().insert(b.data), "dup {:?}", b.data);
            0
        });
        assert_eq!(seen.lock().unwrap().len() as u64, stats.blocks_mapped);
        assert_eq!(stats.blocks_mapped as u128, crate::maps::domain_volume(4, 5));
        assert_eq!(stats.blocks_launched, 4u64.pow(5));
    }

    #[test]
    fn general_m_predication_counts_flow_through() {
        use crate::maps::BoundingBoxM;
        let l = launcher(2, 4);
        let stats = l.launch(&BoundingBoxM::new(4), 3, |_lane, b| {
            // Predicate one thread off in every block on the main
            // diagonal plane Σ = nb-1.
            if b.data.sum() == 2 {
                1
            } else {
                0
            }
        });
        // |{Σ = 2, m = 4}| = C(5, 3) = 10.
        assert_eq!(stats.threads_predicated_off, 10);
        assert!(stats.thread_efficiency() < 1.0);
    }
}
