//! The launch engine: apply a map to a grid, execute surviving blocks.
//!
//! [`Launcher::launch`] is the simulated `kernel<<<grid, block>>>`, and
//! it is the *only* launch path: every map of every dimension goes
//! through the [`MThreadMap`] contract (fixed m ≤ 3 maps arrive via
//! [`FixedAdapter`](crate::maps::FixedAdapter)). It walks every
//! parallel block of every pass, applies the map (the hot path under
//! test), and hands mapped blocks to the block kernel *in place* — the
//! kernel runs inside the map sweep (fused map+execute), so nothing is
//! materialized between the phases. Callers that want the old
//! collect-then-execute flow (trace capture, conformance tests) simply
//! pass a collecting kernel.
//!
//! # Backend axis
//!
//! [`BackendKind`] selects how the sweep executes:
//!
//! - [`BackendKind::Serial`] — one lane walks the whole launch in
//!   index order: the accounting oracle every other backend must match.
//! - [`BackendKind::Parallel`] — the worker pool below (the default).
//! - [`BackendKind::Pjrt`] — identical host-side sweep (the
//!   coordinator collects blocks and dispatches tiles to XLA); the
//!   launcher itself treats it like [`BackendKind::Parallel`].
//!
//! The parallel pool is built **once per launch**, not once per pass:
//! all pass grids are laid end-to-end into a single linear index space
//! (exclusive prefix sums of the per-pass volumes), split into chunks
//! of at most [`LaunchConfig::chunk_blocks`] blocks, and lanes pull
//! chunk indices from a shared atomic cursor. Chunks are capped at
//! `total / workers` blocks so a mid-size grid still fans out into at
//! least one chunk per lane, and the first `workers` chunks are
//! statically pre-assigned (the cursor starts past them) so every lane
//! is guaranteed work before the race begins. Per-lane tallies come
//! back through the join handles — no results mutex.
//!
//! Thread-level predication is the kernel's job (it knows the
//! workload's domain); the launcher provides exact accounting of all
//! four thread populations:
//!
//! - `launched` — every thread the grid paid for,
//! - `filler`   — threads of blocks the map discarded (`None`),
//! - `mapped`   — threads of blocks that reached the kernel,
//! - `predicated_off` — threads the kernel reported as out-of-domain
//!   (diagonal blocks).
//!
//! A per-pass latency charge models kernel-launch overhead — *modeled
//! only* by default ([`LaunchStats::launch_overhead`]); the actual
//! wall-clock sleep is opt-in via [`LaunchConfig::simulate_latency`] —
//! and a `max_concurrent_launches` cap models the ≤32-kernel limit
//! §III.B invokes against the arity-3 recursive map.
//!
//! Memory-ordering policy: the work-stealing chunk cursor only needs
//! each worker to claim a distinct chunk — `fetch_add` is atomic at
//! any ordering and the pool joins before results are read (the join
//! provides the happens-before edge) — so all accesses are Relaxed.
// lint: atomics(Relaxed)

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::maps::MThreadMap;
use crate::simplex::{BlockM, OrthotopeM};

use super::{BlockShape, MappedBlock};

/// Which engine drives a launch (and, at the coordinator level, a
/// job): the single-lane reference interpreter, the chunk-cursor
/// worker pool, or the XLA/PJRT tile path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Single-lane reference sweep — the accounting oracle.
    Serial,
    /// Data-parallel in-process worker pool (the default).
    Parallel,
    /// Host-side sweep collects blocks; tiles execute through XLA.
    Pjrt,
}

impl BackendKind {
    /// Parse a CLI/wire name. `"rust"` survives as a legacy alias for
    /// the in-process parallel backend (the pre-PR-6 job schema named
    /// the whole non-PJRT path after the language).
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "serial" => Some(BackendKind::Serial),
            "parallel" | "rust" => Some(BackendKind::Parallel),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Serial => "serial",
            BackendKind::Parallel => "parallel",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Launch-time knobs.
#[derive(Clone, Debug)]
pub struct LaunchConfig {
    pub shape: BlockShape,
    /// Blocks per work chunk pulled from the shared cursor. Chunks are
    /// additionally capped at `total / workers` so small grids still
    /// feed every lane.
    pub chunk_blocks: usize,
    /// Modeled fixed cost per kernel-launch wave.
    pub launch_latency: Duration,
    /// Hardware cap on concurrent kernel launches (≈32 on the paper's
    /// GPUs): passes beyond the cap serialize into waves.
    pub max_concurrent_launches: u64,
    /// When true, actually sleep for the modeled launch overhead
    /// (latency experiments); when false — the default — the overhead
    /// is accounted in [`LaunchStats::launch_overhead`] only and adds
    /// no wall time.
    pub simulate_latency: bool,
    /// Execution backend for the block sweep.
    pub backend: BackendKind,
    /// Opt-in per-lane profiling: busy-ns / chunks-pulled /
    /// blocks-processed tallies per lane ([`LaunchStats::lanes`]).
    /// Off by default — the disabled path costs one untaken branch
    /// per work chunk (thousands of blocks), nothing per block.
    pub profile_lanes: bool,
}

impl LaunchConfig {
    pub fn new(shape: BlockShape) -> LaunchConfig {
        LaunchConfig {
            shape,
            chunk_blocks: 4096,
            launch_latency: Duration::from_micros(5),
            max_concurrent_launches: 32,
            simulate_latency: false,
            backend: BackendKind::Parallel,
            profile_lanes: false,
        }
    }
}

/// Per-lane work tallies from one launch (opt-in via
/// [`LaunchConfig::profile_lanes`]). `busy_ns` is time spent inside
/// `sweep_range` — excludes the chunk-cursor handoff, so the lane
/// imbalance ratio reflects work distribution, not scheduling jitter.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LaneProfile {
    pub lane: u64,
    pub busy_ns: u64,
    pub chunks_pulled: u64,
    pub blocks_processed: u64,
}

/// Exact accounting of one launch (all passes).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LaunchStats {
    pub passes: u64,
    /// Serialized launch waves: ceil(passes / max_concurrent).
    pub launch_waves: u64,
    pub blocks_launched: u64,
    pub blocks_filler: u64,
    pub blocks_mapped: u64,
    pub threads_launched: u64,
    pub threads_mapped: u64,
    pub threads_predicated_off: u64,
    pub wall: Duration,
    /// Modeled launch-latency component (wall time only when
    /// [`LaunchConfig::simulate_latency`] is set).
    pub launch_overhead: Duration,
    /// Per-lane profile — empty unless [`LaunchConfig::profile_lanes`]
    /// was set. Not part of [`LaunchStats::accounting`]: lane timings
    /// are measurements, not determinism contracts.
    pub lanes: Vec<LaneProfile>,
}

impl LaunchStats {
    /// Fraction of launched threads that did useful work. An empty
    /// launch (zero threads) is vacuously fully efficient — the 0/0
    /// division would otherwise yield NaN (see
    /// [`OccupancyReport::measured_alpha`](super::OccupancyReport::measured_alpha)
    /// for the shared convention).
    pub fn thread_efficiency(&self) -> f64 {
        if self.threads_launched == 0 {
            return 1.0;
        }
        (self.threads_mapped - self.threads_predicated_off) as f64
            / self.threads_launched as f64
    }

    /// Fraction of launched blocks that reached the kernel (1.0 for an
    /// empty launch, 0.0 when everything launched was filler).
    pub fn block_efficiency(&self) -> f64 {
        if self.blocks_launched == 0 {
            return 1.0;
        }
        self.blocks_mapped as f64 / self.blocks_launched as f64
    }

    /// Lane-imbalance ratio: max lane busy time over mean lane busy
    /// time (1.0 = perfectly balanced). `None` without a lane profile
    /// or when no lane did measurable work.
    pub fn lane_imbalance(&self) -> Option<f64> {
        if self.lanes.is_empty() {
            return None;
        }
        let max = self.lanes.iter().map(|l| l.busy_ns).max().unwrap_or(0);
        let sum: u64 = self.lanes.iter().map(|l| l.busy_ns).sum();
        if sum == 0 {
            return None;
        }
        let mean = sum as f64 / self.lanes.len() as f64;
        Some(max as f64 / mean)
    }

    /// The deterministic accounting fields (everything except the
    /// measured wall time) — what execution-mode equivalence means.
    pub fn accounting(&self) -> [u64; 8] {
        [
            self.passes,
            self.launch_waves,
            self.blocks_launched,
            self.blocks_filler,
            self.blocks_mapped,
            self.threads_launched,
            self.threads_mapped,
            self.threads_predicated_off,
        ]
    }
}

/// Odometer increment in storage order (axis 0 fastest) — one add and
/// a rare carry per step instead of [`OrthotopeM::of_linear`]'s full
/// division chain per block.
fn advance(grid: &OrthotopeM, p: &mut BlockM) {
    for axis in 0..p.m() as usize {
        p[axis] += 1;
        if p[axis] < grid.dims[axis] {
            return;
        }
        p[axis] = 0;
    }
}

/// The simulated device.
pub struct Launcher {
    workers: usize,
    pub config: LaunchConfig,
}

impl Launcher {
    /// A launcher that fans work chunks out over `workers` lanes
    /// (scoped threads — no pool to spin up per job).
    pub fn with_workers(workers: usize, config: LaunchConfig) -> Launcher {
        Launcher {
            workers: workers.max(1),
            config,
        }
    }

    /// Number of worker lanes.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `map` over the full grid for problem size `nb` (blocks per
    /// side) and invoke `kernel` on every mapped block, fused into the
    /// map sweep. The kernel receives the *lane index* (stable per
    /// worker across passes, `< workers()`) — per-lane accumulators are
    /// how fused workloads aggregate without a blocks vector — and the
    /// mapped block; it returns how many of the block's threads were
    /// predicated off.
    ///
    /// The kernel is called concurrently from different lanes, but any
    /// given lane index is used by at most one thread at a time.
    pub fn launch<K>(&self, map: &dyn MThreadMap, nb: u64, kernel: K) -> LaunchStats
    where
        K: Fn(usize, &MappedBlock) -> u64 + Send + Sync,
    {
        assert!(
            map.supports(nb),
            "map {} does not support nb={nb}",
            map.name()
        );
        assert_eq!(self.config.shape.m, map.m(), "block shape vs map dim");
        let t0 = Instant::now();
        let threads_per_block = self.config.shape.threads();
        let passes = map.passes(nb);

        // Pass geometry up front: the per-pass grids plus the exclusive
        // prefix sum of their volumes define ONE linear index space for
        // the whole launch, so work chunks flow across pass boundaries
        // instead of a fresh thread scope (with its ragged tail) per
        // pass.
        let mut grids: Vec<OrthotopeM> = Vec::with_capacity(passes as usize);
        let mut offsets: Vec<u64> = Vec::with_capacity(passes as usize + 1);
        let mut total = 0u64;
        for pass in 0..passes {
            let grid = map.grid(nb, pass);
            offsets.push(total);
            total += grid.volume() as u64;
            grids.push(grid);
        }
        offsets.push(total);

        let ((blocks_filler, blocks_mapped, predicated), lanes) = match self.config.backend {
            BackendKind::Serial => {
                let sweep_t0 = self.config.profile_lanes.then(Instant::now);
                let acc = sweep_range(map, nb, &grids, &offsets, 0, total, 0, &kernel);
                let lanes = match sweep_t0 {
                    Some(t) => vec![LaneProfile {
                        lane: 0,
                        busy_ns: t.elapsed().as_nanos() as u64,
                        chunks_pulled: 1,
                        blocks_processed: total,
                    }],
                    None => Vec::new(),
                };
                (acc, lanes)
            }
            BackendKind::Parallel | BackendKind::Pjrt => {
                self.sweep_pool(map, nb, &grids, &offsets, total, &kernel)
            }
        };

        // Launch-latency model: passes serialize in waves of
        // max_concurrent_launches. Accounting-only unless the caller
        // opted into simulating the wall time.
        let waves = passes.div_ceil(self.config.max_concurrent_launches.max(1));
        let overhead = self.config.launch_latency * waves as u32;
        if self.config.simulate_latency {
            std::thread::sleep(overhead);
        }

        LaunchStats {
            passes,
            launch_waves: waves,
            blocks_launched: total,
            blocks_filler,
            blocks_mapped,
            threads_launched: total * threads_per_block,
            threads_mapped: blocks_mapped * threads_per_block,
            threads_predicated_off: predicated,
            wall: t0.elapsed(),
            launch_overhead: overhead,
            lanes,
        }
    }

    /// The persistent worker pool: one `thread::scope` for the whole
    /// launch, chunks of at most `chunk_blocks` blocks (capped at
    /// `total / workers` so every lane gets at least one chunk when
    /// `total ≥ workers`), a shared atomic cursor for distribution.
    /// Lane `i` owns chunk `i` statically — the cursor starts at
    /// `lanes` — so lane coverage is deterministic, not a race outcome.
    /// Per-lane tallies return through the join handles; there is no
    /// results mutex on the hot path.
    #[allow(clippy::too_many_arguments)]
    fn sweep_pool<K>(
        &self,
        map: &dyn MThreadMap,
        nb: u64,
        grids: &[OrthotopeM],
        offsets: &[u64],
        total: u64,
        kernel: &K,
    ) -> ((u64, u64, u64), Vec<LaneProfile>)
    where
        K: Fn(usize, &MappedBlock) -> u64 + Send + Sync,
    {
        if total == 0 {
            return ((0, 0, 0), Vec::new());
        }
        let chunk = (self.config.chunk_blocks.max(1) as u64)
            .min((total / self.workers as u64).max(1));
        let n_chunks = total.div_ceil(chunk);
        let lanes = self.workers.min(n_chunks as usize);
        let cursor = AtomicU64::new(lanes as u64);
        let profile = self.config.profile_lanes;
        let mut acc = (0u64, 0u64, 0u64);
        let mut profiles = Vec::new();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..lanes)
                .map(|lane| {
                    let cursor = &cursor;
                    scope.spawn(move || {
                        let mut lane_acc = (0u64, 0u64, 0u64);
                        let mut prof = LaneProfile {
                            lane: lane as u64,
                            ..LaneProfile::default()
                        };
                        let mut c = lane as u64;
                        loop {
                            let lo = c * chunk;
                            let hi = total.min(lo + chunk);
                            // Time only the sweep itself, and only when
                            // profiling: the disabled path pays one
                            // untaken branch per multi-thousand-block
                            // chunk, not per block.
                            let chunk_t0 = profile.then(Instant::now);
                            let (f, m, p) =
                                sweep_range(map, nb, grids, offsets, lo, hi, lane, kernel);
                            if let Some(t) = chunk_t0 {
                                prof.busy_ns += t.elapsed().as_nanos() as u64;
                                prof.chunks_pulled += 1;
                                prof.blocks_processed += hi - lo;
                            }
                            lane_acc.0 += f;
                            lane_acc.1 += m;
                            lane_acc.2 += p;
                            c = cursor.fetch_add(1, Ordering::Relaxed);
                            if c >= n_chunks {
                                break;
                            }
                        }
                        (lane_acc, prof)
                    })
                })
                .collect();
            for h in handles {
                let ((f, m, p), prof) = h.join().expect("launch lane panicked");
                acc.0 += f;
                acc.1 += m;
                acc.2 += p;
                if profile {
                    profiles.push(prof);
                }
            }
        });
        (acc, profiles)
    }
}

/// Sweep global block indices `[lo, hi)` through `map` and `kernel`,
/// returning `(filler, mapped, predicated_off)` block/thread tallies.
///
/// Within each pass segment the parallel coordinate advances as an
/// incremental odometer over the contiguous rank range — one
/// `of_linear` division chain per segment, then axis-0 increments —
/// which keeps the inner loop branch-light and lets per-block kernels
/// walk ranks in storage order.
#[allow(clippy::too_many_arguments)]
fn sweep_range<K>(
    map: &dyn MThreadMap,
    nb: u64,
    grids: &[OrthotopeM],
    offsets: &[u64],
    lo: u64,
    hi: u64,
    lane: usize,
    kernel: &K,
) -> (u64, u64, u64)
where
    K: Fn(usize, &MappedBlock) -> u64 + Send + Sync,
{
    let (mut filler, mut mapped, mut pred) = (0u64, 0u64, 0u64);
    if lo >= hi {
        return (filler, mapped, pred);
    }
    // Last pass whose offset is ≤ lo (offsets[0] = 0, so ≥ 1). Empty
    // passes share an offset with their successor; skipping forward to
    // the last one keeps the segment loop out of zero-volume grids.
    let mut pass = offsets.partition_point(|&o| o <= lo) - 1;
    let mut idx = lo;
    while idx < hi && pass < grids.len() {
        let grid = &grids[pass];
        let seg_hi = hi.min(offsets[pass + 1]);
        if idx < seg_hi {
            let mut p = grid.of_linear(idx - offsets[pass]);
            while idx < seg_hi {
                match map.map_block(nb, pass as u64, &p) {
                    None => filler += 1,
                    Some(data) => {
                        mapped += 1;
                        let mb = MappedBlock {
                            parallel: p,
                            data,
                            pass: pass as u64,
                        };
                        pred += kernel(lane, &mb);
                    }
                }
                idx += 1;
                if idx < seg_hi {
                    advance(grid, &mut p);
                }
            }
        }
        pass += 1;
    }
    (filler, mapped, pred)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{adapt, BoundingBox2, Lambda2Map, Lambda3Map, RiesMap, ThreadMap};
    use std::sync::Mutex;

    fn launcher(rho: u32, m: u32) -> Launcher {
        let mut cfg = LaunchConfig::new(BlockShape::new(rho, m));
        cfg.launch_latency = Duration::ZERO;
        Launcher::with_workers(4, cfg)
    }

    #[test]
    fn backend_kind_parses_names_and_legacy_alias() {
        assert_eq!(BackendKind::parse("serial"), Some(BackendKind::Serial));
        assert_eq!(BackendKind::parse("parallel"), Some(BackendKind::Parallel));
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("rust"), Some(BackendKind::Parallel));
        assert_eq!(BackendKind::parse("cuda"), None);
        for b in [BackendKind::Serial, BackendKind::Parallel, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(b.name()), Some(b));
        }
    }

    #[test]
    fn bb2_accounting_matches_closed_forms() {
        let l = launcher(16, 2);
        let nb = 64u64;
        let stats = l.launch(&adapt(BoundingBox2), nb, |_lane, _b| 0);
        assert_eq!(stats.blocks_launched, nb * nb);
        assert_eq!(stats.blocks_mapped, nb * (nb + 1) / 2);
        assert_eq!(stats.blocks_filler, nb * (nb - 1) / 2);
        assert_eq!(stats.threads_launched, nb * nb * 256);
        assert!((stats.block_efficiency() - 0.5).abs() < 0.01);
    }

    #[test]
    fn lambda2_has_zero_filler() {
        let l = launcher(16, 2);
        let stats = l.launch(&adapt(Lambda2Map), 128, |_lane, _b| 0);
        assert_eq!(stats.blocks_filler, 0);
        assert_eq!(stats.block_efficiency(), 1.0);
    }

    #[test]
    fn lambda3_filler_matches_container_slack() {
        let l = launcher(8, 3);
        let nb = 32u64;
        let stats = l.launch(&adapt(Lambda3Map), nb, |_lane, _b| 0);
        let expect = Lambda3Map.parallel_volume(nb) - crate::maps::domain_volume(nb, 3);
        assert_eq!(stats.blocks_filler as u128, expect);
    }

    #[test]
    fn kernel_sees_every_mapped_block_once() {
        use std::collections::HashSet;
        let l = launcher(4, 2);
        let nb = 32u64;
        let seen = Mutex::new(HashSet::new());
        let stats = l.launch(&adapt(Lambda2Map), nb, |_lane, b| {
            assert!(seen.lock().unwrap().insert(b.data), "dup {:?}", b.data);
            0
        });
        assert_eq!(seen.lock().unwrap().len() as u64, stats.blocks_mapped);
    }

    #[test]
    fn predication_counts_flow_through() {
        let l = launcher(8, 2);
        // Kernel predicates off half of each diagonal block.
        let stats = l.launch(&adapt(Lambda2Map), 16, |_lane, b| {
            if b.data[0] == b.data[1] {
                28 // 8·7/2 threads above the strict diagonal
            } else {
                0
            }
        });
        assert_eq!(stats.threads_predicated_off, 16 * 28);
        assert!(stats.thread_efficiency() < 1.0);
    }

    #[test]
    fn lane_indices_stay_within_workers() {
        let l = launcher(4, 2);
        let max_lane = AtomicU64::new(0);
        l.launch(&adapt(BoundingBox2), 32, |lane, _b| {
            max_lane.fetch_max(lane as u64, Ordering::Relaxed);
            0
        });
        assert!((max_lane.load(Ordering::Relaxed) as usize) < l.workers());
    }

    #[test]
    fn mid_size_grids_saturate_every_lane() {
        // Lane-starvation regression (the PR-6 headline bug): with
        // workers=8, chunk_blocks=4096 and a grid in the 8k-block class
        // (BB m=3 at nb=20 → 8000 blocks), the old per-pass splitter
        // derived the lane count from ceil(total / chunk_blocks) = 2
        // and left lanes 2..8 idle. The chunk cursor caps the chunk at
        // total/workers and statically hands lane i chunk i, so every
        // lane must observe mapped work.
        use crate::maps::BoundingBoxM;
        let mut cfg = LaunchConfig::new(BlockShape::new(2, 3));
        cfg.launch_latency = Duration::ZERO;
        cfg.chunk_blocks = 4096;
        let l = Launcher::with_workers(8, cfg);
        let seen: Vec<AtomicU64> = (0..8).map(|_| AtomicU64::new(0)).collect();
        let max_lane = AtomicU64::new(0);
        l.launch(&BoundingBoxM::new(3), 20, |lane, _b| {
            seen[lane].fetch_add(1, Ordering::Relaxed);
            max_lane.fetch_max(lane as u64, Ordering::Relaxed);
            0
        });
        assert_eq!(
            max_lane.load(Ordering::Relaxed) as usize,
            l.workers() - 1,
            "highest lane never fed"
        );
        for (lane, s) in seen.iter().enumerate() {
            assert!(s.load(Ordering::Relaxed) > 0, "lane {lane} starved");
        }
    }

    #[test]
    fn serial_and_parallel_backends_agree_exactly() {
        // The serial sweep is the accounting oracle: identical stats
        // (all eight fields) and identical mapped-block sets for maps
        // with and without filler, predication flowing through both.
        use crate::maps::BoundingBoxM;
        let kernel = |_lane: usize, b: &MappedBlock| u64::from(b.data[0] == b.data[1]);
        let maps: Vec<(Box<dyn MThreadMap>, u64)> = vec![
            (Box::new(adapt(Lambda2Map)), 64),
            (Box::new(adapt(BoundingBox2)), 48),
            (Box::new(adapt(RiesMap)), 32),
        ];
        for (map, nb) in maps {
            let mut cfg = LaunchConfig::new(BlockShape::new(4, 2));
            cfg.launch_latency = Duration::ZERO;
            cfg.backend = BackendKind::Serial;
            let serial = Launcher::with_workers(1, cfg.clone()).launch(map.as_ref(), nb, kernel);
            cfg.backend = BackendKind::Parallel;
            cfg.chunk_blocks = 37; // force many chunks across passes
            let parallel = Launcher::with_workers(5, cfg).launch(map.as_ref(), nb, kernel);
            assert_eq!(serial.accounting(), parallel.accounting(), "{}", map.name());
        }
        let mut cfg = LaunchConfig::new(BlockShape::new(2, 4));
        cfg.launch_latency = Duration::ZERO;
        cfg.backend = BackendKind::Serial;
        let map = BoundingBoxM::new(4);
        let serial = Launcher::with_workers(1, cfg.clone()).launch(&map, 5, |_l, _b| 0);
        cfg.backend = BackendKind::Parallel;
        let parallel = Launcher::with_workers(3, cfg).launch(&map, 5, |_l, _b| 0);
        assert_eq!(serial.accounting(), parallel.accounting());
    }

    #[test]
    fn lane_profiling_is_off_by_default() {
        let l = launcher(8, 2);
        assert!(!l.config.profile_lanes);
        let stats = l.launch(&adapt(Lambda2Map), 64, |_lane, _b| 0);
        assert!(stats.lanes.is_empty());
        assert_eq!(stats.lane_imbalance(), None);
    }

    #[test]
    fn lane_profiling_tallies_cover_the_launch() {
        let mut cfg = LaunchConfig::new(BlockShape::new(4, 2));
        cfg.launch_latency = Duration::ZERO;
        cfg.profile_lanes = true;
        cfg.chunk_blocks = 64; // force many chunks
        let l = Launcher::with_workers(4, cfg);
        let stats = l.launch(&adapt(BoundingBox2), 48, |_lane, b| {
            // A little work per block so busy_ns registers.
            black_box_sum(b.data[0] + b.data[1])
        });
        assert!(!stats.lanes.is_empty());
        assert!(stats.lanes.len() <= l.workers());
        let blocks: u64 = stats.lanes.iter().map(|p| p.blocks_processed).sum();
        assert_eq!(blocks, stats.blocks_launched, "every block attributed");
        let chunks: u64 = stats.lanes.iter().map(|p| p.chunks_pulled).sum();
        assert!(chunks >= stats.lanes.len() as u64, "each lane pulled >= 1");
        let busy: u64 = stats.lanes.iter().map(|p| p.busy_ns).sum();
        assert!(busy > 0, "lanes did measurable work");
        // Lane ids are the stable kernel lane indices, in order.
        for (i, p) in stats.lanes.iter().enumerate() {
            assert_eq!(p.lane, i as u64);
        }
        let r = stats.lane_imbalance().expect("profiled launch has a ratio");
        assert!(r >= 1.0, "max/mean is at least 1: {r}");
    }

    fn black_box_sum(x: u64) -> u64 {
        // Cheap data-dependent result the optimizer cannot discard.
        std::hint::black_box(x) % 2
    }

    #[test]
    fn serial_profile_is_one_lane_covering_everything() {
        let mut cfg = LaunchConfig::new(BlockShape::new(4, 2));
        cfg.launch_latency = Duration::ZERO;
        cfg.backend = BackendKind::Serial;
        cfg.profile_lanes = true;
        let l = Launcher::with_workers(1, cfg);
        let stats = l.launch(&adapt(Lambda2Map), 64, |_lane, _b| 0);
        assert_eq!(stats.lanes.len(), 1);
        assert_eq!(stats.lanes[0].lane, 0);
        assert_eq!(stats.lanes[0].chunks_pulled, 1);
        assert_eq!(stats.lanes[0].blocks_processed, stats.blocks_launched);
        let r = stats.lane_imbalance().unwrap();
        assert!((r - 1.0).abs() < 1e-12, "single lane is balanced: {r}");
    }

    #[test]
    fn profiling_does_not_change_accounting() {
        let kernel = |_lane: usize, b: &MappedBlock| u64::from(b.data[0] == b.data[1]);
        let mut cfg = LaunchConfig::new(BlockShape::new(4, 2));
        cfg.launch_latency = Duration::ZERO;
        let plain = Launcher::with_workers(4, cfg.clone()).launch(&adapt(RiesMap), 32, kernel);
        cfg.profile_lanes = true;
        let profiled = Launcher::with_workers(4, cfg).launch(&adapt(RiesMap), 32, kernel);
        assert_eq!(plain.accounting(), profiled.accounting());
    }

    #[test]
    fn multi_pass_map_counts_waves() {
        let mut cfg = LaunchConfig::new(BlockShape::new(4, 2));
        cfg.launch_latency = Duration::ZERO;
        cfg.max_concurrent_launches = 4;
        let l = Launcher::with_workers(2, cfg);
        let nb = 64u64;
        let stats = l.launch(&adapt(RiesMap), nb, |_lane, _b| 0);
        assert_eq!(stats.passes, 7); // log2(64) + 1
        assert_eq!(stats.launch_waves, 2); // ceil(7/4)
    }

    #[test]
    fn latency_is_modeled_but_not_slept_by_default() {
        let mut cfg = LaunchConfig::new(BlockShape::new(4, 2));
        cfg.launch_latency = Duration::from_millis(250);
        assert!(!cfg.simulate_latency, "accounting-only is the default");
        let l = Launcher::with_workers(2, cfg);
        let stats = l.launch(&adapt(Lambda2Map), 8, |_lane, _b| 0);
        assert_eq!(stats.launch_overhead, Duration::from_millis(250));
        assert!(
            stats.wall < Duration::from_millis(200),
            "no sleep: wall {:?}",
            stats.wall
        );
    }

    #[test]
    #[should_panic(expected = "does not support")]
    fn unsupported_size_panics() {
        launcher(8, 2).launch(&adapt(Lambda2Map), 17, |_lane, _b| 0);
    }

    #[test]
    fn lambda_m_accounting_matches_plan() {
        use crate::maps::{LambdaMMap, MThreadMap as _};
        let l = launcher(2, 4);
        let map = LambdaMMap::for_paper(4, 2);
        let nb = 28u64; // first covered size: parallel 31501, filler 36
        let stats = l.launch(&map, nb, |_lane, _b| 0);
        assert_eq!(stats.blocks_launched, 31501);
        assert_eq!(stats.blocks_filler, 36);
        assert_eq!(stats.blocks_mapped, 31465);
        assert_eq!(stats.passes, map.passes(nb));
        assert_eq!(stats.threads_launched, 31501 * 16);
        assert_eq!(stats.threads_mapped, 31465 * 16);
    }

    #[test]
    fn general_m_sees_each_data_block_once() {
        use crate::maps::BoundingBoxM;
        use std::collections::HashSet;
        let l = launcher(2, 5);
        let map = BoundingBoxM::new(5);
        let nb = 4u64;
        let seen = Mutex::new(HashSet::new());
        let stats = l.launch(&map, nb, |_lane, b| {
            assert!(seen.lock().unwrap().insert(b.data), "dup {:?}", b.data);
            0
        });
        assert_eq!(seen.lock().unwrap().len() as u64, stats.blocks_mapped);
        assert_eq!(stats.blocks_mapped as u128, crate::maps::domain_volume(4, 5));
        assert_eq!(stats.blocks_launched, 4u64.pow(5));
    }

    #[test]
    fn general_m_predication_counts_flow_through() {
        use crate::maps::BoundingBoxM;
        let l = launcher(2, 4);
        let stats = l.launch(&BoundingBoxM::new(4), 3, |_lane, b| {
            // Predicate one thread off in every block on the main
            // diagonal plane Σ = nb-1.
            if b.data.sum() == 2 {
                1
            } else {
                0
            }
        });
        // |{Σ = 2, m = 4}| = C(5, 3) = 10.
        assert_eq!(stats.threads_predicated_off, 10);
        assert!(stats.thread_efficiency() < 1.0);
    }
}
