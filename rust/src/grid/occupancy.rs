//! Occupancy / efficiency reporting over launch statistics — turns raw
//! [`LaunchStats`](super::LaunchStats) into the paper's comparative
//! numbers (α, efficiency, improvement factor vs a baseline).

use crate::maps::ThreadMap;

use super::LaunchStats;

/// Side-by-side efficiency report for one map at one size.
#[derive(Clone, Debug)]
pub struct OccupancyReport {
    pub map: &'static str,
    pub nb: u64,
    pub stats: LaunchStats,
}

impl OccupancyReport {
    pub fn new(map: &dyn ThreadMap, nb: u64, stats: LaunchStats) -> OccupancyReport {
        OccupancyReport {
            map: map.name(),
            nb,
            stats,
        }
    }

    /// α = V(Π)/V(useful blocks) - 1, measured (not closed-form).
    ///
    /// Empty-coverage convention (the 0/0 and n/0 cases the plain
    /// division turns into NaN, which then poisons every downstream
    /// `<`/`max` comparison silently): a launch that paid for blocks
    /// but mapped **none** is pure waste — α = +∞ — while an empty
    /// launch (nothing launched, nothing mapped) wasted nothing —
    /// α = 0. Same convention as [`LaunchStats::block_efficiency`]
    /// (0 and 1 respectively).
    pub fn measured_alpha(&self) -> f64 {
        if self.stats.blocks_mapped == 0 {
            return if self.stats.blocks_launched == 0 {
                0.0
            } else {
                f64::INFINITY
            };
        }
        self.stats.blocks_launched as f64 / self.stats.blocks_mapped as f64 - 1.0
    }

    /// Improvement factor of this report's *block* efficiency over a
    /// baseline report (the paper's "2× / 6× more efficient").
    pub fn improvement_over(&self, baseline: &OccupancyReport) -> f64 {
        self.stats.block_efficiency() / baseline.stats.block_efficiency()
    }

    pub fn table_row(&self) -> String {
        format!(
            "{:<14} nb={:<6} passes={:<5} blocks {:>12} launched / {:>12} useful  eff={:<6.4} α={:<8.4}",
            self.map,
            self.nb,
            self.stats.passes,
            self.stats.blocks_launched,
            self.stats.blocks_mapped,
            self.stats.block_efficiency(),
            self.measured_alpha(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::{BlockShape, LaunchConfig, Launcher};
    use crate::maps::{BoundingBox2, BoundingBox3, Lambda2Map, Lambda3Map};
    use std::time::Duration;

    fn run(map: Box<dyn ThreadMap>, nb: u64, m: u32) -> OccupancyReport {
        let mut cfg = LaunchConfig::new(BlockShape::new(4, m));
        cfg.launch_latency = Duration::ZERO;
        let l = Launcher::with_workers(2, cfg);
        let adapter = crate::maps::FixedAdapter::new(map);
        let stats = l.launch(&adapter, nb, |_lane, _b| 0);
        OccupancyReport::new(adapter.inner.as_ref(), nb, stats)
    }

    #[test]
    fn lambda2_improvement_over_bb_approaches_2x() {
        // The abstract's 2× claim, measured.
        let nb = 256;
        let bb = run(Box::new(BoundingBox2), nb, 2);
        let l2 = run(Box::new(Lambda2Map), nb, 2);
        let imp = l2.improvement_over(&bb);
        assert!((imp - 2.0).abs() < 0.02, "improvement={imp}");
    }

    #[test]
    fn lambda3_improvement_over_bb_approaches_6x() {
        // The abstract's 6× claim, measured (λ3 carries 12.5% slack, so
        // ≈ 6/1.125 ≈ 5.3× at finite n).
        let nb = 64;
        let bb = run(Box::new(BoundingBox3), nb, 3);
        let l3 = run(Box::new(Lambda3Map), nb, 3);
        let imp = l3.improvement_over(&bb);
        assert!(imp > 4.5 && imp < 6.0, "improvement={imp}");
    }

    #[test]
    fn measured_alpha_matches_closed_form() {
        let nb = 128;
        let rep = run(Box::new(BoundingBox2), nb, 2);
        let closed = crate::maps::alpha(&BoundingBox2, nb);
        assert!((rep.measured_alpha() - closed).abs() < 1e-9);
    }

    #[test]
    fn table_row_mentions_map_name() {
        let rep = run(Box::new(Lambda2Map), 64, 2);
        assert!(rep.table_row().contains("lambda2"));
    }

    #[test]
    fn lambda_s_measures_2x_over_bb_at_non_pow2_sizes() {
        // The λ_S scalability claim, measured end-to-end at a size λ2
        // rejects outright: improvement = nb²/T(nb) = 2nb/(nb+1).
        let nb = 100;
        let bb = run(Box::new(crate::maps::BoundingBox2), nb, 2);
        let ls = run(Box::new(crate::maps::LambdaScalable2), nb, 2);
        assert_eq!(ls.stats.blocks_filler, 0);
        assert!(ls.measured_alpha().abs() < 1e-12);
        let imp = ls.improvement_over(&bb);
        let closed = 2.0 * nb as f64 / (nb as f64 + 1.0);
        assert!((imp - closed).abs() < 1e-9, "improvement={imp} vs {closed}");
    }

    /// The empty-coverage convention (ISSUE 5): no NaN out of the α /
    /// efficiency accessors, ever.
    #[test]
    fn measured_alpha_empty_coverage_convention() {
        // Nothing launched, nothing mapped: zero waste, full efficiency.
        let empty = OccupancyReport {
            map: "synthetic",
            nb: 0,
            stats: LaunchStats::default(),
        };
        assert_eq!(empty.measured_alpha(), 0.0);
        assert!(!empty.measured_alpha().is_nan());
        assert_eq!(empty.stats.block_efficiency(), 1.0);
        assert_eq!(empty.stats.thread_efficiency(), 1.0);

        // Blocks launched, none useful: pure waste — α = +∞, eff 0.
        let mut wasted = LaunchStats::default();
        wasted.passes = 1;
        wasted.blocks_launched = 64;
        wasted.blocks_filler = 64;
        wasted.threads_launched = 64 * 256;
        let report = OccupancyReport {
            map: "synthetic",
            nb: 8,
            stats: wasted,
        };
        assert!(report.measured_alpha().is_infinite());
        assert!(report.measured_alpha() > 0.0);
        assert_eq!(report.stats.block_efficiency(), 0.0);
        assert_eq!(report.stats.thread_efficiency(), 0.0);
        // The table row renders (inf), it must not panic or show NaN.
        assert!(!report.table_row().contains("NaN"));

        // And a normal report still divides as before.
        let rep = run(Box::new(BoundingBox2), 16, 2);
        assert!(rep.measured_alpha().is_finite());
    }

    #[test]
    fn reports_are_backend_invariant() {
        // α / efficiency are launch-geometry facts; the Serial and
        // Parallel backends must produce bit-identical reports (all
        // eight accounting fields, hence every derived number).
        use crate::grid::BackendKind;
        for (map, nb) in [
            (Box::new(Lambda2Map) as Box<dyn ThreadMap>, 96u64),
            (Box::new(BoundingBox2), 64),
        ] {
            let adapter = crate::maps::FixedAdapter::new(map);
            let mut reports = Vec::new();
            for (backend, workers) in [(BackendKind::Serial, 1), (BackendKind::Parallel, 4)] {
                let mut cfg = LaunchConfig::new(BlockShape::new(4, 2));
                cfg.launch_latency = Duration::ZERO;
                cfg.backend = backend;
                let l = Launcher::with_workers(workers, cfg);
                let stats = l.launch(&adapter, nb, |_lane, _b| 0);
                reports.push(OccupancyReport::new(adapter.inner.as_ref(), nb, stats));
            }
            assert_eq!(
                reports[0].stats.accounting(),
                reports[1].stats.accounting(),
                "{}",
                reports[0].map
            );
            assert_eq!(reports[0].measured_alpha(), reports[1].measured_alpha());
            assert_eq!(reports[0].table_row(), reports[1].table_row());
        }
    }

    #[test]
    fn improvement_over_an_empty_coverage_baseline_is_infinite() {
        // A useful map compared against an all-filler baseline: the
        // ratio is +∞ (not NaN), so comparisons keep ordering.
        let mut wasted = LaunchStats::default();
        wasted.blocks_launched = 8;
        wasted.blocks_filler = 8;
        let baseline = OccupancyReport {
            map: "synthetic",
            nb: 4,
            stats: wasted,
        };
        let good = run(Box::new(Lambda2Map), 16, 2);
        assert!(good.improvement_over(&baseline).is_infinite());
    }
}
