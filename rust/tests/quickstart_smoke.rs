//! Smoke test mirroring `examples/quickstart.rs` step by step, so the
//! documented entry path is exercised by `cargo test` (the example
//! binary itself only compiles under `cargo build --examples`). The
//! λ2 doctest in `lib.rs` covers the API one-liner; this covers the
//! full quickstart flow: geometry → single map_block → end-to-end job.

use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};
use simplexmap::maps::{alpha, space_efficiency, BoundingBox2, Lambda2Map, ThreadMap};

#[test]
fn quickstart_flow_runs_end_to_end() {
    // 1. Parallel-space geometry (quickstart step 1).
    let nb = 256u64;
    assert_eq!(BoundingBox2.parallel_volume(nb), (nb as u128) * (nb as u128));
    assert_eq!(
        Lambda2Map.parallel_volume(nb),
        (nb as u128) * (nb as u128 + 1) / 2
    );
    assert!((space_efficiency(&Lambda2Map, nb) - 1.0).abs() < 1e-12);
    assert!((alpha(&BoundingBox2, nb) - 1.0).abs() < 0.01);

    // 2. One O(1) map evaluation (quickstart step 2).
    let w = [5u64, 9, 0];
    let d = Lambda2Map.map_block(nb, 0, w).unwrap();
    assert!(d[0] <= d[1] && d[1] < nb, "λ2({w:?}) = {d:?}");

    // 3. End-to-end: EDM under both maps, identical answers
    //    (quickstart step 3, at the example's size).
    let sched = Scheduler::new(4, None);
    let mut results = Vec::new();
    for map in ["bb", "lambda2"] {
        let job = Job {
            workload: WorkloadKind::Edm,
            nb: 64,
            map: map.into(),
            backend: Backend::Parallel,
            seed: 42,
        };
        let r = sched.run(&job).expect("quickstart job");
        results.push(r);
    }
    let (bb, l2) = (&results[0], &results[1]);
    assert_eq!(bb.blocks_mapped, l2.blocks_mapped, "same useful blocks");
    assert!(bb.blocks_launched > l2.blocks_launched, "λ2 launches fewer");
    assert_eq!(
        bb.outputs[0].1, l2.outputs[0].1,
        "same neighbour count under both maps"
    );
    let (s_bb, s_l2) = (bb.outputs[1].1, l2.outputs[1].1);
    assert!((s_bb - s_l2).abs() < 1e-3 * s_bb.abs().max(1.0));
}
