//! The tree must lint clean — the same gate CI's `lint` job enforces
//! by running the `simplexlint` binary. Running it as a tier-1 test
//! too means a violation fails `cargo test` locally before it ever
//! reaches CI, and the failure message carries the full report.

use simplexmap::lint;

#[test]
fn tree_is_lint_clean() {
    let cwd = std::env::current_dir().expect("cwd");
    let root = lint::find_root(&cwd).expect("repo root above test cwd");
    let report = lint::run(&root).expect("lint walk");
    assert!(
        report.clean(),
        "simplexlint found unsuppressed violations:\n{}",
        report.render()
    );
    // The walk really covered the tree (guards against a silent
    // empty-walk passing as clean).
    assert!(report.files_scanned > 90, "scanned {}", report.files_scanned);
}
