//! Matrix test: every workload × every applicable map × several sizes
//! must produce identical results (the fundamental guarantee the whole
//! system rests on: the map changes *where blocks come from*, never
//! *what is computed*). Pure-Rust backend — runs without artifacts.

use simplexmap::coordinator::{Backend, Job, Scheduler, WorkloadKind};

fn run(sched: &Scheduler, w: WorkloadKind, nb: u64, map: &str) -> Vec<(String, f64)> {
    sched
        .run(&Job {
            workload: w,
            nb,
            map: map.into(),
            backend: Backend::Rust,
            seed: 99,
        })
        .unwrap_or_else(|e| panic!("{} nb={nb} map={map}: {e}", w.name()))
        .outputs
}

fn assert_outputs_agree(
    name: &str,
    nb: u64,
    base: &[(String, f64)],
    got: &[(String, f64)],
    map: &str,
) {
    assert_eq!(base.len(), got.len());
    for ((k0, v0), (k1, v1)) in base.iter().zip(got) {
        assert_eq!(k0, k1);
        let tol = 1e-6 * v0.abs().max(1.0);
        assert!(
            (v0 - v1).abs() <= tol,
            "{name} nb={nb} map={map}: {k0} {v1} vs baseline {v0}"
        );
    }
}

#[test]
fn m2_workloads_agree_across_all_maps_and_sizes() {
    let sched = Scheduler::new(4, None);
    // Maps valid for general 2-simplex workloads at power-of-two sizes
    // (avril covers strict pairs only → excluded; see maps::avril).
    let maps = ["bb", "lambda2", "enum2", "rb", "ries", "above2", "below2"];
    for w in [
        WorkloadKind::Edm,
        WorkloadKind::Collision,
        WorkloadKind::NBody,
        WorkloadKind::Cellular,
        WorkloadKind::TriMatVec,
    ] {
        for nb in [4u64, 8, 16] {
            let base = run(&sched, w, nb, maps[0]);
            for map in &maps[1..] {
                let got = run(&sched, w, nb, map);
                assert_outputs_agree(w.name(), nb, &base, &got, map);
            }
        }
    }
}

#[test]
fn m2_workloads_agree_at_non_power_of_two_sizes() {
    // The §III.A approaches must agree with BB at awkward sizes.
    let sched = Scheduler::new(4, None);
    for w in [WorkloadKind::Edm, WorkloadKind::Collision] {
        for nb in [6u64, 10, 12] {
            let base = run(&sched, w, nb, "bb");
            for map in ["above2", "below2", "rb", "enum2"] {
                let got = run(&sched, w, nb, map);
                assert_outputs_agree(w.name(), nb, &base, &got, map);
            }
        }
    }
}

#[test]
fn m3_workloads_agree_across_maps_and_sizes() {
    let sched = Scheduler::new(4, None);
    let maps = ["bb", "lambda3", "enum3", "lambda3-rec"];
    for nb in [4u64, 8] {
        let base = run(&sched, WorkloadKind::Triple, nb, maps[0]);
        for map in &maps[1..] {
            let got = run(&sched, WorkloadKind::Triple, nb, map);
            assert_outputs_agree("triple", nb, &base, &got, map);
        }
    }
}

#[test]
fn results_depend_on_seed_not_map() {
    let sched = Scheduler::new(2, None);
    let a = run(&sched, WorkloadKind::Edm, 8, "lambda2");
    let sched2 = Scheduler::new(2, None);
    let b = sched2
        .run(&Job {
            workload: WorkloadKind::Edm,
            nb: 8,
            map: "lambda2".into(),
            backend: Backend::Rust,
            seed: 100, // different seed → different data
        })
        .unwrap()
        .outputs;
    assert_ne!(a[1].1, b[1].1, "different seeds must differ");
}

#[test]
fn tiny_sizes_do_not_break() {
    let sched = Scheduler::new(1, None);
    // nb=2 is the smallest size every pow2 map accepts (λ3 needs 4).
    for map in ["bb", "lambda2", "rb", "enum2", "below2"] {
        let out = run(&sched, WorkloadKind::Edm, 2, map);
        assert_eq!(out[0].0, "neighbour_count");
    }
    let out = run(&sched, WorkloadKind::Triple, 4, "lambda3");
    assert_eq!(out[0].0, "at_energy");
}
